"""Render EXPERIMENTS.md sections Dry-run and Roofline from the sweep JSON.

Usage: PYTHONPATH=src python scripts/render_experiments.py results/dryrun_all.json
Prints markdown to stdout; EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.roofline import analyze  # noqa: E402


def gib(b):
    return f"{b/2**30:.2f}"


def main():
    path = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_all.json")
    cells = json.loads(path.read_text())

    print("### Dry-run table (memory proof; per-device bytes)\n")
    print("| arch | shape | mesh | compile s | accum | args GiB | temp GiB "
          "| fits 16GiB | collectives (raw count) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if "error" in c:
            print(f"| {c['arch']} | {c['shape']} | "
                  f"{'2x16x16' if c['multi_pod'] else '16x16'} | ERROR |  |  |  |  | "
                  f"{c['error'][:60]} |")
            continue
        mesh = "x".join(str(v) for v in c["mesh"].values())
        m = c["memory"]
        print(f"| {c['arch']} | {c['shape']} | {mesh} | {c['compile_s']} | "
              f"{c.get('accum',1)} | {gib(m['argument_bytes'])} | "
              f"{gib(m['temp_bytes'])} | "
              f"{'Y' if c.get('fits_hbm') else 'N'} | "
              f"{c['collectives_raw']['count']} |")

    print("\n### Roofline terms (single-pod 16x16; per-chip, "
          "trip-count-extrapolated)\n")
    rows = [a for a in (analyze(c) for c in cells)
            if a and a["mesh"] == "16x16"]
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | 6ND/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
              f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
              f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
              f"{r['roofline_frac']:.1%} |")

    # summary stats
    ok = [c for c in cells if "error" not in c]
    fit = [c for c in ok if c.get("fits_hbm")]
    print(f"\n{len(ok)}/{len(cells)} cells compiled; "
          f"{len(fit)}/{len(ok)} fit 16 GiB/chip as-configured.")


if __name__ == "__main__":
    main()

"""Embed the dry-run + roofline tables into EXPERIMENTS.md.

Usage: PYTHONPATH=src:. python scripts/finalize_experiments.py results/dryrun_all.json
Replaces the <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> markers.
"""

from __future__ import annotations

import io
import json
import sys
from contextlib import redirect_stdout
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from scripts.render_experiments import main as render_main  # noqa: E402


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_all.json"
    buf = io.StringIO()
    sys.argv = ["render", path]
    with redirect_stdout(buf):
        render_main()
    out = buf.getvalue()
    dry, _, roof = out.partition("### Roofline terms")
    roof = "### Roofline terms" + roof

    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- DRYRUN_TABLE -->", dry.strip())
    md = md.replace("<!-- ROOFLINE_TABLE -->", roof.strip())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

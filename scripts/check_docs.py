#!/usr/bin/env python
"""Documentation gate for CI (the docs-check job in .github/workflows).

Two checks, pure standard library (no jax import — the job stays fast):

  1. **docstring audit** — every public function, class, and public
     method defined under ``src/repro`` must carry a docstring.  Public
     means: name does not start with ``_``, not nested inside a
     function, and the module is not itself private.  The four modules
     whose API grew across PRs 1-4 (core/allpairs, core/placement,
     serving/cover, kernels/ops) are additionally required to cite their
     DESIGN.md section in every public *function* docstring, so the
     design doc and the code cannot drift apart silently.
  2. **markdown link check** — every relative link target in the
     repo-root markdown files must exist, and every intra-document
     ``#anchor`` must match a heading slug of the file it points into.

Exit status 0 iff both pass; offenders are listed one per line.

Run:  python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src" / "repro"
MD_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "PAPERS.md",
            "CHANGES.md"]
# public functions here must cite the design doc ("DESIGN.md" in the
# docstring) — the PR 1-4 API surface the docs pass anchors
MUST_CITE_DESIGN = [
    "core/allpairs.py",
    "core/placement.py",
    "core/sparse.py",
    "core/sweep.py",
    "core/knn.py",
    "core/env.py",
    "core/faults.py",
    "core/delta.py",
    "core/quant.py",
    "launch/elastic.py",
    "serving/cover.py",
    "serving/batching.py",
    "kernels/ops.py",
    "obs/trace.py",
    "obs/comm.py",
    "obs/report.py",
    "obs/feedback.py",
]


def is_public_module(path: Path) -> bool:
    rel = path.relative_to(SRC)
    return not any(part.startswith("_") for part in rel.parts)


def check_docstrings() -> list[str]:
    problems: list[str] = []
    for path in sorted(SRC.rglob("*.py")):
        if not is_public_module(path):
            continue
        rel = path.relative_to(ROOT)
        tree = ast.parse(path.read_text(), filename=str(rel))
        must_cite = any(str(path).endswith(m) for m in MUST_CITE_DESIGN)
        if ast.get_docstring(tree) is None:
            problems.append(f"{rel}: missing module docstring")

        def walk(node, prefix: str, in_class: bool) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    name = child.name
                    if name.startswith("_"):
                        continue
                    qual = f"{prefix}{name}"
                    doc = ast.get_docstring(child)
                    if doc is None:
                        kind = ("class" if isinstance(child, ast.ClassDef)
                                else "function")
                        problems.append(
                            f"{rel}:{child.lineno}: public {kind} {qual} "
                            "has no docstring")
                    elif (must_cite and not in_class
                          and not isinstance(child, ast.ClassDef)
                          and "DESIGN.md" not in doc):
                        problems.append(
                            f"{rel}:{child.lineno}: {qual} docstring must "
                            "cite its DESIGN.md section")
                    if isinstance(child, ast.ClassDef):
                        walk(child, qual + ".", True)
                    # nested defs (closures) are implementation detail
        walk(tree, "", False)
    return problems


_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_markdown_links() -> list[str]:
    problems: list[str] = []
    slugs: dict[Path, set] = {}

    def slugs_of(path: Path) -> set:
        if path not in slugs:
            slugs[path] = {_slug(h)
                           for h in _HEADING_RE.findall(path.read_text())}
        return slugs[path]

    for name in MD_FILES:
        md = ROOT / name
        if not md.exists():
            continue
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            dest = md if not base else (md.parent / base).resolve()
            if base and not dest.exists():
                problems.append(f"{name}: broken link target {target!r}")
                continue
            if anchor and dest.suffix == ".md":
                if _slug(anchor) not in slugs_of(dest):
                    problems.append(
                        f"{name}: anchor {target!r} matches no heading "
                        f"in {dest.name}")
    return problems


def main() -> int:
    problems = check_docstrings() + check_markdown_links()
    for p in problems:
        print(p)
    if problems:
        print(f"\ndocs-check: {len(problems)} problem(s)")
        return 1
    print("docs-check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Quorum-size table (paper section 3.2 / Luk & Wong reference sets).

Columns: P, k, lower bound, replication ratio k/P vs 1 (all-data) and vs
2/sqrt(P) (force decomposition) — the paper's 'up to 50% smaller' claim.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.quorum import difference_set, quorum_size_lower_bound


def run(csv_rows):
    for P in [4, 8, 16, 32, 57, 64, 111, 128, 256, 512]:
        t0 = time.perf_counter()
        A = difference_set(P)
        us = (time.perf_counter() - t0) * 1e6
        k = len(A)
        lb = quorum_size_lower_bound(P)
        quorum_frac = k / P                       # our memory fraction
        force_frac = 2 / np.sqrt(P)               # dual-array baseline
        saving = 1 - quorum_frac / force_frac
        csv_rows.append((f"quorum_size_P{P}", f"{us:.1f}",
                         f"k={k};lb={lb};mem_frac={quorum_frac:.4f};"
                         f"vs_force_decomp_saving={saving:+.2%}"))

"""Communication volume: quorum vs ring vs all-gather sequence-parallel
attention (the beyond-paper application; paper section 1.2 comparison axis).

Counts, per device, the bytes moved by each strategy's collective schedule
for one attention layer at the long_500k geometry, plus the number of
serialized collective phases (latency proxy — ring needs P-1 dependent
steps, quorum needs k-1 + k with k ~ sqrt(P)).
"""

from __future__ import annotations

import numpy as np

from repro.core.scheduler import build_causal_schedule, build_schedule


def run(csv_rows, seq: int = 524_288, kv_heads: int = 8, hd: int = 128,
        dtype_bytes: int = 2):
    for P in [16, 32, 64, 256]:
        block = seq // P
        kv_block_bytes = 2 * block * kv_heads * hd * dtype_bytes  # K and V
        q_block_bytes = kv_block_bytes // 2

        cs = build_causal_schedule(P)
        k = cs.k
        # quorum: gather k-1 shifted (q,k,v) blocks + route k partial (o,m,l)
        out_part_bytes = q_block_bytes + 2 * block * kv_heads * dtype_bytes
        quorum = (k - 1) * (kv_block_bytes + q_block_bytes) + k * out_part_bytes
        quorum_steps = (k - 1) + k
        # ring: P-1 rotations of (k, v)
        ring = (P - 1) * kv_block_bytes
        ring_steps = P - 1
        # all-gather: every device receives all P-1 remote kv blocks
        ag = (P - 1) * kv_block_bytes
        csv_rows.append((
            f"attn_comm_P{P}", f"{quorum/1e6:.1f}",
            f"quorum_MB;ring_MB={ring/1e6:.1f};allgather_MB={ag/1e6:.1f};"
            f"steps={quorum_steps}v{ring_steps};k={k};"
            f"byte_ratio={quorum/ring:.2f}"))

"""k-NN graph microbenchmark (CPU, subprocess-isolated fake devices):
the all-pairs per-row top-k engine per execution mode, fused kernel vs
the unfused batched path — the fourth member of the benchmark JSON
family (DESIGN.md section 12.3).

Timings are steady-state medians of the cached jitted program (one
graph construction per call over the quorum-sharded corpus), for the
same load-noise reasons as bench_engine.  The oracle pass doubles as a
correctness gate: the timed program's output must match the dense
brute-force graph exactly before any number is recorded.  Writes
BENCH_knn.json at the repo root (CI uploads it next to the other
BENCH_*.json artifacts and diffs it with ``benchmarks.run --compare``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_knn.json"

_CHILD = r"""
import json, statistics, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.knn import _knn_fn, brute_force_knn, knn_graph
from repro.core.placement import get_placement

P = int(sys.argv[1]); N = int(sys.argv[2]); d = int(sys.argv[3])
topk = int(sys.argv[4])
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
plc = get_placement("cyclic", P)
block = -(-N // P)

# correctness gate: the timed configuration must be oracle-exact
want = brute_force_knn(corpus, topk)
got = knn_graph(corpus, mesh, topk=topk, mode="scan", placement=plc)
assert (got.indices == want.indices).all(), "scan mode oracle mismatch"

x = np.zeros((P * block, d), np.float32); x[:N] = corpus
xs = jnp.asarray(x)

def bench(fn, reps=15):
    jax.block_until_ready(fn())                 # compile
    jax.block_until_ready(fn())                 # warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)   # median: fake devices oversubscribe cores

out = {}
for name, mode, uk in [("batched", "batched", False),
                       ("kernel", "batched", True),
                       ("overlap", "overlap", False),
                       ("scan", "scan", False)]:
    run = _knn_fn(mesh, "q", N, block, topk, "dot", mode, uk, plc)
    gv, gi = run(xs)
    assert (np.asarray(gi)[:N] == want.indices).all(), name
    out[name] = bench(lambda run=run: run(xs))
out["block"] = block
print(json.dumps(out))
"""


def run(csv_rows, N: int = 2048, d: int = 32, topk: int = 8):
    results: dict = {"N": N, "d": d, "topk": topk, "timings_s": {}}
    for P in [8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(d), str(topk)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        timings = {k: v for k, v in res.items() if k != "block"}
        results["timings_s"][str(P)] = timings
        best = min(timings, key=timings.get)
        results["best_mode"] = {str(P): best}
        results["fused_vs_batched"] = {
            str(P): timings["batched"] / timings["kernel"]}
        csv_rows.append((
            f"knn_graph_P{P}",
            f"{timings[best] * 1e6:.0f}",
            f"best={best};topk={topk}"
            f";fused_vs_batched={results['fused_vs_batched'][str(P)]:.2f}"
            + ";" + ";".join(f"{k}_us={v * 1e6:.0f}"
                             for k, v in timings.items())))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

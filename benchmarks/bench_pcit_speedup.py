"""Paper Fig. 2 (left): PCIT runtime vs number of processes.

Replicates the experiment's structure: quorum PCIT under shard_map with
P in {1, 2, 4, 8} fake host devices (subprocess per P so device counts do
not leak into the caller).

IMPORTANT measurement note: this container exposes ONE physical core, so
the P fake devices execute sequentially and wall-clock stays ~flat in P —
the honest observable here is TOTAL work, which is ~constant in P (the
quorum schedule computes each pair once).  The paper's 7x-on-8-nodes
wall-clock speedup corresponds to the derived ``ideal_speedup`` column
(total work / max per-process work = P * (P+1)/2 / ceil((P+1)/2)), which
the static per-difference balance achieves exactly on real parallel
hardware.  Fig. 2's memory panel is bench_memory.py (fully measurable).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

_CHILD = r"""
import json, sys, time
import numpy as np, jax
from repro.apps.pcit import run_quorum_pcit
P = int(sys.argv[1]); N = int(sys.argv[2]); G = int(sys.argv[3])
rng = np.random.default_rng(0)
Z = rng.normal(size=(8, G)); W = rng.normal(size=(N, 8))
X = (W @ Z + 0.3 * rng.normal(size=(N, G))).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
run_quorum_pcit(X, mesh)               # compile warmup
t0 = time.perf_counter()
for _ in range(3):
    corr, keep = run_quorum_pcit(X, mesh)
dt = (time.perf_counter() - t0) / 3
print(json.dumps({"P": P, "sec": dt, "kept": float(keep.mean())}))
"""


def run(csv_rows, N: int = 192, G: int = 32):
    results = {}
    for P in [1, 2, 4, 8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(G)], env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        results[P] = json.loads(r.stdout.strip().splitlines()[-1])
    t1 = results[1]["sec"]
    for P, res in results.items():
        wall = t1 / res["sec"]
        # total pair-work = P*(P+1)/2 block pairs; per-process = ceil((P+1)/2)
        total_pairs = P * (P + 1) / 2
        per_proc = (P + 1 + 1) // 2 if P > 1 else 1
        ideal = total_pairs / per_proc if P > 1 else 1.0
        csv_rows.append((f"pcit_speedup_P{P}", f"{res['sec']*1e6:.0f}",
                         f"N={N};wall_ratio_1core={wall:.2f}x;"
                         f"ideal_speedup={ideal:.2f}x;"
                         f"kept={res['kept']:.3f}"))

"""Continuous-batching latency benchmark: per-request p50/p99 and
steady-state qps for a heterogeneous request stream (CPU,
subprocess-isolated fake devices) — the serving-front-end half of the
online numbers, next to BENCH_serve.json's per-mode program throughput.

Drives ``serving.batching.BatchScheduler`` at P=8 with the traffic the
scheduler was built for (DESIGN.md section 15): a deterministic mix of
top-k requests with different k, threshold requests with different
thresholds and capacities, dot and l2 — packed into shared padded
launches.  A warmup wave containing every (kind, metric, bucket)
combination compiles the full program set first, so the measured window
is steady-state: what the scheduler serves once its handful of
quantized programs (DESIGN.md section 15.2) is hot.  Per-request
latency is submit-to-resolve from the scheduler's own trace; qps is
requests / wall over the measured window.  Writes BENCH_latency.json at
the repo root (CI uploads it next to the other BENCH_*.json files;
p50/p99 live under ``timings_s`` and throughput under ``qps`` so the
``--compare`` guard covers both directions).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_latency.json"

_CHILD = r"""
import json, sys, time
import numpy as np, jax
from repro.serving import ServingCorpus
from repro.serving.batching import BatchScheduler, latency_summary

P = int(sys.argv[1]); N = int(sys.argv[2]); R = int(sys.argv[3]); d = 64
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh)
sched = BatchScheduler(sc, max_batch=32)

# deterministic heterogeneous mix: mixed k, mixed thresholds/capacities,
# both metrics — cycled so every wave packs all four (kind, metric)
# groups.  Thresholds are far enough out that matches stay sparse and
# the capacity ladder is exercised without escalating to the full
# corpus.
MIX = [
    dict(kind="topk", topk=1, metric="dot"),
    dict(kind="topk", topk=4, metric="dot"),
    dict(kind="topk", topk=8, metric="dot"),
    dict(kind="topk", topk=16, metric="dot"),
    dict(kind="topk", topk=4, metric="l2"),
    dict(kind="topk", topk=8, metric="l2"),
    dict(kind="threshold", threshold=24.0, capacity=32, metric="dot"),
    dict(kind="threshold", threshold=16.0, capacity=64, metric="dot"),
    dict(kind="threshold", threshold=-9.0, metric="l2"),
    dict(kind="threshold", threshold=-10.0, capacity=32, metric="l2"),
]

def wave(n, seed):
    qs = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    reqs = [sched.submit(qs[i], **MIX[i % len(MIX)]) for i in range(n)]
    sched.step()
    sched.drain()                       # finish capacity-escalated requeues
    return reqs

for w in range(2):       # compile + warm every program at measured widths
    wave(32, seed=100 + w)

n0 = len(sched.latencies_s)
t0 = time.perf_counter()
done = 0
while done < R:
    n = min(32, R - done)
    wave(n, seed=done)
    done += n
span = time.perf_counter() - t0
lat = latency_summary(sched.latencies_s[n0:], span)
out = {"qps": lat["qps"], "p50_s": lat["p50_s"], "p99_s": lat["p99_s"],
       "mean_s": lat["mean_s"], "n": lat["n"],
       "launches": sched.counters["launches"],
       "steps": sched.counters["steps"],
       "escalations": sched.counters["escalations"],
       "programs": len(sched.program_keys)}
print(json.dumps(out))
"""


def run(csv_rows, N: int = 4096, R: int = 256):
    results: dict = {"N": N, "R": R, "mix": "topk k in {1,4,8,16} x "
                     "{dot,l2} + threshold (mixed thr/capacity) x {dot,l2}",
                     "qps": {}, "timings_s": {}, "counters": {}}
    for P in [8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(R)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        results["qps"][str(P)] = res["qps"]
        results["timings_s"][str(P)] = {"p50": res["p50_s"],
                                        "p99": res["p99_s"],
                                        "mean": res["mean_s"]}
        results["counters"][str(P)] = {
            k: res[k] for k in ("launches", "steps", "escalations",
                                "programs", "n")}
        csv_rows.append((
            f"serve_latency_P{P}", f"{res['p50_s'] * 1e6:.0f}",
            f"qps={res['qps']:.1f};p99_us={res['p99_s'] * 1e6:.0f};"
            f"launches={res['launches']};programs={res['programs']};"
            f"escalations={res['escalations']}"))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

"""Incremental delta-sweep microbenchmark: maintenance cost of a
standing output under churn (DESIGN.md section 16.5) — the sixth member
of the benchmark JSON family.

For each workload (dense reduce, sparse join, k-NN graph), each
P in {8, 13}, and 1, 2, and 4 dirty blocks, the bench times one block
update folded through a standing ``core.delta.DeltaIndex`` against a
from-scratch recompute of all C(P,2)+P tiles, and reports the tiles
each path swept — the delta schedule touches ``|D|*P - C(|D|,2) <=
|D|*P`` tiles, which is the paper-side point of the whole subsystem
(output-sensitive cost, arXiv:1602.01443).  Bit-exactness of the
maintained output against the recompute is asserted before any number
is recorded — a wrong fast update is not a result.  Writes
BENCH_delta.json at the repo root (CI uploads it next to the other
BENCH_*.json artifacts and diffs it with ``benchmarks.run --compare``).
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_delta.json"


def run(csv_rows, Ps=(8, 13), dirty_counts=(1, 2, 4), reps: int = 3,
        seed: int = 0):
    import numpy as np

    from repro.core.delta import DeltaIndex, churn_workload, scratch_fold
    from repro.core.faults import WORKLOADS
    from repro.core.placement import get_placement

    results: dict = {"Ps": list(Ps), "dirty_counts": list(dirty_counts),
                     "mode": "batched", "reps": reps,
                     "timings_s": {}, "tiles": {}, "speedup": {}}
    for P in Ps:
        plc = get_placement("cyclic", P)
        pk = f"P{P}"
        results["timings_s"][pk] = {}
        results["tiles"][pk] = {}
        results["speedup"][pk] = {}
        for wl_cls in WORKLOADS:
            wl = churn_workload(wl_cls, P, seed=seed)
            index = DeltaIndex(wl, plc)
            dim = wl.blocks[0].shape[1]
            rng = np.random.RandomState(seed + P)
            t_delta: dict = {}
            t_full: dict = {}
            tiles: dict = {}
            for n_dirty in dirty_counts:
                blocks = [(2 * i + 1) % P for i in range(n_dirty)]
                ds, fs = [], []
                for _ in range(reps):
                    for b in blocks:
                        rows = wl.blocks[b].shape[0]
                        index.replace_block(
                            b, rng.randn(rows, dim).astype(np.float32))
                    t0 = time.perf_counter()
                    out = index.apply()
                    ds.append(time.perf_counter() - t0)
                    t0 = time.perf_counter()
                    want = scratch_fold(wl)
                    fs.append(time.perf_counter() - t0)
                    assert wl.equal(out, want), (
                        f"{wl.name} P={P} dirty={n_dirty}: delta output "
                        "diverged from the from-scratch recompute")
                n_tiles = index.stats.last_tiles
                assert n_tiles <= n_dirty * P, (
                    f"{wl.name} P={P}: {n_tiles} tiles > bound "
                    f"{n_dirty} * {P}")
                dk = f"dirty{n_dirty}"
                t_delta[dk] = statistics.median(ds)
                t_full[dk] = statistics.median(fs)
                tiles[dk] = {"delta": n_tiles,
                             "full": index.stats.tiles_full,
                             "bound": n_dirty * P}
            results["timings_s"][pk][wl.name] = {
                "delta": t_delta, "full_recompute": t_full}
            results["tiles"][pk][wl.name] = tiles
            results["speedup"][pk][wl.name] = {
                dk: (t_full[dk] / t_delta[dk] if t_delta[dk] > 0
                     else float("inf"))
                for dk in t_delta}
            d1 = f"dirty{dirty_counts[0]}"
            csv_rows.append((
                f"delta_{wl.name}_P{P}",
                f"{t_delta[d1] * 1e6:.0f}",
                f"full_us={t_full[d1] * 1e6:.0f}"
                f";tiles={tiles[d1]['delta']}/{tiles[d1]['full']}"
                f";speedup={t_full[d1] / max(t_delta[d1], 1e-12):.2f}"))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    return results

"""Fault-tolerance microbenchmark: recovery cost of the fault-tolerant
sweep driver (DESIGN.md section 13) — the fifth member of the benchmark
JSON family.

For each workload (dense reduce, sparse join, k-NN graph) the bench
times the host-side driver fault-free and under a chaos plan (a kill
every other round, drops and slowdowns mixed in, checkpointing every
round), then reports recovery latency (faulted minus fault-free wall
time), the blocks re-replicated to restore the k-residency invariant,
and the slowdown factor.  Bit-exactness of the faulted output against
the fault-free run is asserted before any number is recorded — a wrong
fast recovery is not a result.  Writes BENCH_faults.json at the repo
root (CI uploads it next to the other BENCH_*.json artifacts and diffs
it with ``benchmarks.run --compare``).
"""

from __future__ import annotations

import json
import statistics
import tempfile
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_faults.json"


def run(csv_rows, P: int = 13, n_items: int = 192, reps: int = 3,
        seed: int = 0):
    from repro.core.faults import (FaultPlan, WORKLOADS,
                                   run_fault_tolerant_sweep)
    from repro.core.placement import get_placement
    from repro.core.sweep import sweep_rounds

    plc = get_placement("cyclic", P)
    n_rounds = len(sweep_rounds(plc.schedule(), "scan"))
    results: dict = {"P": P, "n_items": n_items, "mode": "scan",
                     "timings_s": {}, "recovery": {}}
    for wl_cls in WORKLOADS:
        wl = wl_cls(P, n_items=n_items, seed=seed)
        plan = FaultPlan.random_kills(P, n_rounds, every=2, seed=seed)

        def timed(fn):
            fn()  # warm caches (owner tables, schedules)
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                out = fn()
                ts.append(time.perf_counter() - t0)
            return statistics.median(ts), out

        t_free, (base, _) = timed(
            lambda: run_fault_tolerant_sweep(wl, plc, "scan"))

        def faulted():
            with tempfile.TemporaryDirectory() as d:
                return run_fault_tolerant_sweep(
                    wl, plc, "scan", plan, ckpt_dir=str(Path(d) / "ckpt"),
                    ckpt_every=1)

        t_fault, (out, stats) = timed(faulted)
        assert wl.equal(out, base), f"{wl.name}: faulted output diverged"
        slowdown = t_fault / t_free if t_free > 0 else float("inf")
        results["timings_s"][wl.name] = {
            "fault_free": t_free, "faulted": t_fault}
        results["recovery"][wl.name] = {
            "recovery_latency_s": max(0.0, t_fault - t_free),
            "n_kills": stats.n_kills,
            "n_reassigned": stats.n_reassigned,
            "n_rereplicated": stats.n_rereplicated,
            "n_restores": stats.n_restores,
            "n_checkpoints": stats.n_checkpoints,
            # traced recovery breakdown (DESIGN.md section 14): seconds
            # per phase and the bytes recovery actually moved
            "recovery_phase_s": {k: round(v, 6)
                                 for k, v in sorted(
                                     stats.recovery_s.items())},
            "bytes_fetched": stats.bytes_fetched,
            "bytes_rereplicated": stats.bytes_rereplicated,
            "slowdown": slowdown}
        csv_rows.append((
            f"faults_{wl.name}_P{P}",
            f"{t_fault * 1e6:.0f}",
            f"fault_free_us={t_free * 1e6:.0f}"
            f";kills={stats.n_kills}"
            f";rereplicated={stats.n_rereplicated}"
            f";slowdown={slowdown:.2f}"))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    return results

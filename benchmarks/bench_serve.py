"""Serving microbenchmark: steady-state query throughput per engine mode
(CPU, subprocess-isolated fake devices), fused Pallas kernel vs the
unfused jnp reference path — the online half of BENCH_engine.json.

Times the cover-routed top-k program at P=8 in steady state (the jitted
program is built once via serving.engine.query_fn's cache) for every
local-scoring mode plus the fused-kernel batched path, and the
re-jit-per-call baseline (``cold_jit``: query_fn cache cleared every
call — what serving costs without the program cache).  Writes raw
queries/sec to BENCH_serve.json at the repo root (CI uploads it next to
BENCH_engine.json).

Caveat baked into the numbers: on CPU the Pallas kernel runs in interpret
mode (the kernel body is traced into XLA rather than compiled for TPU), so
``fused`` here measures the *algorithmic* fusion win — the running
extract-max top-k (O(topk * block) per slot) replacing the full two-key
sort over k*block candidates — not the TPU DMA/VMEM effects; medians for
the same load-noise reason as bench_engine.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_serve.json"

_CHILD = r"""
import json, statistics, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.serving import ServingCorpus
from repro.serving.engine import query_fn

P = int(sys.argv[1]); N = int(sys.argv[2]); Q = int(sys.argv[3])
topk = int(sys.argv[4]); d = 64
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
queries = rng.normal(size=(Q, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh)

def bench(fn, reps=15):
    fn()                                        # compile
    fn()                                        # warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return Q / statistics.median(ts)            # queries/sec

def run(mode, uk):
    v, i = sc.query(queries, topk=topk, mode=mode, use_kernel=uk)
    jax.block_until_ready((v, i))

out = {}
for name, mode, uk in [("batched", "batched", False),
                       ("fused", "batched", True),
                       ("overlap", "overlap", False),
                       ("scan", "scan", False)]:
    out[name] = bench(lambda: run(mode, uk))

def cold():
    query_fn.cache_clear()
    run("batched", False)
out["cold_jit"] = bench(cold, reps=3)
out["n_cover"] = sc.plan.n_cover
print(json.dumps(out))
"""


def run(csv_rows, N: int = 4096, Q: int = 32, topk: int = 8):
    results: dict = {"N": N, "Q": Q, "topk": topk, "qps": {},
                     "n_cover": {}}
    for P in [8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(Q), str(topk)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        n_cover = res.pop("n_cover")
        results["qps"][str(P)] = res
        results["n_cover"][str(P)] = n_cover
        best = max((m for m in res), key=lambda m: res[m])
        csv_rows.append((
            f"query_serve_P{P}", f"{1e6 / res[best]:.0f}",
            f"best={best};cover={n_cover}/{P};" + ";".join(
                f"{m}_qps={res[m]:.1f}" for m in res) +
            f";fused_vs_batched={res['fused'] / res['batched']:.3f}"))
    results["fused_vs_batched"] = {
        P: r["fused"] / r["batched"] for P, r in results["qps"].items()}
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

"""Quantized-scoring benchmark (CPU, subprocess-isolated fake devices):
the int8/bf16 band-emit + exact-rescoring join against the pure f32
join, plus the quantized-only answer quality the rescoring pass repairs
(DESIGN.md section 17.6).

Three axes, one JSON (BENCH_quant.json at the repo root, uploaded by CI
next to the other BENCH_*.json files):

  * ``bytes_per_device`` — resident working set of the quantized stack
    vs f32 under the cyclic placement (host-side math; the int8 line is
    the >= 2x reduction headline).
  * ``recall_quant_only`` — what the *unrescored* quantized scores get
    wrong: join membership recall/precision at the threshold and k-NN
    top-k overlap, straight off the device lists.  The rescored path
    returns exactly the f32 answer (asserted here), so this is the
    quality gap the certified rescoring closes.
  * ``timings_s`` — steady-state medians of the cached device programs
    (``*_device``) and of the full host entry points including the
    rescoring pass (``*_e2e``), f32 vs int8 vs bf16.
  * ``modeled`` — the sweep-time model (bench_attention_comm's
    byte-counting idiom) at comm-bound geometries: per-device flops /
    compute-rate + gather bytes / link-bandwidth, f32 vs quantized
    payloads, with NO int8 compute advantage assumed.

Measured-caveat, baked into the numbers like bench_engine's: the
single-host fake-device harness moves gather payloads by memcpy and XLA
CPU runs int8 dots at exactly the f32 rate (no VNNI path), so the
*measured* wall-clock axis can only show parity — the quantized path's
win is a bytes-moved/bytes-resident effect.  The measured rows pin that
parity (and the exactness of the rescored answer); the ``modeled``
section is where the 4x-smaller payload turns into sweep-time speedup,
using the repo's own schedule geometry (DESIGN.md section 17.7).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_quant.json"

_CHILD = r"""
import json, statistics, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.placement import get_placement
from repro.core.quant import (_qjoin_fn, _qknn_fn, _shard_quant,
                              quant_knn_graph, quant_similarity_join)
from repro.core.sparse import (_join_fn, brute_force_join, similarity_join,
                               threshold_for_selectivity)
from repro.core.knn import brute_force_knn

P = int(sys.argv[1]); N = int(sys.argv[2]); d = int(sys.argv[3])
topk = 8
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
block = -(-N // P)
corpus[:2 * block] *= 0.05            # vary per-block quant scales
thr = threshold_for_selectivity(corpus, 0.02, "dot")
wi, wj, wv = brute_force_join(corpus, thr, "dot")
true = set(zip(wi.tolist(), wj.tolist()))

mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
plc = get_placement("cyclic", P)

def bench(fn, reps=11):
    jax.block_until_ready(fn())                 # compile
    jax.block_until_ready(fn())                 # warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)   # median: fake devices oversubscribe cores

def bench_host(fn, reps=7):
    fn(); fn()                                  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)

out = {"threshold": float(thr), "n_hits": len(wi)}

# correctness anchors: every path is bit-exact vs the f32 oracle
res_f32 = similarity_join(corpus, mesh, threshold=thr, mode="batched",
                          placement=plc, quant="off")
assert res_f32.n_pairs == len(wi)
cap = res_f32.capacity
stats = {}
for qm in ("int8", "bf16"):
    r = quant_similarity_join(corpus, mesh, threshold=thr, quant=qm,
                              mode="batched", placement=plc, capacity=cap,
                              stats=stats if qm == "int8" else None)
    assert np.array_equal(r.i, wi) and np.array_equal(r.j, wj), qm
    kq = quant_knn_graph(corpus, mesh, topk=topk, quant=qm,
                         mode="batched", placement=plc)
    ref_knn = brute_force_knn(corpus, topk, "dot")
    assert np.array_equal(kq.indices, ref_knn.indices), qm
out["band"] = stats

# quantized-only quality: membership by s_q >= thr off the device band,
# k-NN overlap off the raw quantized top-k lists (no rescoring)
qc, x, n2 = _shard_quant(corpus, P, "int8")
run_q = _qjoin_fn(mesh, "q", N, block, float(thr), "dot", "batched",
                  cap, False, plc, "int8")
vals, gi, gj, counts = (np.asarray(a) for a in run_q(qc.device_arrays()))
vals = vals.reshape(P, -1); gi = gi.reshape(P, -1); gj = gj.reshape(P, -1)
counts = counts.reshape(-1)
qpairs = set()
for dev in range(P):
    n = min(int(counts[dev]), cap)
    for a, b, v in zip(gi[dev, :n], gj[dev, :n], vals[dev, :n]):
        if v >= thr:
            qpairs.add((int(a), int(b)))
join_recall = len(qpairs & true) / max(1, len(true))
join_precision = len(qpairs & true) / max(1, len(qpairs))
run_k = _qknn_fn(mesh, "q", N, block, topk, "dot", "batched", False,
                 plc, "int8")
kv, ki = (np.asarray(a) for a in run_k(qc.device_arrays()))
ref_knn = brute_force_knn(corpus, topk, "dot")
knn_recall = float(np.mean([
    len(set(ki[r].tolist()) & set(ref_knn.indices[r].tolist())) / topk
    for r in range(N)]))
out["recall_quant_only"] = {"join_recall": join_recall,
                            "join_precision": join_precision,
                            "knn_recall_at_k": knn_recall}

# timings: cached device programs + full e2e entry points
xs = jnp.asarray(x)
run_f = _join_fn(mesh, "q", N, block, float(thr), "dot", "batched", cap,
                 True, False, plc)
out["f32_device"] = bench(lambda: run_f(xs))
out["f32_e2e"] = bench_host(lambda: similarity_join(
    corpus, mesh, threshold=thr, mode="batched", placement=plc,
    capacity=cap, quant="off"))
for qm in ("int8", "bf16"):
    qcm, _, _ = _shard_quant(corpus, P, qm)
    leaves = qcm.device_arrays()
    run_qm = _qjoin_fn(mesh, "q", N, block, float(thr), "dot", "batched",
                       cap, False, plc, qm)
    out[f"{qm}_device"] = bench(lambda: run_qm(leaves))
    out[f"{qm}_e2e"] = bench_host(lambda: quant_similarity_join(
        corpus, mesh, threshold=thr, quant=qm, mode="batched",
        placement=plc, capacity=cap))
print(json.dumps(out))
"""


def _bytes_per_device(N: int, d: int, P: int) -> dict:
    """The resident-bytes section (host-side math, no jax): f32 vs
    int8/bf16 under the cyclic placement, with reduction ratios
    (DESIGN.md section 17.1) — same formula as
    bench_memory.quant_resident_bytes."""
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    from repro.core.scheduler import build_schedule

    from .bench_memory import quant_resident_bytes
    k = build_schedule(P).k
    f32 = quant_resident_bytes(N, d, P, k, "off")
    out = {"k": k, "f32": f32}
    for mode in ("int8", "bf16"):
        b = quant_resident_bytes(N, d, P, k, mode)
        out[mode] = b
        out[f"{mode}_reduction_x"] = round(f32 / b, 4)
    return out


def modeled_sweep_speedup(P: int, block: int, d: int,
                          compute_flops: float = 50e12,
                          link_bw: float = 25e9) -> dict:
    """Sweep-time model at one geometry: per-device tile flops over an
    accelerator compute rate plus per-device gather bytes over a
    cross-device link — the regime the quantized payload targets
    (DESIGN.md section 17.7).  Deliberately conservative: int8/bf16 are
    charged the SAME compute rate as f32 (no VNNI/matrix-unit credit),
    so any modeled speedup is purely the comm term shrinking."""
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    from repro.core.scheduler import build_schedule

    s = build_schedule(P)
    t_compute = 2.0 * s.n_pairs * block * block * d / compute_flops
    payloads = {
        "f32": block * d * 4,
        "int8": block * d * 1 + 8 + 8 * block,
        "bf16": block * d * 2 + 8 + 8 * block,
    }
    times = {m: t_compute + (s.k - 1) * b / link_bw
             for m, b in payloads.items()}
    out = {"k": s.k, "n_pairs": s.n_pairs, "block": block, "d": d,
           "compute_flops": compute_flops, "link_bw": link_bw,
           "t_compute_s": t_compute}
    for m in ("f32", "int8", "bf16"):
        out[f"{m}_gather_bytes"] = (s.k - 1) * payloads[m]
        out[f"{m}_sweep_s"] = times[m]
    out["int8_speedup_x"] = times["f32"] / times["int8"]
    out["bf16_speedup_x"] = times["f32"] / times["bf16"]
    return out


def run(csv_rows, N: int = 2048, d: int = 128):
    results: dict = {"N": N, "d": d, "timings_s": {}, "bytes_per_device": {},
                     "speedup": {}, "modeled": {}}
    results["measured_caveat"] = (
        "single-host fake devices: gather is memcpy and XLA CPU runs int8 "
        "dots at the f32 rate, so measured wall-clock shows parity; the "
        "payload win is carried by bytes_per_device and the modeled "
        "comm-bound sweep times")
    for P in (64, 256):
        # comm-bound geometry: small blocks, wide rows — compute is
        # block^2*d per tile, gather is block*d per hop
        results["modeled"][str(P)] = modeled_sweep_speedup(P, 256, 256)
    m = results["modeled"]["256"]
    csv_rows.append((
        "quant_modeled_P256", f"{m['int8_sweep_s'] * 1e6:.0f}",
        f"int8_sweep_us;f32_sweep_us={m['f32_sweep_s'] * 1e6:.0f}"
        f";int8_speedup={m['int8_speedup_x']:.2f}"
        f";bf16_speedup={m['bf16_speedup_x']:.2f}"
        f";k={m['k']};gather_MB_f32={m['f32_gather_bytes'] / 1e6:.1f}"))
    for P in [8]:
        results["bytes_per_device"][str(P)] = _bytes_per_device(N, d, P)
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(d)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        timings = {k: v for k, v in res.items()
                   if k.endswith(("_device", "_e2e"))}
        results["timings_s"][str(P)] = timings
        results["recall_quant_only"] = res["recall_quant_only"]
        results["band"] = res["band"]
        results["threshold"] = res["threshold"]
        results["n_hits"] = res["n_hits"]
        results["speedup"][str(P)] = {
            "int8_device_vs_f32": res["f32_device"] / res["int8_device"],
            "bf16_device_vs_f32": res["f32_device"] / res["bf16_device"],
            "int8_e2e_vs_f32": res["f32_e2e"] / res["int8_e2e"],
            "bf16_e2e_vs_f32": res["f32_e2e"] / res["bf16_e2e"]}
        bpd = results["bytes_per_device"][str(P)]
        rq = res["recall_quant_only"]
        csv_rows.append((
            f"quant_join_P{P}", f"{res['int8_e2e'] * 1e6:.0f}",
            f"int8_e2e_us;f32_e2e_us={res['f32_e2e'] * 1e6:.0f}"
            f";e2e_speedup={results['speedup'][str(P)]['int8_e2e_vs_f32']:.2f}"
            f";device_speedup="
            f"{results['speedup'][str(P)]['int8_device_vs_f32']:.2f}"
            f";bytes_reduction={bpd['int8_reduction_x']:.2f}"
            f";quant_only_recall={rq['join_recall']:.4f}"
            f";knn_recall={rq['knn_recall_at_k']:.4f}"))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

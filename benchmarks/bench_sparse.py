"""Sparse similarity-join microbenchmark (CPU, subprocess-isolated fake
devices): the thresholded engine with and without the norm-bound
prefilter, per execution mode — the sparse third of the benchmark JSON
family (DESIGN.md section 11.5).

The corpus is crafted so block-level pruning has teeth: two of the P
blocks hold full-scale vectors, the rest are down-scaled, and the
threshold sits at a ~2% pair selectivity — so only big-block tiles can
pass and the prefilter skips ~90% of tiles whole.  ``scan`` mode turns
each skip into a real ``lax.cond`` FLOP saving, which is the
``prefilter_speedup`` headline (sparse-with-prefilter vs the same engine
computing every tile — the dense-scoring configuration); ``batched``
cannot skip inside one fused einsum and is timed for contrast.  Timings
are steady-state medians of the cached jitted program (the host
compaction is excluded), for the same load-noise reasons as
bench_engine.  Writes BENCH_sparse.json at the repo root (CI uploads it
next to BENCH_engine.json / BENCH_serve.json).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_sparse.json"

_CHILD = r"""
import json, statistics, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.placement import get_placement
from repro.core.sparse import (_join_fn, brute_force_join, default_capacity,
                               similarity_join, threshold_for_selectivity)

P = int(sys.argv[1]); N = int(sys.argv[2]); d = int(sys.argv[3])
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
block = -(-N // P)
corpus[2 * block:] *= 0.02          # only blocks 0-1 can clear the threshold
thr = threshold_for_selectivity(corpus, 0.02, "dot")
wi, _, _ = brute_force_join(corpus, thr, "dot")
selectivity = len(wi) / (N * (N - 1) // 2)

mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
plc = get_placement("cyclic", P)
sched = plc.schedule()

# host-side prune accounting: fraction of the tiles the engine actually
# computes (dedup-mask survivors — at even P one copy of each d=P/2
# orbit tile is mask-killed before any compute) whose bound misses the
# threshold, i.e. what the prefilter skips on top of the mask
from repro.core.allpairs import pair_mask_table
x = np.zeros((P * block, d), np.float32); x[:N] = corpus
norms = np.linalg.norm(x.reshape(P, block, d), axis=-1)
maxn = norms.max(axis=1)
mask = pair_mask_table(sched)                  # [P, n_pairs]
active = 0; total = 0
for dev in range(P):
    for s_i, (ga, gb) in enumerate(sched.global_pairs_of(dev)):
        if mask[dev, s_i] == 0:
            continue
        total += 1
        active += maxn[ga] * maxn[gb] >= thr
pruned_frac = 1.0 - active / total

xs = jnp.asarray(x)
cap = default_capacity(sched.n_pairs * block * block)
# one escalation-checked reference pass (also warms nothing: fresh caches)
res = similarity_join(corpus, mesh, threshold=thr, mode="scan",
                      placement=plc, capacity=cap)
assert res.n_pairs == len(wi), (res.n_pairs, len(wi))
cap = res.capacity

def bench(fn, reps=15):
    jax.block_until_ready(fn())                 # compile
    jax.block_until_ready(fn())                 # warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)   # median: fake devices oversubscribe cores

out = {}
for name, mode, prefilter in [("scan_prefilter", "scan", True),
                              ("scan_dense", "scan", False),
                              ("batched_prefilter", "batched", True),
                              ("batched_dense", "batched", False)]:
    run = _join_fn(mesh, "q", N, block, float(thr), "dot", mode, cap,
                   prefilter, False, plc)
    out[name] = bench(lambda run=run: run(xs))
out["selectivity"] = selectivity
out["pruned_tile_frac"] = pruned_frac
out["capacity"] = cap
out["threshold"] = float(thr)
out["n_hits"] = len(wi)
print(json.dumps(out))
"""


def run(csv_rows, N: int = 2048, d: int = 32):
    results: dict = {"N": N, "d": d, "timings_s": {}}
    for P in [8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            str(d)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        timings = {k: v for k, v in res.items()
                   if k.endswith(("_prefilter", "_dense"))}
        results["timings_s"][str(P)] = timings
        results["selectivity"] = res["selectivity"]
        results["pruned_tile_frac"] = res["pruned_tile_frac"]
        results["threshold"] = res["threshold"]
        results["n_hits"] = res["n_hits"]
        results["capacity"] = res["capacity"]
        # the headline: prefilter vs dense scoring, same engine/mode, and
        # best-sparse vs best-dense across modes
        results["prefilter_speedup"] = {
            str(P): timings["scan_dense"] / timings["scan_prefilter"]}
        best_sparse = min(timings["scan_prefilter"],
                          timings["batched_prefilter"])
        best_dense = min(timings["scan_dense"], timings["batched_dense"])
        results["sparse_vs_dense"] = {str(P): best_dense / best_sparse}
        csv_rows.append((
            f"sparse_join_P{P}",
            f"{timings['scan_prefilter'] * 1e6:.0f}",
            f"selectivity={res['selectivity']:.4f}"
            f";pruned={res['pruned_tile_frac']:.2f}"
            f";prefilter_speedup="
            f"{results['prefilter_speedup'][str(P)]:.2f}"
            f";sparse_vs_dense={results['sparse_vs_dense'][str(P)]:.2f}"
            + ";" + ";".join(f"{k}_us={v * 1e6:.0f}"
                             for k, v in timings.items())))
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

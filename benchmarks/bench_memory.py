"""Paper Fig. 2 (right): memory per process vs number of processes.

Analytic + measured: the resident working set of the quorum PCIT pipeline is
  raw data   k * (N/P) * G
  corr rows  k * (N/P) * N
versus the single-node N*G + N^2 — the paper's "1/3rd the memory at 8
nodes (16 processes)" claim is the k(16)/16 = 5/16 ≈ 0.31 line.
Measured bytes come from the shard_map-lowered per-device buffer sizes.
"""

from __future__ import annotations

from repro.core.scheduler import build_schedule


def run(csv_rows, N: int = 3072, G: int = 256):
    base = N * G * 4 + N * N * 4
    for P in [1, 2, 4, 8, 16, 32, 64]:
        s = build_schedule(P)
        per = s.k * (N // P) * G * 4 + s.k * (N // P) * N * 4
        frac = per / base
        csv_rows.append((
            f"pcit_memory_P{P}", f"{per/1e6:.2f}",
            f"MB_per_proc;frac_of_single={frac:.4f};k={s.k};"
            f"paper_claim_P16=0.3125"))

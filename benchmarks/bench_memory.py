"""Paper Fig. 2 (right): memory per process vs number of processes.

Analytic + measured: the resident working set of the quorum PCIT pipeline is
  raw data   k * (N/P) * G
  corr rows  k * (N/P) * N
versus the single-node N*G + N^2 — the paper's "1/3rd the memory at 8
nodes (16 processes)" claim is the k(16)/16 = 5/16 ≈ 0.31 line.
Measured bytes come from the shard_map-lowered per-device buffer sizes.

Alongside the CSV rows, :func:`run` records a ``memory`` section into
BENCH_engine.json (read-modify-write — bench_engine owns the rest of
that file) comparing the f32 resident bytes/device against the
quantized int8/bf16 working set (DESIGN.md section 17.1): the int8
ratio must clear the >= 2x reduction the quant path exists for.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.scheduler import build_schedule

ROOT = Path(__file__).resolve().parents[1]
ENGINE_JSON = ROOT / "BENCH_engine.json"


def quant_resident_bytes(N: int, d: int, P: int, k: int, mode: str) -> int:
    """Resident working-set bytes/device of an [N, d] corpus under a
    quant mode — a jax-free mirror of
    ``repro.core.quant.corpus_bytes_per_device`` (tests pin the two
    formulas equal): f32 is ``k * block * d * 4``; int8/bf16 add the
    per-block scale/delta scalars and the f32 l1/sq rows that ride the
    gather (DESIGN.md section 17.1)."""
    block = -(-N // P)
    if mode == "off":
        return k * block * d * 4
    itemsize = {"int8": 1, "bf16": 2}[mode]
    return k * (block * d * itemsize + 8 + 8 * block)


def quant_memory_stats(N: int = 4096, d: int = 256,
                       Ps=(4, 8, 13)) -> dict:
    """The BENCH_engine.json ``memory`` section: per P, the f32 vs
    int8/bf16 resident bytes/device under the cyclic placement and the
    reduction ratios (DESIGN.md section 17.1).  Host-side math only —
    no jax import."""
    out: dict[str, dict] = {"N": N, "d": d, "per_P": {}}
    for P in Ps:
        s = build_schedule(P)
        f32 = quant_resident_bytes(N, d, P, s.k, "off")
        entry = {"k": s.k, "f32_bytes_per_device": f32}
        for mode in ("int8", "bf16"):
            b = quant_resident_bytes(N, d, P, s.k, mode)
            entry[f"{mode}_bytes_per_device"] = b
            entry[f"{mode}_reduction_x"] = round(f32 / b, 4)
        out["per_P"][str(P)] = entry
    return out


def run(csv_rows, N: int = 3072, G: int = 256):
    base = N * G * 4 + N * N * 4
    for P in [1, 2, 4, 8, 16, 32, 64]:
        s = build_schedule(P)
        per = s.k * (N // P) * G * 4 + s.k * (N // P) * N * 4
        frac = per / base
        csv_rows.append((
            f"pcit_memory_P{P}", f"{per/1e6:.2f}",
            f"MB_per_proc;frac_of_single={frac:.4f};k={s.k};"
            f"paper_claim_P16=0.3125"))
    mem = quant_memory_stats()
    for P, st in mem["per_P"].items():
        csv_rows.append((
            f"quant_memory_P{P}", f"{st['f32_bytes_per_device']}",
            f"f32_B;int8_B={st['int8_bytes_per_device']}"
            f";int8_x={st['int8_reduction_x']}"
            f";bf16_B={st['bf16_bytes_per_device']}"
            f";bf16_x={st['bf16_reduction_x']};k={st['k']}"))
    # read-modify-write: bench_engine owns the rest of the file (and
    # preserves this key when it rewrites)
    obj = (json.loads(ENGINE_JSON.read_text()) if ENGINE_JSON.exists()
           else {})
    obj["memory"] = mem
    ENGINE_JSON.write_text(json.dumps(obj, indent=2) + "\n")

"""Roofline analysis from the dry-run JSON (deliverable g).

Per (arch x shape x mesh):
  compute    = HLO_FLOPs / peak_FLOP/s          (per chip; extrapolated)
  memory     = HLO_bytes / HBM_bw               (per chip)
  collective = wire_bytes / (links * link_bw)   (per chip)
plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs_total.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 4 links usable per chip on the 2-D torus (we charge
the ICI term conservatively against ONE link — the schedule rarely balances
all links).

Usage:
  PYTHONPATH=src python -m benchmarks.roofline results/dryrun_all.json [--md]
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link (1 link charged)


def analyze(cell: dict) -> dict | None:
    if "error" in cell or "cost" not in cell:
        return None
    chips = 1
    for v in cell["mesh"].values():
        chips *= v
    flops_dev = cell["cost"]["flops"]          # per-device (SPMD module)
    bytes_dev = cell["cost"]["bytes"]
    wire_dev = cell["cost"]["collectives"]["wire_total"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = cell["model_flops"] / chips
    ratio = model_flops_dev / flops_dev if flops_dev else 0.0
    t_bound = max(terms.values())
    if cell["kind"] == "decode":
        # decode is bandwidth-bound by construction: the ideal step streams
        # the resident state (params shard + caches = argument bytes) from
        # HBM exactly once; roofline fraction = ideal stream time / bound.
        t_ideal = cell["memory"]["argument_bytes"] / HBM_BW
        frac = t_ideal / t_bound if t_bound else 0.0
    else:
        # train/prefill: useful model FLOP/s achievable under the dominant
        # term, as a fraction of peak compute.
        frac = (model_flops_dev / t_bound) / PEAK_FLOPS if t_bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "mesh": "x".join(str(v) for v in cell["mesh"].values()),
        "chips": chips, "accum": cell.get("accum", 1),
        "fits": cell.get("fits_hbm"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "model_flops": cell["model_flops"],
        "hlo_flops_dev": flops_dev,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "mem_gib": (cell["memory"]["argument_bytes"]
                    + cell["memory"]["temp_bytes"]) / 2 ** 30,
    }


def render_md(rows) -> str:
    hdr = ("| arch | shape | mesh | fits | accum | compute s | memory s | "
           "collective s | bottleneck | 6ND/HLO | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'Y' if r['fits'] else 'N'} | {r['accum']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | **{r['bottleneck']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.1%} |\n")
    return "".join(out)


def main(argv=None):
    argv = argv or sys.argv[1:]
    path = Path(argv[0] if argv else "results/dryrun_all.json")
    cells = json.loads(path.read_text())
    rows = [a for a in (analyze(c) for c in cells) if a]
    if "--md" in argv:
        print(render_md(rows))
        return rows
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"cmp={r['t_compute_s']:.2e} mem={r['t_memory_s']:.2e} "
              f"col={r['t_collective_s']:.2e} -> {r['bottleneck']:10s} "
              f"useful={r['useful_ratio']:.2f} roof={r['roofline_frac']:.1%}")
    return rows


if __name__ == "__main__":
    main()

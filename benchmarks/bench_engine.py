"""Engine microbenchmarks: quorum vs all-gather all-pairs wall time (CPU,
subprocess-isolated fake devices) on the n-body kernel — the paper's
motivating algorithm family."""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

_CHILD = r"""
import json, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.apps.nbody import distributed_forces
P = int(sys.argv[1]); N = int(sys.argv[2])
rng = np.random.default_rng(0)
bodies = np.concatenate([rng.normal(size=(N,3)),
                         rng.uniform(0.5,2,(N,1))], -1).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
out = {}
for strat in ["quorum", "atom"]:
    distributed_forces(jnp.asarray(bodies), mesh, strategy=strat)  # warmup
    t0 = time.perf_counter()
    for _ in range(5):
        distributed_forces(jnp.asarray(bodies), mesh, strategy=strat).block_until_ready()
    out[strat] = (time.perf_counter() - t0) / 5
print(json.dumps(out))
"""


def run(csv_rows, N: int = 4096):
    for P in [4, 8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        csv_rows.append((
            f"nbody_engine_P{P}", f"{res['quorum']*1e6:.0f}",
            f"quorum_us;atom_us={res['atom']*1e6:.0f};"
            f"ratio={res['quorum']/res['atom']:.2f}"))

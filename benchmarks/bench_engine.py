"""Engine microbenchmarks: per-mode quorum vs all-gather all-pairs wall time
(CPU, subprocess-isolated fake devices) on the n-body kernel — the paper's
motivating algorithm family.

Times every engine execution mode (batched / overlap / scan, DESIGN.md
section 4) in steady state (jitted callable built once via
nbody.forces_fn's cache), the atom-decomposition all-gather baseline, and
``seed_scan`` — the seed engine's as-shipped behavior (serial scan plus a
fresh jax.jit per call), kept as the PR-over-PR reference point.  Writes
the raw per-mode seconds to BENCH_engine.json at the repo root so the perf
trajectory is tracked across PRs (CI uploads it as an artifact).

Caveats baked into the numbers: medians (the fake-device harness
oversubscribes host cores, so minima collapse to the collective-sync floor
and means are load-noise); on a few-core host the mode spread at small
n_pairs (P=4 -> 3 pairs) sits near that noise floor, while P=8 (5 pairs)
separates clearly.

Alongside the timings, a host-side ``placements`` section records, for
every registered placement defined at each benchmarked P (plus the
plane-friendly P = 13), the replication factor and the resident
bytes/device for the N-body working set — the storage axis the placement
layer trades against (DESIGN.md section 10).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
JSON_PATH = ROOT / "BENCH_engine.json"

def _modes() -> list[str]:
    """Engine mode list, single-sourced from the engine (imported lazily so
    merely importing this module keeps the parent process jax-free — the
    benchmarks run in subprocess-isolated fake-device children)."""
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    from repro.core.allpairs import ENGINE_MODES
    return list(ENGINE_MODES)

_CHILD = r"""
import json, statistics, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.apps import nbody
from repro.apps.nbody import distributed_forces
P = int(sys.argv[1]); N = int(sys.argv[2]); modes = sys.argv[3].split(",")
rng = np.random.default_rng(0)
bodies = np.concatenate([rng.normal(size=(N,3)),
                         rng.uniform(0.5,2,(N,1))], -1).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
out = {}

def bench(fn, reps=15):
    fn().block_until_ready()                    # compile
    fn().block_until_ready()                    # warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn().block_until_ready()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)   # median: fake devices oversubscribe cores

xb = jnp.asarray(bodies)
for mode in modes:
    out[mode] = bench(lambda: distributed_forces(xb, mesh, strategy="quorum",
                                                 mode=mode))
out["atom"] = bench(lambda: distributed_forces(xb, mesh, strategy="atom"))

def seed_scan():
    # the seed engine as shipped: serial scan AND a fresh jax.jit every call
    nbody.forces_fn.cache_clear()
    return distributed_forces(xb, mesh, strategy="quorum", mode="scan")
out["seed_scan"] = bench(seed_scan, reps=3)

# traced comm volume (AFTER the timings, so they stay tracing-free):
# one fresh traced batched sweep; actuals must equal the analytical
# predictor exactly (DESIGN.md section 14.3)
from repro.obs import trace as obs_trace
from repro.obs.comm import predict_sweep_comm, traced_sweep_comm
from repro.core.placement import get_placement
tracer = obs_trace.configure()
nbody.forces_fn.cache_clear()
distributed_forces(xb, mesh, strategy="quorum",
                   mode="batched").block_until_ready()
got = traced_sweep_comm(tracer)
rows = N // P
pred = predict_sweep_comm(get_placement("cyclic", P), rows * 4 * 4,
                          partial_bytes=rows * 3 * 4)  # forces are [m, 3]
assert got["gather_bytes"] == pred.gather_bytes, (got, pred.as_dict())
assert got["scatter_bytes"] == pred.scatter_bytes, (got, pred.as_dict())
out["comm"] = {"traced": got, "predicted": pred.as_dict()}
obs_trace.reset()
nbody.forces_fn.cache_clear()
print(json.dumps(out))
"""


def placement_stats(N: int, Ps=(4, 8, 13)) -> dict:
    """Per-placement replication + resident bytes/device (host-side math,
    no jax): the n-body working set is [N, 4] float32 rows, so a device
    resident under replication k holds k * ceil(N/P) rows.  ``full`` is
    the all-gather baseline (N rows), cyclic/planes are O(sqrt(P))."""
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    from repro.core.placement import supported_placements
    row_bytes = 4 * 4                      # 4 float32 features per body
    out: dict[str, dict] = {}
    for P in Ps:
        rows_per_block = -(-N // P)
        out[str(P)] = {
            plc.name: {
                "replication": plc.replication,
                "bytes_per_device": plc.replication * rows_per_block * row_bytes,
            }
            for plc in supported_placements(P)
        }
    return out


def run(csv_rows, N: int = 1024):
    modes = _modes()
    results: dict[str, dict] = {"N": N, "timings_s": {}, "comm": {},
                                "placements": placement_stats(N)}
    if JSON_PATH.exists():
        # bench_memory.py owns the quantized-vs-f32 "memory" section
        # (read-modify-write); carry it across this full rewrite
        prev = json.loads(JSON_PATH.read_text())
        if "memory" in prev:
            results["memory"] = prev["memory"]
    for P, stats in results["placements"].items():
        csv_rows.append((
            f"placement_bytes_P{P}", "",
            ";".join(f"{name}_k={s['replication']}"
                     f";{name}_B={s['bytes_per_device']}"
                     for name, s in stats.items())))
    for P in [4, 8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = str(SRC)
        r = subprocess.run([sys.executable, "-c", _CHILD, str(P), str(N),
                            ",".join(modes)],
                           env=env, capture_output=True, text=True,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads(r.stdout.strip().splitlines()[-1])
        results["comm"][str(P)] = res.pop("comm")
        results["timings_s"][str(P)] = res
        best = min(modes, key=lambda m: res[m])
        csv_rows.append((
            f"nbody_engine_P{P}", f"{res[best]*1e6:.0f}",
            f"best={best};" + ";".join(
                f"{m}_us={res[m]*1e6:.0f}"
                for m in modes + ["atom", "seed_scan"]) +
            f";speedup_vs_scan={res['scan']/res[best]:.2f}"
            f";speedup_vs_seed={res['seed_scan']/res[best]:.1f}"))
    results["speedup_vs_scan"] = {
        P: {m: t["scan"] / t[m] for m in modes}
        for P, t in results["timings_s"].items()}
    results["speedup_vs_seed_scan"] = {
        P: {m: t["seed_scan"] / t[m] for m in modes}
        for P, t in results["timings_s"].items()}
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")

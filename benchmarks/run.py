"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_quorum         — quorum size table (paper section 3.2)
  bench_memory         — Fig. 2 right: memory/process vs P
  bench_pcit_speedup   — Fig. 2 left: PCIT runtime + speedup vs P
  bench_engine         — n-body per-engine-mode quorum vs atom wall time
                         (also writes BENCH_engine.json at the repo root;
                         ``--fast-engine`` runs only this one, for CI)
  bench_serve          — online query subsystem: steady-state queries/sec
                         per mode, fused kernel vs unfused reference
                         (writes BENCH_serve.json; ``--fast-serve`` runs
                         only this one, for CI)
  bench_latency        — continuous-batching front end: per-request
                         p50/p99 latency + steady-state qps for a
                         heterogeneous request mix (writes
                         BENCH_latency.json; ``--fast-latency`` runs
                         only this one, for CI)
  bench_sparse         — thresholded similarity join: norm-bound
                         prefilter vs dense scoring at low selectivity
                         (writes BENCH_sparse.json; ``--fast-sparse``
                         runs only this one, for CI)
  bench_knn            — all-pairs k-NN graph: per-mode wall time, fused
                         kernel vs unfused batched (writes
                         BENCH_knn.json; ``--fast-knn`` runs only this
                         one, for CI)
  bench_faults         — fault-tolerant sweep driver: fault-free vs
                         chaos-plan wall time, recovery latency, blocks
                         re-replicated (writes BENCH_faults.json;
                         ``--fast-faults`` runs only this one, for CI)
  bench_delta          — incremental delta-sweep: standing-index update
                         vs from-scratch recompute wall time and tiles
                         swept at 1/2/4 dirty blocks (writes
                         BENCH_delta.json; ``--fast-delta`` runs only
                         this one, for CI)
  bench_quant          — quantized int8/bf16 scoring path: bytes/device
                         reduction vs f32, quantized-only recall, and
                         the error-bounded rescored join f32-vs-quant
                         wall time (writes BENCH_quant.json;
                         ``--fast-quant`` runs only this one, for CI)
  bench_attention_comm — comm-volume model: quorum vs ring vs all-gather

``--compare`` snapshots the committed BENCH_*.json files before running,
re-reads them afterwards, and prints a regression warning (a GitHub
``::warning::`` annotation in CI) for every timing that slipped past the
tolerance — seconds-valued leaves under ``timings_s`` warn when the
fresh value exceeds ``tolerance x`` the committed one, ``qps`` leaves
when it drops below ``committed / tolerance``.  Warn-only: noisy CI
hosts make a hard gate a flake machine, but the diff is always visible
in the job log (``--compare-strict`` upgrades the warning to a nonzero
exit for gating jobs that accept the flake risk).  Every BENCH_*.json is
also stamped with an
``environment`` section (python/jax versions, device kind/platform/
count) and ``--compare`` warns on drift in those fields, so a timing
diff taken on different software or hardware is never silently read as
a code regression.

Roofline extraction from the dry-run lives in benchmarks/roofline.py (it
needs the 512-device dry-run JSON, produced by repro.launch.dryrun --all).
"""

from __future__ import annotations

import json
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BENCH_FILES = ("BENCH_engine.json", "BENCH_serve.json",
               "BENCH_latency.json", "BENCH_sparse.json",
               "BENCH_knn.json", "BENCH_faults.json",
               "BENCH_delta.json", "BENCH_quant.json")
COMPARE_TOLERANCE = 1.5


def _numeric_leaves(obj, path=()):
    """Yield (path, value) for every numeric leaf of a JSON tree."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _numeric_leaves(v, path + (str(k),))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _numeric_leaves(v, path + (str(i),))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, float(obj)


def snapshot_committed():
    """The committed BENCH_*.json contents, read before the benches
    overwrite them (for ``--compare``)."""
    out = {}
    for name in BENCH_FILES:
        p = ROOT / name
        if p.exists():
            out[name] = json.loads(p.read_text())
    return out


def environment_stamp() -> dict:
    """The benchmark host's identity: python/jax versions and device
    kind/platform/count.  Stamped into every BENCH_*.json so
    ``--compare`` can tell a code regression from an environment change
    (different jax, different accelerator — DESIGN.md section 14).
    Imports are guarded: a jax-free caller still gets the python row."""
    import platform
    stamp = {"python": platform.python_version()}
    try:
        import jax
        stamp["jax"] = jax.__version__
        import jaxlib
        stamp["jaxlib"] = jaxlib.__version__
        devs = jax.devices()
        stamp["device_kind"] = devs[0].device_kind
        stamp["platform"] = devs[0].platform
        stamp["device_count"] = len(devs)
    except Exception:  # pragma: no cover - jax absent or device init fails
        pass
    return stamp


def stamp_results() -> None:
    """Write :func:`environment_stamp` into every BENCH_*.json present
    (after the benches ran, before ``--compare`` reads them back)."""
    stamp = environment_stamp()
    for name in BENCH_FILES:
        p = ROOT / name
        if not p.exists():
            continue
        obj = json.loads(p.read_text())
        obj["environment"] = stamp
        p.write_text(json.dumps(obj, indent=2) + "\n")


def compare_results(committed, tolerance: float = COMPARE_TOLERANCE) -> int:
    """Diff fresh BENCH_*.json against the committed snapshot; print a
    warning per regressed timing (``timings_s`` leaves: slower than
    tolerance x committed; ``qps`` leaves: below committed / tolerance).
    Returns the number of regressions (informational — warn-only)."""
    regressions = 0
    drift_seen = set()
    for name, old in committed.items():
        p = ROOT / name
        if not p.exists():
            continue
        new = json.loads(p.read_text())
        # environment drift: a timing diff against a different
        # jax/device is not a code regression — flag it loudly
        old_env = old.get("environment", {})
        new_env = new.get("environment", {})
        for key in sorted(set(old_env) | set(new_env)):
            if old_env.get(key) != new_env.get(key) and key not in drift_seen:
                drift_seen.add(key)
                print(f"::warning::bench environment drift: {key} was "
                      f"{old_env.get(key)!r}, now {new_env.get(key)!r} — "
                      f"timing diffs below may reflect the environment, "
                      f"not the code")
        fresh = dict(_numeric_leaves(new))
        for path, old_v in _numeric_leaves(old):
            new_v = fresh.get(path)
            if new_v is None or old_v <= 0:
                continue
            label = f"{name}:{'/'.join(path)}"
            if "timings_s" in path:                  # seconds: lower is better
                if new_v > tolerance * old_v:
                    print(f"::warning::bench regression {label}: "
                          f"{new_v:.6f}s vs committed {old_v:.6f}s "
                          f"({new_v / old_v:.2f}x, tolerance {tolerance}x)")
                    regressions += 1
            elif "qps" in path:                      # rates: higher is better
                if new_v < old_v / tolerance:
                    print(f"::warning::bench regression {label}: "
                          f"{new_v:.1f} qps vs committed {old_v:.1f} qps "
                          f"({old_v / new_v:.2f}x, tolerance {tolerance}x)")
                    regressions += 1
    if regressions:
        print(f"bench compare: {regressions} timing(s) beyond "
              f"{tolerance}x of the committed BENCH_*.json (warn-only)")
    else:
        print("bench compare: no regressions beyond "
              f"{tolerance}x of the committed BENCH_*.json")
    return regressions


def main() -> None:
    """CLI driver (see module docstring for flags)."""
    from . import (bench_attention_comm, bench_attention_hlo, bench_delta,
                   bench_engine, bench_faults, bench_knn, bench_latency,
                   bench_memory, bench_pcit_speedup, bench_quant,
                   bench_quorum, bench_serve, bench_sparse)
    rows = [("name", "us_per_call", "derived")]
    modules = [bench_quorum, bench_memory, bench_attention_comm,
               bench_attention_hlo, bench_engine, bench_serve,
               bench_latency, bench_sparse, bench_knn, bench_faults,
               bench_delta, bench_quant, bench_pcit_speedup]
    if "--fast-engine" in sys.argv:
        modules = [bench_engine]
    elif "--fast-serve" in sys.argv:
        modules = [bench_serve]
    elif "--fast-latency" in sys.argv:
        modules = [bench_latency]
    elif "--fast-sparse" in sys.argv:
        modules = [bench_sparse]
    elif "--fast-knn" in sys.argv:
        modules = [bench_knn]
    elif "--fast-faults" in sys.argv:
        modules = [bench_faults]
    elif "--fast-delta" in sys.argv:
        modules = [bench_delta]
    elif "--fast-quant" in sys.argv:
        modules = [bench_quant]
    elif "--fast" in sys.argv:
        modules = modules[:3]
    strict = "--compare-strict" in sys.argv
    compare = strict or "--compare" in sys.argv
    committed = snapshot_committed() if compare else None
    for mod in modules:
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append((mod.__name__, "ERROR", ""))
    stamp_results()
    for r in rows:
        print(",".join(str(x) for x in r))
    if committed is not None:
        regressions = compare_results(committed)
        if strict and regressions:
            sys.exit(1)


if __name__ == "__main__":
    main()

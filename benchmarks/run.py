"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_quorum         — quorum size table (paper section 3.2)
  bench_memory         — Fig. 2 right: memory/process vs P
  bench_pcit_speedup   — Fig. 2 left: PCIT runtime + speedup vs P
  bench_engine         — n-body per-engine-mode quorum vs atom wall time
                         (also writes BENCH_engine.json at the repo root;
                         ``--fast-engine`` runs only this one, for CI)
  bench_serve          — online query subsystem: steady-state queries/sec
                         per mode, fused kernel vs unfused reference
                         (writes BENCH_serve.json; ``--fast-serve`` runs
                         only this one, for CI)
  bench_sparse         — thresholded similarity join: norm-bound
                         prefilter vs dense scoring at low selectivity
                         (writes BENCH_sparse.json; ``--fast-sparse``
                         runs only this one, for CI)
  bench_attention_comm — comm-volume model: quorum vs ring vs all-gather

Roofline extraction from the dry-run lives in benchmarks/roofline.py (it
needs the 512-device dry-run JSON, produced by repro.launch.dryrun --all).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_attention_comm, bench_attention_hlo, bench_engine,
                   bench_memory, bench_pcit_speedup, bench_quorum,
                   bench_serve, bench_sparse)
    rows = [("name", "us_per_call", "derived")]
    modules = [bench_quorum, bench_memory, bench_attention_comm,
               bench_attention_hlo, bench_engine, bench_serve,
               bench_sparse, bench_pcit_speedup]
    if "--fast-engine" in sys.argv:
        modules = [bench_engine]
    elif "--fast-serve" in sys.argv:
        modules = [bench_serve]
    elif "--fast-sparse" in sys.argv:
        modules = [bench_sparse]
    elif "--fast" in sys.argv:
        modules = modules[:3]
    for mod in modules:
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append((mod.__name__, "ERROR", ""))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()

"""Measured collective schedule: quorum vs ring sequence-parallel attention.

Lowers both strategies on a 16-device mesh (subprocess) and parses the
optimized HLO: per-device wire bytes and collective-op counts.  This is the
measured counterpart of bench_attention_comm's analytic model, and the
evidence for the beyond-paper claim (sqrt(P) collective phases vs P-1).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
ROOT = Path(__file__).resolve().parents[1]

_CHILD = r"""
import json, sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as PS
from repro.apps.attention import quorum_attention_local, ring_attention_local
from repro.core.scheduler import build_causal_schedule
from repro.launch.dryrun import collective_bytes

P = 16
B, T, H, KV, hd = 1, 16*512, 8, 8, 64
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sched = build_causal_schedule(P)
valid = sched.valid.astype(np.float32)
q = jax.ShapeDtypeStruct((B, T, H, hd), jnp.bfloat16)
kv = jax.ShapeDtypeStruct((B, T, KV, hd), jnp.bfloat16)
vr = jax.ShapeDtypeStruct(valid.shape, jnp.float32)

out = {}
with mesh:
    f_q = jax.jit(jax.shard_map(
        lambda qb, kb, vb, v: quorum_attention_local(qb, kb, vb, v,
                                                     schedule=sched, axis_name="q"),
        mesh=mesh,
        in_specs=(PS(None, "q"), PS(None, "q"), PS(None, "q"), PS("q")),
        out_specs=PS(None, "q")))
    txt = f_q.lower(q, kv, kv, vr).compile().as_text()
    out["quorum"] = collective_bytes(txt)
    f_r = jax.jit(jax.shard_map(
        lambda qb, kb, vb: ring_attention_local(qb, kb, vb, axis_name="q",
                                                axis_size=P),
        mesh=mesh,
        in_specs=(PS(None, "q"),) * 3, out_specs=PS(None, "q")))
    txt = f_r.lower(q, kv, kv).compile().as_text()
    # ring permutes live inside a scan body: multiply by trip count P
    c = collective_bytes(txt)
    c = {k: (v * P if k != "count" else v) for k, v in c.items()}
    out["ring"] = c
print(json.dumps(out))
""" % (str(SRC),)


def run(csv_rows):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = f"{SRC}:{ROOT}"
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    qw = res["quorum"]["wire_total"]
    rw = res["ring"]["wire_total"]
    csv_rows.append(("attn_hlo_quorum_P16", f"{qw/1e6:.1f}",
                     f"MB_wire;ops={res['quorum']['count']};"
                     f"ring_MB={rw/1e6:.1f};ring_ops_x_trip={res['ring']['count']}x16;"
                     f"bytes_ratio={qw/max(rw,1):.2f}"))

"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3 family].

40L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=17408 vocab=151936.
long_500k: skipped (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1e6,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen3_14b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
)

"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256; RMSNorm + SwiGLU.
long_500k: skipped (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_coder_33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    rope_theta=1e5,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek_coder_33b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160,
    vocab_size=256,
)

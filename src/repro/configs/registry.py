"""Registry: arch id -> (full config, reduced smoke config), shape cells.

Every assigned architecture is selectable via ``--arch <id>`` in the
launchers.  ``shape_cells(arch)`` yields the (shape, kind) pairs that apply —
skips are per DESIGN.md section 5 (long_500k only for SSM / hybrid / SWA).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Iterable, List, Tuple

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "mamba2_130m",
    "starcoder2_3b",
    "deepseek_coder_33b",
    "qwen3_14b",
    "h2o_danube_1_8b",
    "jamba_v0_1_52b",
    "whisper_large_v3",
    "llama4_scout_17b_a16e",
    "llama4_maverick_400b_a17b",
    "qwen2_vl_72b",
    # the paper's own application "architecture" (PCIT) lives in apps/, not
    # here — it has no LM shape cells.
]

# canonical ids with dashes also accepted
_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class Shape:
    """A benchmark cell shape: run kind, sequence length, batch."""
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# archs allowed to run the sub-quadratic long-context cell
LONG_OK = {"mamba2_130m", "jamba_v0_1_52b", "h2o_danube_1_8b"}


def get_config(arch: str) -> ModelConfig:
    """The full-scale ModelConfig registered under ``arch``."""
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f".{arch}", package=__package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """The tiny smoke-test variant of ``arch`` (same topology)."""
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f".{arch}", package=__package__)
    return mod.SMOKE


def shape_cells(arch: str) -> Iterable[Shape]:
    """The benchmark shapes ``arch`` runs (long-context gated)."""
    arch = _ALIAS.get(arch, arch)
    for s in SHAPES.values():
        if s.name == "long_500k" and arch not in LONG_OK:
            continue  # pure full-attention arch: documented skip
        yield s


def all_cells() -> List[Tuple[str, Shape]]:
    """Every (arch, shape) benchmark cell in the matrix."""
    return [(a, s) for a in ARCHS for s in shape_cells(a)]

"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Superblock of 8
layers with attention at position 4 (Jamba's layout); MoE replaces the MLP on
every other layer (moe_every=2).  long_500k: RUNS (hybrid; only 4 attention
layers carry KV).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    layer_pattern=("M", "M", "M", "M", "A", "M", "M", "M"),
    moe_experts=16, moe_top_k=2, moe_every=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="jamba_v0_1_52b_smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    layer_pattern=("M", "M", "M", "M", "A", "M", "M", "M"),
    moe_experts=4, moe_top_k=2, moe_every=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
)

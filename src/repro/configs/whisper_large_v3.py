"""whisper-large-v3 [audio] — encoder-decoder backbone [arXiv:2212.04356].

32 enc + 32 dec layers, d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
The conv audio frontend is a STUB: input_specs provides precomputed frame
embeddings [B, T_enc, d].  Decoder length = seq_len // dec_ratio for train
cells; decode cells run one decoder token against a seq_len-frame cross-KV.
long_500k: skipped (quadratic encoder self-attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper_large_v3",
    family="audio",
    encdec=True,
    n_layers=32, n_enc_layers=32,
    d_model=1280,
    n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    norm="layernorm", mlp="gelu", pos="sincos",
    frontend="audio_frames",
    tie_embeddings=True,
    dec_ratio=8,
    fsdp=False,
)

SMOKE = ModelConfig(
    name="whisper_large_v3_smoke",
    family="audio",
    encdec=True,
    n_layers=2, n_enc_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128,
    vocab_size=256,
    norm="layernorm", mlp="gelu", pos="sincos",
    frontend="audio_frames",
    tie_embeddings=True,
    dec_ratio=8,
)

"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048; every layer is
MoE (16 routed experts, top-1) + an always-on shared expert.
long_500k: skipped (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4_scout_17b_a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    moe_experts=16, moe_top_k=1, moe_every=1, moe_shared=True,
    rope_theta=5e5,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="llama4_scout_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    moe_experts=4, moe_top_k=1, moe_every=1, moe_shared=True,
)

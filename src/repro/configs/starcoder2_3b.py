"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173].

30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152; layernorm + GELU MLP
(starcoder2 uses standard MLP, not gated).  long_500k: skipped (full attn).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12_288,
    vocab_size=49_152,
    norm="layernorm", mlp="gelu",
    rope_theta=1e5,
    fsdp=False,
)

SMOKE = ModelConfig(
    name="starcoder2_3b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    norm="layernorm", mlp="gelu",
)

"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  The vision
frontend is a STUB: input_specs provides precomputed patch embeddings
[B, vis_tokens, d] fused in front of the text tokens (early fusion);
M-RoPE sections (16, 24, 24) over head_dim/2 = 64.
long_500k: skipped (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    pos="mrope", mrope_sections=(16, 24, 24),
    frontend="vision_patches", vis_tokens=1024,
    rope_theta=1e6,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2_vl_72b_smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    pos="mrope", mrope_sections=(4, 2, 2),
    frontend="vision_patches", vis_tokens=8,
)

"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention
[arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
long_500k: RUNS — SWA is sub-quadratic and the decode cache is O(window).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o_danube_1_8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912,
    vocab_size=32_000,
    window=4096,
    fsdp=False,
)

SMOKE = ModelConfig(
    name="h2o_danube_1_8b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    window=16,
)

"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, shared expert
[hf:meta-llama/Llama-4-Maverick family].

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048; MoE on
alternating layers (maverick interleaves dense/MoE), 128 routed experts,
top-1 + shared expert.  ~400B total, ~17B active.
long_500k: skipped (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b_a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    moe_experts=128, moe_top_k=1, moe_every=2, moe_shared=True,
    rope_theta=5e5,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="llama4_maverick_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab_size=256,
    moe_experts=8, moe_top_k=1, moe_every=2, moe_shared=True,
)

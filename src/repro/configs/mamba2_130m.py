"""mamba2-130m [ssm] — SSD, attention-free [arXiv:2405.21060].

24L d_model=768, no attention (d_ff=0: the Mamba2 block carries the MLP role),
vocab 50280, ssm_state=128.  The paper's quorum technique does not apply to
token mixing here (DESIGN.md section 5 Arch-applicability); the arch runs
without it.  long_500k: runs (linear-time scan, O(1) decode state).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0, n_kv_heads=0, head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=("M",),
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True,
    fsdp=False,
)

SMOKE = ModelConfig(
    name="mamba2_130m_smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0, n_kv_heads=0, head_dim=16,
    d_ff=0,
    vocab_size=256,
    layer_pattern=("M",),
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    tie_embeddings=True,
)

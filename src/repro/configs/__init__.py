"""Architecture configs (one module per assigned arch) and the shape cells."""

from .registry import ARCHS, SHAPES, get_config, get_smoke_config, shape_cells  # noqa: F401

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-12
NEG_INF = -1e30
# shared "no candidate" index sentinel for every top-k path (query engine,
# fused kernel, jnp oracle) — cross-path index agreement depends on all of
# them using this exact value
IDX_SENTINEL = np.int32(np.iinfo(np.int32).max)
QUERY_METRICS = ("dot", "l2")
# relative float32-accumulation slack folded into the certified
# quantization error bound (core/quant.py; DESIGN.md section 17) — a few
# hundred ulps, orders of magnitude above what a <=2^13-term f32 dot can
# actually accumulate at the repo's block sizes, and still orders of
# magnitude below any real quantization error
FP_REL = 1e-6


def quant_eps_tile(delta_lo, delta_hi, l1_lo, l1_hi, *, dim: int,
                   metric: str = "dot") -> jax.Array:
    """Certified per-entry error bound of one quantized score tile
    (DESIGN.md section 17.2).

    For rows quantized with per-block steps ``delta`` (max per-entry
    rounding error) and f32 row L1 norms ``l1``,

      |s_q - s_f32| <= d_lo*l1_hi + d_hi*l1_lo + 3*dim*d_lo*d_hi
                       + FP_REL*(l1_lo*l1_hi + 1)

    per (row, col) entry; the ``3*dim*d_lo*d_hi`` term absorbs the
    |x_hat|_1 <= |x|_1 + dim*delta slack of bounding via the quantized
    operands, and the FP_REL term covers f32 accumulation order.  L2
    scores are ``2*dot - |a|^2 - |b|^2`` with exact f32 norms carried as
    side arrays, so their bound is exactly twice the dot bound.

    delta_lo/delta_hi: scalars (or [1]); l1_lo/l1_hi: [block] f32.
    Returns the [block, block] bound, rows = lo side, cols = hi side.
    """
    delta_lo = jnp.asarray(delta_lo, jnp.float32).reshape(())
    delta_hi = jnp.asarray(delta_hi, jnp.float32).reshape(())
    eps = (delta_lo * l1_hi[None, :] + delta_hi * l1_lo[:, None]
           + 3.0 * dim * delta_lo * delta_hi
           + FP_REL * (l1_lo[:, None] * l1_hi[None, :] + 1.0))
    if metric == "l2":
        eps = 2.0 * eps
    return eps


def pairwise_corr(xs_i: jax.Array, xs_j: jax.Array) -> jax.Array:
    """Correlation tile of standardized blocks: [bm, G] x [bn, G] -> [bm, bn]."""
    return xs_i @ xs_j.T


def pcit_filter(r_xy, rows_x, rows_y, gx, gy) -> jax.Array:
    """PCIT keep-mask oracle — mirrors apps.pcit.pcit_tile."""
    rxz = rows_x[:, None, :]
    ryz = rows_y[None, :, :]
    rxy = r_xy[:, :, None]
    den_z = jnp.sqrt(jnp.maximum((1 - rxz ** 2) * (1 - ryz ** 2), EPS))
    rxy_z = (rxy - rxz * ryz) / den_z
    den_y = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - ryz ** 2), EPS))
    rxz_y = (rxz - rxy * ryz) / den_y
    den_x = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - rxz ** 2), EPS))
    ryz_x = (ryz - rxy * rxz) / den_x
    eps = (rxy_z / (rxy + EPS) + rxz_y / (rxz + EPS) + ryz_x / (ryz + EPS)) / 3.0
    explained = ((jnp.abs(rxy) <= jnp.abs(eps * rxz))
                 & (jnp.abs(rxy) <= jnp.abs(eps * ryz)))
    N = rows_x.shape[-1]
    z_ids = jnp.arange(N)[None, None, :]
    explained &= (z_ids != gx[:, None, None]) & (z_ids != gy[None, :, None])
    keep = ~jnp.any(explained, axis=-1)
    keep |= gx[:, None] == gy[None, :]
    return keep


def pairwise_batch_forces(quorum, lo, hi, wi, wj, *,
                          softening: float = 1e-2) -> jax.Array:
    """Batched n-body slot accumulation oracle (kernels/pairwise_batch.py).

    quorum: [k, block, 4] (x, y, z, mass); lo/hi: [n_pairs] slot ids;
    wi/wj: [n_pairs] per-side weights.  Returns [k, block, 3] float32.
    """
    def pair(bi, bj):
        pi, mi = bi[:, :3], bi[:, 3]
        pj, mj = bj[:, :3], bj[:, 3]
        d = pj[None, :, :] - pi[:, None, :]
        r2 = jnp.sum(d * d, axis=-1) + softening
        inv_r3 = jax.lax.rsqrt(r2) / r2
        w = (mi[:, None] * mj[None, :] * inv_r3)[..., None]
        f_ij = w * d
        return jnp.sum(f_ij, axis=1), -jnp.sum(f_ij, axis=0)

    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    out_i, out_j = jax.vmap(pair)(jnp.take(quorum, lo, axis=0).astype(jnp.float32),
                                  jnp.take(quorum, hi, axis=0).astype(jnp.float32))
    data = jnp.concatenate([out_i * wi[:, None, None],
                            out_j * wj[:, None, None]], axis=0)
    ids = jnp.concatenate([lo, hi])
    return jax.ops.segment_sum(data, ids, num_segments=quorum.shape[0])


def query_topk(stack, queries, mask, gidx, *, topk: int,
               metric: str = "dot"):
    """Fused query-scoring top-k oracle (kernels/query_score.py).

    stack: [k, block, d]; queries: [Q, d]; mask: [k, block] (1 = score the
    row); gidx: [k, block] int32 global row ids.  Selection is by the
    (-score, index) total order; masked rows become (NEG_INF, int32 max)
    sentinels.  Returns (values [Q, topk] f32, indices [Q, topk] i32).
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    sent = jnp.int32(IDX_SENTINEL)
    k, block, d = stack.shape
    Q = queries.shape[0]
    stack = stack.astype(jnp.float32)
    queries = queries.astype(jnp.float32)
    s = jnp.einsum("qd,sbd->qsb", queries, stack)
    if metric == "l2":
        s = (2.0 * s - jnp.sum(stack * stack, axis=-1)[None]
             - jnp.sum(queries * queries, axis=-1)[:, None, None])
    valid = jnp.asarray(mask) > 0
    s = jnp.where(valid[None], s, NEG_INF).reshape(Q, k * block)
    ids = jnp.where(valid, jnp.asarray(gidx, jnp.int32), sent)
    ids = jnp.broadcast_to(ids.reshape(-1)[None], (Q, k * block))
    n = k * block
    if n < topk:
        s = jnp.pad(s, ((0, 0), (0, topk - n)), constant_values=NEG_INF)
        ids = jnp.pad(ids, ((0, 0), (0, topk - n)), constant_values=sent)
    sv, si = jax.lax.sort((-s, ids), num_keys=2)
    return -sv[:, :topk], si[:, :topk]


def pairwise_threshold(quorum, lo, hi, meta, *, threshold: float,
                       capacity: int, block_rows: int, metric: str = "dot"):
    """Thresholded sparse-join compaction oracle
    (kernels/pairwise_threshold.py; DESIGN.md section 11).

    quorum: [k, block, d]; lo/hi: [n_pairs] slot ids; meta: [n_pairs, 6]
    int32 rows ``(active, is_self, ga, gb, nv_lo, nv_hi)`` — tile skip
    flag (prefilter x dedup mask), self-pair flag, the two global block
    ids, and the two valid-row counts.  Emits each passing entry's
    ``(score, min_gid, max_gid)`` with ``gid = g * block_rows + row``,
    compacted in (pair-major, row-major) order into [capacity] buffers;
    entries past capacity are dropped while the returned count keeps the
    true total (the overflow contract).  Returns
    ``(vals f32 [capacity], i i32 [capacity], j i32 [capacity],
    count i32 [])``; unused slots are (NEG_INF, IDX_SENTINEL).
    """
    if metric not in ("dot", "l2"):
        raise ValueError(f"metric must be one of ('dot', 'l2'), "
                         f"got {metric!r}")
    quorum = quorum.astype(jnp.float32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    meta = jnp.asarray(meta, jnp.int32)
    lhs = jnp.take(quorum, lo, axis=0)          # [n_pairs, block, d]
    rhs = jnp.take(quorum, hi, axis=0)
    dots = jnp.einsum("pbd,pcd->pbc", lhs, rhs)
    if metric == "l2":
        scores = (2.0 * dots
                  - jnp.sum(rhs * rhs, axis=-1)[:, None, :]
                  - jnp.sum(lhs * lhs, axis=-1)[:, :, None])
    else:
        scores = dots
    active, is_self, ga, gb, nv_lo, nv_hi = (meta[:, c] for c in range(6))
    r = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    s = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
    keep = (scores >= threshold) & (active == 1)[:, None, None]
    keep &= (r < nv_lo[:, None, None]) & (s < nv_hi[:, None, None])
    keep &= jnp.where((is_self == 1)[:, None, None], r < s, True)
    gi = ga[:, None, None] * block_rows + r
    gj = gb[:, None, None] * block_rows + s
    ei = jnp.minimum(gi, gj).reshape(-1)
    ej = jnp.maximum(gi, gj).reshape(-1)
    keep = keep.reshape(-1)
    vals = scores.reshape(-1).astype(jnp.float32)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    pos = jnp.where(keep, pos, capacity)
    count = jnp.sum(keep.astype(jnp.int32))
    vbuf = jnp.full((capacity,), NEG_INF, jnp.float32
                    ).at[pos].set(vals, mode="drop")
    ibuf = jnp.full((capacity,), jnp.int32(IDX_SENTINEL)
                    ).at[pos].set(ei, mode="drop")
    jbuf = jnp.full((capacity,), jnp.int32(IDX_SENTINEL)
                    ).at[pos].set(ej, mode="drop")
    used = jnp.arange(capacity) < count
    return (jnp.where(used, vbuf, NEG_INF),
            jnp.where(used, ibuf, jnp.int32(IDX_SENTINEL)),
            jnp.where(used, jbuf, jnp.int32(IDX_SENTINEL)),
            count)


def pairwise_threshold_q(q, scale, delta, l1, sq, lo, hi, meta, *,
                         threshold: float, capacity: int, block_rows: int,
                         metric: str = "dot"):
    """Quantized sparse-join compaction oracle with the widened keep band
    (kernels/pairwise_batch_q.py; DESIGN.md section 17.3).

    q: [k, block, d] int8 or bf16 quantized blocks; scale/delta: [k] (or
    [k, 1]) f32 per-block dequant scale and rounding step; l1/sq: [k,
    block] f32 row L1 norms and exact squared L2 norms of the *original*
    f32 rows; lo/hi/meta as in :func:`pairwise_threshold`.  Scores are
    the dequantized ``(qi_f32 @ qj_f32.T) * (s_lo * s_hi)`` (l2: ``(2 s -
    sq_hi) - sq_lo`` with the exact norms), and an entry is emitted when
    ``s_q >= threshold - eps`` with eps from :func:`quant_eps_tile` — the
    sound over-approximation the host-side exact rescoring pass then
    resolves.  Buffer layout, compaction order, overflow contract, and
    sentinels match :func:`pairwise_threshold` exactly.
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    qf = jnp.asarray(q).astype(jnp.float32)
    d = qf.shape[-1]
    scale = jnp.asarray(scale, jnp.float32).reshape(-1)
    delta = jnp.asarray(delta, jnp.float32).reshape(-1)
    l1 = jnp.asarray(l1, jnp.float32)
    sq = jnp.asarray(sq, jnp.float32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    meta = jnp.asarray(meta, jnp.int32)
    lhs = jnp.take(qf, lo, axis=0)              # [n_pairs, block, d]
    rhs = jnp.take(qf, hi, axis=0)
    s_lo = jnp.take(scale, lo)                  # [n_pairs]
    s_hi = jnp.take(scale, hi)
    dots = jnp.einsum("pbd,pcd->pbc", lhs, rhs) * (s_lo * s_hi)[:, None, None]
    if metric == "l2":
        scores = (2.0 * dots
                  - jnp.take(sq, hi, axis=0)[:, None, :]) \
            - jnp.take(sq, lo, axis=0)[:, :, None]
    else:
        scores = dots
    d_lo = jnp.take(delta, lo)[:, None, None]
    d_hi = jnp.take(delta, hi)[:, None, None]
    l1_lo = jnp.take(l1, lo, axis=0)[:, :, None]
    l1_hi = jnp.take(l1, hi, axis=0)[:, None, :]
    eps = (d_lo * l1_hi + d_hi * l1_lo + 3.0 * d * d_lo * d_hi
           + FP_REL * (l1_lo * l1_hi + 1.0))
    if metric == "l2":
        eps = 2.0 * eps
    active, is_self, ga, gb, nv_lo, nv_hi = (meta[:, c] for c in range(6))
    r = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    s = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
    keep = (scores >= threshold - eps) & (active == 1)[:, None, None]
    keep &= (r < nv_lo[:, None, None]) & (s < nv_hi[:, None, None])
    keep &= jnp.where((is_self == 1)[:, None, None], r < s, True)
    gi = ga[:, None, None] * block_rows + r
    gj = gb[:, None, None] * block_rows + s
    ei = jnp.minimum(gi, gj).reshape(-1)
    ej = jnp.maximum(gi, gj).reshape(-1)
    keep = keep.reshape(-1)
    vals = scores.reshape(-1).astype(jnp.float32)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    pos = jnp.where(keep, pos, capacity)
    count = jnp.sum(keep.astype(jnp.int32))
    vbuf = jnp.full((capacity,), NEG_INF, jnp.float32
                    ).at[pos].set(vals, mode="drop")
    ibuf = jnp.full((capacity,), jnp.int32(IDX_SENTINEL)
                    ).at[pos].set(ei, mode="drop")
    jbuf = jnp.full((capacity,), jnp.int32(IDX_SENTINEL)
                    ).at[pos].set(ej, mode="drop")
    used = jnp.arange(capacity) < count
    return (jnp.where(used, vbuf, NEG_INF),
            jnp.where(used, ibuf, jnp.int32(IDX_SENTINEL)),
            jnp.where(used, jbuf, jnp.int32(IDX_SENTINEL)),
            count)


def pairwise_topk_q(q, scale, sq, lo, hi, meta, *, topk: int,
                    block_rows: int, metric: str = "dot"):
    """Quantized per-slot batch top-k oracle
    (kernels/pairwise_batch_q.py; DESIGN.md section 17.3).

    q: [k, block, d] int8 or bf16 quantized blocks; scale: [k] (or
    [k, 1]) f32 dequant scales; sq: [k, block] exact f32 squared row
    norms (l2 only); lo/hi/meta as in :func:`pairwise_topk`.  Tiles are
    the dequantized ``(qi_f32 @ qj_f32.T) * (s_lo * s_hi)`` with the l2
    orientation formulas substituting the exact norms; the merge order,
    sentinels, and output layout match :func:`pairwise_topk` exactly.
    No error band is applied here — the caller certifies and rescores
    the quantized lists host-side (core/quant.py).
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    qf = jnp.asarray(q).astype(jnp.float32)
    k, block, d = qf.shape
    scale = jnp.asarray(scale, jnp.float32).reshape(-1)
    sq = jnp.asarray(sq, jnp.float32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    meta = jnp.asarray(meta, jnp.int32)
    sent = jnp.int32(IDX_SENTINEL)

    def merge(cv, ci, sv, si):
        v = jnp.concatenate([cv, sv], axis=-1)
        i = jnp.concatenate([ci, si], axis=-1)
        nv, ni = jax.lax.sort((-v, i), num_keys=2)
        return -nv[..., :topk], ni[..., :topk]

    def body(carry, inp):
        vals, idx = carry
        lo_p, hi_p, m = inp
        active, is_self, ga, gb, nv_lo, nv_hi = (m[c] for c in range(6))
        bi = jnp.take(qf, lo_p, axis=0)
        bj = jnp.take(qf, hi_p, axis=0)
        dots = (bi @ bj.T) * (scale[lo_p] * scale[hi_p])  # [block, block]
        if metric == "l2":
            bin2 = jnp.take(sq, lo_p, axis=0)
            bjn2 = jnp.take(sq, hi_p, axis=0)
            t_lo = (2.0 * dots - bjn2[None, :]) - bin2[:, None]
            t_hi = (2.0 * dots - bin2[:, None]) - bjn2[None, :]
        else:
            t_lo = t_hi = dots
        r = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        keep = ((active == 1) & (s < nv_hi)
                & jnp.where(is_self == 1, r != s, True))
        cv = jnp.where(keep, t_lo, NEG_INF)
        ci = jnp.where(keep, gb * block_rows + s, sent)
        mv, mi = merge(jnp.take(vals, lo_p, axis=0),
                       jnp.take(idx, lo_p, axis=0), cv, ci)
        vals = vals.at[lo_p].set(mv)
        idx = idx.at[lo_p].set(mi)
        keep_t = ((active == 1) & (is_self == 0) & (r < nv_lo)).T
        cv_t = jnp.where(keep_t, t_hi.T, NEG_INF)
        ci_t = jnp.where(keep_t, (ga * block_rows + r).T, sent)
        mv2, mi2 = merge(jnp.take(vals, hi_p, axis=0),
                         jnp.take(idx, hi_p, axis=0), cv_t, ci_t)
        vals = vals.at[hi_p].set(mv2)
        idx = idx.at[hi_p].set(mi2)
        return (vals, idx), None

    init = (jnp.full((k, block, topk), NEG_INF, jnp.float32),
            jnp.full((k, block, topk), sent, jnp.int32))
    (vals, idx), _ = jax.lax.scan(body, init, (lo, hi, meta))
    return vals, idx


def pairwise_topk(quorum, lo, hi, meta, *, topk: int, block_rows: int,
                  metric: str = "dot"):
    """Per-slot batch top-k accumulation oracle (kernels/pairwise_topk.py;
    DESIGN.md section 12.3 — the k-NN graph workload's batched step).

    quorum: [k, block, d]; lo/hi: [n_pairs] slot ids; meta: [n_pairs, 6]
    int32 rows ``(active, is_self, ga, gb, nv_lo, nv_hi)`` — the item
    mask (ownership dedup), self-pair flag, the two global block ids,
    and the two valid-row counts.  For each scheduled tile the rows of
    the ``lo`` block receive the ``hi`` block's valid rows as neighbor
    candidates (and vice versa for non-self tiles; self tiles exclude
    the diagonal and contribute one side only), folded into per-slot
    running [k, block, topk] (value, index) lists under the (-score,
    index) total order.  The two orientations of an L2 tile use the
    orientation-consistent subtraction order ``(2 d - |cand|^2) -
    |row|^2`` so both match the host oracle's matrix bitwise.  Masked
    candidates are (NEG_INF, IDX_SENTINEL) sentinels.  Returns
    ``(vals f32 [k, block, topk], idx i32 [k, block, topk])``; rows
    beyond a block's valid count carry unspecified (sentinel-merged)
    lists — callers slice them off.
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    quorum = quorum.astype(jnp.float32)
    k, block, d = quorum.shape
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    meta = jnp.asarray(meta, jnp.int32)
    sent = jnp.int32(IDX_SENTINEL)

    def merge(cv, ci, sv, si):
        v = jnp.concatenate([cv, sv], axis=-1)
        i = jnp.concatenate([ci, si], axis=-1)
        nv, ni = jax.lax.sort((-v, i), num_keys=2)
        return -nv[..., :topk], ni[..., :topk]

    def body(carry, inp):
        vals, idx = carry
        lo_p, hi_p, m = inp
        active, is_self, ga, gb, nv_lo, nv_hi = (m[c] for c in range(6))
        bi = jnp.take(quorum, lo_p, axis=0)
        bj = jnp.take(quorum, hi_p, axis=0)
        dots = bi @ bj.T                                  # [block, block]
        if metric == "l2":
            bin2 = jnp.sum(bi * bi, axis=-1)
            bjn2 = jnp.sum(bj * bj, axis=-1)
            t_lo = (2.0 * dots - bjn2[None, :]) - bin2[:, None]
            t_hi = (2.0 * dots - bin2[:, None]) - bjn2[None, :]
        else:
            t_lo = t_hi = dots
        r = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        # lo side: rows of bi receive bj's valid rows as candidates
        keep = ((active == 1) & (s < nv_hi)
                & jnp.where(is_self == 1, r != s, True))
        cv = jnp.where(keep, t_lo, NEG_INF)
        ci = jnp.where(keep, gb * block_rows + s, sent)
        mv, mi = merge(jnp.take(vals, lo_p, axis=0),
                       jnp.take(idx, lo_p, axis=0), cv, ci)
        vals = vals.at[lo_p].set(mv)
        idx = idx.at[lo_p].set(mi)
        # hi side (transposed orientation; self tiles contribute once)
        keep_t = ((active == 1) & (is_self == 0) & (r < nv_lo)).T
        cv_t = jnp.where(keep_t, t_hi.T, NEG_INF)
        ci_t = jnp.where(keep_t, (ga * block_rows + r).T, sent)
        mv2, mi2 = merge(jnp.take(vals, hi_p, axis=0),
                         jnp.take(idx, hi_p, axis=0), cv_t, ci_t)
        vals = vals.at[hi_p].set(mv2)
        idx = idx.at[hi_p].set(mi2)
        return (vals, idx), None

    init = (jnp.full((k, block, topk), NEG_INF, jnp.float32),
            jnp.full((k, block, topk), sent, jnp.int32))
    (vals, idx), _ = jax.lax.scan(body, init, (lo, hi, meta))
    return vals, idx


def flash_attention(q, k, v, *, causal: bool) -> jax.Array:
    """Plain attention oracle: q [B, Tq, H, hd], k/v [B, Tk, KV, hd]."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Tq, KV, H // KV, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32) / math.sqrt(hd),
                   k.astype(jnp.float32))
    if causal:
        Tk = k.shape[1]
        msk = np.tril(np.ones((Tq, Tk), np.bool_), k=Tk - Tq)
        s = jnp.where(msk, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def ssd_chunk(x, dt, A, Bm, Cm) -> jax.Array:
    """Sequential (non-chunked) SSD oracle.

    x: [B, T, H, P]; dt: [B, T, H]; A: [H]; Bm/Cm: [B, T, N].
    Returns y [B, T, H, P] (fp32).
    """
    Bb, T, H, Pd = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt * A)                              # [B, H]
        h = a[:, :, None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bb, H, N, Pd), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)                         # [B, T, H, P]

"""Pallas TPU kernel: flash attention (online softmax, no T^2 HBM traffic).

Used as the inner block-pair computation of quorum attention and as the
training attention hot spot.  Layout: heads are flattened into the leading
grid dimension ([BH, T, hd]); the q-tile (m, l, acc) running state lives in
VMEM scratch across the sequential kv-tile grid dimension.

Tiles (v5e): BQ = BK = 512, hd <= 256 -> q/k/v tiles 3 * 512 * hd * 4B plus
acc (512, hd) fp32: ~2-3 MB VMEM; matmul dims multiples of 128.

Causality is handled at block granularity: fully-masked kv tiles are
skipped (mask_value write only), the diagonal tile applies the triangular
mask, fully-visible tiles skip masking entirely.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, bq: int, bk: int, causal: bool, offset: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    def compute(masked: bool):
        q = q_ref[0].astype(jnp.float32) * scale            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if masked:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        c = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * c + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * c[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # block classification: beyond-diagonal blocks contribute nothing
        first_q = qi * bq + offset
        last_q = first_q + bq - 1
        first_k = ki * bk

        @pl.when(first_k <= last_q)
        def _():
            # diagonal-crossing block -> masked path; else unmasked
            @pl.when(first_k + bk - 1 > first_q)
            def _m():
                compute(masked=True)

            @pl.when(first_k + bk - 1 <= first_q)
            def _u():
                compute(masked=False)
    else:
        compute(masked=False)

    @pl.when(ki == n_k - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False):
    """q: [BH, Tq, hd]; k/v: [BH, Tk, hd] (heads pre-flattened; GQA k/v
    pre-broadcast — see ops.flash_attention for the 4-d entry point).

    causal masking aligns the ends: query i attends keys <= i + (Tk - Tq).
    """
    BH, Tq, hd = q.shape
    Tk = k.shape[1]
    bq, bk = min(bq, Tq), min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk, bq, bk)
    offset = Tk - Tq

    return pl.pallas_call(
        functools.partial(_flash_kernel, n_k=Tk // bk, bq=bq, bk=bk,
                          causal=causal, offset=offset,
                          scale=1.0 / math.sqrt(hd)),
        grid=(BH, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

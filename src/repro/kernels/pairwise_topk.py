"""Pallas TPU kernel: fused pair scoring + per-row running top-k.

The k-NN graph engine's batched inner step (core/knn.py, DESIGN.md
section 12.3) materializes an [n_pairs, block, block] score tensor and
sorts every block row's candidate list.  This kernel fuses the whole
step, one grid step per scheduled slot pair:

  * slot gather — the scalar-prefetched pair slot ids index the quorum
    operand directly in the BlockSpec index maps (the pairwise_batch /
    pairwise_threshold pattern), so each grid step DMAs only its two
    [block, d] corpus blocks,
  * tile scoring — the [block, block] dot (or L2) tile lives only in
    VMEM; the two tile orientations use the orientation-consistent
    subtraction order of ref.pairwise_topk so both sides of a pair see
    bit-identical scores to the jnp oracle,
  * running top-k — a [k*block, topk] (value, index) accumulator pair in
    VMEM holds every slot row's running neighbor list; the tile's two
    candidate planes are merged into the ``lo`` and ``hi`` slot row
    ranges (dynamic-sliced by the prefetched slot ids) with ``topk``
    rounds of extract-the-maximum under the (-score, index) total order
    — bit-identical to the two-key-sort selection of the oracle.

Masked tiles (the ownership dedup mask rides in ``meta[:, 0]``) skip
their whole body with ``pl.when``; self tiles contribute one side with
the diagonal excluded; candidate columns beyond a block's valid-row
count become (NEG_INF, IDX_SENTINEL) sentinels.

Layout notes (v5e): ``block`` should be a multiple of 8 sublanes (the
ops.py wrapper zero-pads rows; padded rows are rejected by the valid-row
bounds so padding is exact) and ``topk`` ideally of the 128-lane tile;
the extract-max merge is O(block * topk * (topk + block)) VPU work per
side, far below the tile's O(block^2 * d) MXU work for topk << block.
Interpret mode on CPU mirrors kernels/ops.py conventions and is swept in
tests/test_kernels.py against ref.pairwise_topk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import IDX_SENTINEL as _IDX_SENTINEL
from .ref import NEG_INF, QUERY_METRICS

IDX_SENTINEL = int(_IDX_SENTINEL)


def _merge_rows(vacc_ref, iacc_ref, row0, block: int, topk: int,
                cand_v, cand_i):
    """Merge [block, c] candidates into acc rows [row0 : row0+block] with
    topk rounds of extract-max under the (-score, index) order."""
    cv = jnp.concatenate([vacc_ref[pl.ds(row0, block), :], cand_v], axis=1)
    ci = jnp.concatenate([iacc_ref[pl.ds(row0, block), :], cand_i], axis=1)
    out_v, out_i = [], []
    for _ in range(topk):
        m = jnp.max(cv, axis=1)                              # [block]
        tie = cv == m[:, None]
        sel = jnp.min(jnp.where(tie, ci, IDX_SENTINEL), axis=1)
        out_v.append(m)
        out_i.append(sel)
        hit = tie & (ci == sel[:, None])
        cv = jnp.where(hit, NEG_INF, cv)
        ci = jnp.where(hit, IDX_SENTINEL, ci)
    vacc_ref[pl.ds(row0, block), :] = jnp.stack(out_v, axis=1)
    iacc_ref[pl.ds(row0, block), :] = jnp.stack(out_i, axis=1)


def _pairwise_topk_kernel(lo_ref, hi_ref, meta_ref, x_lo_ref, x_hi_ref,
                          ov_ref, oi_ref, vacc_ref, iacc_ref, *,
                          n_pairs: int, block_rows: int, topk: int,
                          metric: str):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        vacc_ref[...] = jnp.full_like(vacc_ref, NEG_INF)
        iacc_ref[...] = jnp.full_like(iacc_ref, IDX_SENTINEL)

    @pl.when(meta_ref[p, 0] == 1)
    def _tile():
        bi = x_lo_ref[0]                                  # [block, d]
        bj = x_hi_ref[0]
        blk = bi.shape[0]
        dots = jnp.dot(bi, bj.T, preferred_element_type=jnp.float32)
        if metric == "l2":  # orientation-consistent order: oracle parity
            bin2 = jnp.sum(bi * bi, axis=-1)
            bjn2 = jnp.sum(bj * bj, axis=-1)
            t_lo = (2.0 * dots - bjn2[None, :]) - bin2[:, None]
            t_hi = (2.0 * dots - bin2[:, None]) - bjn2[None, :]
        else:
            t_lo = t_hi = dots
        is_self = meta_ref[p, 1]
        r = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        # lo side: rows of bi receive bj's valid rows as candidates
        keep = (s < meta_ref[p, 5]) & jnp.where(is_self == 1, r != s, True)
        cand_v = jnp.where(keep, t_lo, NEG_INF)
        cand_i = jnp.where(keep, meta_ref[p, 3] * block_rows + s,
                           IDX_SENTINEL)
        _merge_rows(vacc_ref, iacc_ref, lo_ref[p] * blk, blk, topk,
                    cand_v, cand_i)

        # hi side (transposed orientation; self tiles contribute once)
        @pl.when(is_self == 0)
        def _hi_side():
            keep_t = (r < meta_ref[p, 4]).T
            cv_t = jnp.where(keep_t, t_hi.T, NEG_INF)
            ci_t = jnp.where(keep_t,
                             (meta_ref[p, 2] * block_rows + r).T,
                             IDX_SENTINEL)
            _merge_rows(vacc_ref, iacc_ref, hi_ref[p] * blk, blk, topk,
                        cv_t, ci_t)

    @pl.when(p == n_pairs - 1)
    def _done():
        ov_ref[...] = vacc_ref[...]
        oi_ref[...] = iacc_ref[...]


def pairwise_topk_pallas(quorum: jax.Array, lo: jax.Array, hi: jax.Array,
                         meta: jax.Array, *, topk: int, block_rows: int,
                         metric: str = "dot", interpret: bool = False):
    """quorum: [k, block, d] corpus blocks; lo/hi: [n_pairs] int32 slot
    ids; meta: [n_pairs, 6] int32 ``(active, is_self, ga, gb, nv_lo,
    nv_hi)`` (see ref.pairwise_topk, the bit-parity oracle).
    ``block_rows`` is the unpadded global block stride for row-id math
    (``block`` may be sublane-padded above it).  Returns the per-slot
    running top-k after all tiles: ``(vals f32 [k, block, topk],
    idx i32 [k, block, topk])``.
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    k, block, d = quorum.shape
    n_pairs = lo.shape[0]
    assert hi.shape == (n_pairs,) and meta.shape == (n_pairs, 6), \
        (hi.shape, meta.shape)
    assert block >= block_rows, (block, block_rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # lo, hi, meta drive the tiles
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (lo[p], 0, 0)),
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (hi[p], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k * block, topk), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((k * block, topk), lambda p, lo, hi, meta: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((k * block, topk), jnp.float32),
                        pltpu.VMEM((k * block, topk), jnp.int32)],
    )
    vals, idx = pl.pallas_call(
        functools.partial(_pairwise_topk_kernel, n_pairs=n_pairs,
                          block_rows=block_rows, topk=topk, metric=metric),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((k * block, topk), jnp.float32),
                   jax.ShapeDtypeStruct((k * block, topk), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
      jnp.asarray(meta, jnp.int32), quorum.astype(jnp.float32),
      quorum.astype(jnp.float32))
    return vals.reshape(k, block, topk), idx.reshape(k, block, topk)

"""Pallas TPU kernel: correlation tile of standardized gene blocks.

The PCIT phase-2 hot spot ([6] optimized this loop for Xeon-Phi; on TPU it is
an MXU matmul).  C[bm, bn] = Xs_i [bm, G] @ Xs_j [bn, G]^T, tiled so each
(BM, BK) x (BN, BK) working set sits in VMEM and the contraction accumulates
in a float32 VMEM scratch across the k-grid dimension.

Tile choice (v5e): BM = BN = 256, BK = 512 -> VMEM use
(256*512 + 256*512 + 256*256) * 4B ~= 1.3 MB of ~16 MB/core, and all matmul
dims are multiples of the 128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _corr_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pairwise_corr_pallas(xs_i: jax.Array, xs_j: jax.Array, *,
                         bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                         bk: int = DEFAULT_BK, interpret: bool = False):
    """xs_i: [M, G], xs_j: [N, G] standardized rows -> corr tile [M, N]."""
    M, G = xs_i.shape
    N, G2 = xs_j.shape
    assert G == G2, (G, G2)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, G)
    assert M % bm == 0 and N % bn == 0 and G % bk == 0, (M, N, G, bm, bn, bk)
    n_k = G // bk

    return pl.pallas_call(
        functools.partial(_corr_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xs_i, xs_j)

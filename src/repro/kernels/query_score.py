"""Pallas TPU kernel: fused query scoring + dedup mask + running top-k.

The serving engine's batched local step (serving/engine.py) materializes a
[Q, k, block] score tensor, masks it, and sorts k*block candidates per
query.  This kernel fuses the whole step, one grid step per quorum slot:

  * slot gather — the BlockSpec index map DMAs exactly slot s's
    [block, d] corpus block (the quorum stack never round-trips through a
    gathered [Q, k, block] HBM intermediate),
  * scoring — the [Q, block] dot (or L2) tile lives only in VMEM,
  * dedup mask — cover mask and row validity fold in as a NEG_INF select,
  * running top-k — a [Q, topk] (value, index) accumulator pair in VMEM
    is merged with each slot's scores by ``topk`` rounds of
    extract-the-maximum; outputs are written once at the final step.

Selection follows the engine's total order (-score, global index): among
equal scores the smallest corpus index wins, so results are bit-identical
to the two-key-sort jnp path (kernels/ref.py `query_topk`) and the
brute-force oracle.

Layout notes (v5e): `Q` should be a multiple of 8 sublanes (the ops.py
wrapper pads query rows — exact: padded rows are dropped after the call)
and `block` of the 128-lane tile for peak VPU efficiency; the extract-max
merge is O(topk * (topk + block)) VPU work per slot, which stays far below
the dot's O(Q * block * d) MXU work for the topk << block serving regime.
Interpret mode on CPU mirrors kernels/ops.py conventions and is swept in
tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import IDX_SENTINEL as _IDX_SENTINEL
from .ref import NEG_INF, QUERY_METRICS

IDX_SENTINEL = int(_IDX_SENTINEL)


def _query_topk_kernel(x_ref, q_ref, m_ref, g_ref, ov_ref, oi_ref,
                       vacc_ref, iacc_ref, *, k: int, topk: int, metric: str):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        vacc_ref[...] = jnp.full_like(vacc_ref, NEG_INF)
        iacc_ref[...] = jnp.full_like(iacc_ref, IDX_SENTINEL)

    x = x_ref[0]                                         # [block, d]
    q = q_ref[...]                                       # [Q, d]
    dot = jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    if metric == "l2":  # same formula as the engine/oracle: bit-parity
        scores = (2.0 * dot - jnp.sum(x * x, axis=-1)[None, :]
                  - jnp.sum(q * q, axis=-1)[:, None])
    else:
        scores = dot
    valid = m_ref[0] > 0                                 # [block]
    scores = jnp.where(valid[None, :], scores, NEG_INF)  # [Q, block]
    gids = jnp.where(valid, g_ref[0], IDX_SENTINEL)      # [block]

    cv = jnp.concatenate([vacc_ref[...], scores], axis=1)    # [Q, topk+block]
    ci = jnp.concatenate(
        [iacc_ref[...], jnp.broadcast_to(gids[None], scores.shape)], axis=1)
    out_v, out_i = [], []
    for _ in range(topk):  # extract-max under the (-score, index) order
        m = jnp.max(cv, axis=1)                              # [Q]
        tie = cv == m[:, None]
        sel = jnp.min(jnp.where(tie, ci, IDX_SENTINEL), axis=1)
        out_v.append(m)
        out_i.append(sel)
        hit = tie & (ci == sel[:, None])
        cv = jnp.where(hit, NEG_INF, cv)
        ci = jnp.where(hit, IDX_SENTINEL, ci)
    vacc_ref[...] = jnp.stack(out_v, axis=1)
    iacc_ref[...] = jnp.stack(out_i, axis=1)

    @pl.when(s == k - 1)
    def _done():
        ov_ref[...] = vacc_ref[...]
        oi_ref[...] = iacc_ref[...]


def query_topk_pallas(stack: jax.Array, queries: jax.Array, mask: jax.Array,
                      gidx: jax.Array, *, topk: int, metric: str = "dot",
                      interpret: bool = False):
    """stack: [k, block, d] quorum blocks; queries: [Q, d]; mask: [k, block]
    float32 (1 = this device scores the row: cover dedup x validity);
    gidx: [k, block] int32 global corpus row ids.  Returns the running
    top-k after all k slots: (values [Q, topk] f32, indices [Q, topk] i32).
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    k, block, d = stack.shape
    Q = queries.shape[0]
    assert queries.shape == (Q, d), (queries.shape, stack.shape)
    assert mask.shape == (k, block) and gidx.shape == (k, block), \
        (mask.shape, gidx.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda s: (s, 0, 0)),
            pl.BlockSpec((Q, d), lambda s: (0, 0)),
            pl.BlockSpec((1, block), lambda s: (s, 0)),
            pl.BlockSpec((1, block), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Q, topk), lambda s: (0, 0)),
            pl.BlockSpec((Q, topk), lambda s: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((Q, topk), jnp.float32),
                        pltpu.VMEM((Q, topk), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_query_topk_kernel, k=k, topk=topk, metric=metric),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q, topk), jnp.float32),
                   jax.ShapeDtypeStruct((Q, topk), jnp.int32)],
        interpret=interpret,
    )(stack.astype(jnp.float32), queries.astype(jnp.float32),
      jnp.asarray(mask, jnp.float32), jnp.asarray(gidx, jnp.int32))

"""Pallas TPU kernels: quantized pair scoring (int8 / bf16 operands,
f32 accumulation, dequant epilogue).

Quantized variants of the sparse-join and k-NN batched inner steps
(kernels/pairwise_threshold.py, kernels/pairwise_topk.py; DESIGN.md
section 17.3).  The corpus operand stays in its quantized storage dtype
(int8 or bfloat16) all the way into VMEM — the 4x / 2x byte saving is
the point — and is cast to f32 only inside the tile body, where the MXU
accumulates in f32 and a scalar dequant epilogue (``* s_lo * s_hi``)
restores score scale.  int8 products are exactly representable in f32,
so the dequantized tile matches the jnp oracle bitwise in interpret
mode.

Differences from the f32 kernels, per tile:

  * the per-block (scale, delta) pairs ride as one [k, 2] f32 SMEM
    operand indexed by the prefetched slot ids — scalars, not tiles,
  * L2 scores substitute the *exact* f32 squared row norms carried as
    [k, block] side arrays (``(2 s - sq_hi) - sq_lo``), so the L2 error
    bound stays exactly twice the dot bound,
  * the threshold kernel widens its keep test to ``s_q >= thr - eps``
    with the in-tile certified bound of ref.quant_eps_tile (built from
    the delta scalars and the [k, block] L1-norm side arrays) — every
    possible true hit is emitted and the host's exact f32 rescoring
    pass (core/quant.py) resolves the borderline band,
  * the top-k kernel applies no band (candidate lists are certified and
    rescored host-side), it only swaps the scoring arithmetic.

Compaction, running top-k merge, sentinels, overflow contract, and
layout notes are identical to the f32 kernels.  Interpret mode on CPU
mirrors kernels/ops.py conventions and is swept in tests/test_quant.py
against ref.pairwise_threshold_q / ref.pairwise_topk_q.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pairwise_topk import _merge_rows
from .ref import IDX_SENTINEL as _IDX_SENTINEL
from .ref import FP_REL, NEG_INF, QUERY_METRICS

IDX_SENTINEL = int(_IDX_SENTINEL)


def _eps_tile(d_lo, d_hi, l1_lo, l1_hi, *, dim: int, metric: str):
    # expression order matches ref.quant_eps_tile for bit parity
    eps = (d_lo * l1_hi[None, :] + d_hi * l1_lo[:, None]
           + 3.0 * dim * d_lo * d_hi
           + FP_REL * (l1_lo[:, None] * l1_hi[None, :] + 1.0))
    if metric == "l2":
        eps = 2.0 * eps
    return eps


def _threshold_q_kernel(lo_ref, hi_ref, meta_ref, q_lo_ref, q_hi_ref,
                        sd_ref, l1_lo_ref, l1_hi_ref, sq_lo_ref, sq_hi_ref,
                        ov_ref, oi_ref, oj_ref, oc_ref,
                        vacc_ref, iacc_ref, jacc_ref, cnt_ref, *,
                        n_pairs: int, block_rows: int, capacity: int,
                        threshold: float, metric: str, dim: int):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        vacc_ref[...] = jnp.zeros_like(vacc_ref)
        iacc_ref[...] = jnp.zeros_like(iacc_ref)
        jacc_ref[...] = jnp.zeros_like(jacc_ref)
        cnt_ref[0, 0] = 0

    @pl.when(meta_ref[p, 0] == 1)
    def _tile():
        bi = q_lo_ref[0].astype(jnp.float32)              # [block, d]
        bj = q_hi_ref[0].astype(jnp.float32)
        s_lo = sd_ref[lo_ref[p], 0]
        s_hi = sd_ref[hi_ref[p], 0]
        dots = jnp.dot(bi, bj.T,
                       preferred_element_type=jnp.float32) * (s_lo * s_hi)
        if metric == "l2":  # exact norms: oracle parity + 2x-dot bound
            scores = (2.0 * dots - sq_hi_ref[0][None, :]) \
                - sq_lo_ref[0][:, None]
        else:
            scores = dots
        eps = _eps_tile(sd_ref[lo_ref[p], 1], sd_ref[hi_ref[p], 1],
                        l1_lo_ref[0], l1_hi_ref[0], dim=dim, metric=metric)
        blk = scores.shape[0]
        r = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        keep = scores >= threshold - eps
        keep &= (r < meta_ref[p, 4]) & (s < meta_ref[p, 5])
        keep &= jnp.where(meta_ref[p, 1] == 1, r < s, True)
        gi = meta_ref[p, 2] * block_rows + r
        gj = meta_ref[p, 3] * block_rows + s
        ei = jnp.minimum(gi, gj)
        ej = jnp.maximum(gi, gj)

        M = blk * blk
        keep_f = keep.reshape(M, 1)
        base = cnt_ref[0, 0]
        pos = base + jnp.cumsum(keep_f.astype(jnp.int32), axis=0) - 1
        slots = jax.lax.broadcasted_iota(jnp.int32, (M, capacity), 1)
        onehot = ((pos == slots) & keep_f).astype(jnp.float32)  # [M, cap]
        vacc_ref[...] += jnp.dot(scores.reshape(1, M), onehot,
                                 preferred_element_type=jnp.float32)
        iacc_ref[...] += jnp.dot(ei.reshape(1, M).astype(jnp.float32),
                                 onehot, preferred_element_type=jnp.float32)
        jacc_ref[...] += jnp.dot(ej.reshape(1, M).astype(jnp.float32),
                                 onehot, preferred_element_type=jnp.float32)
        cnt_ref[0, 0] = base + jnp.sum(keep_f.astype(jnp.int32))

    @pl.when(p == n_pairs - 1)
    def _done():
        total = cnt_ref[0, 0]
        used = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1) < total
        ov_ref[...] = jnp.where(used, vacc_ref[...], NEG_INF)
        oi_ref[...] = jnp.where(used, iacc_ref[...].astype(jnp.int32),
                                IDX_SENTINEL)
        oj_ref[...] = jnp.where(used, jacc_ref[...].astype(jnp.int32),
                                IDX_SENTINEL)
        oc_ref[0, 0] = total


def pairwise_threshold_q_pallas(q: jax.Array, sd: jax.Array,
                                l1: jax.Array, sq: jax.Array,
                                lo: jax.Array, hi: jax.Array,
                                meta: jax.Array, *, threshold: float,
                                capacity: int, block_rows: int,
                                metric: str = "dot",
                                interpret: bool = False):
    """q: [k, block, d] int8/bf16 quantized blocks; sd: [k, 2] f32
    per-block (scale, delta); l1/sq: [k, block] f32 row L1 norms and
    exact squared norms; lo/hi: [n_pairs] int32 slot ids; meta:
    [n_pairs, 6] int32 ``(active, is_self, ga, gb, nv_lo, nv_hi)`` (see
    ref.pairwise_threshold_q, the bit-parity oracle; DESIGN.md section
    17.3).  Emits the widened ``s_q >= threshold - eps`` band.  Returns
    ``(vals f32 [capacity], i i32 [capacity], j i32 [capacity],
    count i32 [1, 1])``.
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    k, block, d = q.shape
    n_pairs = lo.shape[0]
    assert hi.shape == (n_pairs,) and meta.shape == (n_pairs, 6), \
        (hi.shape, meta.shape)
    assert sd.shape == (k, 2) and l1.shape == (k, block) \
        and sq.shape == (k, block), (sd.shape, l1.shape, sq.shape)
    assert block >= block_rows, (block, block_rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # lo, hi, meta drive the tiles
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (lo[p], 0, 0)),
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (hi[p], 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),        # sd: [k, 2]
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (lo[p], 0)),
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (hi[p], 0)),
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (lo[p], 0)),
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (hi[p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.SMEM((1, 1), jnp.int32)],
    )
    vals, gi, gj, count = pl.pallas_call(
        functools.partial(_threshold_q_kernel, n_pairs=n_pairs,
                          block_rows=block_rows, capacity=capacity,
                          threshold=float(threshold), metric=metric,
                          dim=d),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, capacity), jnp.float32),
                   jax.ShapeDtypeStruct((1, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((1, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
      jnp.asarray(meta, jnp.int32), q, q,
      jnp.asarray(sd, jnp.float32),
      jnp.asarray(l1, jnp.float32), jnp.asarray(l1, jnp.float32),
      jnp.asarray(sq, jnp.float32), jnp.asarray(sq, jnp.float32))
    return vals[0], gi[0], gj[0], count[0, 0]


def _pairwise_topk_q_kernel(lo_ref, hi_ref, meta_ref, q_lo_ref, q_hi_ref,
                            sd_ref, sq_lo_ref, sq_hi_ref,
                            ov_ref, oi_ref, vacc_ref, iacc_ref, *,
                            n_pairs: int, block_rows: int, topk: int,
                            metric: str):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        vacc_ref[...] = jnp.full_like(vacc_ref, NEG_INF)
        iacc_ref[...] = jnp.full_like(iacc_ref, IDX_SENTINEL)

    @pl.when(meta_ref[p, 0] == 1)
    def _tile():
        bi = q_lo_ref[0].astype(jnp.float32)              # [block, d]
        bj = q_hi_ref[0].astype(jnp.float32)
        blk = bi.shape[0]
        dots = jnp.dot(bi, bj.T, preferred_element_type=jnp.float32) \
            * (sd_ref[lo_ref[p], 0] * sd_ref[hi_ref[p], 0])
        if metric == "l2":  # exact-norm orientation order: oracle parity
            bin2 = sq_lo_ref[0]
            bjn2 = sq_hi_ref[0]
            t_lo = (2.0 * dots - bjn2[None, :]) - bin2[:, None]
            t_hi = (2.0 * dots - bin2[:, None]) - bjn2[None, :]
        else:
            t_lo = t_hi = dots
        is_self = meta_ref[p, 1]
        r = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        # lo side: rows of bi receive bj's valid rows as candidates
        keep = (s < meta_ref[p, 5]) & jnp.where(is_self == 1, r != s, True)
        cand_v = jnp.where(keep, t_lo, NEG_INF)
        cand_i = jnp.where(keep, meta_ref[p, 3] * block_rows + s,
                           IDX_SENTINEL)
        _merge_rows(vacc_ref, iacc_ref, lo_ref[p] * blk, blk, topk,
                    cand_v, cand_i)

        # hi side (transposed orientation; self tiles contribute once)
        @pl.when(is_self == 0)
        def _hi_side():
            keep_t = (r < meta_ref[p, 4]).T
            cv_t = jnp.where(keep_t, t_hi.T, NEG_INF)
            ci_t = jnp.where(keep_t,
                             (meta_ref[p, 2] * block_rows + r).T,
                             IDX_SENTINEL)
            _merge_rows(vacc_ref, iacc_ref, hi_ref[p] * blk, blk, topk,
                        cv_t, ci_t)

    @pl.when(p == n_pairs - 1)
    def _done():
        ov_ref[...] = vacc_ref[...]
        oi_ref[...] = iacc_ref[...]


def pairwise_topk_q_pallas(q: jax.Array, sd: jax.Array, sq: jax.Array,
                           lo: jax.Array, hi: jax.Array, meta: jax.Array,
                           *, topk: int, block_rows: int,
                           metric: str = "dot", interpret: bool = False):
    """q: [k, block, d] int8/bf16 quantized blocks; sd: [k, 2] f32
    per-block (scale, delta) — only scale is read here; sq: [k, block]
    exact f32 squared row norms (l2); lo/hi: [n_pairs] int32 slot ids;
    meta: [n_pairs, 6] int32 ``(active, is_self, ga, gb, nv_lo, nv_hi)``
    (see ref.pairwise_topk_q, the bit-parity oracle; DESIGN.md section
    17.3).  Returns the per-slot running quantized top-k after all
    tiles: ``(vals f32 [k, block, topk], idx i32 [k, block, topk])``.
    """
    if metric not in QUERY_METRICS:
        raise ValueError(f"metric must be one of {QUERY_METRICS}, "
                         f"got {metric!r}")
    k, block, d = q.shape
    n_pairs = lo.shape[0]
    assert hi.shape == (n_pairs,) and meta.shape == (n_pairs, 6), \
        (hi.shape, meta.shape)
    assert sd.shape == (k, 2) and sq.shape == (k, block), \
        (sd.shape, sq.shape)
    assert block >= block_rows, (block, block_rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # lo, hi, meta drive the tiles
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (lo[p], 0, 0)),
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (hi[p], 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),        # sd: [k, 2]
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (lo[p], 0)),
            pl.BlockSpec((1, block), lambda p, lo, hi, meta: (hi[p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((k * block, topk), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((k * block, topk), lambda p, lo, hi, meta: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((k * block, topk), jnp.float32),
                        pltpu.VMEM((k * block, topk), jnp.int32)],
    )
    vals, idx = pl.pallas_call(
        functools.partial(_pairwise_topk_q_kernel, n_pairs=n_pairs,
                          block_rows=block_rows, topk=topk, metric=metric),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((k * block, topk), jnp.float32),
                   jax.ShapeDtypeStruct((k * block, topk), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
      jnp.asarray(meta, jnp.int32), q, q,
      jnp.asarray(sd, jnp.float32),
      jnp.asarray(sq, jnp.float32), jnp.asarray(sq, jnp.float32))
    return vals.reshape(k, block, topk), idx.reshape(k, block, topk)

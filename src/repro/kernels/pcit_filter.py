"""Pallas TPU kernel: PCIT significance filter (phase-4 hot spot).

For a pair tile (x-block, y-block) the filter reduces over ALL z genes:
  explained(x, y, z) = |r_xy| <= |eps * r_xz|  AND  |r_xy| <= |eps * r_yz|
  keep(x, y) = NOT OR_z explained(x, y, z)

The z axis is the long one (N = P * block genes), so the kernel tiles z into
BZ-wide VMEM strips and OR-accumulates into an int32 tile, visiting
(i, j, z-tile) grid cells with the z dimension innermost (sequential on TPU,
so the accumulator lives in the revisited output block).

VMEM per step: rows_x (BM, BZ) + rows_y (BN, BZ) + r_xy (BM, BN) + out
(BM, BN) in fp32/int32 — with BM = BN = 128, BZ = 512: ~0.8 MB.

The (BM, BN, BZ) broadcast intermediate stays in VREGs/VMEM as an
elementwise fused loop over the BZ lanes (no materialized cube in HBM —
exactly the restructuring [6] did for Xeon-Phi, here for the VPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BZ = 512


def _pcit_kernel(rxy_ref, rowsx_ref, rowsy_ref, gx_ref, gy_ref,
                 out_ref, *, n_z: int, bz: int):
    zi = pl.program_id(2)

    @pl.when(zi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rxy = rxy_ref[...][:, :, None].astype(jnp.float32)     # [BM, BN, 1]
    rxz = rowsx_ref[...][:, None, :].astype(jnp.float32)   # [BM, 1, BZ]
    ryz = rowsy_ref[...][None, :, :].astype(jnp.float32)   # [1, BN, BZ]

    den_z = jnp.sqrt(jnp.maximum((1 - rxz ** 2) * (1 - ryz ** 2), EPS))
    rxy_z = (rxy - rxz * ryz) / den_z
    den_y = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - ryz ** 2), EPS))
    rxz_y = (rxz - rxy * ryz) / den_y
    den_x = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - rxz ** 2), EPS))
    ryz_x = (ryz - rxy * rxz) / den_x

    eps = (rxy_z / (rxy + EPS) + rxz_y / (rxz + EPS) + ryz_x / (ryz + EPS)) / 3.0
    explained = ((jnp.abs(rxy) <= jnp.abs(eps * rxz))
                 & (jnp.abs(rxy) <= jnp.abs(eps * ryz)))

    z_ids = zi * bz + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bz), 2)
    gx = gx_ref[...][:, None, None]
    gy = gy_ref[...][None, :, None]
    explained &= (z_ids != gx) & (z_ids != gy)

    out_ref[...] |= jnp.any(explained, axis=-1).astype(jnp.int32)


def pcit_filter_pallas(r_xy, rows_x, rows_y, gx, gy, *,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       bz: int = DEFAULT_BZ, interpret: bool = False):
    """r_xy: [M, N]; rows_x: [M, Z]; rows_y: [N, Z]; gx: [M]; gy: [N] int32.

    Returns keep [M, N] bool.
    """
    M, N = r_xy.shape
    Z = rows_x.shape[1]
    bm, bn, bz = min(bm, M), min(bn, N), min(bz, Z)
    assert M % bm == 0 and N % bn == 0 and Z % bz == 0, (M, N, Z, bm, bn, bz)
    n_z = Z // bz

    explained = pl.pallas_call(
        functools.partial(_pcit_kernel, n_z=n_z, bz=bz),
        grid=(M // bm, N // bn, n_z),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, z: (i, j)),
            pl.BlockSpec((bm, bz), lambda i, j, z: (i, z)),
            pl.BlockSpec((bn, bz), lambda i, j, z: (j, z)),
            pl.BlockSpec((bm,), lambda i, j, z: (i,)),
            pl.BlockSpec((bn,), lambda i, j, z: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, z: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(r_xy, rows_x, rows_y, gx, gy)
    keep = explained == 0
    # diagonal (x == y) trivially kept
    return keep | (gx[:, None] == gy[None, :])

"""Pallas TPU kernel: fused thresholded scoring + sparse compaction.

The sparse join's batched inner step (core/sparse.py, DESIGN.md section
11) materializes an [n_pairs, block, block] score tensor, thresholds it,
and cumsum-scatters the survivors.  This kernel fuses the whole step, one
grid step per scheduled slot pair:

  * slot gather — the scalar-prefetched pair slot ids index the quorum
    operand directly in the BlockSpec index maps (exactly the
    pairwise_batch pattern), so each grid step DMAs only its two
    [block, d] corpus blocks,
  * prefilter skip — the per-pair ``active`` flag (norm-bound prefilter x
    ownership dedup mask, computed outside) gates the whole tile body
    with ``pl.when``: a pruned tile costs neither the score matmul nor
    the compaction,
  * threshold compaction — passing entries' positions come from an
    in-tile cumsum offset by a running SMEM count, and land in the
    [capacity] output through a one-hot matmul
    (``values^T @ onehot(pos)``): scatter-free, MXU-shaped, exactly the
    compaction a TPU can do fast.  Entries past ``capacity`` match no
    one-hot column and drop, while the count keeps the true total — the
    overflow contract of DESIGN.md 11.2.

Global row ids ride the one-hot matmul as exact float32 integers, which
caps ids at 2^24 (enforced by the core wrapper).  Layout notes (v5e):
``block`` should be a multiple of 8 sublanes (the ops.py wrapper
zero-pads rows; padded rows are rejected by the valid-row bounds so
padding is exact) and ``capacity`` of the 128-lane tile; the [M,
capacity] one-hot (M = block^2) is the VMEM high-water mark — a
production variant would tile the compaction over M.  Interpret mode on
CPU mirrors kernels/ops.py conventions and is swept in
tests/test_kernels.py against ref.pairwise_threshold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import IDX_SENTINEL as _IDX_SENTINEL
from .ref import NEG_INF

IDX_SENTINEL = int(_IDX_SENTINEL)


def _threshold_kernel(lo_ref, hi_ref, meta_ref, x_lo_ref, x_hi_ref,
                      ov_ref, oi_ref, oj_ref, oc_ref,
                      vacc_ref, iacc_ref, jacc_ref, cnt_ref, *,
                      n_pairs: int, block_rows: int, capacity: int,
                      threshold: float, metric: str):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        vacc_ref[...] = jnp.zeros_like(vacc_ref)
        iacc_ref[...] = jnp.zeros_like(iacc_ref)
        jacc_ref[...] = jnp.zeros_like(jacc_ref)
        cnt_ref[0, 0] = 0

    @pl.when(meta_ref[p, 0] == 1)
    def _tile():
        bi = x_lo_ref[0]                                  # [block, d]
        bj = x_hi_ref[0]
        dot = jnp.dot(bi, bj.T, preferred_element_type=jnp.float32)
        if metric == "l2":  # same formula as engine/oracle: bit parity
            scores = (2.0 * dot - jnp.sum(bj * bj, axis=-1)[None, :]
                      - jnp.sum(bi * bi, axis=-1)[:, None])
        else:
            scores = dot
        blk = scores.shape[0]
        r = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        s = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        keep = scores >= threshold
        keep &= (r < meta_ref[p, 4]) & (s < meta_ref[p, 5])
        keep &= jnp.where(meta_ref[p, 1] == 1, r < s, True)
        gi = meta_ref[p, 2] * block_rows + r
        gj = meta_ref[p, 3] * block_rows + s
        ei = jnp.minimum(gi, gj)
        ej = jnp.maximum(gi, gj)

        M = blk * blk
        keep_f = keep.reshape(M, 1)
        base = cnt_ref[0, 0]
        pos = base + jnp.cumsum(keep_f.astype(jnp.int32), axis=0) - 1
        slots = jax.lax.broadcasted_iota(jnp.int32, (M, capacity), 1)
        onehot = ((pos == slots) & keep_f).astype(jnp.float32)  # [M, cap]
        vacc_ref[...] += jnp.dot(scores.reshape(1, M), onehot,
                                 preferred_element_type=jnp.float32)
        iacc_ref[...] += jnp.dot(ei.reshape(1, M).astype(jnp.float32),
                                 onehot, preferred_element_type=jnp.float32)
        jacc_ref[...] += jnp.dot(ej.reshape(1, M).astype(jnp.float32),
                                 onehot, preferred_element_type=jnp.float32)
        cnt_ref[0, 0] = base + jnp.sum(keep_f.astype(jnp.int32))

    @pl.when(p == n_pairs - 1)
    def _done():
        total = cnt_ref[0, 0]
        used = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1) < total
        ov_ref[...] = jnp.where(used, vacc_ref[...], NEG_INF)
        oi_ref[...] = jnp.where(used, iacc_ref[...].astype(jnp.int32),
                                IDX_SENTINEL)
        oj_ref[...] = jnp.where(used, jacc_ref[...].astype(jnp.int32),
                                IDX_SENTINEL)
        oc_ref[0, 0] = total


def pairwise_threshold_pallas(quorum: jax.Array, lo: jax.Array,
                              hi: jax.Array, meta: jax.Array, *,
                              threshold: float, capacity: int,
                              block_rows: int, metric: str = "dot",
                              interpret: bool = False):
    """quorum: [k, block, d] corpus blocks; lo/hi: [n_pairs] int32 slot
    ids; meta: [n_pairs, 6] int32 ``(active, is_self, ga, gb, nv_lo,
    nv_hi)`` (see ref.pairwise_threshold, the bit-parity oracle).
    ``block_rows`` is the unpadded global block stride for row-id math
    (``block`` may be sublane-padded above it).  Returns ``(vals f32
    [capacity], i i32 [capacity], j i32 [capacity], count i32 [1, 1])``.
    """
    if metric not in ("dot", "l2"):
        raise ValueError(f"metric must be one of ('dot', 'l2'), "
                         f"got {metric!r}")
    k, block, d = quorum.shape
    n_pairs = lo.shape[0]
    assert hi.shape == (n_pairs,) and meta.shape == (n_pairs, 6), \
        (hi.shape, meta.shape)
    assert block >= block_rows, (block, block_rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # lo, hi, meta drive the tiles
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (lo[p], 0, 0)),
            pl.BlockSpec((1, block, d), lambda p, lo, hi, meta: (hi[p], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec((1, capacity), lambda p, lo, hi, meta: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.VMEM((1, capacity), jnp.float32),
                        pltpu.SMEM((1, 1), jnp.int32)],
    )
    vals, gi, gj, count = pl.pallas_call(
        functools.partial(_threshold_kernel, n_pairs=n_pairs,
                          block_rows=block_rows, capacity=capacity,
                          threshold=float(threshold), metric=metric),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, capacity), jnp.float32),
                   jax.ShapeDtypeStruct((1, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((1, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
      jnp.asarray(meta, jnp.int32), quorum.astype(jnp.float32),
      quorum.astype(jnp.float32))
    return vals[0], gi[0], gj[0], count[0, 0]

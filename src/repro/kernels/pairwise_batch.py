"""Pallas TPU kernel: fused batched pair interaction + slot segment reduction.

The batched engine mode (core.allpairs, DESIGN.md section 4) evaluates all
n_pairs quorum-block interactions as one launch.  The generic jnp path
materializes [n_pairs, block, ...] gathered operands and a [2*n_pairs,
block, ...] contribution buffer before the segment_sum; this kernel fuses
the whole step for the n-body-shaped ``pair_fn``:

  * slot gather — the scalar-prefetched pair slot ids index the quorum
    operand directly in the BlockSpec index maps, so each grid step DMAs
    exactly the two [block, 4] body blocks it interacts,
  * pair interaction — the [block, block] force tile lives only in VMEM,
  * segment reduction — both sides accumulate straight into a [k, block, 3]
    VMEM accumulator at their slot rows; the output is written once at the
    final grid step.

Layout notes (v5e): the feature dims (4-wide bodies in, 3-wide forces out)
sit far below the 128-lane tile, so on hardware this kernel is VPU/DMA-bound
rather than MXU-bound — the win over the jnp path is the removed HBM
round-trip of the [n_pairs, block, block] distance intermediates.  ``block``
should be a multiple of 8 sublanes; the ops.py wrapper pads with zero-mass
bodies (exact: zero mass contributes zero force).  Interpret mode on CPU
mirrors kernels/ops.py conventions and is what tests/test_kernels.py sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_SOFTENING = 1e-2


def _nbody_batch_kernel(lo_ref, hi_ref, x_lo_ref, x_hi_ref, w_ref, o_ref,
                        acc_ref, *, n_pairs: int, softening: float):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bi = x_lo_ref[0]                                     # [block, 4]
    bj = x_hi_ref[0]
    pi, mi = bi[:, :3], bi[:, 3]
    pj, mj = bj[:, :3], bj[:, 3]
    d = pj[None, :, :] - pi[:, None, :]                  # [block, block, 3]
    r2 = jnp.sum(d * d, axis=-1) + softening
    inv_r3 = jax.lax.rsqrt(r2) / r2
    w = (mi[:, None] * mj[None, :] * inv_r3)[..., None]
    f_ij = w * d                                         # force ON i FROM j
    f_i = jnp.sum(f_ij, axis=1)                          # [block, 3]
    f_j = -jnp.sum(f_ij, axis=0)

    lo = lo_ref[p]
    hi = hi_ref[p]
    wi = w_ref[0, 0]
    wj = w_ref[0, 1]
    cur = pl.load(acc_ref, (pl.dslice(lo, 1),))
    pl.store(acc_ref, (pl.dslice(lo, 1),), cur + wi * f_i[None])
    cur = pl.load(acc_ref, (pl.dslice(hi, 1),))
    pl.store(acc_ref, (pl.dslice(hi, 1),), cur + wj * f_j[None])

    @pl.when(p == n_pairs - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def pairwise_batch_pallas(quorum: jax.Array, lo: jax.Array, hi: jax.Array,
                          w: jax.Array, *,
                          softening: float = DEFAULT_SOFTENING,
                          interpret: bool = False) -> jax.Array:
    """quorum: [k, block, 4] body blocks (x, y, z, mass); lo/hi: [n_pairs]
    int32 slot ids; w: [n_pairs, 2] float32 (out_i, out_j) pair weights —
    wj = 0 for the self pair (count once) and masked d = P/2 orbits.
    Returns the slot-accumulated forces [k, block, 3] float32.
    """
    k, block, feat = quorum.shape
    assert feat == 4, quorum.shape
    n_pairs = lo.shape[0]
    assert hi.shape == (n_pairs,) and w.shape == (n_pairs, 2), (hi.shape, w.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # lo, hi drive the index maps
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, block, 4), lambda p, lo, hi: (lo[p], 0, 0)),
            pl.BlockSpec((1, block, 4), lambda p, lo, hi: (hi[p], 0, 0)),
            pl.BlockSpec((1, 2), lambda p, lo, hi: (p, 0)),
        ],
        out_specs=pl.BlockSpec((k, block, 3), lambda p, lo, hi: (0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((k, block, 3), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_nbody_batch_kernel, n_pairs=n_pairs,
                          softening=softening),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, block, 3), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
      quorum, quorum, jnp.asarray(w, jnp.float32))

"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (arXiv:2405.21060 alg. 1).

Computes, per (batch*head, chunk) grid cell with chunk length L in VMEM:
  cums   = cumsum(dt * A)                         [L]
  y      = ((C B^T) .* exp(cums_i - cums_j) tril .* dt_j) x      [L, P]
  S      = sum_j exp(cums_L - cums_j) dt_j B_j x_j^T             [N, P]
  cd     = exp(cums)                                             [L]
The O(1/L)-state inter-chunk recurrence (a tiny scan over nc chunks) stays
in jnp — it is bandwidth-trivial; the matmul-dense intra-chunk work is what
feeds the MXU.  Tiles: L = 256, P = 64, N = 128 -> ~0.6 MB VMEM/cell.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, s_ref, cd_ref, *, L: int):
    x = x_ref[0, 0].astype(jnp.float32)       # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [L]
    A = a_ref[0].astype(jnp.float32)          # scalar (per bh)
    Bm = b_ref[0, 0].astype(jnp.float32)      # [L, N]
    Cm = c_ref[0, 0].astype(jnp.float32)      # [L, N]

    la = dt * A                               # [L]
    cums = jnp.cumsum(la)                     # [L]

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    seg = cums[:, None] - cums[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    w = CB * decay * dt[None, :]
    y_ref[0, 0, ...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    dend = jnp.exp(cums[L - 1] - cums) * dt   # [L]
    s_ref[0, 0, ...] = jax.lax.dot_general(
        Bm * dend[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)
    cd_ref[0, 0, ...] = jnp.exp(cums).astype(cd_ref.dtype)


def ssd_chunk_pallas(x, dt, A_bh, Bm, Cm, *, interpret: bool = False):
    """x: [BH, nc, L, P]; dt: [BH, nc, L]; A_bh: [BH]; Bm/Cm: [BH, nc, L, N].

    Returns (y_intra [BH, nc, L, P] f32, S [BH, nc, N, P] f32,
             cd [BH, nc, L] f32 — per-position decay exp(cumsum)).
    """
    BH, nc, L, P = x.shape
    N = Bm.shape[-1]
    grid = (BH, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, L=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, L, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, L), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A_bh, Bm, Cm)

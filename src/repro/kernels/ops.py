"""Jit'd public entry points for the Pallas kernels.

On TPU backends the kernels run compiled; elsewhere (CPU tests, smoke) they
run in interpret mode, which executes the kernel body in Python with
identical block semantics — the per-kernel allclose sweeps in
tests/test_kernels.py validate every (shape, dtype) cell against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .pairwise_batch import pairwise_batch_pallas
from .pairwise_corr import pairwise_corr_pallas
from .pcit_filter import pcit_filter_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def pairwise_corr(xs_i, xs_j, *, bm=128, bn=128, bk=128):
    """Correlation tile [M, N] of standardized blocks [M, G] x [N, G].

    Pads every dim up to the tile multiple and slices back, so arbitrary
    shapes are accepted (padded K columns are zeros — exact for the dot).
    """
    xs_i, M = _pad_to(xs_i, bm, 0)
    xs_j, N = _pad_to(xs_j, bn, 0)
    xs_i, _ = _pad_to(xs_i, bk, 1)
    xs_j, _ = _pad_to(xs_j, bk, 1)
    out = pairwise_corr_pallas(xs_i, xs_j, bm=bm, bn=bn, bk=bk,
                               interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bz"))
def pcit_filter(r_xy, rows_x, rows_y, gx, gy, *, bm=128, bn=128, bz=128):
    """PCIT keep tile [M, N]; see kernels/pcit_filter.py.

    Padded z columns get rows == 0 which yields eps ratios that never
    explain an edge with |r_xy| > 0; padded z ids are also >= N so the
    z-exclusion mask keeps them inert.  Padded x/y rows are sliced off.
    """
    (r_xy, M) = _pad_to(r_xy, bm, 0)
    (r_xy, N) = _pad_to(r_xy, bn, 1)
    rows_x, _ = _pad_to(rows_x, bm, 0)
    rows_y, _ = _pad_to(rows_y, bn, 0)
    rows_x, _ = _pad_to(rows_x, bz, 1)
    rows_y, _ = _pad_to(rows_y, bz, 1)
    # pad gene ids with sentinels that can't collide with real z indices
    def pad_ids(g, to):
        pad = to - g.shape[0]
        if pad:
            g = jnp.concatenate([g, jnp.full((pad,), -1, g.dtype)])
        return g
    gx = pad_ids(gx, rows_x.shape[0])
    gy = pad_ids(gy, rows_y.shape[0])
    out = pcit_filter_pallas(r_xy, rows_x, rows_y, gx, gy,
                             bm=bm, bn=bn, bz=bz, interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("softening",))
def pairwise_batch_forces(quorum, lo, hi, wi, wj, *, softening=1e-2):
    """Fused batched n-body step for the engine's ``batch_fn`` hook.

    quorum: [k, block, 4]; lo/hi: [n_pairs] slot ids; wi/wj: [n_pairs]
    per-side pair weights (engine passes mask and self-zeroed mask).
    Returns slot-accumulated forces [k, block, 3] float32.

    Pads block up to the 8-sublane multiple with zero-mass bodies at the
    origin — exact, since zero mass contributes zero force either way —
    and slices back.
    """
    q, block = _pad_to(quorum, 8, 1)
    w = jnp.stack([jnp.asarray(wi, jnp.float32),
                   jnp.asarray(wj, jnp.float32)], axis=1)
    out = pairwise_batch_pallas(q, lo, hi, w, softening=softening,
                                interpret=_interpret())
    return out[:, :block]


@functools.partial(jax.jit, static_argnames=("topk", "metric"))
def query_topk(stack, queries, mask, gidx, *, topk, metric="dot"):
    """Fused serving scoring step for the query engine's ``batch_fn`` hook.

    stack: [k, block, d] quorum blocks; queries: [Q, d]; mask: [k, block]
    float (cover dedup x row validity); gidx: [k, block] int32 global row
    ids.  Returns (scores [Q, topk] f32, indices [Q, topk] i32) under the
    engine's (-score, index) order.

    Pads Q up to the 8-sublane multiple with zero queries and slices the
    padded rows back off — exact, the extra rows never leave the wrapper.
    """
    from .query_score import query_topk_pallas
    q, Q = _pad_to(queries, 8, 0)
    vals, idx = query_topk_pallas(stack, q, mask, gidx, topk=topk,
                                  metric=metric, interpret=_interpret())
    return vals[:Q], idx[:Q]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    """4-d entry point: q [B, Tq, H, hd], k/v [B, Tk, KV, hd] (GQA).

    K/V heads are broadcast to H before flattening to the kernel's [BH, T,
    hd] layout.  (A production TPU kernel indexes kv-heads in the grid map
    instead of materializing the broadcast; that variant changes only the
    BlockSpec index_map — noted for the perf log.)
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                 interpret=_interpret())
    return out.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunk(x, dt, A, Bm, Cm, *, chunk=256):
    """Full SSD via the Pallas intra-chunk kernel + jnp inter-chunk scan.

    x: [B, T, H, P]; dt: [B, T, H]; A: [H]; Bm/Cm: [B, T, N].
    Returns y [B, T, H, P] float32 (parity with ref.ssd_chunk).
    """
    from .ssd_chunk import ssd_chunk_pallas
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    assert T % L == 0
    nc = T // L
    # flatten (B, H) -> BH with per-bh A; B/C shared across heads
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, nc, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, nc, L)
    Af = jnp.tile(A, (B,))
    Bf = jnp.repeat(Bm.reshape(B, 1, nc, L, N), H, 1).reshape(B * H, nc, L, N)
    Cf = jnp.repeat(Cm.reshape(B, 1, nc, L, N), H, 1).reshape(B * H, nc, L, N)
    y_intra, S, cd = ssd_chunk_pallas(xf, dtf, Af, Bf, Cf,
                                      interpret=_interpret())

    # inter-chunk recurrence (tiny): h_c = cd_last * h_{c-1} + S_c
    def step(h, inp):
        s_c, cdl, c_c, cd_c = inp
        y_int = jnp.einsum("bln,bl,bnp->blp", c_c, cd_c, h)
        h = cdl[:, None, None] * h + s_c
        return h, y_int

    cd_last = cd[:, :, -1]                                # [BH, nc]
    h0 = jnp.zeros((B * H, N, P), jnp.float32)
    xs = (jnp.moveaxis(S, 1, 0), jnp.moveaxis(cd_last, 1, 0),
          jnp.moveaxis(Cf.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cd, 1, 0))
    _, y_inter = jax.lax.scan(step, h0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(B, H, T, P).transpose(0, 2, 1, 3)


# re-export oracles for convenience in benchmarks/tests
reference = ref

"""Jit'd public entry points for the Pallas kernels (DESIGN.md section 6).

On TPU backends the kernels run compiled; elsewhere (CPU tests, smoke) they
run in interpret mode, which executes the kernel body in Python with
identical block semantics — the per-kernel allclose sweeps in
tests/test_kernels.py validate every (shape, dtype) cell against ref.py.

The three engine ``batch_fn`` hooks (:func:`pairwise_batch_forces`,
:func:`query_topk`, :func:`pairwise_threshold`) additionally degrade
gracefully when the Pallas lowering itself is unavailable on the running
backend (an ``ImportError``/``NotImplementedError`` from the kernel
machinery — e.g. a jax build without Pallas support): they fall back to
the bit-parity jnp oracle in ref.py with a one-time warning, so an engine
configured with ``use_kernel=True`` stays correct everywhere.  Numeric
kernel bugs are *not* masked — those surface as value mismatches in the
kernel sweeps, never as these exception types.  Both dispatch layers
(interpret-vs-compiled via :func:`_interpret`, kernel-absent via
:func:`_call_with_fallback`) are covered directly in
tests/test_ops_dispatch.py.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .pairwise_batch import pairwise_batch_pallas
from .pairwise_corr import pairwise_corr_pallas
from .pcit_filter import pcit_filter_pallas


def _interpret() -> bool:
    """True when the Pallas kernels should run in interpret mode (any
    backend without a Mosaic TPU compiler — CPU tests, GPU smoke).  The
    dispatch every jit'd entry point below routes through (DESIGN.md
    section 6)."""
    return jax.default_backend() != "tpu"


# hooks that already warned about a missing kernel this process — the
# warn-once keyset (the core/env.py ``_seen_env_keys`` pattern, keyed on
# the hook name so it survives ``warnings.simplefilter('always')``);
# tests reset it between cases (tests/test_ops_dispatch.py)
_warned_fallback: set = set()


def _call_with_fallback(kernel_thunk, ref_thunk, name: str):
    """Run a Pallas engine-hook kernel, degrading to its ref.py oracle.

    Only ``ImportError`` / ``NotImplementedError`` — the "kernel is
    absent on this backend" signals raised at trace time by the Pallas
    machinery — trigger the fallback; anything else (shape errors,
    numeric asserts) propagates so real kernel bugs stay visible.  The
    warning fires once per hook per process (every retrace of a hot
    engine loop hits this path, and a per-call warning floods the log
    without adding information).
    """
    try:
        return kernel_thunk()
    except (ImportError, NotImplementedError) as e:
        if name not in _warned_fallback:
            _warned_fallback.add(name)
            warnings.warn(
                f"Pallas kernel {name!r} unavailable on this backend "
                f"({type(e).__name__}: {e}); falling back to the jnp "
                "reference implementation", RuntimeWarning, stacklevel=2)
        return ref_thunk()


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def pairwise_corr(xs_i, xs_j, *, bm=128, bn=128, bk=128):
    """Correlation tile [M, N] of standardized blocks [M, G] x [N, G]
    (PCIT phase 2; DESIGN.md section 6).

    Pads every dim up to the tile multiple and slices back, so arbitrary
    shapes are accepted (padded K columns are zeros — exact for the dot).
    """
    xs_i, M = _pad_to(xs_i, bm, 0)
    xs_j, N = _pad_to(xs_j, bn, 0)
    xs_i, _ = _pad_to(xs_i, bk, 1)
    xs_j, _ = _pad_to(xs_j, bk, 1)
    out = pairwise_corr_pallas(xs_i, xs_j, bm=bm, bn=bn, bk=bk,
                               interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bz"))
def pcit_filter(r_xy, rows_x, rows_y, gx, gy, *, bm=128, bn=128, bz=128):
    """PCIT keep tile [M, N]; see kernels/pcit_filter.py (DESIGN.md
    section 6).

    Padded z columns get rows == 0 which yields eps ratios that never
    explain an edge with |r_xy| > 0; padded z ids are also >= N so the
    z-exclusion mask keeps them inert.  Padded x/y rows are sliced off.
    """
    (r_xy, M) = _pad_to(r_xy, bm, 0)
    (r_xy, N) = _pad_to(r_xy, bn, 1)
    rows_x, _ = _pad_to(rows_x, bm, 0)
    rows_y, _ = _pad_to(rows_y, bn, 0)
    rows_x, _ = _pad_to(rows_x, bz, 1)
    rows_y, _ = _pad_to(rows_y, bz, 1)
    # pad gene ids with sentinels that can't collide with real z indices
    def pad_ids(g, to):
        pad = to - g.shape[0]
        if pad:
            g = jnp.concatenate([g, jnp.full((pad,), -1, g.dtype)])
        return g
    gx = pad_ids(gx, rows_x.shape[0])
    gy = pad_ids(gy, rows_y.shape[0])
    out = pcit_filter_pallas(r_xy, rows_x, rows_y, gx, gy,
                             bm=bm, bn=bn, bz=bz, interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("softening",))
def pairwise_batch_forces(quorum, lo, hi, wi, wj, *, softening=1e-2):
    """Fused batched n-body step for the engine's ``batch_fn`` hook
    (DESIGN.md section 6).

    quorum: [k, block, 4]; lo/hi: [n_pairs] slot ids; wi/wj: [n_pairs]
    per-side pair weights (engine passes mask and self-zeroed mask).
    Returns slot-accumulated forces [k, block, 3] float32.

    Pads block up to the 8-sublane multiple with zero-mass bodies at the
    origin — exact, since zero mass contributes zero force either way —
    and slices back.  Falls back to ref.pairwise_batch_forces when the
    Pallas lowering is absent (see module docstring).
    """
    q, block = _pad_to(quorum, 8, 1)
    w = jnp.stack([jnp.asarray(wi, jnp.float32),
                   jnp.asarray(wj, jnp.float32)], axis=1)
    out = _call_with_fallback(
        lambda: pairwise_batch_pallas(q, lo, hi, w, softening=softening,
                                      interpret=_interpret()),
        lambda: ref.pairwise_batch_forces(q, lo, hi, w[:, 0], w[:, 1],
                                          softening=softening),
        "pairwise_batch_forces")
    return out[:, :block]


@functools.partial(jax.jit, static_argnames=("topk", "metric"))
def query_topk(stack, queries, mask, gidx, *, topk, metric="dot"):
    """Fused serving scoring step for the query engine's ``batch_fn``
    hook (DESIGN.md section 9.3).

    stack: [k, block, d] quorum blocks; queries: [Q, d]; mask: [k, block]
    float (cover dedup x row validity); gidx: [k, block] int32 global row
    ids.  Returns (scores [Q, topk] f32, indices [Q, topk] i32) under the
    engine's (-score, index) order.

    Pads Q up to the 8-sublane multiple with zero queries and slices the
    padded rows back off — exact, the extra rows never leave the wrapper.
    Falls back to ref.query_topk when the Pallas lowering is absent (see
    module docstring).
    """
    from .query_score import query_topk_pallas
    q, Q = _pad_to(queries, 8, 0)
    vals, idx = _call_with_fallback(
        lambda: query_topk_pallas(stack, q, mask, gidx, topk=topk,
                                  metric=metric, interpret=_interpret()),
        lambda: ref.query_topk(stack, q, mask, gidx, topk=topk,
                               metric=metric),
        "query_topk")
    return vals[:Q], idx[:Q]


@functools.partial(jax.jit, static_argnames=("threshold", "capacity",
                                             "block_rows", "metric"))
def pairwise_threshold(quorum, lo, hi, meta, *, threshold, capacity,
                       block_rows, metric="dot"):
    """Fused thresholded-join step for the sparse engine's ``batch_fn``
    hook (core/sparse.py; DESIGN.md section 11).

    quorum: [k, block, d]; lo/hi: [n_pairs] slot ids; meta: [n_pairs, 6]
    int32 ``(active, is_self, ga, gb, nv_lo, nv_hi)``.  ``threshold`` is
    a *static* float (the kernel bakes it in; the host join program is
    cached per threshold), ``capacity`` the per-device buffer size,
    ``block_rows`` the global block stride for row-id math.  Returns
    ``(vals f32 [capacity], i i32 [capacity], j i32 [capacity],
    count i32 [])`` under the overflow contract of DESIGN.md 11.2.

    Pads block rows up to the 8-sublane multiple with zero rows — exact,
    the valid-row bounds in ``meta`` already reject them — and capacity
    up to the 128-lane multiple, slicing back (the dropped tail keeps the
    first-``capacity`` prefix semantics).  Falls back to
    ref.pairwise_threshold when the Pallas lowering is absent (see
    module docstring).
    """
    from .pairwise_threshold import pairwise_threshold_pallas
    q, _ = _pad_to(quorum, 8, 1)
    capp = -(-capacity // 128) * 128
    vals, gi, gj, count = _call_with_fallback(
        lambda: pairwise_threshold_pallas(
            q, lo, hi, meta, threshold=threshold, capacity=capp,
            block_rows=block_rows, metric=metric, interpret=_interpret()),
        lambda: ref.pairwise_threshold(
            q, lo, hi, meta, threshold=threshold, capacity=capp,
            block_rows=block_rows, metric=metric),
        "pairwise_threshold")
    return (vals[:capacity], gi[:capacity], gj[:capacity],
            count.reshape(()))


@functools.partial(jax.jit, static_argnames=("topk", "block_rows", "metric"))
def pairwise_topk(quorum, lo, hi, meta, *, topk, block_rows, metric="dot"):
    """Fused pair-scoring top-k step for the k-NN graph engine's
    ``batch_fn`` hook (core/knn.py; DESIGN.md section 12.3).

    quorum: [k, block, d]; lo/hi: [n_pairs] slot ids; meta: [n_pairs, 6]
    int32 ``(active, is_self, ga, gb, nv_lo, nv_hi)``.  ``topk`` is the
    per-row neighbor-list length, ``block_rows`` the global block stride
    for row-id math.  Returns the per-slot running top-k
    ``(vals f32 [k, block, topk], idx i32 [k, block, topk])`` under the
    engine's (-score, index) order — bit-parity with ref.pairwise_topk.

    Pads block rows up to the 8-sublane multiple with zero rows — exact,
    the valid-row bounds in ``meta`` already reject them as candidates
    and the padded rows' own lists are sliced back off.  Falls back to
    ref.pairwise_topk when the Pallas lowering is absent (see module
    docstring).
    """
    from .pairwise_topk import pairwise_topk_pallas
    q, block = _pad_to(quorum, 8, 1)
    vals, idx = _call_with_fallback(
        lambda: pairwise_topk_pallas(q, lo, hi, meta, topk=topk,
                                     block_rows=block_rows, metric=metric,
                                     interpret=_interpret()),
        lambda: ref.pairwise_topk(q, lo, hi, meta, topk=topk,
                                  block_rows=block_rows, metric=metric),
        "pairwise_topk")
    return vals[:, :block], idx[:, :block]


@functools.partial(jax.jit, static_argnames=("threshold", "capacity",
                                             "block_rows", "metric"))
def pairwise_threshold_q(q, sd, l1, sq, lo, hi, meta, *, threshold,
                         capacity, block_rows, metric="dot"):
    """Quantized thresholded-join step for the quant engine's
    ``batch_fn`` hook (core/quant.py; DESIGN.md section 17.3).

    q: [k, block, d] int8/bf16 quantized blocks; sd: [k, 2] f32
    per-block (scale, delta); l1/sq: [k, block] f32 row L1 norms and
    exact squared norms; lo/hi/meta and the static args as in
    :func:`pairwise_threshold`.  Emits the widened ``s_q >= threshold -
    eps`` band under the same overflow contract; the host rescoring pass
    resolves it exactly.

    Pads block rows up to the 8-sublane multiple with zero rows (zero
    quantized values and zero norms — the valid-row bounds in ``meta``
    already reject them) and capacity up to the 128-lane multiple,
    slicing back.  Falls back to ref.pairwise_threshold_q when the
    Pallas lowering is absent (see module docstring).
    """
    from .pairwise_batch_q import pairwise_threshold_q_pallas
    qp, _ = _pad_to(q, 8, 1)
    l1p, _ = _pad_to(l1, 8, 1)
    sqp, _ = _pad_to(sq, 8, 1)
    capp = -(-capacity // 128) * 128
    vals, gi, gj, count = _call_with_fallback(
        lambda: pairwise_threshold_q_pallas(
            qp, sd, l1p, sqp, lo, hi, meta, threshold=threshold,
            capacity=capp, block_rows=block_rows, metric=metric,
            interpret=_interpret()),
        lambda: ref.pairwise_threshold_q(
            qp, sd[:, 0], sd[:, 1], l1p, sqp, lo, hi, meta,
            threshold=threshold, capacity=capp, block_rows=block_rows,
            metric=metric),
        "pairwise_threshold_q")
    return (vals[:capacity], gi[:capacity], gj[:capacity],
            count.reshape(()))


@functools.partial(jax.jit, static_argnames=("topk", "block_rows", "metric"))
def pairwise_topk_q(q, sd, sq, lo, hi, meta, *, topk, block_rows,
                    metric="dot"):
    """Quantized pair-scoring top-k step for the quant engine's
    ``batch_fn`` hook (core/quant.py; DESIGN.md section 17.3).

    q: [k, block, d] int8/bf16 quantized blocks; sd: [k, 2] f32
    per-block (scale, delta); sq: [k, block] exact f32 squared row
    norms; lo/hi/meta and the static args as in :func:`pairwise_topk`.
    Returns the per-slot running *quantized* top-k — the host certifies
    and rescores the lists exactly.

    Pads block rows up to the 8-sublane multiple with zero rows (zero
    quantized values and zero norms — the valid-row bounds in ``meta``
    already reject them as candidates and padded rows' own lists are
    sliced back off).  Falls back to ref.pairwise_topk_q when the
    Pallas lowering is absent (see module docstring).
    """
    from .pairwise_batch_q import pairwise_topk_q_pallas
    qp, block = _pad_to(q, 8, 1)
    sqp, _ = _pad_to(sq, 8, 1)
    vals, idx = _call_with_fallback(
        lambda: pairwise_topk_q_pallas(
            qp, sd, sqp, lo, hi, meta, topk=topk, block_rows=block_rows,
            metric=metric, interpret=_interpret()),
        lambda: ref.pairwise_topk_q(
            qp, sd[:, 0], sqp, lo, hi, meta, topk=topk,
            block_rows=block_rows, metric=metric),
        "pairwise_topk_q")
    return vals[:, :block], idx[:, :block]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    """4-d entry point: q [B, Tq, H, hd], k/v [B, Tk, KV, hd] (GQA; the
    attention substrate of DESIGN.md section 6).

    K/V heads are broadcast to H before flattening to the kernel's [BH, T,
    hd] layout.  (A production TPU kernel indexes kv-heads in the grid map
    instead of materializing the broadcast; that variant changes only the
    BlockSpec index_map — noted for the perf log.)
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                 interpret=_interpret())
    return out.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunk(x, dt, A, Bm, Cm, *, chunk=256):
    """Full SSD via the Pallas intra-chunk kernel + jnp inter-chunk scan
    (the SSM substrate of DESIGN.md section 6).

    x: [B, T, H, P]; dt: [B, T, H]; A: [H]; Bm/Cm: [B, T, N].
    Returns y [B, T, H, P] float32 (parity with ref.ssd_chunk).
    """
    from .ssd_chunk import ssd_chunk_pallas
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    assert T % L == 0
    nc = T // L
    # flatten (B, H) -> BH with per-bh A; B/C shared across heads
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, nc, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, nc, L)
    Af = jnp.tile(A, (B,))
    Bf = jnp.repeat(Bm.reshape(B, 1, nc, L, N), H, 1).reshape(B * H, nc, L, N)
    Cf = jnp.repeat(Cm.reshape(B, 1, nc, L, N), H, 1).reshape(B * H, nc, L, N)
    y_intra, S, cd = ssd_chunk_pallas(xf, dtf, Af, Bf, Cf,
                                      interpret=_interpret())

    # inter-chunk recurrence (tiny): h_c = cd_last * h_{c-1} + S_c
    def step(h, inp):
        s_c, cdl, c_c, cd_c = inp
        y_int = jnp.einsum("bln,bl,bnp->blp", c_c, cd_c, h)
        h = cdl[:, None, None] * h + s_c
        return h, y_int

    cd_last = cd[:, :, -1]                                # [BH, nc]
    h0 = jnp.zeros((B * H, N, P), jnp.float32)
    xs = (jnp.moveaxis(S, 1, 0), jnp.moveaxis(cd_last, 1, 0),
          jnp.moveaxis(Cf.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cd, 1, 0))
    _, y_inter = jax.lax.scan(step, h0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(B, H, T, P).transpose(0, 2, 1, 3)


# re-export oracles for convenience in benchmarks/tests
reference = ref

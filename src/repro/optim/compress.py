"""Gradient compression for cross-pod all-reduce.

``compress_tree``/``decompress_tree`` implement stochastic-rounded bf16 and
block-scaled int8 codecs.  The intended use at scale: grads are
reduce-scattered in full precision inside a pod (ICI), compressed once per
pod, all-reduced across pods over DCN (the slow hop), then decompressed —
cutting the cross-pod bytes 2x (bf16) or 4x (int8).

The train step exposes this via AdamWConfig-independent hooks; tests verify
codec round-trip error bounds and that training with bf16-compressed grads
still converges on the smoke model.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any

_BLOCK = 256


def _stochastic_round_bf16(x: jax.Array, key) -> jax.Array:
    x32 = x.astype(jnp.float32)
    down = jax.lax.convert_element_type(x32, jnp.bfloat16)
    down32 = down.astype(jnp.float32)
    # distance to the next representable value, sign-aware
    eps = jnp.spacing(down32) * jnp.sign(x32 - down32)
    up32 = down32 + eps
    p = jnp.where(eps != 0, (x32 - down32) / jnp.where(eps == 0, 1.0, eps), 0.0)
    u = jax.random.uniform(key, x.shape)
    return jnp.where(u < p, up32, down32).astype(jnp.bfloat16)


def compress_bf16(tree: Tree, key=None) -> Tree:
    """Cast a gradient tree to bf16 (stochastic rounding with a key)."""
    if key is None:
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_stochastic_round_bf16(l, k) for l, k in zip(leaves, keys)])


def compress_int8(tree: Tree) -> Tree:
    """Per-block absmax int8: leaf -> (codes int8, scales f32)."""
    def enc(g):
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % _BLOCK
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, _BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        codes = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                         -127, 127).astype(jnp.int8)
        return {"codes": codes, "scale": scale, "shape": g.shape}
    return jax.tree.map(enc, tree)


def decompress_int8(tree: Tree) -> Tree:
    """Invert compress_int8: rescale block codes back to f32."""
    def dec(e):
        blocks = e["codes"].astype(jnp.float32) * e["scale"]
        flat = blocks.reshape(-1)
        n = 1
        for s in e["shape"]:
            n *= s
        return flat[:n].reshape(e["shape"])
    return jax.tree.map(dec, tree,
                        is_leaf=lambda x: isinstance(x, dict) and "codes" in x)

"""AdamW with cosine schedule, global-norm clipping, and ZeRO-1 sharding.

Optimizer moments are fp32 and carry the same placeholder specs as their
params except that the "F" (fsdp) placeholder is ALWAYS resolved to the data
axis — that is ZeRO-1: even when params are replicated across data, the
m/v/update math is sharded and the updated params implicitly re-gathered by
GSPMD.  See launch/mesh.resolve_specs(zero1=True).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    """AdamW + cosine-schedule hyperparameters."""
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    """Warmup + cosine decay learning rate at ``step``."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_init(params) -> Dict[str, Any]:
    """Fresh f32 (m, v, count) state matching ``params``."""
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, grad_norm)."""
    count = opt_state["count"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, opt_state["count"])

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm

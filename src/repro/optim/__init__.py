from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr  # noqa: F401

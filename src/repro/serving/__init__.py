"""Online all-pairs query serving over quorum-replicated corpora.

The batch engine (core.allpairs) computes every pair once; this package
serves *query-vs-all* traffic against the same quorum-sharded residency:

  * ``cover``  — route a query to a ~ceil(P/k)-device set whose quorums
    cover all blocks, with a dedup mask so replicas score once,
  * ``engine`` — the shard_map query program: fused local top-k scoring
    plus a ppermute tree merge (`ServingCorpus` is the host handle),
  * ``stream`` — streamed corpus updates (replace / append a block)
    over the existing cyclic ppermute shifts, no global reshuffle,
  * ``batching`` — the continuous-batching front end: bounded admission
    queue, heterogeneous microbatch packing onto quantized program
    keys, per-request deadlines, p50/p99 latency accounting
    (imported lazily — ``from repro.serving.batching import
    BatchScheduler``).

See DESIGN.md sections 9 ("Online serving") and 15 (continuous
batching).
"""

from .cover import CoverPlan, build_cover
from .engine import ServingCorpus, quorum_query_threshold, quorum_query_topk
from .stream import ServingState, build_state, replace_block

__all__ = [
    "CoverPlan",
    "build_cover",
    "ServingCorpus",
    "quorum_query_topk",
    "quorum_query_threshold",
    "ServingState",
    "build_state",
    "replace_block",
]

"""Online all-pairs query serving over quorum-replicated corpora.

The batch engine (core.allpairs) computes every pair once; this package
serves *query-vs-all* traffic against the same quorum-sharded residency:

  * ``cover``  — route a query to a ~ceil(P/k)-device set whose quorums
    cover all blocks, with a dedup mask so replicas score once,
  * ``engine`` — the shard_map query program: fused local top-k scoring
    plus a ppermute tree merge (`ServingCorpus` is the host handle),
  * ``stream`` — streamed corpus updates (replace / append a block)
    over the existing cyclic ppermute shifts, no global reshuffle.

See DESIGN.md section 9 ("Online serving").
"""

from .cover import CoverPlan, build_cover
from .engine import ServingCorpus, quorum_query_threshold, quorum_query_topk
from .stream import ServingState, build_state, replace_block

__all__ = [
    "CoverPlan",
    "build_cover",
    "ServingCorpus",
    "quorum_query_topk",
    "quorum_query_threshold",
    "ServingState",
    "build_state",
    "replace_block",
]

"""Quorum-cover routing for online query serving.

The batch engine replicates every block into k = O(sqrt(P)) cyclic quorums
so that every *pair* of blocks is co-resident somewhere.  A query-vs-all
computation needs much less: a set of devices whose quorums jointly cover
all P blocks.  Because each block b lives in exactly k quorums (paper
Eq. 13 — devices {b - a mod P : a in A}), a cover of ~ceil(P/k) devices
exists in the best case, and the serving tier only has to fan a query out
to those devices instead of all P (DESIGN.md section 9).

Cover construction, cheapest-first:

  * **closed form from the cyclic structure** — the difference-cover
    property ``A - A = Z_P`` says the translates at ``C = -A mod P``
    always cover (``S_{-a_j} ∋ a_i - a_j``): a guaranteed size-k cover
    with zero search.  When A contains a run {0..m-1} (the ladder sets
    do), the *step cover* at devices {0, m, 2m, ...} does better:
    ~ceil(P/m) + 1 devices.
  * **greedy set-cover** over the P translates (O(P^2 k)).
  * **exact branch-and-bound** for P <= _EXACT_COVER_MAX_P, branching on
    the k holders of a least-covered block (depth <= |cover|, factor k).

``build_cover`` takes the smallest verified result.  NOTE a deviation from
the obvious ``ceil(P/k) + 1`` target: that bound is *not achievable in
general* — e.g. for P = 22 (k = 6) exhaustive search shows no 5-translate
cover of the optimal difference set exists; the exact minimum over all
P <= 64 stays within ``ceil(P/k) + 3`` (tests/test_cover.py pins this).

The **dedup mask** assigns every block to exactly one (cover device, slot)
so replicated blocks score each query exactly once; `mask_table` turns the
assignment into a [P, k] sharded operand (zero rows for devices outside
the cover), mirroring ``core.allpairs.pair_mask_table``.

Covers are built over any registered *placement* (core.placement,
DESIGN.md section 10): ``build_cover(P, placement)`` unions that
placement's residency sets — plane placements give plane covers, full
replication collapses to one device — and :func:`exact_cover_sets` runs
the branch-and-bound over arbitrary residency sets (the cyclic
:func:`exact_cover` wrapper keeps bit-identical historical results).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.placement import get_placement, resolve_placement

__all__ = [
    "CoverPlan",
    "build_cover",
    "build_degraded_cover",
    "closed_form_cover",
    "step_cover",
    "greedy_cover",
    "exact_cover",
    "exact_cover_sets",
    "is_cover",
]

# exact search is k^|cover| worst case; beyond this P the heuristics (which
# the exact search only ever improves by ~1 device) stand alone
_EXACT_COVER_MAX_P = 64


def _quorum(P: int, A: Sequence[int], i: int) -> frozenset:
    return frozenset((a + i) % P for a in A)


def is_cover(P: int, A: Sequence[int], devices: Sequence[int]) -> bool:
    """True iff the quorums of ``devices`` jointly cover all P blocks
    (the cover-validity predicate of DESIGN.md section 9.1)."""
    got: set = set()
    for i in devices:
        got |= _quorum(P, A, i)
    return len(got) == P


def closed_form_cover(P: int, A: Sequence[int]) -> List[int]:
    """The always-valid size-k cover ``C = -A mod P`` (the cyclic closed
    form of DESIGN.md section 9.1).

    For every residue r, the difference-cover property gives a_i - a_j = r
    (mod P), so quorum S_{-a_j} = A - a_j contains r.  No search, O(k).
    """
    return sorted({(-a) % P for a in A})


def step_cover(P: int, A: Sequence[int]) -> List[int] | None:
    """Cover by translates at multiples of m, when A hits every residue
    mod m — e.g. the ladder sets contain the run {0..r-1} (DESIGN.md
    section 9.1).

    For block b >= a with a = min{x in A : x ≡ b (mod m)}, b - a is a
    multiple of m below P, so b is in the quorum of a chosen translate;
    the wraparound cases (b < a) are patched greedily — that is the "+1"
    (occasionally +2) over ceil(P/m).  Returns None when only m = 1
    qualifies (every translate set trivially hits residues mod 1).
    """
    m = 0
    for cand in range(min(P, len(A)), 1, -1):
        if {a % cand for a in A} == set(range(cand)):
            m = cand
            break
    if m == 0:
        return None
    devices = [(j * m) % P for j in range(math.ceil(P / m))]
    covered: set = set()
    for i in devices:
        covered |= _quorum(P, A, i)
    missing = set(range(P)) - covered
    while missing:  # wraparound patch
        best = max(range(P), key=lambda i: len(missing & _quorum(P, A, i)))
        devices.append(best)
        missing -= _quorum(P, A, best)
    return sorted(set(devices))


def greedy_cover(P: int, A: Sequence[int]) -> List[int]:
    """Classic greedy set-cover over the P cyclic translates (DESIGN.md
    section 9.1)."""
    quorums = [_quorum(P, A, i) for i in range(P)]
    uncovered = set(range(P))
    cover: List[int] = []
    while uncovered:
        best = max(range(P), key=lambda i: (len(uncovered & quorums[i]), -i))
        cover.append(best)
        uncovered -= quorums[best]
    return sorted(cover)


def exact_cover_sets(residency: Sequence[Sequence[int]], ub: int, *,
                     holders: Optional[Dict[int, List[int]]] = None,
                     pin_first: Optional[int] = None) -> List[int] | None:
    """Minimal device cover of *arbitrary* residency sets by
    branch-and-bound, or None if nothing beats ``ub`` (DESIGN.md
    sections 9.1 and 10 "Threading").

    ``residency[i]`` is the block set device i holds (any placement, not
    just cyclic translates).  Branches on the holders of the smallest
    uncovered block; prunes on ``|cover| + ceil(|uncovered| / kmax) >=
    ub`` with kmax the largest residency.  ``pin_first`` roots the search
    at one device — only sound under a symmetry argument (for cyclic
    translates, some optimal cover contains device 0), so the default
    leaves the root open.  ``holders`` optionally fixes the per-block
    branch order (the cyclic wrapper uses the historical shift order so
    results stay bit-identical with the pre-generalization search).
    """
    sets = [frozenset(S) for S in residency]
    blocks = frozenset().union(*sets) if sets else frozenset()
    kmax = max((len(S) for S in sets), default=0)
    if holders is None:
        holders = {b: [i for i, S in enumerate(sets) if b in S]
                   for b in blocks}
    best: List[int] | None = None
    bound = ub

    def bb(cover: List[int], uncovered: frozenset) -> None:
        nonlocal best, bound
        if not uncovered:
            if len(cover) < bound:
                bound = len(cover)
                best = list(cover)
            return
        if len(cover) + math.ceil(len(uncovered) / kmax) >= bound:
            return
        b = min(uncovered)
        for i in holders[b]:
            if i in cover:  # pragma: no cover - holders of uncovered b aren't in cover
                continue
            cover.append(i)
            bb(cover, uncovered - sets[i])
            cover.pop()

    if pin_first is None:
        bb([], blocks)
    else:
        bb([pin_first], blocks - sets[pin_first])
    return sorted(best) if best is not None else None


def exact_cover(P: int, A: Sequence[int], ub: int) -> List[int] | None:
    """Minimal cover of the P cyclic translates of A, or None if nothing
    beats ``ub`` (DESIGN.md section 9.1).
    Thin wrapper over :func:`exact_cover_sets` pinning
    device 0 (sound by translational symmetry) and branching holders in
    the historical shift order, so cyclic results are unchanged."""
    sets = [_quorum(P, A, i) for i in range(P)]
    holders = {b: [(b - a) % P for a in sorted(A)] for b in range(P)}
    return exact_cover_sets(sets, ub, holders=holders, pin_first=0)


@dataclasses.dataclass(frozen=True)
class CoverPlan:
    """Query routing plan: which devices to visit, and who scores what.

    Attributes
    ----------
    P : quorum axis size.
    A : the placement's shift structure (sorted difference cover) the
        residency derives from — ``difference_set(P)`` for the default
        cyclic placement.
    placement : name of the placement the plan was built over.
    devices : sorted cover device ids; their quorums union to all P blocks.
    block_owner : np [P] int32 — the cover device assigned to score each
        block (the first cover device holding it): the dedup rule.
    slot_mask : np [P, k] float32 — per-device, per-slot scoring mask.
        Row i is all-zero for devices outside the cover; inside it,
        slot s is 1 iff block (i + A[s]) % P is assigned to device i.
        Summed over all devices every block scores exactly once.
    """

    P: int
    A: Tuple[int, ...]
    devices: Tuple[int, ...]
    block_owner: np.ndarray
    slot_mask: np.ndarray
    placement: str = "cyclic"

    @property
    def k(self) -> int:
        """Quorum size (slots per device) the slot mask is defined over."""
        return len(self.A)

    @property
    def n_cover(self) -> int:
        """Devices a query fans out to (~ceil(P/k) in the best case)."""
        return len(self.devices)

    def mask_table(self) -> np.ndarray:
        """[P, k] float32 mask rows, the sharded shard_map operand."""
        return np.asarray(self.slot_mask, np.float32)


_COVER_CACHE: dict = {}


def build_cover(P: int, placement=None) -> CoverPlan:
    """Build (and memo-cache) the smallest verified cover plan for P
    (DESIGN.md section 9.1).

    Pure function of (P, placement) — like the schedules — so elastic
    resize just recomputes it.  ``placement`` is a
    ``core.placement.Placement`` instance or spec name; None keeps the
    bit-exact default (the cyclic placement, whose shifts are
    ``difference_set(P)``).  Any shift-structured placement works: the
    residency sets the cover unions are the P translates of its shifts
    (for full replication the plan collapses to a single device).
    """
    if P < 1:
        raise ValueError(f"P must be >= 1, got {P}")
    plc = (get_placement("cyclic", P) if placement is None
           else resolve_placement(placement, P))
    key = (P, plc.name)
    if key in _COVER_CACHE:
        return _COVER_CACHE[key]
    if plc.shifts is None:
        raise NotImplementedError(
            f"placement {plc.name!r} has no shift structure; CoverPlan's "
            "slot mask is defined over shift slots")
    A = list(plc.shifts)
    k = len(A)

    candidates = [closed_form_cover(P, A), greedy_cover(P, A)]
    stepped = step_cover(P, A)
    if stepped is not None:
        candidates.append(stepped)
    best = min(candidates, key=len)
    if P <= _EXACT_COVER_MAX_P:
        exact = exact_cover(P, A, ub=len(best))
        if exact is not None:
            best = exact
    for c in candidates + [best]:
        assert is_cover(P, A, c), (P, A, c)

    devices = tuple(sorted(best))
    shifts = sorted(A)
    block_owner = np.full((P,), -1, np.int32)
    for i in devices:  # first cover device holding the block scores it
        for a in shifts:
            b = (a + i) % P
            if block_owner[b] < 0:
                block_owner[b] = i
    assert (block_owner >= 0).all(), (P, devices)

    slot_mask = np.zeros((P, k), np.float32)
    for i in devices:
        for s, a in enumerate(shifts):
            if block_owner[(a + i) % P] == i:
                slot_mask[i, s] = 1.0

    plan = CoverPlan(P=P, A=tuple(shifts), devices=devices,
                     block_owner=block_owner, slot_mask=slot_mask,
                     placement=plc.name)
    _COVER_CACHE[key] = plan
    return plan


def build_degraded_cover(P: int, placement=None,
                         dead: Sequence[int] = ()) -> CoverPlan:
    """A cover plan that visits no dead device (DESIGN.md section 13) —
    serving's half of failure handling: queries keep full-corpus answers
    while recovery runs, as long as every block still has a live holder.

    Same plan shape as :func:`build_cover` (and bit-identical to it when
    ``dead`` is empty): greedy set-cover restricted to live translates,
    improved by the exact search when P is small, then the same
    first-holder dedup rule over live cover devices.  Raises
    ``RuntimeError`` when some block's holders all died (the corpus is
    no longer coverable — restore from checkpoint / re-replicate first).
    Memoized on (P, placement, dead).
    """
    if P < 1:
        raise ValueError(f"P must be >= 1, got {P}")
    plc = (get_placement("cyclic", P) if placement is None
           else resolve_placement(placement, P))
    dead_set = frozenset(int(d) for d in dead)
    if not dead_set:
        return build_cover(P, plc)
    key = (P, plc.name, tuple(sorted(dead_set)))
    if key in _COVER_CACHE:
        return _COVER_CACHE[key]
    if plc.shifts is None:
        raise NotImplementedError(
            f"placement {plc.name!r} has no shift structure; CoverPlan's "
            "slot mask is defined over shift slots")
    A = list(plc.shifts)
    k = len(A)
    live = [i for i in range(P) if i not in dead_set]
    quorums = {i: _quorum(P, A, i) for i in live}
    reachable: set = set()
    for q in quorums.values():
        reachable |= q
    if reachable != set(range(P)):
        b = min(set(range(P)) - reachable)
        raise RuntimeError(
            f"block {b} lost: all holders are dead; no degraded cover "
            f"exists — restore from checkpoint / re-replicate first")
    # greedy over live translates only, then exact search when feasible
    uncovered = set(range(P))
    cover: List[int] = []
    while uncovered:
        best = max(live, key=lambda i: (len(uncovered & quorums[i]), -i))
        cover.append(best)
        uncovered -= quorums[best]
    best_cover = sorted(cover)
    if P <= _EXACT_COVER_MAX_P:
        residency = [quorums[i] if i in quorums else frozenset()
                     for i in range(P)]
        exact = exact_cover_sets(residency, ub=len(best_cover))
        if exact is not None:
            best_cover = exact
    assert is_cover(P, A, best_cover) and not (set(best_cover) & dead_set)

    devices = tuple(sorted(best_cover))
    shifts = sorted(A)
    block_owner = np.full((P,), -1, np.int32)
    for i in devices:
        for a in shifts:
            b = (a + i) % P
            if block_owner[b] < 0:
                block_owner[b] = i
    slot_mask = np.zeros((P, k), np.float32)
    for i in devices:
        for s, a in enumerate(shifts):
            if block_owner[(a + i) % P] == i:
                slot_mask[i, s] = 1.0
    plan = CoverPlan(P=P, A=tuple(shifts), devices=devices,
                     block_owner=block_owner, slot_mask=slot_mask,
                     placement=plc.name)
    _COVER_CACHE[key] = plan
    return plan

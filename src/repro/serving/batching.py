"""Continuous-batching front end for the online serving tier (DESIGN.md
section 15).

``launch/query_serve.py``'s original loop was a synchronous
fixed-microbatch drain: homogeneous requests, one shape, one program.
Real traffic is ragged, bursty, and mixed — different ``topk`` per
request, range queries with different thresholds and capacities, both
metrics at once, per-request latency budgets.  This module puts an
iteration-level scheduler (the aphrodite/Orca engine-loop shape) in
front of :class:`serving.engine.ServingCorpus`:

  * **admission control** — a bounded FIFO request queue;
    :meth:`BatchScheduler.submit` raises :class:`AdmissionError` when
    the queue is full, so overload backpressures at the front door
    instead of growing an unbounded backlog (DESIGN.md section 15.1),
  * **dynamic microbatch assembly** — each :meth:`BatchScheduler.step`
    pops up to ``max_batch`` waiting requests and packs them into one
    padded launch per *program key* (DESIGN.md section 15.2): top-k
    requests with heterogeneous ``k`` share a launch at the
    power-of-two bucket of the largest ``k`` (exact by the prefix
    property of the (-score, index) total order), range queries with
    different thresholds share a launch through the per-query traced
    threshold vector, and capacities quantize onto the same pow2
    ladder the escalation loop doubles along — so a whole mixed batch
    compiles O(log) programs, not one per observed shape,
  * **deadlines with straggler preemption** — a request past its
    deadline at assembly time is *expired* (sentinel result, counted,
    zero batch slots); a range query that overflows its capacity
    re-enters the queue head for an escalated relaunch unless its
    deadline has passed, in which case it returns its truncated buffer
    as a *partial* result (DESIGN.md section 15.3).  Expired and
    partial requests never block the batch,
  * **latency accounting** — per-request submit-to-complete latency
    feeds :func:`latency_summary` (p50/p99 via :func:`percentile`,
    steady-state qps), exported by ``benchmarks/bench_latency.py`` into
    ``BENCH_latency.json`` (DESIGN.md section 15.4).

The scheduler is deterministic given a deterministic clock (the
``clock`` hook exists for exactly that — deadline tests inject a manual
clock), and every packed result is bit-identical to issuing the request
alone through ``ServingCorpus.query`` / ``query_threshold`` — the
selfcheck at the bottom proves it and CI runs it at P in {5, 8}.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=<P> \
      PYTHONPATH=src python -m repro.serving.batching [P]
"""

from __future__ import annotations

import itertools
import math
import threading
import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import env as env_mod
from ..core.sparse import default_capacity as sparse_default_capacity
from ..kernels.ref import IDX_SENTINEL, NEG_INF, QUERY_METRICS as METRICS
from ..obs import trace as obs_trace
from .engine import ServingCorpus, quantize_pow2

__all__ = [
    "AdmissionError",
    "Request",
    "RequestResult",
    "BatchScheduler",
    "percentile",
    "latency_summary",
    "main",
]

REQUEST_KINDS = ("topk", "threshold")
#: scheduler defaults, overridable per instance or via the env registry
DEFAULT_MAX_BATCH = 32
DEFAULT_MAX_QUEUE = 1024


class AdmissionError(RuntimeError):
    """Raised by :meth:`BatchScheduler.submit` when the request queue is
    at ``max_queue`` — the admission-control backpressure signal
    (DESIGN.md section 15.1).  Callers shed load or retry later; the
    rejection is counted, never silently dropped."""


@dataclass
class RequestResult:
    """Terminal outcome of one request (DESIGN.md section 15.3).

    status    : ``"done"`` (complete result), ``"partial"`` (range query
                hit its deadline mid-escalation: ``indices``/``scores``
                hold a valid but truncated hit subset, ``count`` is the
                true total), or ``"expired"`` (deadline passed before
                any launch: sentinel payload).
    scores    : [k] (top-k) or [hits] (range) f32 scores.
    indices   : matching global corpus row ids (int32).
    count     : range queries: the true number of passing rows (may
                exceed ``len(indices)`` iff partial); None for top-k.
    latency_s : submit-to-completion wall time under the scheduler's
                clock.
    """

    status: str
    scores: np.ndarray
    indices: np.ndarray
    count: Optional[int]
    latency_s: float

    @property
    def ok(self) -> bool:
        """True iff the request produced its full result set."""
        return self.status == "done"


_RID = itertools.count()


@dataclass
class Request:
    """One admitted serving request (DESIGN.md section 15.1).

    Built by :meth:`BatchScheduler.submit`; host code holds it as a
    future — :meth:`result` blocks until the scheduler completes,
    expires, or partially returns it.  ``deadline_s`` is relative to
    submission; the absolute ``t_deadline`` is stamped under the
    scheduler clock at admission.
    """

    kind: str
    query: np.ndarray
    metric: str = "dot"
    topk: Optional[int] = None
    threshold: Optional[float] = None
    capacity: Optional[int] = None
    deadline_s: Optional[float] = None
    rid: int = field(default_factory=lambda: next(_RID))
    t_submit: float = 0.0
    t_deadline: Optional[float] = None
    escalations: int = 0
    outcome: Optional[RequestResult] = None
    _event: threading.Event = field(default_factory=threading.Event,
                                    repr=False)

    def done(self) -> bool:
        """True once a terminal :class:`RequestResult` is attached."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Block until the scheduler resolves this request; raises
        ``TimeoutError`` after ``timeout`` seconds (None = wait
        forever).  See DESIGN.md section 15.1."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} unresolved after {timeout}s "
                "(is the scheduler loop running?)")
        assert self.outcome is not None
        return self.outcome


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile over ``values`` (numpy's default
    "linear" method, restated here so the serving metrics are
    stdlib-checkable): with the n sorted samples at ranks 0..n-1, the
    q-th percentile sits at fractional rank ``(n - 1) * q / 100`` and
    interpolates between its neighbors (DESIGN.md section 15.4)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of an empty latency trace")
    pos = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


def latency_summary(latencies_s: Sequence[float],
                    span_s: Optional[float] = None) -> Dict[str, float]:
    """Tail-latency + throughput summary of a per-request latency trace
    (DESIGN.md section 15.4): ``n``, ``mean_s``, ``p50_s``, ``p99_s``,
    ``max_s``, and — when ``span_s`` (the wall-clock span the requests
    completed over) is given and positive — steady-state ``qps``."""
    xs = [float(v) for v in latencies_s]
    out = {"n": float(len(xs))}
    if xs:
        out.update(mean_s=sum(xs) / len(xs), p50_s=percentile(xs, 50.0),
                   p99_s=percentile(xs, 99.0), max_s=max(xs))
    if span_s is not None and span_s > 0 and xs:
        out["qps"] = len(xs) / span_s
    return out


class BatchScheduler:
    """Iteration-level continuous batcher over a :class:`ServingCorpus`
    (DESIGN.md section 15).

    One :meth:`step` = one scheduler iteration: pop up to ``max_batch``
    admitted requests (expiring the dead ones), group them by program
    key — ``(kind, metric)`` picks the compiled program family, the
    pow2 parameter buckets pick the member — and run one padded launch
    per group.  Drive it synchronously (:meth:`step` / :meth:`drain`,
    the deterministic path tests and benchmarks use) or spin the
    background loop (:meth:`start` / :meth:`stop`) and treat
    :meth:`submit` as the async front door.

    ``pad_queries_to`` pins every launch's query width (the legacy
    fixed-microbatch shape ``launch/query_serve.py`` keeps for its
    drain contract); None (default) pads to the pow2 bucket of the
    group size.  ``max_batch``/``max_queue`` default from the
    ``REPRO_SERVE_MAX_BATCH`` / ``REPRO_SERVE_QUEUE_DEPTH`` env knobs.
    ``clock`` is injectable for deterministic deadline tests.
    """

    def __init__(self, corpus: ServingCorpus, *,
                 max_batch: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 mode: str = "auto", use_kernel: bool = False,
                 pad_queries_to: Optional[int] = None,
                 max_escalations: int = 16,
                 clock: Callable[[], float] = time.monotonic):
        self.corpus = corpus
        env_batch = env_mod.read_knob("REPRO_SERVE_MAX_BATCH")
        env_queue = env_mod.read_knob("REPRO_SERVE_QUEUE_DEPTH")
        self.max_batch = int(max_batch if max_batch is not None
                             else (env_batch or DEFAULT_MAX_BATCH))
        self.max_queue = int(max_queue if max_queue is not None
                             else (env_queue or DEFAULT_MAX_QUEUE))
        if self.max_batch < 1 or self.max_queue < 1:
            raise ValueError(
                f"max_batch/max_queue must be >= 1, got "
                f"{self.max_batch}/{self.max_queue}")
        if pad_queries_to is not None and pad_queries_to < self.max_batch:
            raise ValueError(
                f"pad_queries_to={pad_queries_to} is narrower than "
                f"max_batch={self.max_batch}; launches could not hold a "
                "full batch")
        self.mode = mode
        self.use_kernel = use_kernel
        self.pad_queries_to = pad_queries_to
        self.max_escalations = max_escalations
        self._clock = clock
        self._queue: deque = deque()
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self.counters: Counter = Counter()
        self.program_keys: set = set()
        self.latencies_s: List[float] = []
        self._t_first_done: Optional[float] = None
        self._t_last_done: Optional[float] = None
        total = corpus.P * corpus.block
        self._default_capacity = min(sparse_default_capacity(total), total)

    # ------------------------------------------------------------- front door

    def submit(self, query, *, kind: str = "topk", topk: Optional[int] = None,
               threshold: Optional[float] = None,
               capacity: Optional[int] = None, metric: str = "dot",
               deadline_s: Optional[float] = None) -> Request:
        """Admit one request (DESIGN.md section 15.1) and return its
        :class:`Request` future.

        ``kind="topk"`` needs ``topk``; ``kind="threshold"`` needs
        ``threshold`` (``capacity`` optional — the escalation ladder
        starts from the sparse-engine default).  ``deadline_s`` is a
        relative latency budget; past it the request expires or returns
        partial (DESIGN.md section 15.3).  Raises
        :class:`AdmissionError` when the queue is at ``max_queue``.
        """
        if kind not in REQUEST_KINDS:
            raise ValueError(
                f"kind must be one of {REQUEST_KINDS}, got {kind!r}")
        if metric not in METRICS:
            raise ValueError(f"metric must be one of {METRICS}, "
                             f"got {metric!r}")
        q = np.asarray(query, np.float32).reshape(-1)
        if q.shape[0] != self.corpus.d:
            raise ValueError(f"query must have {self.corpus.d} features, "
                             f"got shape {np.shape(query)}")
        if kind == "topk":
            if topk is None or topk < 1:
                raise ValueError(f"top-k request needs topk >= 1, "
                                 f"got {topk}")
        else:
            if threshold is None:
                raise ValueError("threshold request needs a threshold")
            if capacity is not None and capacity < 1:
                raise ValueError(f"capacity must be >= 1, got {capacity}")
        now = self._clock()
        req = Request(kind=kind, query=q, metric=metric, topk=topk,
                      threshold=(None if threshold is None
                                 else float(threshold)),
                      capacity=capacity, deadline_s=deadline_s,
                      t_submit=now,
                      t_deadline=(None if deadline_s is None
                                  else now + float(deadline_s)))
        with self._lock:
            if len(self._queue) >= self.max_queue:
                self.counters["rejected"] += 1
                tr = obs_trace.get_tracer()
                if tr:
                    tr.count("serving.sched.rejected")
                raise AdmissionError(
                    f"request queue full ({self.max_queue} waiting); "
                    "shed load or raise REPRO_SERVE_QUEUE_DEPTH")
            self._queue.append(req)
            self.counters["admitted"] += 1
            self._wakeup.notify()
        tr = obs_trace.get_tracer()
        if tr:
            tr.count("serving.sched.admitted")
        return req

    @property
    def queue_depth(self) -> int:
        """Number of admitted requests waiting for a batch slot."""
        with self._lock:
            return len(self._queue)

    # ----------------------------------------------------------- batch engine

    def _q_width(self, n: int) -> int:
        """Launch query width for an ``n``-request group: the fixed
        ``pad_queries_to`` shape when pinned, else the pow2 bucket —
        either way a program-cache-friendly small set (DESIGN.md
        section 15.2)."""
        if self.pad_queries_to is not None:
            return self.pad_queries_to
        return quantize_pow2(n)

    def _resolve(self, req: Request, res: RequestResult, now: float) -> None:
        """Attach the terminal result, record latency + counters."""
        req.outcome = res
        self.counters[res.status] += 1
        self.latencies_s.append(res.latency_s)
        if self._t_first_done is None:
            self._t_first_done = now
        self._t_last_done = now
        tr = obs_trace.get_tracer()
        if tr:
            tr.count(f"serving.sched.{res.status}")
            tr.record("serving.request", dur_s=res.latency_s,
                      kind=req.kind, metric=req.metric, status=res.status,
                      rid=req.rid)
        req._event.set()

    def _expire(self, req: Request, now: float) -> None:
        """Deadline passed before any launch: sentinel payload, counted,
        zero batch slots (DESIGN.md section 15.3)."""
        k = req.topk or 0
        res = RequestResult(
            status="expired",
            scores=np.full((k,), NEG_INF, np.float32),
            indices=np.full((k,), IDX_SENTINEL, np.int32),
            count=None, latency_s=now - req.t_submit)
        self._resolve(req, res, now)

    def step(self) -> int:
        """Run one scheduler iteration (DESIGN.md section 15.2): expire
        dead requests, assemble up to ``max_batch`` live ones, one
        padded launch per (kind, metric) group, resolve or re-enqueue
        (capacity escalation) every popped request.  Returns the number
        of requests resolved this iteration."""
        now = self._clock()
        batch: List[Request] = []
        expired: List[Request] = []
        with self._lock:
            while self._queue and len(batch) < self.max_batch:
                req = self._queue.popleft()
                if req.t_deadline is not None and now > req.t_deadline:
                    expired.append(req)
                else:
                    batch.append(req)
            depth = len(self._queue)
        for req in expired:
            self._expire(req, now)
        if not batch:
            return len(expired)
        resolved = len(expired)
        self.counters["steps"] += 1
        self.counters["packed_requests"] += len(batch)
        groups: Dict[Tuple[str, str], List[Request]] = {}
        for req in batch:
            groups.setdefault((req.kind, req.metric), []).append(req)
        tr = obs_trace.get_tracer()
        span = tr.span("serving.sched.step", batch=len(batch),
                       groups=len(groups), queue_depth=depth) if tr \
            else obs_trace.NOOP.span("")
        with span:
            for (kind, metric), reqs in groups.items():
                self.counters["launches"] += 1
                if tr:
                    tr.count("serving.sched.launches")
                if kind == "topk":
                    resolved += self._launch_topk(reqs, metric)
                else:
                    resolved += self._launch_threshold(reqs, metric)
        return resolved

    def _pack_queries(self, reqs: List[Request]) -> np.ndarray:
        """[Q_width, d] launch payload: group queries, zero-padded."""
        q = np.zeros((self._q_width(len(reqs)), self.corpus.d), np.float32)
        for i, r in enumerate(reqs):
            q[i] = r.query
        return q

    def _launch_topk(self, reqs: List[Request], metric: str) -> int:
        """One padded top-k launch at the pow2 bucket of the largest
        requested k; per-request rows sliced back to their own k —
        exact by the total-order prefix property (DESIGN.md 15.2)."""
        kmax = max(r.topk for r in reqs)
        self.program_keys.add(
            ("topk", metric, self.mode, quantize_pow2(kmax),
             self.use_kernel))
        q = self._pack_queries(reqs)
        vals, idx = self.corpus.query(q, topk=kmax, mode=self.mode,
                                      metric=metric,
                                      use_kernel=self.use_kernel)
        vals, idx = np.asarray(vals), np.asarray(idx)   # block until ready
        now = self._clock()
        for i, r in enumerate(reqs):
            self._resolve(r, RequestResult(
                status="done", scores=vals[i, :r.topk].copy(),
                indices=idx[i, :r.topk].copy(), count=None,
                latency_s=now - r.t_submit), now)
        return len(reqs)

    def _launch_threshold(self, reqs: List[Request], metric: str) -> int:
        """One padded range-query launch: per-query threshold vector
        (padding rows get +inf, matching nothing), capacity = the
        group max on the pow2 ladder.  Overflowing requests re-enter
        the queue head at double capacity unless their deadline passed,
        in which case the truncated buffer returns as a partial result
        (DESIGN.md sections 15.2, 15.3)."""
        cap_req = max(r.capacity or self._default_capacity for r in reqs)
        q = self._pack_queries(reqs)
        thr = np.full((q.shape[0],), np.inf, np.float32)
        for i, r in enumerate(reqs):
            thr[i] = r.threshold
        vals, idx, cnt = self.corpus.query_threshold(
            q, threshold=thr, capacity=cap_req, mode=self.mode,
            metric=metric, escalate=False)
        vals, idx = np.asarray(vals), np.asarray(idx)
        cnt = np.asarray(cnt)
        cap_used = vals.shape[1]
        self.program_keys.add(("threshold", metric, self.mode, cap_used))
        now = self._clock()
        total = self.corpus.P * self.corpus.block
        resolved = 0
        requeue: List[Request] = []
        tr = obs_trace.get_tracer()
        for i, r in enumerate(reqs):
            n = int(cnt[i])
            if n <= cap_used:
                self._resolve(r, RequestResult(
                    status="done", scores=vals[i, :n].copy(),
                    indices=idx[i, :n].copy(), count=n,
                    latency_s=now - r.t_submit), now)
                resolved += 1
                continue
            # overflow: escalate along the pow2 ladder, deadline allowing
            out_of_time = (r.t_deadline is not None and now > r.t_deadline)
            if (not out_of_time and cap_used < total
                    and r.escalations < self.max_escalations):
                r.escalations += 1
                r.capacity = min(2 * cap_used, total)
                self.counters["escalations"] += 1
                if tr:
                    tr.count("serving.sched.escalations")
                requeue.append(r)
                continue
            self._resolve(r, RequestResult(
                status="partial", scores=vals[i].copy(),
                indices=idx[i].copy(), count=n,
                latency_s=now - r.t_submit), now)
            resolved += 1
        if requeue:
            with self._lock:
                self._queue.extendleft(reversed(requeue))
        return resolved

    # -------------------------------------------------------------- lifecycle

    def drain(self, max_steps: int = 10_000) -> int:
        """Step until the queue is empty (synchronous drivers); returns
        requests resolved.  ``max_steps`` guards against a pathological
        escalation livelock (DESIGN.md section 15.3)."""
        resolved = 0
        for _ in range(max_steps):
            if not self.queue_depth:
                return resolved
            resolved += self.step()
        raise RuntimeError(f"queue not drained after {max_steps} steps")

    def start(self) -> None:
        """Spin the background engine loop: steps whenever requests are
        waiting, sleeps on the queue condition otherwise (DESIGN.md
        section 15.1)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stopping = False

        def loop():
            while True:
                with self._lock:
                    while not self._queue and not self._stopping:
                        self._wakeup.wait(timeout=0.05)
                    if self._stopping and not self._queue:
                        return
                self.step()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="repro-batch-scheduler")
        self._thread.start()

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the background loop after the queue drains."""
        if self._thread is None:
            return
        with self._lock:
            self._stopping = True
            self._wakeup.notify_all()
        self._thread.join(timeout)
        self._thread = None

    def stats(self) -> Dict[str, float]:
        """Counter snapshot + latency/throughput summary (DESIGN.md
        section 15.4): admitted/rejected/expired/partial/done totals,
        launches, escalations, distinct compiled program keys, and the
        :func:`latency_summary` of every resolved request."""
        span = None
        if (self._t_first_done is not None
                and self._t_last_done is not None
                and len(self.latencies_s) > 1):
            span = self._t_last_done - self._t_first_done
        out: Dict[str, float] = dict(self.counters)
        out["programs"] = float(len(self.program_keys))
        out.update(latency_summary(self.latencies_s, span))
        return out


# ---------------------------------------------------------------- selfcheck

def _oracle_topk(sc: ServingCorpus, req: Request):
    """The solo per-request oracle: the same request issued alone
    through ``ServingCorpus.query`` (DESIGN.md section 15.5)."""
    v, i = sc.query(req.query[None], topk=req.topk, metric=req.metric)
    return np.asarray(v)[0], np.asarray(i)[0]


def _oracle_threshold(sc: ServingCorpus, req: Request):
    """The solo range-query oracle: issued alone with full escalation
    through ``ServingCorpus.query_threshold`` (DESIGN.md 15.5)."""
    v, i, c = sc.query_threshold(req.query[None], threshold=req.threshold,
                                 metric=req.metric)
    n = int(np.asarray(c)[0])
    return np.asarray(v)[0, :n], np.asarray(i)[0, :n], n


def _check_heterogeneous_pack(sc: ServingCorpus, rng) -> dict:
    """Packed heterogeneous batch == per-request oracles, bit-exact
    (DESIGN.md section 15.5): mixed k, mixed thresholds/capacities,
    both metrics, one drain."""
    sched = BatchScheduler(sc, max_batch=64)
    d = sc.d
    reqs: List[Request] = []
    # thresholds near the upper score range so counts are small but
    # nonzero; capacity=1 on some forces the escalation ladder
    for metric in METRICS:
        for k in (1, 3, 5, 8):
            reqs.append(sched.submit(rng.normal(size=(d,)), kind="topk",
                                     topk=k, metric=metric))
        for thr, cap in ((2.0, None), (4.0, 1), (-1e9, 2)):
            reqs.append(sched.submit(
                rng.normal(size=(d,)), kind="threshold", threshold=thr,
                capacity=cap, metric=metric))
    n_res = sched.drain()
    assert n_res == len(reqs), (n_res, len(reqs))
    for req in reqs:
        res = req.result(timeout=0)
        assert res.ok, (req.rid, res.status)
        if req.kind == "topk":
            ov, oi = _oracle_topk(sc, req)
            np.testing.assert_array_equal(res.indices, oi)
            assert np.array_equal(res.scores, ov), (req.rid, "scores")
        else:
            ov, oi, on = _oracle_threshold(sc, req)
            assert res.count == on, (req.rid, res.count, on)
            np.testing.assert_array_equal(res.indices, oi)
            assert np.array_equal(res.scores, ov), (req.rid, "scores")
    st = sched.stats()
    # program-key taxonomy: the mixed batch stays on a handful of
    # compiled programs (pow2 buckets), escalation included
    assert st["programs"] <= 12, st
    assert all(isinstance(key[3], int) and key[3] & (key[3] - 1) == 0
               or key[3] == sc.P * sc.block
               for key in sched.program_keys), sched.program_keys
    return st


def _check_escalation(sc: ServingCorpus, rng) -> int:
    """Capacity escalation walks the pow2 program-key ladder (every
    relaunch doubles onto the next bucket, never a fresh raw-capacity
    key) and converges to the oracle hit set (DESIGN.md sections 15.2,
    15.3)."""
    sched = BatchScheduler(sc, max_batch=8)
    reqs = [sched.submit(rng.normal(size=(sc.d,)), kind="threshold",
                         threshold=-1e9, capacity=1) for _ in range(2)]
    sched.drain()
    assert sched.counters["escalations"] > 0, sched.counters
    for req in reqs:
        res = req.result(0)
        assert res.ok and res.count == sc.n_valid, (res.status, res.count)
        ov, oi, _n = _oracle_threshold(sc, req)
        np.testing.assert_array_equal(res.indices, oi)
        assert np.array_equal(res.scores, ov)
    total = sc.P * sc.block
    caps = sorted(key[3] for key in sched.program_keys)
    assert all(c == total or (c & (c - 1)) == 0 for c in caps), caps
    return int(sched.counters["escalations"])


def _check_deadlines(sc: ServingCorpus, rng) -> None:
    """Deadline semantics under a manual clock (DESIGN.md 15.3): expiry
    before launch -> sentinel; overflow past deadline -> partial; live
    requests in the same batch are unaffected."""
    t = [0.0]
    sched = BatchScheduler(sc, max_batch=8, clock=lambda: t[0])
    d = sc.d
    live = sched.submit(rng.normal(size=(d,)), kind="topk", topk=4)
    dead = sched.submit(rng.normal(size=(d,)), kind="topk", topk=4,
                        deadline_s=1.0)
    t[0] = 2.0                                    # dead expires unlaunched
    sched.drain()
    res_live, res_dead = live.result(0), dead.result(0)
    assert res_live.ok and not (res_live.indices == IDX_SENTINEL).any()
    assert res_dead.status == "expired"
    assert (res_dead.indices == IDX_SENTINEL).all()
    assert (res_dead.scores == NEG_INF).all()
    ov, oi = _oracle_topk(sc, live)
    np.testing.assert_array_equal(res_live.indices, oi)

    # a range query that still overflows when its budget runs out
    # returns the truncated buffer as partial (true count preserved).
    # The stepping clock advances 0.4s per read: submitted at 0.4
    # (deadline 0.9), popped alive at 0.8, launch resolves at 1.2 —
    # past deadline exactly when the overflow wants to escalate.
    t2 = [0.0]

    def stepping_clock():
        t2[0] += 0.4
        return t2[0]

    sched2 = BatchScheduler(sc, max_batch=8, clock=stepping_clock)
    part = sched2.submit(rng.normal(size=(d,)), kind="threshold",
                         threshold=-1e9, capacity=1, deadline_s=0.5)
    sched2.step()
    res = part.result(0)
    assert res.status == "partial", res.status
    assert res.count == sc.n_valid, (res.count, sc.n_valid)
    assert len(res.indices) < res.count
    _, oi, _ = _oracle_threshold(sc, part)
    np.testing.assert_array_equal(res.indices, oi[:len(res.indices)])


def _check_admission(sc: ServingCorpus, rng) -> None:
    """Backpressure: the (max_queue + 1)-th waiting request is rejected
    with :class:`AdmissionError`; draining reopens admission
    (DESIGN.md section 15.1)."""
    sched = BatchScheduler(sc, max_batch=4, max_queue=3)
    d = sc.d
    for _ in range(3):
        sched.submit(rng.normal(size=(d,)), kind="topk", topk=2)
    try:
        sched.submit(rng.normal(size=(d,)), kind="topk", topk=2)
    except AdmissionError:
        pass
    else:
        raise AssertionError("no AdmissionError at max_queue")
    assert sched.counters["rejected"] == 1
    sched.drain()
    sched.submit(rng.normal(size=(d,)), kind="topk", topk=2)   # reopened
    sched.drain()


def _check_async_loop(sc: ServingCorpus, rng) -> None:
    """The background engine loop resolves requests submitted from the
    host thread (DESIGN.md section 15.1)."""
    sched = BatchScheduler(sc, max_batch=8)
    sched.start()
    try:
        reqs = [sched.submit(rng.normal(size=(sc.d,)), kind="topk", topk=3)
                for _ in range(10)]
        results = [r.result(timeout=120) for r in reqs]
        assert all(r.ok for r in results)
        for req, res in zip(reqs, results):
            _, oi = _oracle_topk(sc, req)
            np.testing.assert_array_equal(res.indices, oi)
    finally:
        sched.stop()


def main(nblocks: Optional[int] = None) -> None:
    """Scheduler selfcheck (DESIGN.md section 15.5): heterogeneous
    packed batches bit-exact vs the per-request oracles, deadline
    expiry/partial semantics, admission backpressure, and the async
    loop — the CI latency-smoke job runs this at P in {5, 8}."""
    import jax

    devs = jax.devices()
    P = nblocks or len(devs)
    assert len(devs) >= P, f"need {P} devices, have {len(devs)}"
    mesh = jax.make_mesh((P,), ("q",), devices=devs[:P])
    block, d = 16, 24
    rng = np.random.default_rng(0)
    N = P * block - block // 2          # ragged tail: validity masking on
    corpus = rng.normal(size=(N, d)).astype(np.float32)
    sc = ServingCorpus.build(corpus, mesh, block=block)

    st = _check_heterogeneous_pack(sc, rng)
    n_esc = _check_escalation(sc, rng)
    _check_deadlines(sc, rng)
    _check_admission(sc, rng)
    _check_async_loop(sc, rng)
    print(f"batching selfcheck OK: P={P} N={N} "
          f"requests={int(st['admitted'])} launches={int(st['launches'])} "
          f"escalations={n_esc} "
          f"programs={int(st['programs'])} p50={st['p50_s']:.4f}s "
          f"p99={st['p99_s']:.4f}s")


if __name__ == "__main__":
    import sys

    main(int(sys.argv[1]) if len(sys.argv) > 1 else None)

"""Distributed self-check for the online query serving subsystem.

Run as ``XLA_FLAGS=--xla_force_host_platform_device_count=<P> python -m
repro.serving.selfcheck [P] [modes] [placement]`` — the test suite
invokes this in a subprocess (dry-run isolation rule).  ``modes`` is an
optional comma-separated subset of the engine modes plus ``kernel`` (the
fused Pallas batched path); default: all of batched, overlap, scan,
kernel.  ``placement`` is an optional placement spec (registered name,
``auto``, or ``plane``); unset it defers to ``REPRO_PLACEMENT`` — plane
placements route covers over plane residency, full replication serves
from a single-device cover.

Checks, against a single-host brute-force oracle (same score formula and
(-score, index) tie order; indices are global row ids in the P*block slot
numbering, restricted to valid rows):
  1. cover-routed top-k over the quorum-sharded corpus matches the oracle
     exactly (indices) / to float tolerance (scores) in every mode, for
     both metrics, including a partially-filled corpus,
  2. after a streamed ``replace_block`` and an ``append_block`` the
     results track the updated corpus — updates really reach all k holder
     quorums through the ppermute push,
  3. the thresholded range-query path (``query_threshold``, DESIGN.md
     section 11.4) returns exactly the oracle's passing index set per
     query — in every mode, for both metrics, through the same streamed
     updates — including a capacity-escalation pass from a deliberately
     tiny starting capacity.
"""

from __future__ import annotations

import sys

import jax
import numpy as np

from ..core.allpairs import ENGINE_MODES
from ..core.placement import placement_from_env, resolve_placement
from ..core.sparse import threshold_with_gap
from .engine import IDX_SENTINEL, ServingCorpus

CHECK_MODES = ENGINE_MODES + ("kernel",)


def oracle_topk(full: np.ndarray, valid: np.ndarray, queries: np.ndarray,
                topk: int, metric: str):
    """Brute force on the host over the valid rows of the [P*block, d]
    slot-numbered corpus, same score formula and tie order as the engine."""
    rows = np.nonzero(valid)[0]
    c = full[rows].astype(np.float32)
    q = queries.astype(np.float32)
    s = q @ c.T
    if metric == "l2":
        s = 2.0 * s - (c * c).sum(-1)[None, :] - (q * q).sum(-1)[:, None]
    vals = np.empty((len(q), topk), np.float32)
    idx = np.empty((len(q), topk), np.int32)
    for r in range(len(q)):
        order = np.lexsort((rows, -s[r]))[:topk]   # by -score, then row id
        vals[r] = s[r, order]
        idx[r] = rows[order]
    return vals, idx


def check(full: np.ndarray, valid: np.ndarray, sc: ServingCorpus,
          queries: np.ndarray, topk: int, modes, label: str) -> None:
    """Top-k under every requested mode vs the brute-force oracle."""
    for metric in ("dot", "l2"):
        want_v, want_i = oracle_topk(full, valid, queries, topk, metric)
        for m in modes:
            mode, uk = ("batched", True) if m == "kernel" else (m, False)
            got_v, got_i = sc.query(queries, topk=topk, mode=mode,
                                    metric=metric, use_kernel=uk)
            got_v, got_i = np.asarray(got_v), np.asarray(got_i)
            assert not (got_i == IDX_SENTINEL).any(), (label, m, metric)
            np.testing.assert_array_equal(
                got_i, want_i, err_msg=f"{label} mode={m} metric={metric}")
            np.testing.assert_allclose(
                got_v, want_v, rtol=1e-5, atol=1e-5,
                err_msg=f"{label} mode={m} metric={metric}")


def oracle_threshold(full: np.ndarray, valid: np.ndarray,
                     queries: np.ndarray, threshold: float, metric: str):
    """Brute force range query: per query, the valid rows scoring >=
    threshold, sorted by ascending row id (the engine's canonical
    order)."""
    rows = np.nonzero(valid)[0]
    c = full[rows].astype(np.float32)
    q = queries.astype(np.float32)
    s = q @ c.T
    if metric == "l2":
        s = 2.0 * s - (c * c).sum(-1)[None, :] - (q * q).sum(-1)[:, None]
    out = []
    for r in range(len(q)):
        keep = s[r] >= threshold
        out.append((rows[keep], s[r][keep]))
    return out


def check_threshold(full: np.ndarray, valid: np.ndarray, sc: ServingCorpus,
                    queries: np.ndarray, modes, label: str) -> None:
    """Thresholded range query (DESIGN.md 11.4) vs the brute-force
    oracle: exact index sets per query, counts, sentinels, and a
    capacity-escalation pass."""
    engine_modes = [m for m in modes if m != "kernel"]
    for metric in ("dot", "l2"):
        # a gap-placed threshold so membership is float-rounding-proof
        # (the shared idiom of core.sparse, DESIGN.md 11.3)
        rows = np.nonzero(valid)[0]
        c = full[rows].astype(np.float32)
        s = queries.astype(np.float32) @ c.T
        if metric == "l2":
            s = (2.0 * s - (c * c).sum(-1)[None, :]
                 - (queries.astype(np.float32) ** 2).sum(-1)[:, None])
        thr = threshold_with_gap(s, 0.1)
        want = oracle_threshold(full, valid, queries, thr, metric)
        for m in engine_modes:
            got_v, got_i, got_c = sc.query_threshold(
                queries, threshold=thr, mode=m, metric=metric)
            got_v, got_i = np.asarray(got_v), np.asarray(got_i)
            got_c = np.asarray(got_c)
            for r, (wi, wv) in enumerate(want):
                n = int(got_c[r])
                assert n == len(wi), (label, m, metric, r, n, len(wi))
                np.testing.assert_array_equal(
                    got_i[r, :n], wi,
                    err_msg=f"{label} mode={m} metric={metric} q={r}")
                assert (got_i[r, n:] == IDX_SENTINEL).all(), (label, m, r)
                np.testing.assert_allclose(
                    got_v[r, :n], wv, rtol=1e-5, atol=1e-5,
                    err_msg=f"{label} mode={m} metric={metric} q={r}")
    # escalation regression: a tiny starting capacity must double up to
    # the same exact answer (program cache keyed per capacity)
    want = oracle_threshold(full, valid, queries, thr, "l2")
    got_v, got_i, got_c = sc.query_threshold(queries, threshold=thr,
                                             capacity=2, metric="l2")
    assert got_i.shape[1] >= max(len(w[0]) for w in want), got_i.shape
    for r, (wi, _) in enumerate(want):
        np.testing.assert_array_equal(np.asarray(got_i)[r, :len(wi)], wi)


def main(nblocks: int | None = None,
         modes: tuple[str, ...] = CHECK_MODES,
         placement: str | None = None) -> None:
    """Run the serving selfcheck (see module docstring for the CLI)."""
    devs = jax.devices()
    Pn = nblocks or len(devs)
    assert len(devs) >= Pn, f"need {Pn} devices, have {len(devs)}"
    plc = (placement_from_env(Pn) if placement is None
           else resolve_placement(placement, Pn))
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    block, d, Q, topk = 16, 24, 12, 8
    rng = np.random.default_rng(0)
    # leave one block's worth of rows empty: exercises validity masking
    # at build time and gives append_block somewhere to land (degenerate
    # small P keeps at least half a block of corpus and skips the append)
    N = max(block // 2, Pn * block - block)
    corpus = rng.normal(size=(N, d)).astype(np.float32)
    queries = rng.normal(size=(Q, d)).astype(np.float32)

    sc = ServingCorpus.build(corpus, mesh, block=block, placement=plc)
    # host mirror in the global P*block slot numbering
    full = np.zeros((Pn * block, d), np.float32)
    full[:N] = corpus
    valid = np.arange(Pn * block) < N
    check(full, valid, sc, queries, topk, modes, "static")
    check_threshold(full, valid, sc, queries, modes, "static")

    # streamed replace: block 0 gets fewer, fresh vectors
    fresh = rng.normal(size=(block - 3, d)).astype(np.float32)
    sc.replace_block(0, fresh)
    full[:block] = 0.0
    full[:len(fresh)] = fresh
    valid[:block] = np.arange(block) < len(fresh)
    check(full, valid, sc, queries, topk, modes, "replace")
    check_threshold(full, valid, sc, queries, modes, "replace")

    # streamed append into the empty tail block
    if (sc.filled == 0).any():
        extra = rng.normal(size=(block, d)).astype(np.float32)
        b = sc.append_block(extra)
        assert b == Pn - 1, (b, Pn)
        full[b * block:(b + 1) * block] = extra
        valid[b * block:(b + 1) * block] = True
        check(full, valid, sc, queries, topk, modes, "append")

    plan = sc.plan
    print(f"serving selfcheck OK: P={Pn} placement={plc.describe()} "
          f"k={plan.k} cover={plan.n_cover}/{Pn} modes={','.join(modes)} "
          f"topk={topk} N_valid={int(valid.sum())}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else None,
         tuple(sys.argv[2].split(",")) if len(sys.argv) > 2 else CHECK_MODES,
         sys.argv[3] if len(sys.argv) > 3 else None)

"""The online query engine: cover-routed fused top-k over quorum stacks.

A query microbatch ``[Q, d]`` is broadcast to the cover devices
(serving/cover.py); each scores it against its resident ``[k, block, d]``
quorum stack under the dedup mask (so every corpus row scores exactly
once), selects a local top-k, and a ppermute tree merge combines the
per-device lists into the global ``[Q, topk]`` result in ceil(log2 P)
rounds (DESIGN.md section 9).  In this harness all P devices run the SPMD
program — non-cover devices contribute sentinel-only lists; a production
router would simply not send them the query.

Selection is everywhere by the total order **(-score, global index)** via
two-key ``lax.sort``, so results are deterministic and bit-identical
across execution modes, the fused kernel, and the brute-force oracle —
ties break toward the smaller corpus index.

Local scoring reuses the batch engine's mode surface (core.allpairs,
DESIGN.md section 4):

  * ``batched`` — one einsum over the whole stack + a single top-k over
    k*block candidates (fastest; O(Q * k * block) score memory).  An
    optional ``batch_fn`` (kernels/query_score.py via kernels.ops) fuses
    slot gather + scoring + dedup mask + the running top-k in one Pallas
    launch.
  * ``overlap`` — per-slot scoring unrolled with a tournament (pairwise
    tree) merge: slot scores are independent, so the log2(k)-deep merge
    exposes slot-level parallelism to the scheduler instead of the scan
    mode's k-long serial carry chain.
  * ``scan``    — lax.scan over slots with a running [Q, topk] carry
    (lowest memory; the correctness oracle).
  * ``auto``    — ``REPRO_ALLPAIRS_MODE`` override first (reusing
    :func:`core.allpairs.env_mode_override`), then batched while the
    score working set fits the ``REPRO_BATCH_BYTES_LIMIT`` budget, else
    overlap when k >= 3, else scan.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as PS

from ..core.allpairs import (ENGINE_MODES, auto_batch_bytes,
                             env_mode_override, mark_varying)
from ..core.placement import (Placement, get_placement, placement_from_env,
                              resolve_placement)
from ..core.scheduler import PairSchedule
from ..core.sparse import default_capacity
from ..kernels.ref import IDX_SENTINEL, NEG_INF, QUERY_METRICS as METRICS
from .cover import build_cover
from .stream import ServingState, build_state, replace_block

__all__ = [
    "IDX_SENTINEL",
    "topk_by_score",
    "merge_topk",
    "tree_merge_topk",
    "quorum_query_topk",
    "quorum_query_threshold",
    "ServingCorpus",
]



def _scores(queries: jax.Array, blk: jax.Array, metric: str) -> jax.Array:
    """[Q, d] x [block, d] -> [Q, block] under the chosen metric.

    ``l2`` scores are ``2 q.x - |x|^2 - |q|^2`` (= -|q - x|^2); the oracle
    and the fused kernel use the identical formula so float rounding, and
    therefore ranking, agree everywhere.
    """
    dot = queries @ blk.T
    if metric == "dot":
        return dot
    if metric == "l2":
        return (2.0 * dot - jnp.sum(blk * blk, axis=-1)[None, :]
                - jnp.sum(queries * queries, axis=-1)[:, None])
    raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")


def topk_by_score(vals: jax.Array, idx: jax.Array, topk: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Top-k along the last axis by the (-score, index) total order.

    Pads with (NEG_INF, IDX_SENTINEL) when fewer than ``topk`` candidates.
    """
    n = vals.shape[-1]
    if n < topk:
        pad = [(0, 0)] * (vals.ndim - 1) + [(0, topk - n)]
        vals = jnp.pad(vals, pad, constant_values=NEG_INF)
        idx = jnp.pad(idx, pad, constant_values=IDX_SENTINEL)
    sv, si = lax.sort((-vals, idx.astype(jnp.int32)), num_keys=2)
    return -sv[..., :topk], si[..., :topk]


def merge_topk(va, ia, vb, ib, topk: int) -> Tuple[jax.Array, jax.Array]:
    """Merge two candidate lists, deduplicating repeated corpus indices.

    Duplicates only arise from the tree merge's wraparound windows (the
    dedup mask guarantees each index is *scored* once), so copies carry
    identical scores and land adjacent under the two-key sort — the
    second copy is demoted to a sentinel and a re-sort restores order.
    """
    vals = jnp.concatenate([va, vb], axis=-1)
    idx = jnp.concatenate([ia, ib], axis=-1).astype(jnp.int32)
    sv, si = lax.sort((-vals, idx), num_keys=2)
    dup = jnp.concatenate(
        [jnp.zeros_like(si[..., :1], bool),
         (si[..., 1:] == si[..., :-1]) & (sv[..., 1:] == sv[..., :-1])],
        axis=-1)
    sv = jnp.where(dup, -NEG_INF, sv)          # sv holds negated scores
    si = jnp.where(dup, IDX_SENTINEL, si)
    sv, si = lax.sort((sv, si), num_keys=2)
    return -sv[..., :topk], si[..., :topk]


def tree_merge_topk(vals, idx, *, axis_name: str, P: int, topk: int):
    """Recursive-doubling merge: after ceil(log2 P) ppermute rounds every
    device holds the global top-k.  Round r pulls the running list from
    device i + 2^r; windows overlap when P is not a power of two, which
    the index dedup in :func:`merge_topk` absorbs exactly."""
    shift = 1
    while shift < P:
        perm = [(j, (j - shift) % P) for j in range(P)]
        ov = lax.ppermute(vals, axis_name, perm)
        oi = lax.ppermute(idx, axis_name, perm)
        vals, idx = merge_topk(vals, idx, ov, oi, topk)
        shift *= 2
    return vals, idx


def _select_mode(schedule: PairSchedule, queries, block: int, batch_fn) -> str:
    """``mode="auto"`` for the query engine, mirroring the batch engine's
    heuristic: env override (conflicts with a fused batch_fn raise), fused
    kernel -> batched, batched while the [Q, k*block] score working set
    (x2 for the sort copy) fits the byte budget, overlap when k >= 3."""
    env = env_mode_override()
    if env is not None:
        if batch_fn is not None and env != "batched":
            raise ValueError(
                f"REPRO_ALLPAIRS_MODE={env} conflicts with a fused batch_fn "
                "(the kernel only replaces the batched local scoring step)")
        return env
    if batch_fn is not None:
        return "batched"
    Q = queries.shape[0]
    itemsize = jnp.dtype(queries.dtype).itemsize
    if 2 * Q * schedule.k * block * itemsize <= auto_batch_bytes():
        return "batched"
    if schedule.k >= 3:
        return "overlap"
    return "scan"


def quorum_query_topk(
    queries: jax.Array,
    stack: jax.Array,
    stack_valid: jax.Array,
    mask_row: jax.Array,
    *,
    topk: int,
    axis_name: str,
    schedule: PairSchedule,
    mode: str = "auto",
    metric: str = "dot",
    batch_fn: Callable[..., Tuple[jax.Array, jax.Array]] | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Score a query microbatch against the corpus; global top-k per query.

    Must run inside shard_map over ``axis_name``.  Args (per device):
      queries     : [Q, d] replicated microbatch.
      stack       : [k, block, d] resident quorum stack (stream.py layout).
      stack_valid : [k, block] bool row validity for the stack.
      mask_row    : [k] this device's cover dedup mask row
                    (CoverPlan.mask_table, sharded; zero off-cover).
      batch_fn    : optional fused local step — called as
                    ``batch_fn(stack, queries, mask [k, block], gidx
                    [k, block]) -> (vals [Q, topk], idx [Q, topk])``
                    (kernels.ops.query_topk); implies ``batched``.

    Returns (scores [Q, topk], global corpus indices [Q, topk]); ties
    break toward smaller indices, missing candidates are (NEG_INF,
    IDX_SENTINEL).  Identical on every device after the tree merge.
    """
    if mode not in ENGINE_MODES + ("auto",):
        raise ValueError(f"mode must be one of {ENGINE_MODES + ('auto',)}, "
                         f"got {mode!r}")
    if batch_fn is not None and mode not in ("batched", "auto"):
        raise ValueError(
            f"batch_fn only replaces the batched local scoring step (got "
            f"mode={mode!r}); drop it or use mode='batched'")
    k, block, d = stack.shape
    mask_row = mask_row.reshape(-1)  # accept [1, k] shard_map leftovers
    if mode == "auto":
        mode = _select_mode(schedule, queries, block, batch_fn)

    P = schedule.P
    i = lax.axis_index(axis_name)
    gblocks = (i + jnp.asarray(schedule.shifts, jnp.int32)) % P      # [k]
    gidx = gblocks[:, None] * block + jnp.arange(block, dtype=jnp.int32)
    mask = (mask_row[:, None] > 0) & stack_valid                     # [k, block]

    if batch_fn is not None:
        vals, idx = batch_fn(stack, queries,
                             mask.astype(jnp.float32), gidx)
    elif mode == "batched":
        s = jnp.einsum("qd,sbd->qsb", queries, stack)
        if metric == "l2":
            s = (2.0 * s - jnp.sum(stack * stack, axis=-1)[None]
                 - jnp.sum(queries * queries, axis=-1)[:, None, None])
        elif metric != "dot":
            raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
        s = jnp.where(mask[None], s, NEG_INF)
        Q = queries.shape[0]
        midx = jnp.where(mask, gidx, IDX_SENTINEL)   # masked rows: sentinels
        flat_idx = jnp.broadcast_to(midx[None], (Q, k, block))
        vals, idx = topk_by_score(s.reshape(Q, k * block),
                                  flat_idx.reshape(Q, k * block), topk)
    elif mode == "scan":
        Q = queries.shape[0]

        def body(carry, inp):
            cv, ci = carry
            blk, vrow, grow = inp
            s = jnp.where(vrow[None], _scores(queries, blk, metric), NEG_INF)
            g = jnp.broadcast_to(jnp.where(vrow, grow, IDX_SENTINEL)[None],
                                 (Q, block))
            return merge_topk(cv, ci, s, g, topk), None

        init = (jnp.full((Q, topk), NEG_INF, queries.dtype),
                jnp.full((Q, topk), IDX_SENTINEL, jnp.int32))
        (vals, idx), _ = lax.scan(body, init, (stack, mask, gidx))
    else:  # overlap: unrolled per-slot scoring + tournament merge
        Q = queries.shape[0]
        lists = []
        for s_i in range(k):
            s = jnp.where(mask[s_i][None],
                          _scores(queries, stack[s_i], metric), NEG_INF)
            g = jnp.broadcast_to(
                jnp.where(mask[s_i], gidx[s_i], IDX_SENTINEL)[None],
                (Q, block))
            lists.append(topk_by_score(s, g, topk))
        while len(lists) > 1:
            nxt = []
            for j in range(0, len(lists) - 1, 2):
                nxt.append(merge_topk(*lists[j], *lists[j + 1], topk))
            if len(lists) % 2:
                nxt.append(lists[-1])
            lists = nxt
        vals, idx = lists[0]

    return tree_merge_topk(vals, idx, axis_name=axis_name, P=P, topk=topk)


def _compact_rows(vbuf, ibuf, cnt, keep, vals, idx, capacity: int):
    """Append each query row's kept entries to its (vbuf, ibuf) prefix.

    keep/vals/idx: [Q, M] candidates; positions are per-row
    ``cnt + cumsum(keep) - 1`` and entries past ``capacity`` drop while
    ``cnt`` grows by the true kept total — the same overflow contract as
    the batch sparse engine (core/sparse.py, DESIGN.md section 11.2).
    """
    keep_i = keep.astype(jnp.int32)
    pos = cnt[:, None] + jnp.cumsum(keep_i, axis=1) - 1
    pos = jnp.where(keep, pos, capacity)
    rows = lax.broadcasted_iota(jnp.int32, pos.shape, 0)
    vbuf = vbuf.at[rows, pos].set(vals.astype(vbuf.dtype), mode="drop")
    ibuf = ibuf.at[rows, pos].set(idx.astype(jnp.int32), mode="drop")
    return vbuf, ibuf, cnt + jnp.sum(keep_i, axis=1)


def _select_threshold_mode(schedule: PairSchedule, queries,
                           block: int) -> str:
    """``mode="auto"`` for the thresholded query path: the shared
    ``REPRO_ALLPAIRS_MODE`` override first, then batched while the
    [Q, k*block] score working set (x2 for the compaction copy) fits the
    ``REPRO_BATCH_BYTES_LIMIT`` budget, overlap when k >= 3, else scan —
    the same shape as the top-k heuristic minus the (inapplicable) fused
    kernel arm."""
    env = env_mode_override()
    if env is not None:
        return env
    Q = queries.shape[0]
    itemsize = jnp.dtype(queries.dtype).itemsize
    if 2 * Q * schedule.k * block * itemsize <= auto_batch_bytes():
        return "batched"
    if schedule.k >= 3:
        return "overlap"
    return "scan"


def quorum_query_threshold(
    queries: jax.Array,
    stack: jax.Array,
    stack_valid: jax.Array,
    mask_row: jax.Array,
    *,
    threshold: jax.Array,
    capacity: int,
    axis_name: str,
    schedule: PairSchedule,
    mode: str = "auto",
    metric: str = "dot",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Range query: every corpus row scoring >= threshold, per query.

    The sparse sibling of :func:`quorum_query_topk` (DESIGN.md section
    11.4): the same cover-routed local scoring under the dedup mask —
    each valid corpus row is scored by exactly one device — but instead
    of a top-k selection, passing rows are cumsum-compacted into
    fixed-capacity [Q, capacity] buffers, and a **ppermute ring gather**
    (P - 1 single-step shifts) appends every other device's passing
    prefix, so all devices end with the identical global result, sorted
    by ascending corpus index.

    Must run inside shard_map.  ``threshold`` is a traced f32 scalar (one
    compiled program serves any threshold at a given capacity).  Returns
    ``(scores [Q, capacity], indices [Q, capacity], count [Q])``; count
    is each query's TRUE passing total — ``count > capacity`` flags
    overflow (escalate per DESIGN.md 11.2; overflowing buffers keep a
    valid but device-order-dependent subset), and slots past
    ``min(count, capacity)`` hold (NEG_INF, IDX_SENTINEL) sentinels.
    """
    if mode not in ENGINE_MODES + ("auto",):
        raise ValueError(f"mode must be one of {ENGINE_MODES + ('auto',)}, "
                         f"got {mode!r}")
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
    k, block, d = stack.shape
    Q = queries.shape[0]
    mask_row = mask_row.reshape(-1)
    if mode == "auto":
        mode = _select_threshold_mode(schedule, queries, block)

    P = schedule.P
    i = lax.axis_index(axis_name)
    gblocks = (i + jnp.asarray(schedule.shifts, jnp.int32)) % P      # [k]
    gidx = gblocks[:, None] * block + jnp.arange(block, dtype=jnp.int32)
    mask = (mask_row[:, None] > 0) & stack_valid                     # [k, block]
    thr = jnp.asarray(threshold, jnp.float32)

    vbuf = mark_varying(jnp.full((Q, capacity), NEG_INF, jnp.float32),
                        axis_name)
    ibuf = mark_varying(jnp.full((Q, capacity), IDX_SENTINEL, jnp.int32),
                        axis_name)
    cnt = mark_varying(jnp.zeros((Q,), jnp.int32), axis_name)

    if mode == "batched":
        s = jnp.einsum("qd,sbd->qsb", queries, stack)
        if metric == "l2":
            s = (2.0 * s - jnp.sum(stack * stack, axis=-1)[None]
                 - jnp.sum(queries * queries, axis=-1)[:, None, None])
        keep = (s >= thr) & mask[None]
        vbuf, ibuf, cnt = _compact_rows(
            vbuf, ibuf, cnt, keep.reshape(Q, k * block),
            s.reshape(Q, k * block),
            jnp.broadcast_to(gidx[None], (Q, k, block)).reshape(Q, k * block),
            capacity)
    elif mode == "scan":
        def body(carry, inp):
            vb, ib, c = carry
            blk, mrow, grow = inp
            s = _scores(queries, blk, metric)
            keep = (s >= thr) & mrow[None]
            g = jnp.broadcast_to(grow[None], (Q, block))
            return _compact_rows(vb, ib, c, keep, s, g, capacity), None

        (vbuf, ibuf, cnt), _ = lax.scan(body, (vbuf, ibuf, cnt),
                                        (stack, mask, gidx))
    else:  # overlap: unrolled per-slot scoring, then one compaction
        slot_s, slot_keep, slot_g = [], [], []
        for s_i in range(k):
            s = _scores(queries, stack[s_i], metric)
            slot_s.append(s)
            slot_keep.append((s >= thr) & mask[s_i][None])
            slot_g.append(jnp.broadcast_to(gidx[s_i][None], (Q, block)))
        vbuf, ibuf, cnt = _compact_rows(
            vbuf, ibuf, cnt, jnp.concatenate(slot_keep, axis=1),
            jnp.concatenate(slot_s, axis=1),
            jnp.concatenate(slot_g, axis=1), capacity)

    # ppermute ring gather: append every other device's passing prefix
    perm = [(j, (j + 1) % P) for j in range(P)]
    cur = (vbuf, ibuf, cnt)
    slot_iota = lax.broadcasted_iota(jnp.int32, (Q, capacity), 1)
    for _ in range(1, P):
        cur = tuple(lax.ppermute(c, axis_name, perm) for c in cur)
        rv, ri, rc = cur
        valid_in = slot_iota < jnp.minimum(rc, capacity)[:, None]
        vbuf, ibuf, _unclamped = _compact_rows(vbuf, ibuf, cnt, valid_in,
                                               rv, ri, capacity)
        cnt = cnt + rc        # true totals, not the clamped append

    # canonical order: ascending corpus index (sentinels sort last)
    ibuf, vbuf = lax.sort((ibuf, vbuf), num_keys=1)
    return vbuf, ibuf, cnt


@functools.lru_cache(maxsize=64)
def threshold_fn(mesh, axis_name: str, capacity: int, mode: str,
                 metric: str, placement: Placement | None = None):
    """Build (and cache) the jitted distributed range-query program.

    Returns ``f(queries [Q, d], threshold, state) -> (scores [Q,
    capacity], idx [Q, capacity], count [Q])`` — cached per capacity
    like :func:`query_fn`; the threshold is a traced operand, so one
    compiled program serves every threshold value (DESIGN.md 11.4).
    """
    P = mesh.shape[axis_name]
    if placement is None:
        placement = get_placement("cyclic", P)
    sched = placement.schedule()
    plan = build_cover(P, placement)
    mask_table = jnp.asarray(plan.mask_table())          # [P, k]

    def body(queries, thr, stack, stack_valid, mask_row):
        vals, idx, cnt = quorum_query_threshold(
            queries, stack, stack_valid, mask_row, threshold=thr,
            capacity=capacity, axis_name=axis_name, schedule=sched,
            mode=mode, metric=metric)
        return vals[None], idx[None], cnt[None]   # [1, ...] per device

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(PS(), PS(), spec, spec, spec),
        out_specs=(spec, spec, spec)))

    def run(queries, threshold, state: ServingState):
        vals, idx, cnt = fn(queries, jnp.float32(threshold), state.stack,
                            state.stack_valid, mask_table)
        return vals[0], idx[0], cnt[0]      # all device copies identical

    return run


@functools.lru_cache(maxsize=64)
def query_fn(mesh, axis_name: str, topk: int, mode: str, metric: str,
             use_kernel: bool, placement: Placement | None = None):
    """Build (and cache) the jitted distributed query program.

    Returns ``f(queries [Q, d], state) -> (scores [Q, topk], idx [Q,
    topk])`` — re-jits only per microbatch shape, like nbody.forces_fn.
    ``placement`` selects the residency layer (None = cyclic; pass a
    memoized Placement — it is part of the program cache key).  The
    serving data plane is the generic shift pipeline for every placement
    (full replication degenerates to a one-device cover over an
    everything-resident stack; no allgather special case needed).
    """
    P = mesh.shape[axis_name]
    if placement is None:
        placement = get_placement("cyclic", P)
    sched = placement.schedule()
    plan = build_cover(P, placement)
    mask_table = jnp.asarray(plan.mask_table())          # [P, k]
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched local step")
        from ..kernels import ops as kops
        batch_fn = functools.partial(kops.query_topk, topk=topk,
                                     metric=metric)

    def body(queries, stack, stack_valid, mask_row):
        vals, idx = quorum_query_topk(
            queries, stack, stack_valid, mask_row, topk=topk,
            axis_name=axis_name, schedule=sched, mode=mode, metric=metric,
            batch_fn=batch_fn)
        return vals[None], idx[None]        # [1, Q, topk] per device

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(PS(), spec, spec, spec),
        out_specs=(spec, spec)))

    def run(queries, state: ServingState):
        vals, idx = fn(queries, state.stack, state.stack_valid, mask_table)
        return vals[0], idx[0]              # all device copies identical

    return run


class ServingCorpus:
    """Host-side handle: resident corpus state + cached query programs.

    >>> corpus = ServingCorpus.build(vectors, mesh)
    >>> scores, ids = corpus.query(q, topk=8)
    >>> corpus.replace_block(3, new_vectors)     # streamed, no reshuffle
    >>> corpus.append_block(more_vectors)        # lands in empty capacity
    """

    def __init__(self, mesh, axis_name: str, state: ServingState,
                 filled: np.ndarray, placement: Placement | None = None):
        self.mesh = mesh
        self.axis_name = axis_name
        self.state = state
        self.filled = filled                 # [P] valid-row count per block
        self.P = mesh.shape[axis_name]
        self.placement = (get_placement("cyclic", self.P)
                          if placement is None
                          else resolve_placement(placement, self.P))
        self.block = state.shard.shape[0] // self.P
        self.d = state.shard.shape[1]
        self.schedule = self.placement.schedule()
        self.plan = build_cover(self.P, self.placement)

    @classmethod
    def build(cls, corpus: np.ndarray, mesh, axis_name: str = "q",
              block: int | None = None, placement=None) -> "ServingCorpus":
        """``block`` (optional) reserves a larger per-block row capacity
        than ceil(N/P), leaving empty slots for streamed appends.
        ``placement`` picks the residency layer (a Placement or spec
        name); None defers to ``REPRO_PLACEMENT`` (default auto ==
        cyclic)."""
        P = mesh.shape[axis_name]
        plc = (placement_from_env(P) if placement is None
               else resolve_placement(placement, P))
        state = build_state(np.asarray(corpus, np.float32), mesh, axis_name,
                            block=block, placement=plc)
        block = state.shard.shape[0] // P
        N = corpus.shape[0]
        filled = np.clip(N - block * np.arange(P), 0, block).astype(np.int64)
        return cls(mesh, axis_name, state, filled, placement=plc)

    @property
    def n_valid(self) -> int:
        """Total valid corpus rows across all blocks."""
        return int(self.filled.sum())

    def query(self, queries, *, topk: int, mode: str = "auto",
              metric: str = "dot", use_kernel: bool = False):
        """queries [Q, d] -> (scores [Q, topk], global row ids [Q, topk])."""
        run = query_fn(self.mesh, self.axis_name, topk, mode, metric,
                       use_kernel, self.placement)
        return run(jnp.asarray(queries, jnp.float32), self.state)

    def query_threshold(self, queries, *, threshold: float,
                        capacity: int | None = None, mode: str = "auto",
                        metric: str = "dot", escalate: bool = True,
                        max_doublings: int = 16):
        """Range query: every corpus row with score >= threshold, per query.

        queries [Q, d] -> ``(scores [Q, capacity], global row ids
        [Q, capacity], count [Q])``, each query's hits sorted by
        ascending corpus index with (NEG_INF, IDX_SENTINEL) sentinels
        past ``count`` (:func:`quorum_query_threshold`, DESIGN.md
        section 11.4).  ``capacity`` defaults to the
        ``REPRO_SPARSE_CAPACITY``-aware heuristic and, under the
        overflow contract (DESIGN.md 11.2), doubles until every query's
        true ``count`` fits (capped at the corpus size); with
        ``escalate=False`` the first pass returns as-is — ``count >
        capacity`` then marks a truncated query.  The compiled program
        is cached per capacity, not per threshold.
        """
        total_rows = self.P * self.block
        cap = (int(capacity) if capacity is not None
               else min(default_capacity(total_rows), total_rows))
        q = jnp.asarray(queries, jnp.float32)
        escalations = 0
        while True:
            run = threshold_fn(self.mesh, self.axis_name, cap, mode, metric,
                               self.placement)
            vals, idx, cnt = run(q, threshold, self.state)
            counts = np.asarray(cnt)
            if (not (counts > cap).any() or not escalate
                    or cap >= total_rows or escalations >= max_doublings):
                break
            cap = min(2 * cap, total_rows)
            escalations += 1
        if escalate and (counts > cap).any():
            raise RuntimeError(
                f"thresholded query still overflows capacity {cap} after "
                f"{escalations} doublings; raise `capacity` or the "
                "threshold")
        return vals, idx, cnt

    def replace_block(self, b: int, data, nvalid: int | None = None) -> None:
        """Replace block ``b`` in place (streamed to its k holder quorums)."""
        if not 0 <= b < self.P:
            raise ValueError(f"block id {b} out of range [0, {self.P})")
        self.state = replace_block(self.state, self.mesh, self.axis_name,
                                   b, np.asarray(data, np.float32), nvalid,
                                   placement=self.placement)
        self.filled[b] = (data.shape[0] if nvalid is None else nvalid)

    def append_block(self, data) -> int:
        """Stream ``data`` (rows <= block capacity) into the first empty
        block slot; returns the block id it landed in."""
        empty = np.nonzero(self.filled == 0)[0]
        if empty.size == 0:
            raise ValueError(
                "corpus full: no empty block slot; grow the quorum axis "
                "(launch.elastic.rescale) to add capacity")
        b = int(empty[0])
        self.replace_block(b, data)
        return b

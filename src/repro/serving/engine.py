"""The online query engine: cover-routed fused top-k over quorum stacks.

A query microbatch ``[Q, d]`` is broadcast to the cover devices
(serving/cover.py); each scores it against its resident ``[k, block, d]``
quorum stack under the dedup mask (so every corpus row scores exactly
once), selects a local top-k, and a ppermute tree merge combines the
per-device lists into the global ``[Q, topk]`` result in ceil(log2 P)
rounds (DESIGN.md section 9).  In this harness all P devices run the SPMD
program — non-cover devices contribute sentinel-only lists; a production
router would simply not send them the query.

Selection is everywhere by the total order **(-score, global index)** via
two-key ``lax.sort``, so results are deterministic and bit-identical
across execution modes, the fused kernel, and the brute-force oracle —
ties break toward the smaller corpus index.

Local scoring is a *slot sweep* on the unified pair-sweep runtime
(core/sweep.py, DESIGN.md section 12): the work items are the k resident
slots (``sweep.slot_items``) instead of the schedule's slot pairs, the
stack is already resident (no gather), and the runtime's shared mode
surface applies (DESIGN.md section 4):

  * ``batched`` — one einsum over the whole stack + a single top-k over
    k*block candidates (fastest; O(Q * k * block) score memory).  An
    optional ``batch_fn`` (kernels/query_score.py via kernels.ops) fuses
    slot gather + scoring + dedup mask + the running top-k in one Pallas
    launch.
  * ``overlap`` — per-slot scoring unrolled with a tournament (pairwise
    tree) merge: slot scores are independent, so the log2(k)-deep merge
    exposes slot-level parallelism to the scheduler instead of the scan
    mode's k-long serial carry chain.
  * ``scan``    — lax.scan over slots with a running [Q, topk] carry
    (lowest memory; the correctness oracle).
  * ``auto``    — the shared heuristic (``REPRO_ALLPAIRS_MODE`` override
    first, then batched while the score working set fits the
    ``REPRO_BATCH_BYTES_LIMIT`` budget, else overlap when k >= 3, else
    scan).
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as PS

from ..core import sweep as sweep_mod
from ..core.allpairs import mark_varying
from ..core.placement import (Placement, get_placement, placement_from_env,
                              resolve_placement)
from ..core.scheduler import PairSchedule
from ..core.sparse import default_capacity
from ..core.sweep import SweepEmitter, merge_topk, slot_items, topk_by_score
from ..kernels.ref import IDX_SENTINEL, NEG_INF, QUERY_METRICS as METRICS
from ..obs import trace as obs_trace
from .cover import build_cover
from .stream import ServingState, build_state, replace_block

__all__ = [
    "IDX_SENTINEL",
    "topk_by_score",
    "merge_topk",
    "tree_merge_topk",
    "quantize_pow2",
    "quorum_query_topk",
    "quorum_query_threshold",
    "QueryTopKEmitter",
    "QueryThresholdEmitter",
    "ServingCorpus",
]


def quantize_pow2(n: int, floor: int = 1) -> int:
    """Round ``n`` up to the smallest power of two >= max(n, floor).

    The program-cache quantizer (DESIGN.md section 15.2): request-shape
    parameters (``topk``, range-query ``capacity``, packed microbatch
    width) are bucketed onto powers of two before they become jit
    program-cache keys, so heterogeneous traffic compiles O(log N)
    programs instead of one per observed value — and capacity
    escalation (doubling) maps onto the *same* bucket set instead of
    flooding the LRU with one entry per escalated size.
    """
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()



def _scores(queries: jax.Array, blk: jax.Array, metric: str) -> jax.Array:
    """[Q, d] x [block, d] -> [Q, block] under the chosen metric.

    ``l2`` scores are ``2 q.x - |x|^2 - |q|^2`` (= -|q - x|^2); the oracle
    and the fused kernel use the identical formula so float rounding, and
    therefore ranking, agree everywhere.
    """
    dot = queries @ blk.T
    if metric == "dot":
        return dot
    if metric == "l2":
        return (2.0 * dot - jnp.sum(blk * blk, axis=-1)[None, :]
                - jnp.sum(queries * queries, axis=-1)[:, None])
    raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")


def tree_merge_topk(vals, idx, *, axis_name: str, P: int, topk: int):
    """Recursive-doubling merge: after ceil(log2 P) ppermute rounds every
    device holds the global top-k.  Round r pulls the running list from
    device i + 2^r; windows overlap when P is not a power of two, which
    the index dedup in :func:`core.sweep.merge_topk` absorbs exactly."""
    tr = obs_trace.get_tracer()
    shift = 1
    while shift < P:
        perm = [(j, (j - shift) % P) for j in range(P)]
        if tr:  # per hop: the running (vals, idx) candidate payload
            tr.count("comm.ppermute.merge_hops")
            tr.count("comm.ppermute.merge_bytes",
                     obs_trace.nbytes_of(vals) + obs_trace.nbytes_of(idx))
        ov = lax.ppermute(vals, axis_name, perm)
        oi = lax.ppermute(idx, axis_name, perm)
        vals, idx = merge_topk(vals, idx, ov, oi, topk)
        shift *= 2
    return vals, idx


def _select_mode(schedule: PairSchedule, queries, block: int, batch_fn) -> str:
    """The query engine's ``mode="auto"`` working set fed to the shared
    heuristic (core/sweep.py select_mode): the [Q, k*block] score tensor
    (x2 for the sort copy)."""
    Q = queries.shape[0]
    itemsize = jnp.dtype(queries.dtype).itemsize
    return sweep_mod.select_mode(
        schedule, 2 * Q * schedule.k * block * itemsize, batch_fn)


def _query_geometry(schedule: PairSchedule, axis_name: str, block: int,
                    mask_row, stack_valid):
    """Shared per-device geometry of both query paths: global row ids
    [k, block] and the cover-dedup x validity mask [k, block]."""
    P = schedule.P
    i = lax.axis_index(axis_name)
    gblocks = (i + jnp.asarray(schedule.shifts, jnp.int32)) % P      # [k]
    gidx = gblocks[:, None] * block + jnp.arange(block, dtype=jnp.int32)
    mask = (mask_row[:, None] > 0) & stack_valid                     # [k, block]
    return gidx, mask


class QueryTopKEmitter(SweepEmitter):
    """Per-row top-k selection over the resident slot sweep (DESIGN.md
    sections 9.2, 12.2 — the serving top-k workload).

    Each slot's [Q, block] score tile is masked (cover dedup x row
    validity) and folded into a running [Q, topk] (value, index) list
    under the (-score, index) total order; the three modes fold
    differently (single sort / serial merge / tournament merge) but
    select identically.
    """

    def __init__(self, schedule: PairSchedule, queries, mask, gidx,
                 topk: int, metric: str, batch_fn=None):
        self.schedule = schedule
        self.queries = queries
        self.mask = mask
        self.gidx = gidx
        self.topk = topk
        self.metric = metric
        self.batch_fn = batch_fn

    def items(self):
        """Slot sweep: one work item per resident slot."""
        return slot_items(self.schedule.k)

    def batch(self, quorum):
        """One einsum over the whole stack + a single top-k over all
        k*block candidates (or the fused kernel via ``batch_fn``)."""
        k, block = quorum.shape[0], quorum.shape[1]
        if self.batch_fn is not None:
            return self.batch_fn(quorum, self.queries,
                                 self.mask.astype(jnp.float32), self.gidx)
        s = jnp.einsum("qd,sbd->qsb", self.queries, quorum)
        if self.metric == "l2":
            s = (2.0 * s - jnp.sum(quorum * quorum, axis=-1)[None]
                 - jnp.sum(self.queries * self.queries, axis=-1)[:, None, None])
        elif self.metric != "dot":
            raise ValueError(
                f"metric must be one of {METRICS}, got {self.metric!r}")
        s = jnp.where(self.mask[None], s, NEG_INF)
        Q = self.queries.shape[0]
        midx = jnp.where(self.mask, self.gidx, IDX_SENTINEL)
        flat_idx = jnp.broadcast_to(midx[None], (Q, k, block))
        return topk_by_score(s.reshape(Q, k * block),
                             flat_idx.reshape(Q, k * block), self.topk)

    def scan_init(self):
        """Sentinel-filled [Q, topk] running lists."""
        Q = self.queries.shape[0]
        return (jnp.full((Q, self.topk), NEG_INF, self.queries.dtype),
                jnp.full((Q, self.topk), IDX_SENTINEL, jnp.int32))

    def scan_items(self):
        """(slot, mask row, global-id row) per resident slot."""
        k = self.schedule.k
        return (jnp.arange(k, dtype=jnp.int32), self.mask, self.gidx)

    def scan_emit(self, carry, quorum, item):
        """Merge one slot's masked scores into the running list."""
        cv, ci = carry
        slot, vrow, grow = item
        blk = jnp.take(quorum, slot, axis=0)
        Q, block = self.queries.shape[0], blk.shape[0]
        s = jnp.where(vrow[None], _scores(self.queries, blk, self.metric),
                      NEG_INF)
        g = jnp.broadcast_to(jnp.where(vrow, grow, IDX_SENTINEL)[None],
                             (Q, block))
        return merge_topk(cv, ci, s, g, self.topk)

    def overlap_begin(self):
        """The per-slot candidate lists the tournament merge folds."""
        return []

    def overlap_emit(self, lists, idx, bi, bj):
        """Select each slot's local top-k as its scores materialize."""
        Q, block = self.queries.shape[0], bi.shape[0]
        s = jnp.where(self.mask[idx][None],
                      _scores(self.queries, bi, self.metric), NEG_INF)
        g = jnp.broadcast_to(
            jnp.where(self.mask[idx], self.gidx[idx], IDX_SENTINEL)[None],
            (Q, block))
        lists.append(topk_by_score(s, g, self.topk))

    def overlap_finalize(self, lists):
        """Pairwise tournament merge: log2(k) depth instead of the scan
        mode's serial carry chain."""
        while len(lists) > 1:
            nxt = []
            for j in range(0, len(lists) - 1, 2):
                nxt.append(merge_topk(*lists[j], *lists[j + 1], self.topk))
            if len(lists) % 2:
                nxt.append(lists[-1])
            lists = nxt
        return lists[0]


def quorum_query_topk(
    queries: jax.Array,
    stack: jax.Array,
    stack_valid: jax.Array,
    mask_row: jax.Array,
    *,
    topk: int,
    axis_name: str,
    schedule: PairSchedule,
    mode: str = "auto",
    metric: str = "dot",
    batch_fn: Callable[..., Tuple[jax.Array, jax.Array]] | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Score a query microbatch against the corpus; global top-k per query.

    Must run inside shard_map over ``axis_name``.  Args (per device):
      queries     : [Q, d] replicated microbatch.
      stack       : [k, block, d] resident quorum stack (stream.py layout).
      stack_valid : [k, block] bool row validity for the stack.
      mask_row    : [k] this device's cover dedup mask row
                    (CoverPlan.mask_table, sharded; zero off-cover).
      batch_fn    : optional fused local step — called as
                    ``batch_fn(stack, queries, mask [k, block], gidx
                    [k, block]) -> (vals [Q, topk], idx [Q, topk])``
                    (kernels.ops.query_topk); implies ``batched``.

    Returns (scores [Q, topk], global corpus indices [Q, topk]); ties
    break toward smaller indices, missing candidates are (NEG_INF,
    IDX_SENTINEL).  Identical on every device after the tree merge.
    """
    sweep_mod.validate_mode(mode, batch_fn)
    k, block, d = stack.shape
    mask_row = mask_row.reshape(-1)  # accept [1, k] shard_map leftovers
    if mode == "auto":
        mode = _select_mode(schedule, queries, block, batch_fn)

    gidx, mask = _query_geometry(schedule, axis_name, block, mask_row,
                                 stack_valid)
    emitter = QueryTopKEmitter(schedule, queries, mask, gidx, topk, metric,
                               batch_fn=batch_fn)
    vals, idx = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                     axis_name=axis_name, mode=mode,
                                     stack=stack)
    return tree_merge_topk(vals, idx, axis_name=axis_name, P=schedule.P,
                           topk=topk)


def _compact_rows(vbuf, ibuf, cnt, keep, vals, idx, capacity: int):
    """Append each query row's kept entries to its (vbuf, ibuf) prefix.

    keep/vals/idx: [Q, M] candidates; positions are per-row
    ``cnt + cumsum(keep) - 1`` and entries past ``capacity`` drop while
    ``cnt`` grows by the true kept total — the same overflow contract as
    the batch sparse engine (core/sparse.py, DESIGN.md section 11.2).
    """
    keep_i = keep.astype(jnp.int32)
    pos = cnt[:, None] + jnp.cumsum(keep_i, axis=1) - 1
    pos = jnp.where(keep, pos, capacity)
    rows = lax.broadcasted_iota(jnp.int32, pos.shape, 0)
    vbuf = vbuf.at[rows, pos].set(vals.astype(vbuf.dtype), mode="drop")
    ibuf = ibuf.at[rows, pos].set(idx.astype(jnp.int32), mode="drop")
    return vbuf, ibuf, cnt + jnp.sum(keep_i, axis=1)


def _select_threshold_mode(schedule: PairSchedule, queries,
                           block: int) -> str:
    """``mode="auto"`` for the thresholded query path — the same shared
    heuristic and working set as the top-k path, minus the
    (inapplicable) fused kernel arm."""
    return _select_mode(schedule, queries, block, None)


class QueryThresholdEmitter(SweepEmitter):
    """Per-query fixed-capacity threshold compaction over the resident
    slot sweep (DESIGN.md sections 11.4, 12.2 — the range-query
    workload).

    Each slot's passing (score, index) entries are cumsum-compacted into
    [Q, capacity] buffers under the overflow contract of DESIGN.md 11.2;
    the adapter appends the other devices' prefixes with a ppermute ring
    gather afterwards.
    """

    def __init__(self, schedule: PairSchedule, queries, mask, gidx,
                 thr, capacity: int, metric: str, axis_name: str):
        self.schedule = schedule
        self.queries = queries
        self.mask = mask
        self.gidx = gidx
        self.thr = thr
        self.capacity = capacity
        self.metric = metric
        self.axis_name = axis_name

    def items(self):
        """Slot sweep: one work item per resident slot."""
        return slot_items(self.schedule.k)

    def _init_bufs(self):
        """Sentinel-filled [Q, capacity] buffers + zero counts
        (varying-marked)."""
        Q = self.queries.shape[0]
        vbuf = mark_varying(jnp.full((Q, self.capacity), NEG_INF,
                                     jnp.float32), self.axis_name)
        ibuf = mark_varying(jnp.full((Q, self.capacity), IDX_SENTINEL,
                                     jnp.int32), self.axis_name)
        cnt = mark_varying(jnp.zeros((Q,), jnp.int32), self.axis_name)
        return vbuf, ibuf, cnt

    def batch(self, quorum):
        """One einsum over the whole stack + a single compaction."""
        k, block = quorum.shape[0], quorum.shape[1]
        Q = self.queries.shape[0]
        vbuf, ibuf, cnt = self._init_bufs()
        s = jnp.einsum("qd,sbd->qsb", self.queries, quorum)
        if self.metric == "l2":
            s = (2.0 * s - jnp.sum(quorum * quorum, axis=-1)[None]
                 - jnp.sum(self.queries * self.queries, axis=-1)[:, None, None])
        keep = (s >= self.thr[:, None, None]) & self.mask[None]
        return _compact_rows(
            vbuf, ibuf, cnt, keep.reshape(Q, k * block),
            s.reshape(Q, k * block),
            jnp.broadcast_to(self.gidx[None], (Q, k, block)
                             ).reshape(Q, k * block),
            self.capacity)

    def scan_init(self):
        """Empty per-query compaction buffers."""
        return self._init_bufs()

    def scan_items(self):
        """(slot, mask row, global-id row) per resident slot."""
        k = self.schedule.k
        return (jnp.arange(k, dtype=jnp.int32), self.mask, self.gidx)

    def scan_emit(self, carry, quorum, item):
        """Compact one slot's passing entries into the running buffers."""
        vb, ib, c = carry
        slot, mrow, grow = item
        blk = jnp.take(quorum, slot, axis=0)
        Q, block = self.queries.shape[0], blk.shape[0]
        s = _scores(self.queries, blk, self.metric)
        keep = (s >= self.thr[:, None]) & mrow[None]
        g = jnp.broadcast_to(grow[None], (Q, block))
        return _compact_rows(vb, ib, c, keep, s, g, self.capacity)

    def overlap_begin(self):
        """Per-slot (scores, keep, ids) lists for the single deferred
        compaction."""
        return {"s": [], "keep": [], "g": []}

    def overlap_emit(self, state, idx, bi, bj):
        """Score one slot as it lands; compaction is deferred so the
        slot scores stay independent for the scheduler."""
        Q, block = self.queries.shape[0], bi.shape[0]
        s = _scores(self.queries, bi, self.metric)
        state["s"].append(s)
        state["keep"].append((s >= self.thr[:, None]) & self.mask[idx][None])
        state["g"].append(jnp.broadcast_to(self.gidx[idx][None], (Q, block)))

    def overlap_finalize(self, state):
        """One compaction over every slot's concatenated candidates."""
        vbuf, ibuf, cnt = self._init_bufs()
        return _compact_rows(
            vbuf, ibuf, cnt, jnp.concatenate(state["keep"], axis=1),
            jnp.concatenate(state["s"], axis=1),
            jnp.concatenate(state["g"], axis=1), self.capacity)


def quorum_query_threshold(
    queries: jax.Array,
    stack: jax.Array,
    stack_valid: jax.Array,
    mask_row: jax.Array,
    *,
    threshold: jax.Array,
    capacity: int,
    axis_name: str,
    schedule: PairSchedule,
    mode: str = "auto",
    metric: str = "dot",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Range query: every corpus row scoring >= threshold, per query.

    The sparse sibling of :func:`quorum_query_topk` (DESIGN.md section
    11.4): the same cover-routed local scoring under the dedup mask —
    each valid corpus row is scored by exactly one device — but instead
    of a top-k selection, passing rows are cumsum-compacted into
    fixed-capacity [Q, capacity] buffers, and a **ppermute ring gather**
    (P - 1 single-step shifts) appends every other device's passing
    prefix, so all devices end with the identical global result, sorted
    by ascending corpus index.

    Must run inside shard_map.  ``threshold`` is a traced f32 scalar or a
    per-query ``[Q]`` vector (one compiled program serves any threshold
    values at a given capacity — the per-query form is what lets the
    continuous batcher pack requests with different thresholds into one
    launch, DESIGN.md section 15.2).  Returns
    ``(scores [Q, capacity], indices [Q, capacity], count [Q])``; count
    is each query's TRUE passing total — ``count > capacity`` flags
    overflow (escalate per DESIGN.md 11.2; overflowing buffers keep a
    valid but device-order-dependent subset), and slots past
    ``min(count, capacity)`` hold (NEG_INF, IDX_SENTINEL) sentinels.
    """
    sweep_mod.validate_mode(mode, None)
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
    k, block, d = stack.shape
    Q = queries.shape[0]
    mask_row = mask_row.reshape(-1)
    if mode == "auto":
        mode = _select_threshold_mode(schedule, queries, block)

    P = schedule.P
    gidx, mask = _query_geometry(schedule, axis_name, block, mask_row,
                                 stack_valid)
    thr = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32), (Q,))
    emitter = QueryThresholdEmitter(schedule, queries, mask, gidx, thr,
                                    capacity, metric, axis_name)
    vbuf, ibuf, cnt = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                           axis_name=axis_name, mode=mode,
                                           stack=stack)

    # ppermute ring gather: append every other device's passing prefix
    tr = obs_trace.get_tracer()
    perm = [(j, (j + 1) % P) for j in range(P)]
    cur = (vbuf, ibuf, cnt)
    slot_iota = lax.broadcasted_iota(jnp.int32, (Q, capacity), 1)
    for _ in range(1, P):
        if tr:  # per hop: the three ring buffers (vals, idx, count)
            tr.count("comm.ppermute.ring_hops")
            tr.count("comm.ppermute.ring_bytes",
                     sum(obs_trace.nbytes_of(c) for c in cur))
        cur = tuple(lax.ppermute(c, axis_name, perm) for c in cur)
        rv, ri, rc = cur
        valid_in = slot_iota < jnp.minimum(rc, capacity)[:, None]
        vbuf, ibuf, _unclamped = _compact_rows(vbuf, ibuf, cnt, valid_in,
                                               rv, ri, capacity)
        cnt = cnt + rc        # true totals, not the clamped append

    # canonical order: ascending corpus index (sentinels sort last)
    ibuf, vbuf = lax.sort((ibuf, vbuf), num_keys=1)
    return vbuf, ibuf, cnt


@functools.lru_cache(maxsize=64)
def threshold_fn(mesh, axis_name: str, capacity: int, mode: str,
                 metric: str, placement: Placement | None = None):
    """Build (and cache) the jitted distributed range-query program.

    Returns ``f(queries [Q, d], threshold, state) -> (scores [Q,
    capacity], idx [Q, capacity], count [Q])`` — cached per capacity
    like :func:`query_fn`; the threshold (scalar or per-query ``[Q]``
    vector) is a traced operand, so one compiled program serves every
    threshold value (DESIGN.md 11.4).  Callers are expected to
    pre-quantize ``capacity`` through :func:`quantize_pow2` so the LRU
    holds one entry per power-of-two bucket, not one per observed
    capacity (DESIGN.md section 15.2).
    """
    P = mesh.shape[axis_name]
    if placement is None:
        placement = get_placement("cyclic", P)
    sched = placement.schedule()
    plan = build_cover(P, placement)
    mask_table = jnp.asarray(plan.mask_table())          # [P, k]

    def body(queries, thr, stack, stack_valid, mask_row):
        vals, idx, cnt = quorum_query_threshold(
            queries, stack, stack_valid, mask_row, threshold=thr,
            capacity=capacity, axis_name=axis_name, schedule=sched,
            mode=mode, metric=metric)
        return vals[None], idx[None], cnt[None]   # [1, ...] per device

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(PS(), PS(), spec, spec, spec),
        out_specs=(spec, spec, spec)))

    def run(queries, threshold, state: ServingState):
        thr = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32),
                               (queries.shape[0],))
        vals, idx, cnt = fn(queries, thr, state.stack,
                            state.stack_valid, mask_table)
        return vals[0], idx[0], cnt[0]      # all device copies identical

    return run


@functools.lru_cache(maxsize=64)
def query_fn(mesh, axis_name: str, topk: int, mode: str, metric: str,
             use_kernel: bool, placement: Placement | None = None):
    """Build (and cache) the jitted distributed query program.

    Returns ``f(queries [Q, d], state) -> (scores [Q, topk], idx [Q,
    topk])`` — re-jits only per microbatch shape, like nbody.forces_fn.
    ``placement`` selects the residency layer (None = cyclic; pass a
    memoized Placement — it is part of the program cache key).  The
    serving data plane is the generic shift pipeline for every placement
    (full replication degenerates to a one-device cover over an
    everything-resident stack; no allgather special case needed).
    """
    P = mesh.shape[axis_name]
    if placement is None:
        placement = get_placement("cyclic", P)
    sched = placement.schedule()
    plan = build_cover(P, placement)
    mask_table = jnp.asarray(plan.mask_table())          # [P, k]
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched local step")
        from ..kernels import ops as kops
        batch_fn = functools.partial(kops.query_topk, topk=topk,
                                     metric=metric)

    def body(queries, stack, stack_valid, mask_row):
        vals, idx = quorum_query_topk(
            queries, stack, stack_valid, mask_row, topk=topk,
            axis_name=axis_name, schedule=sched, mode=mode, metric=metric,
            batch_fn=batch_fn)
        return vals[None], idx[None]        # [1, Q, topk] per device

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(PS(), spec, spec, spec),
        out_specs=(spec, spec)))

    def run(queries, state: ServingState):
        vals, idx = fn(queries, state.stack, state.stack_valid, mask_table)
        return vals[0], idx[0]              # all device copies identical

    return run


class ServingCorpus:
    """Host-side handle: resident corpus state + cached query programs.

    >>> corpus = ServingCorpus.build(vectors, mesh)
    >>> scores, ids = corpus.query(q, topk=8)
    >>> corpus.replace_block(3, new_vectors)     # streamed, no reshuffle
    >>> corpus.append_block(more_vectors)        # lands in empty capacity
    """

    def __init__(self, mesh, axis_name: str, state: ServingState,
                 filled: np.ndarray, placement: Placement | None = None):
        self.mesh = mesh
        self.axis_name = axis_name
        self.state = state
        self.filled = filled                 # [P] valid-row count per block
        self.P = mesh.shape[axis_name]
        self.placement = (get_placement("cyclic", self.P)
                          if placement is None
                          else resolve_placement(placement, self.P))
        self.block = state.shard.shape[0] // self.P
        self.d = state.shard.shape[1]
        self.schedule = self.placement.schedule()
        self.plan = build_cover(self.P, self.placement)
        self.quant = None        # QuantServing when built with quant != off

    @classmethod
    def build(cls, corpus: np.ndarray, mesh, axis_name: str = "q",
              block: int | None = None, placement=None,
              quant: str | None = None) -> "ServingCorpus":
        """``block`` (optional) reserves a larger per-block row capacity
        than ceil(N/P), leaving empty slots for streamed appends.
        ``placement`` picks the residency layer (a Placement or spec
        name); None defers to ``REPRO_PLACEMENT`` (default auto ==
        cyclic).  ``quant`` additionally keeps a quantized resident
        stack (core/quant.py QuantServing; DESIGN.md section 17.4) the
        :meth:`query` path scores against with certified exact
        rescoring — ``"int8"``/``"bf16"`` enable it, ``"off"`` stays
        pure f32, None defers to ``REPRO_QUANT``."""
        P = mesh.shape[axis_name]
        plc = (placement_from_env(P) if placement is None
               else resolve_placement(placement, P))
        state = build_state(np.asarray(corpus, np.float32), mesh, axis_name,
                            block=block, placement=plc)
        block = state.shard.shape[0] // P
        N = corpus.shape[0]
        filled = np.clip(N - block * np.arange(P), 0, block).astype(np.int64)
        out = cls(mesh, axis_name, state, filled, placement=plc)
        from ..core.quant import QuantServing, quant_from_env
        qmode = quant_from_env() if quant is None else quant
        if qmode != "off":
            rows = np.zeros((P * block, corpus.shape[1]), np.float32)
            rows[:N] = np.asarray(corpus, np.float32)
            out.quant = QuantServing(qmode, mesh, axis_name, out.schedule,
                                     block, rows)
        return out

    @property
    def n_valid(self) -> int:
        """Total valid corpus rows across all blocks."""
        return int(self.filled.sum())

    def query(self, queries, *, topk: int, mode: str = "auto",
              metric: str = "dot", use_kernel: bool = False):
        """queries [Q, d] -> (scores [Q, topk], global row ids [Q, topk]).

        The compiled program is keyed on the power-of-two bucket
        ``quantize_pow2(topk)`` rather than the raw ``topk`` (DESIGN.md
        section 15.2) and the result is sliced back to ``topk`` columns
        — exact by the prefix property of the (-score, index) total
        order: the first k entries of a top-K list *are* the top-k list.
        Heterogeneous k therefore share one program per bucket.

        With tracing on, each call is a ``serving.query`` host span
        (blocked until the result is device-complete, so the span is
        true end-to-end latency) and a ``serving.queries`` counter
        (DESIGN.md section 14.2).

        A corpus built with ``quant != "off"`` scores against its
        quantized resident stack and rescores the certified candidates
        exactly (core/quant.py serving_query; DESIGN.md section 17.4) —
        bit-identical results; the fused f32 kernel does not apply
        there."""
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        if self.quant is not None:
            if use_kernel:
                raise ValueError(
                    "use_kernel applies to the f32 serving path only; "
                    "the quantized path has no fused kernel (rebuild "
                    "with quant='off' for kernel queries)")
            from ..core.quant import serving_query
            return serving_query(self, queries, topk=topk, mode=mode,
                                 metric=metric)
        kq = quantize_pow2(topk)
        run = query_fn(self.mesh, self.axis_name, kq, mode, metric,
                       use_kernel, self.placement)
        q = jnp.asarray(queries, jnp.float32)
        tr = obs_trace.get_tracer()
        if not tr:
            out = run(q, self.state)
        else:
            with tr.span("serving.query", Q=int(q.shape[0]), topk=topk,
                         mode=mode, metric=metric, P=self.P):
                out = run(q, self.state)
                jax.block_until_ready(out)
            tr.count("serving.queries", int(q.shape[0]))
        if kq == topk:
            return out
        return out[0][:, :topk], out[1][:, :topk]

    def query_threshold(self, queries, *, threshold,
                        capacity: int | None = None, mode: str = "auto",
                        metric: str = "dot", escalate: bool = True,
                        max_doublings: int = 16):
        """Range query: every corpus row with score >= threshold, per query.

        queries [Q, d] -> ``(scores [Q, cap], global row ids [Q, cap],
        count [Q])``, each query's hits sorted by ascending corpus index
        with (NEG_INF, IDX_SENTINEL) sentinels past ``count``
        (:func:`quorum_query_threshold`, DESIGN.md section 11.4).
        ``threshold`` is a scalar or a per-query ``[Q]`` vector (the
        packed-batch form, DESIGN.md section 15.2).

        ``capacity`` defaults to the ``REPRO_SPARSE_CAPACITY``-aware
        heuristic; the *program* capacity ``cap`` is its
        :func:`quantize_pow2` bucket (clamped to the corpus size), so
        returned buffers may be wider than requested and the compiled
        programs stay on the power-of-two bucket ladder — escalation
        doubles along that same ladder instead of flooding the LRU with
        one ``threshold_fn`` entry per observed capacity (DESIGN.md
        sections 11.2, 15.2).  Under the overflow contract doubling
        continues until every query's true ``count`` fits (capped at
        the corpus size); with ``escalate=False`` the first pass
        returns as-is — ``count > cap`` then marks a truncated query.
        The compiled program is cached per capacity bucket, never per
        threshold.
        """
        total_rows = self.P * self.block
        cap_req = (int(capacity) if capacity is not None
                   else min(default_capacity(total_rows), total_rows))
        cap = min(quantize_pow2(cap_req), total_rows)
        q = jnp.asarray(queries, jnp.float32)
        escalations = 0
        tr = obs_trace.get_tracer()
        span = tr.span("serving.query_threshold", Q=int(q.shape[0]),
                       mode=mode, metric=metric, P=self.P) if tr \
            else obs_trace.NOOP.span("")
        with span:
            while True:
                run = threshold_fn(self.mesh, self.axis_name, cap, mode,
                                   metric, self.placement)
                vals, idx, cnt = run(q, threshold, self.state)
                counts = np.asarray(cnt)
                if (not (counts > cap).any() or not escalate
                        or cap >= total_rows
                        or escalations >= max_doublings):
                    break
                cap = min(2 * cap, total_rows)
                escalations += 1
        if tr:
            tr.count("serving.queries", int(q.shape[0]))
            tr.count("serving.threshold_escalations", escalations)
        if escalate and (counts > cap).any():
            raise RuntimeError(
                f"thresholded query still overflows capacity {cap} after "
                f"{escalations} doublings; raise `capacity` or the "
                "threshold")
        return vals, idx, cnt

    def _check_block_data(self, data, what: str) -> np.ndarray:
        """Validate streamed block payloads at the handle layer: ``data``
        must be ``[rows, d]`` with ``rows <= block`` — the docstring
        contract of :meth:`replace_block`/:meth:`append_block` — so
        oversized or misshapen updates fail here with the block capacity
        in the message instead of deep inside ``stream.replace_block``
        (DESIGN.md section 9.4)."""
        arr = np.asarray(data, np.float32)
        if arr.ndim != 2 or arr.shape[1] != self.d:
            raise ValueError(
                f"{what} data must be a [rows, {self.d}] array (the "
                f"corpus embedding dim), got shape {arr.shape}")
        if arr.shape[0] > self.block:
            raise ValueError(
                f"{what} data has {arr.shape[0]} rows but the block "
                f"capacity is {self.block}; split the update or rebuild "
                "with a larger `block` (ServingCorpus.build)")
        return arr

    def replace_block(self, b: int, data, nvalid: int | None = None) -> None:
        """Replace block ``b`` in place (streamed to its k holder
        quorums).  ``data`` must be ``[rows <= block capacity, d]`` —
        validated here (DESIGN.md section 9.4)."""
        if not 0 <= b < self.P:
            raise ValueError(f"block id {b} out of range [0, {self.P})")
        data = self._check_block_data(data, f"replace_block({b})")
        self.state = replace_block(self.state, self.mesh, self.axis_name,
                                   b, data, nvalid,
                                   placement=self.placement)
        self.filled[b] = (data.shape[0] if nvalid is None else nvalid)
        if self.quant is not None:
            self.quant.update_block(b, data, int(self.filled[b]))

    def append_block(self, data) -> int:
        """Stream ``data`` (rows <= block capacity, validated at this
        layer) into the first empty block slot; returns the block id it
        landed in."""
        data = self._check_block_data(data, "append_block")
        empty = np.nonzero(self.filled == 0)[0]
        if empty.size == 0:
            raise ValueError(
                "corpus full: no empty block slot; grow the quorum axis "
                "(launch.elastic.rescale) to add capacity")
        b = int(empty[0])
        self.replace_block(b, data)
        return b

"""Streaming corpus updates for the online serving tier.

Corpus residency follows the batch engine exactly: the corpus is chunked
into P blocks of ``block`` rows, device i owns block i (its *shard*) and
additionally holds the k blocks of its cyclic quorum as a resident
``[k, block, d]`` *stack* (slot s = block (i + A[s]) % P, the same layout
``quorum_gather`` produces).  A validity flag per row handles partially
filled blocks — appends land in empty block capacity, no resharding.

``replace_block`` writes the new data into the owner's shard and
propagates it to the block's k holder quorums with the *existing* cyclic
ppermute shifts — the same k-1 shifts that built the residency, one
collective round, O(k * N/P) bytes per device, no data-layer reshuffle,
no divergence (uniform SPMD: non-holders receive their unchanged
neighbors' blocks, which the stack invariant makes a no-op).  The
validity row rides along as an extra feature column so one permute moves
both.  ``append_block`` is ``replace_block`` into the first empty block
slot (tracked host-side).

All programs are jitted once per (mesh, P, block, d) and reused across
updates — the block id and row count are traced scalars.
"""

from __future__ import annotations

import functools
from typing import Callable, List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from ..core.allpairs import quorum_gather
from ..core.placement import Placement, placement_from_env, resolve_placement

__all__ = ["ServingState", "build_state", "update_fn", "replace_block",
           "register_dirty_listener", "unregister_dirty_listener"]

# Dirty-block listeners (DESIGN.md section 16.5): every streamed block
# update — replace, and append (which is a replace into empty capacity,
# see engine.ServingCorpus.append_block) — notifies the registered
# callbacks with the block id, so standing delta indexes
# (core.delta.DeltaIndex.mark_dirty) learn about churn at the moment it
# is applied, not by polling.
_DIRTY_LISTENERS: List[Callable[[int], None]] = []


def register_dirty_listener(fn: Callable[[int], None]) -> Callable[[int], None]:
    """Register a callback invoked with the block id after every
    streamed block update (replace or append) — the hook that marks
    standing ``core.delta.DeltaIndex`` objects dirty.  Returns ``fn``
    so it can be used as a decorator."""
    _DIRTY_LISTENERS.append(fn)
    return fn


def unregister_dirty_listener(fn: Callable[[int], None]) -> None:
    """Remove a callback added by :func:`register_dirty_listener`
    (no-op if it is not registered)."""
    try:
        _DIRTY_LISTENERS.remove(fn)
    except ValueError:
        pass


def _notify_dirty(b: int) -> None:
    for fn in list(_DIRTY_LISTENERS):
        fn(int(b))


class ServingState(NamedTuple):
    """Device-resident serving arrays (a pytree; host metadata lives in
    ``engine.ServingCorpus``).

    shard       : [P * block, d]  — block i is device i's owned chunk.
    valid       : [P * block]     — row validity of the owned chunks.
    stack       : [P * k, block, d] — per-device quorum stacks, device-major
                  (device i's slot s is row i*k + s).
    stack_valid : [P * k, block]  — validity rows aligned with ``stack``.
    """

    shard: jax.Array
    valid: jax.Array
    stack: jax.Array
    stack_valid: jax.Array


def _with_valid(shard: jax.Array, valid: jax.Array) -> jax.Array:
    """Append validity as a feature column so one permute carries both."""
    return jnp.concatenate([shard, valid.astype(shard.dtype)[:, None]], axis=1)


@functools.lru_cache(maxsize=32)
def _build_fn(mesh, axis_name: str, P: int, placement: Placement):
    """Jitted initial-residency program: shard -> quorum stack (one gather).
    ``placement`` supplies the shift structure (and is part of the program
    cache key — placements are hashable memoized value objects)."""
    sched = placement.schedule()

    def f(shard, valid):
        stacked = quorum_gather(_with_valid(shard, valid), sched, axis_name)
        return stacked[..., :-1], stacked[..., -1] > 0.5

    return jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(PS(axis_name), PS(axis_name)),
        out_specs=(PS(axis_name), PS(axis_name))))


@functools.lru_cache(maxsize=32)
def update_fn(mesh, axis_name: str, P: int, placement: Placement):
    """Jitted update program shared by replace and append.

    ``f(shard, valid, b, data, nvalid)``: the owner of block ``b``
    overwrites its shard with ``data`` (rows >= nvalid invalid), then the
    k cyclic shifts redistribute the updated shards — each holder of b
    receives the new block at its matching slot, every other slot arrives
    unchanged (the stack invariant: slot s on device i always holds block
    (i + A[s]) % P with A the placement's shifts), so the gather *is* the
    propagation.  Works for any shift-structured placement, including
    full replication (where every device is a holder).
    """
    sched = placement.schedule()

    def f(shard, valid, b, data, nvalid):
        i = jax.lax.axis_index(axis_name)
        block = shard.shape[0]
        new_valid = jnp.arange(block) < nvalid
        is_owner = i == b
        shard = jnp.where(is_owner, data, shard)
        valid = jnp.where(is_owner, new_valid, valid)
        stacked = quorum_gather(_with_valid(shard, valid), sched, axis_name)
        return shard, valid, stacked[..., :-1], stacked[..., -1] > 0.5

    spec = PS(axis_name)
    return jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(spec, spec, PS(), PS(), PS()),
        out_specs=(spec, spec, spec, spec)))


def build_state(corpus: np.ndarray, mesh, axis_name: str = "q",
                block: int | None = None, placement=None) -> ServingState:
    """Chunk ``corpus`` [N, d] into P blocks (zero-padded; padding rows
    invalid) and build the resident quorum stacks with one gather.
    ``block`` overrides the per-block row capacity (>= ceil(N/P)) to leave
    empty slots for streamed appends.  ``placement`` picks the residency
    layer (None defers to ``REPRO_PLACEMENT`` / auto == cyclic)."""
    P = mesh.shape[axis_name]
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    N, d = corpus.shape
    block = max(block or 1, 1, -(-N // P))
    pad = P * block - N
    shard = jnp.asarray(np.pad(np.asarray(corpus, np.float32),
                               ((0, pad), (0, 0))))
    valid = jnp.arange(P * block) < N
    stack, stack_valid = _build_fn(mesh, axis_name, P, plc)(shard, valid)
    return ServingState(shard=shard, valid=valid, stack=stack,
                        stack_valid=stack_valid)


def replace_block(state: ServingState, mesh, axis_name: str, b: int,
                  data: np.ndarray, nvalid: int | None = None,
                  placement=None) -> ServingState:
    """Replace block ``b`` with ``data`` ([rows <= block, d]) and push it to
    the k holder quorums.  Rows beyond ``nvalid`` (default: data row count)
    are marked invalid; data is zero-padded to the block size.
    ``placement`` must match the one the state was built with (the stack
    layout is placement-defined)."""
    P = mesh.shape[axis_name]
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    block = state.shard.shape[0] // P
    rows, d = data.shape
    if rows > block:
        raise ValueError(f"data has {rows} rows; block capacity is {block}")
    nvalid = rows if nvalid is None else nvalid
    if not 0 <= nvalid <= rows:
        raise ValueError(f"nvalid={nvalid} outside [0, {rows}] — padding "
                         "rows must not be marked valid")
    full = np.zeros((block, d), np.float32)
    full[:rows] = np.asarray(data, np.float32)
    out = update_fn(mesh, axis_name, P, plc)(
        state.shard, state.valid,
        jnp.int32(b), jnp.asarray(full), jnp.int32(nvalid))
    _notify_dirty(b)
    return ServingState(*out)

"""Distributed self-check for the quorum all-pairs engine.

Run as ``XLA_FLAGS=--xla_force_host_platform_device_count=<P> python -m
repro.core.selfcheck [P] [modes]`` — the test suite invokes this in a
subprocess so the main pytest process keeps a single CPU device (see
launch/dryrun.py note).  ``modes`` is an optional comma-separated subset of
the engine modes (default: all of batched, overlap, scan).

Checks, for a toy n-body-style interaction: every engine execution mode ==
allgather_allpairs == pure-numpy O(N^2) oracle.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .allpairs import (ENGINE_MODES, allgather_allpairs, pair_mask_table,
                       quorum_allpairs)
from .scheduler import build_schedule


def pairwise_force(bi, bj):
    """Toy 1/r^2-ish interaction between two blocks of 3D points."""
    d = bi[:, None, :] - bj[None, :, :]                  # [m, n, 3]
    r2 = jnp.sum(d * d, axis=-1) + 1e-3
    f = d / (r2 ** 1.5)[..., None]
    out_i = jnp.sum(f, axis=1)                           # force on bi points
    out_j = -jnp.sum(f, axis=0)                          # force on bj points
    return out_i, out_j


def oracle(x: np.ndarray) -> np.ndarray:
    n = x.shape[0]
    d = x[:, None, :] - x[None, :, :]
    r2 = (d * d).sum(-1) + 1e-3
    f = d / (r2 ** 1.5)[..., None]
    # exclude self-interaction of identical points? the toy kernel includes
    # i==j terms (d=0 -> f=0 anyway), so the plain sum matches.
    return f.sum(axis=1)


def main(nblocks: int | None = None,
         modes: tuple[str, ...] = ENGINE_MODES) -> None:
    devs = jax.devices()
    Pn = nblocks or len(devs)
    assert len(devs) >= Pn, f"need {Pn} devices, have {len(devs)}"
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    sched = build_schedule(Pn)
    block = 8
    rng = np.random.default_rng(0)
    x = rng.normal(size=(Pn * block, 3)).astype(np.float32)
    masks = pair_mask_table(sched)  # [P, n_pairs]

    def run_quorum(xs, ms, mode):
        def f(xb, mb):
            return quorum_allpairs(pairwise_force, xb, axis_name="q",
                                   schedule=sched, mask=mb, mode=mode)
        return jax.jit(jax.shard_map(f, mesh=mesh,
                                     in_specs=(P("q"), P("q")),
                                     out_specs=P("q")))(xs, ms)

    @jax.jit
    def run_allgather(xs):
        def f(xb):
            return allgather_allpairs(pairwise_force, xb, axis_name="q",
                                      axis_size=Pn)
        return jax.shard_map(f, mesh=mesh, in_specs=P("q"), out_specs=P("q"))(xs)

    want = oracle(x)
    got_a = np.asarray(run_allgather(x))
    np.testing.assert_allclose(got_a, want, rtol=2e-4, atol=2e-5)
    max_err = 0.0
    for mode in modes:
        got_q = np.asarray(run_quorum(x, masks, mode))
        np.testing.assert_allclose(got_q, want, rtol=2e-4, atol=2e-5,
                                   err_msg=f"mode={mode} vs oracle")
        np.testing.assert_allclose(got_q, got_a, rtol=2e-4, atol=2e-5,
                                   err_msg=f"mode={mode} vs allgather")
        max_err = max(max_err, float(np.abs(got_q - want).max()))
    print(f"selfcheck OK: P={Pn} k={sched.k} pairs/dev={sched.n_pairs} "
          f"modes={','.join(modes)} max|err|={max_err:.2e}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else None,
         tuple(sys.argv[2].split(",")) if len(sys.argv) > 2 else ENGINE_MODES)

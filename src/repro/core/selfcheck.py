"""Distributed self-check for the quorum all-pairs engine.

Run as ``XLA_FLAGS=--xla_force_host_platform_device_count=<P> python -m
repro.core.selfcheck [P] [modes] [placement]`` — the test suite invokes
this in a subprocess so the main pytest process keeps a single CPU device
(see launch/dryrun.py note).  ``modes`` is an optional comma-separated
subset of the engine modes (default: all of batched, overlap, scan).
``placement`` is an optional placement spec (a registered name, ``auto``,
or ``plane``); unset it defers to the ``REPRO_PLACEMENT`` env var — the
CI placement matrix sets only the env var.

Checks, for a toy n-body-style interaction: every engine execution mode
under the selected placement == allgather_allpairs == pure-numpy O(N^2)
oracle.  A full-replication placement delegates to allgather inside the
engine, so the check degenerates to oracle equality (still asserted per
requested mode).
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .allpairs import (ENGINE_MODES, allgather_allpairs, pair_mask_table,
                       quorum_allpairs)
from .placement import placement_from_env, resolve_placement


def pairwise_force(bi, bj):
    """Toy 1/r^2-ish interaction between two blocks of 3D points."""
    d = bi[:, None, :] - bj[None, :, :]                  # [m, n, 3]
    r2 = jnp.sum(d * d, axis=-1) + 1e-3
    f = d / (r2 ** 1.5)[..., None]
    out_i = jnp.sum(f, axis=1)                           # force on bi points
    out_j = -jnp.sum(f, axis=0)                          # force on bj points
    return out_i, out_j


def oracle(x: np.ndarray) -> np.ndarray:
    """Numpy O(N^2) oracle for the toy interaction."""
    n = x.shape[0]
    d = x[:, None, :] - x[None, :, :]
    r2 = (d * d).sum(-1) + 1e-3
    f = d / (r2 ** 1.5)[..., None]
    # exclude self-interaction of identical points? the toy kernel includes
    # i==j terms (d=0 -> f=0 anyway), so the plain sum matches.
    return f.sum(axis=1)


def main(nblocks: int | None = None,
         modes: tuple[str, ...] = ENGINE_MODES,
         placement: str | None = None) -> None:
    """Run the engine selfcheck (see module docstring for the CLI)."""
    devs = jax.devices()
    Pn = nblocks or len(devs)
    assert len(devs) >= Pn, f"need {Pn} devices, have {len(devs)}"
    plc = (placement_from_env(Pn) if placement is None
           else resolve_placement(placement, Pn))
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    sched = None if plc.full else plc.schedule()
    block = 8
    rng = np.random.default_rng(0)
    x = rng.normal(size=(Pn * block, 3)).astype(np.float32)
    masks = (np.ones((Pn, 1), np.float32) if sched is None
             else pair_mask_table(sched))  # [P, n_pairs]

    def run_quorum(xs, ms, mode):
        def f(xb, mb):
            if plc.full:  # engine routes to allgather; mask does not apply
                return quorum_allpairs(pairwise_force, xb, axis_name="q",
                                       mode=mode, placement=plc)
            return quorum_allpairs(pairwise_force, xb, axis_name="q",
                                   schedule=sched, mask=mb, mode=mode,
                                   placement=plc)
        return jax.jit(jax.shard_map(f, mesh=mesh,
                                     in_specs=(P("q"), P("q")),
                                     out_specs=P("q")))(xs, ms)

    @jax.jit
    def run_allgather(xs):
        def f(xb):
            return allgather_allpairs(pairwise_force, xb, axis_name="q",
                                      axis_size=Pn)
        return jax.shard_map(f, mesh=mesh, in_specs=P("q"), out_specs=P("q"))(xs)

    want = oracle(x)
    got_a = np.asarray(run_allgather(x))
    np.testing.assert_allclose(got_a, want, rtol=2e-4, atol=2e-5)
    max_err = 0.0
    for mode in modes:
        got_q = np.asarray(run_quorum(x, masks, mode))
        np.testing.assert_allclose(got_q, want, rtol=2e-4, atol=2e-5,
                                   err_msg=f"mode={mode} vs oracle")
        np.testing.assert_allclose(got_q, got_a, rtol=2e-4, atol=2e-5,
                                   err_msg=f"mode={mode} vs allgather")
        max_err = max(max_err, float(np.abs(got_q - want).max()))
    pairs = "P" if plc.full else str(sched.n_pairs)
    print(f"selfcheck OK: P={Pn} placement={plc.describe()} "
          f"k={plc.replication} pairs/dev={pairs} "
          f"modes={','.join(modes)} max|err|={max_err:.2e}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else None,
         tuple(sys.argv[2].split(",")) if len(sys.argv) > 2 else ENGINE_MODES,
         sys.argv[3] if len(sys.argv) > 3 else None)

"""Quantized int8/bf16 scoring with error-bounded exact rescoring
(DESIGN.md section 17).

The all-pairs workloads in this repo score f32 row blocks.  This module
adds a *quantized working set*: each quorum block is stored int8 (per
block symmetric scale) or bf16, shrinking both the resident bytes per
device and the ppermute gather payload, while every workload still
returns **bit-exact f32 answers** via a certified error bound plus a
cheap host-side rescoring pass:

  * :func:`quantize_corpus` builds a :class:`QuantizedCorpus` — the
    quantized codes plus the per-block ``scale``/``delta`` and per-row
    ``l1``/``sq`` side arrays that travel with the codes as one
    :class:`QuantBlocks` pytree through ``quorum_gather`` /
    ``quorum_scatter`` (core/sweep.py's pytree data plane).
  * The quantized tile score obeys ``|score_q - score_f32| <=
    eps(i, j)`` with eps derived from the per-block deltas and row L1
    norms (kernels/ref.py quant_eps_tile; DESIGN.md section 17.2) —
    dot and (via the exact stored ``sq`` norms) l2.
  * :func:`quant_similarity_join` emits the widened band ``score_q >=
    threshold - eps`` on device and rescores every emitted pair in f32
    on the host; :func:`quant_knn_graph` and :func:`serving_query` keep
    quantized top-M lists, certify the k-th/M-th margin against the
    bound, double M until certified, and rescore the certified
    candidate set — all three match their f32 oracles bit-exactly.

``REPRO_QUANT`` (core/env.py) selects the mode (``off``/``int8``/
``bf16``) wherever a workload's ``quant=None`` default defers to the
environment (:func:`quant_from_env`).
"""

from __future__ import annotations

import dataclasses
import functools
import sys
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.ref import (FP_REL, IDX_SENTINEL, NEG_INF, QUERY_METRICS,
                           quant_eps_tile)
from . import env as env_mod
from . import sweep as sweep_mod
from .knn import KNN_METRICS, KnnResult, _merge_lists
from .scheduler import PairSchedule
from .sparse import (JOIN_METRICS, JoinResult, MAX_ROWS_F32_EXACT,
                     SparseHits, _empty_bufs, _finalize, _pair_meta,
                     _scatter_hits, _tile_emit, default_capacity)
from .sweep import (ENGINE_MODES, SweepEmitter, mark_varying,
                    pair_mask_table, quorum_scatter)

__all__ = [
    "QUANT_DTYPES",
    "QuantBlocks",
    "QuantizedCorpus",
    "quant_from_env",
    "quantize_corpus",
    "quant_itemsize",
    "corpus_bytes_per_device",
    "eps_pairs",
    "eps_rows_upper",
    "eps_queries",
    "QuantThresholdEmitter",
    "QuantKnnEmitter",
    "quorum_allpairs_threshold_q",
    "quorum_allpairs_knn_q",
    "quant_similarity_join",
    "quant_knn_graph",
    "QuantServing",
    "serving_query",
]

#: the quantized storage modes (``REPRO_QUANT`` minus ``off``)
QUANT_DTYPES: Tuple[str, ...] = ("int8", "bf16")


class QuantBlocks(NamedTuple):
    """The per-device quantized working set as one pytree (DESIGN.md
    section 17.1) — the unit ``quorum_gather`` stacks leaf-wise, so the
    side arrays ride the same ppermute shifts as the codes.

    q     : [block, d] quantized codes (int8 or bfloat16)
    scale : [1] f32 per-block dequant scale (1.0 for bf16)
    delta : [1] f32 per-block worst-case elementwise error bound
    l1    : [block] f32 L1 norms of the ORIGINAL f32 rows
    sq    : [block] f32 exact squared L2 norms of the original rows
    """

    q: jax.Array
    scale: jax.Array
    delta: jax.Array
    l1: jax.Array
    sq: jax.Array


def quant_from_env() -> str:
    """The ``REPRO_QUANT`` knob value, defaulting to ``"off"`` (core/
    env.py registry; DESIGN.md section 17.5) — consulted by every
    workload whose ``quant=None`` argument defers to the environment."""
    val = env_mod.read_knob("REPRO_QUANT")
    return "off" if val is None else str(val)


def quant_itemsize(mode: str) -> int:
    """Bytes per stored element under a quant mode (DESIGN.md section
    17.1): 1 for int8, 2 for bf16, 4 for the f32 baseline (``off``)."""
    if mode == "int8":
        return 1
    if mode == "bf16":
        return 2
    if mode == "off":
        return 4
    raise ValueError(
        f"quant mode must be one of {('off',) + QUANT_DTYPES}, "
        f"got {mode!r}")


@dataclasses.dataclass(frozen=True)
class QuantizedCorpus:
    """Host-side quantized corpus (:func:`quantize_corpus`; DESIGN.md
    section 17.1).

    ``q`` is the [nblocks * block, d] quantized code matrix (int8, or
    bfloat16 via ml_dtypes), ``scale``/``delta`` the [nblocks] f32
    per-block dequant scales and elementwise error bounds, ``l1``/``sq``
    the [nblocks * block] f32 L1 norms and exact squared norms of the
    *original* rows; ``n_valid`` marks the trailing padding rows.
    """

    mode: str
    q: np.ndarray
    scale: np.ndarray
    delta: np.ndarray
    l1: np.ndarray
    sq: np.ndarray
    block: int
    n_valid: int

    def device_arrays(self):
        """The five leaves as jnp arrays in :class:`QuantBlocks` order
        (host [nblocks*block, ...] / [nblocks] shapes, ready for
        per-leaf ``PartitionSpec(axis)`` sharding)."""
        return (jnp.asarray(self.q), jnp.asarray(self.scale),
                jnp.asarray(self.delta), jnp.asarray(self.l1),
                jnp.asarray(self.sq))


def quantize_corpus(x: np.ndarray, nblocks: int, block: int,
                    mode: str) -> QuantizedCorpus:
    """Quantize a padded [nblocks * block, d] f32 matrix per block
    (DESIGN.md section 17.1).

    int8: symmetric per-block maxabs scaling — ``scale = maxabs / 127``,
    ``q = clip(rint(x / scale), -127, 127)``, worst-case elementwise
    error ``delta = scale / 2`` (round-to-nearest); all-zero blocks
    (corpus padding) get scale 1 and delta 0 so they never pollute the
    row-level bound maxima.  bf16: a dtype cast — ``scale = 1``,
    ``delta = maxabs * 2^-8`` (bfloat16's 8-bit mantissa step at the
    block's magnitude).  ``l1``/``sq`` are computed from the *original*
    f32 rows with the same reduction the f32 engines use, so the l2
    identity ``2 dot - |x|^2 - |y|^2`` stays exact up to the dot term.
    """
    if mode not in QUANT_DTYPES:
        raise ValueError(
            f"quant mode must be one of {QUANT_DTYPES}, got {mode!r}")
    x = np.asarray(x, np.float32)
    total, d = x.shape
    if total != nblocks * block:
        raise ValueError(
            f"expected [{nblocks * block}, d] padded rows, got {x.shape}")
    xb = x.reshape(nblocks, block, d)
    maxabs = np.abs(xb).max(axis=(1, 2)).astype(np.float32)   # [nblocks]
    if mode == "int8":
        scale = np.where(maxabs > 0, maxabs / 127.0, 1.0).astype(np.float32)
        q = np.clip(np.rint(xb / scale[:, None, None]), -127, 127)
        q = q.astype(np.int8).reshape(total, d)
        delta = np.where(maxabs > 0, scale / 2.0, 0.0).astype(np.float32)
    else:  # bf16 — the cast IS the quantizer (ml_dtypes via jnp)
        q = np.asarray(jnp.asarray(x).astype(jnp.bfloat16))
        scale = np.ones((nblocks,), np.float32)
        delta = (maxabs * np.float32(2.0 ** -8)).astype(np.float32)
    l1 = np.abs(x).sum(axis=1).astype(np.float32)
    sq = (x * x).sum(axis=1).astype(np.float32)
    return QuantizedCorpus(mode=mode, q=q, scale=scale, delta=delta,
                           l1=l1, sq=sq, block=block, n_valid=total)


def corpus_bytes_per_device(N: int, d: int, P: int, k: int,
                            mode: str) -> int:
    """Resident working-set bytes per device for an N x d corpus under
    P blocks with k resident slots (DESIGN.md section 17.1) — the
    formula ``benchmarks/bench_memory.py`` and BENCH_quant.json report.

    f32 (``off``): ``k * block * d * 4``.  Quantized: each resident
    block adds its code matrix plus the side arrays that ride the
    gather — ``k * (block * d * itemsize + 8 + 8 * block)`` (scale +
    delta f32 scalars, l1 + sq f32 rows).
    """
    block = -(-N // P)
    if mode == "off":
        return k * block * d * 4
    item = quant_itemsize(mode)
    return k * (block * d * item + 8 + 8 * block)


# ---------------------------------------------------------------------------
# Host-side certified error bounds (DESIGN.md section 17.2)
# ---------------------------------------------------------------------------

def _eps_terms(delta_r, l1_r, delta_c, l1_c, dim: int):
    # the shared scalar/vector eps body: quantization cross terms plus
    # the fp32 accumulation allowance (kernels/ref.py FP_REL)
    return (delta_r * l1_c + delta_c * l1_r
            + 3.0 * dim * delta_r * delta_c
            + FP_REL * (l1_r * l1_c + 1.0))


def eps_pairs(qc: QuantizedCorpus, ai: np.ndarray, aj: np.ndarray,
              metric: str) -> np.ndarray:
    """Per-pair certified bound ``|score_q(i, j) - score_f32(i, j)| <=
    eps`` for explicit global row-id vectors (DESIGN.md section 17.2) —
    the host-side twin of kernels/ref.py ``quant_eps_tile``; l2 doubles
    the dot bound (the norms are stored exactly)."""
    dim = qc.q.shape[1]
    bi = np.asarray(ai, np.int64) // qc.block
    bj = np.asarray(aj, np.int64) // qc.block
    eps = _eps_terms(qc.delta[bi].astype(np.float64), qc.l1[ai],
                     qc.delta[bj].astype(np.float64), qc.l1[aj], dim)
    return np.asarray(2.0 * eps if metric == "l2" else eps, np.float64)


def eps_rows_upper(qc: QuantizedCorpus, metric: str,
                   n: Optional[int] = None) -> np.ndarray:
    """Per-row upper bound over *any* partner row: ``|score_q(r, c) -
    score_f32(r, c)| <= eps_rows_upper[r]`` for every valid c
    (DESIGN.md section 17.2) — the k-NN certification margin.  Maxing
    ``delta``/``l1`` over all blocks is safe because all-zero padding
    blocks carry delta 0 and l1 0 (:func:`quantize_corpus`)."""
    n = qc.n_valid if n is None else int(n)
    dim = qc.q.shape[1]
    max_l1 = float(qc.l1[:n].max()) if n else 0.0
    max_delta = float(qc.delta.max())
    bi = np.arange(n, dtype=np.int64) // qc.block
    eps = _eps_terms(qc.delta[bi].astype(np.float64), qc.l1[:n],
                     np.float64(max_delta), np.float64(max_l1), dim)
    return np.asarray(2.0 * eps if metric == "l2" else eps, np.float64)


def eps_queries(qc: QuantizedCorpus, queries: np.ndarray,
                metric: str, n: Optional[int] = None) -> np.ndarray:
    """Per-query certified bound for f32 queries against the quantized
    corpus (DESIGN.md section 17.4): only the corpus side is quantized,
    so the bound drops the query-delta terms — ``max_delta * |q|_1 +
    FP_REL * (|q|_1 * max_l1 + 1)`` (l2 doubled)."""
    n = qc.n_valid if n is None else int(n)
    queries = np.asarray(queries, np.float32)
    max_l1 = float(qc.l1[:n].max()) if n else 0.0
    max_delta = float(qc.delta.max())
    l1_q = np.abs(queries).sum(axis=1).astype(np.float64)
    eps = max_delta * l1_q + FP_REL * (l1_q * max_l1 + 1.0)
    return np.asarray(2.0 * eps if metric == "l2" else eps, np.float64)


# ---------------------------------------------------------------------------
# Traced tile helpers (shared by all modes; DESIGN.md section 17.3)
# ---------------------------------------------------------------------------

def _q_scores_eps(fi, fj, s_lo, s_hi, d_lo, d_hi, l1_i, l1_j, sq_i, sq_j,
                  metric: str):
    # the single traced home of the quantized tile score + bound:
    # dequantized-dot in f32, exact stored norms for l2 (bit-parity with
    # kernels/ref.py pairwise_threshold_q / pairwise_topk_q)
    dots = jnp.dot(fi, fj.T, preferred_element_type=jnp.float32) \
        * (s_lo * s_hi)
    if metric == "l2":
        scores = (2.0 * dots - sq_j[None, :]) - sq_i[:, None]
    else:
        scores = dots
    eps = quant_eps_tile(d_lo, d_hi, l1_i, l1_j, dim=fi.shape[1],
                         metric=metric)
    return scores, eps


def _q_tile_take(quorum: QuantBlocks, lo_p, hi_p):
    # one pair's two quantized blocks + side rows out of the gathered
    # stack (traced slot indices — the scan mode's per-item gather)
    scale = quorum.scale.reshape(-1)
    delta = quorum.delta.reshape(-1)
    fi = jnp.take(quorum.q, lo_p, axis=0).astype(jnp.float32)
    fj = jnp.take(quorum.q, hi_p, axis=0).astype(jnp.float32)
    return (fi, fj, jnp.take(scale, lo_p), jnp.take(scale, hi_p),
            jnp.take(delta, lo_p), jnp.take(delta, hi_p),
            jnp.take(quorum.l1, lo_p, axis=0),
            jnp.take(quorum.l1, hi_p, axis=0),
            jnp.take(quorum.sq, lo_p, axis=0),
            jnp.take(quorum.sq, hi_p, axis=0))


def _q_tile_pair(bi: QuantBlocks, bj: QuantBlocks):
    # overlap mode hands per-slot QuantBlocks trees; normalize the
    # scalar leaves (shape () after slot indexing, (1,) on the shard)
    return (bi.q.astype(jnp.float32), bj.q.astype(jnp.float32),
            jnp.asarray(bi.scale).reshape(()),
            jnp.asarray(bj.scale).reshape(()),
            jnp.asarray(bi.delta).reshape(()),
            jnp.asarray(bj.delta).reshape(()),
            bi.l1, bj.l1, bi.sq, bj.sq)


def _q_tile_keep(scores, eps, thr, nv_lo, nv_hi, is_self):
    # the widened-band membership mask: emit everything the bound cannot
    # exclude; ownership rules identical to sparse._tile_keep
    r = lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    s = lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    keep = (scores >= thr - eps) & (r < nv_lo) & (s < nv_hi)
    return keep & jnp.where(is_self, r < s, True)


def _q_cand_planes(fi, fj, s_lo, s_hi, sq_i, sq_j, metric: str, active,
                   is_self, ga, gb, nv_lo, nv_hi, block_rows: int):
    # both orientations' masked quantized candidate planes for one tile
    # (the quantized twin of knn._item_candidates; exact stored norms)
    dots = jnp.dot(fi, fj.T, preferred_element_type=jnp.float32) \
        * (s_lo * s_hi)
    if metric == "l2":
        t_lo = (2.0 * dots - sq_j[None, :]) - sq_i[:, None]
        t_hi = (2.0 * dots - sq_i[:, None]) - sq_j[None, :]
    else:
        t_lo = t_hi = dots
    block = fi.shape[0]
    sent = jnp.int32(IDX_SENTINEL)
    r = lax.broadcasted_iota(jnp.int32, (block, block), 0)
    s = lax.broadcasted_iota(jnp.int32, (block, block), 1)
    keep = active & (s < nv_hi) & jnp.where(is_self, r != s, True)
    cv_l = jnp.where(keep, t_lo, NEG_INF)
    ci_l = jnp.where(keep, gb * block_rows + s, sent)
    keep_t = (active & jnp.logical_not(is_self) & (r < nv_lo)).T
    cv_h = jnp.where(keep_t, t_hi.T, NEG_INF)
    ci_h = jnp.where(keep_t, (ga * block_rows + r).T, sent)
    return cv_l, ci_l, cv_h, ci_h


# ---------------------------------------------------------------------------
# Emitters (DESIGN.md section 17.3)
# ---------------------------------------------------------------------------

class QuantThresholdEmitter(SweepEmitter):
    """Widened-band threshold compaction over quantized tiles (DESIGN.md
    section 17.3).

    Identical to sparse.ThresholdJoinEmitter except the tile score is
    the dequantized dot and the membership test is the certified band
    ``score_q >= threshold - eps`` — every true hit is provably inside
    the band, so the host's f32 rescoring pass recovers the exact join.
    No norm-bound prefilter: the band itself is the selectivity control
    (a pruned-but-true tile would break soundness).
    """

    def __init__(self, schedule: PairSchedule, mask, thr, capacity: int,
                 metric: str, block: int, axis_name: str, meta,
                 batch_fn=None):
        self.schedule = schedule
        self.mask = mask
        self.thr = thr
        self.capacity = capacity
        self.metric = metric
        self.block = block
        self.axis_name = axis_name
        self.lo, self.hi, self.ga, self.gb, self.nv_lo, self.nv_hi, \
            self.is_self = meta
        self.batch_fn = batch_fn
        self.active = self.mask > 0

    def batch(self, quorum: QuantBlocks):
        """One compaction over every tile — the batched jnp step IS the
        ref oracle (kernels/ref.py pairwise_threshold_q), with the fused
        Pallas kernel swapping in through ``batch_fn``."""
        meta = jnp.stack([self.active.astype(jnp.int32),
                          self.is_self.astype(jnp.int32),
                          self.ga, self.gb, self.nv_lo, self.nv_hi],
                         axis=1)                           # [n_pairs, 6]
        if self.batch_fn is not None:
            vals, ei, ej, count = self.batch_fn(quorum, self.lo, self.hi,
                                                meta)
        else:
            from ..kernels import ref as kref
            vals, ei, ej, count = kref.pairwise_threshold_q(
                quorum.q, quorum.scale.reshape(-1),
                quorum.delta.reshape(-1), quorum.l1, quorum.sq,
                self.lo, self.hi, meta, threshold=self.thr,
                capacity=self.capacity, block_rows=self.block,
                metric=self.metric)
        return SparseHits(vals=vals, i=ei, j=ej,
                          count=count.reshape(()).astype(jnp.int32))

    def scan_init(self):
        """Empty compaction buffers + zero true count (varying-marked)."""
        return (_empty_bufs(self.capacity, self.axis_name),
                mark_varying(jnp.int32(0), self.axis_name))

    def scan_items(self):
        """Per-pair (slots, active, self flag, block ids, valid counts)."""
        return (self.lo, self.hi, self.active, self.is_self, self.ga,
                self.gb, self.nv_lo, self.nv_hi)

    def scan_emit(self, carry, quorum: QuantBlocks, item):
        """Serial per-pair band compaction (``lax.cond`` skips masked
        tiles' compute, as in the f32 engine)."""
        bufs, count = carry
        lo_p, hi_p, act_p, self_p, ga_p, gb_p, nvl_p, nvh_p = item

        def compute(c):
            bufs_c, cnt = c
            parts = _q_tile_take(quorum, lo_p, hi_p)
            scores, eps = _q_scores_eps(*parts, self.metric)
            keep = _q_tile_keep(scores, eps, self.thr, nvl_p, nvh_p,
                                self_p)
            ei, ej = _tile_emit(scores, keep, ga_p, gb_p, self.block)
            return _scatter_hits(bufs_c, cnt, keep.reshape(-1),
                                 scores.reshape(-1).astype(jnp.float32),
                                 ei.reshape(-1), ej.reshape(-1),
                                 self.capacity)

        return lax.cond(act_p, compute, lambda c: c, (bufs, count))

    def scan_finalize(self, carry):
        """Sentinel-fill the unused buffer tail (the shared layout)."""
        bufs, count = carry
        return _finalize(bufs, count, self.capacity)

    def overlap_begin(self):
        """Boxed (bufs, count) carry the unrolled sweep threads."""
        return {"carry": (_empty_bufs(self.capacity, self.axis_name),
                          mark_varying(jnp.int32(0), self.axis_name))}

    def overlap_emit(self, state, idx, bi: QuantBlocks, bj: QuantBlocks):
        """Band-compact one tile as soon as its later block lands."""
        act = self.mask[idx] > 0

        def compute(c, bi=bi, bj=bj, idx=idx):
            bufs_c, cnt = c
            scores, eps = _q_scores_eps(*_q_tile_pair(bi, bj), self.metric)
            keep = _q_tile_keep(scores, eps, self.thr, self.nv_lo[idx],
                                self.nv_hi[idx], self.is_self[idx])
            ei, ej = _tile_emit(scores, keep, self.ga[idx], self.gb[idx],
                                self.block)
            return _scatter_hits(bufs_c, cnt, keep.reshape(-1),
                                 scores.reshape(-1).astype(jnp.float32),
                                 ei.reshape(-1), ej.reshape(-1),
                                 self.capacity)

        state["carry"] = lax.cond(act, compute, lambda c: c, state["carry"])

    def overlap_finalize(self, state):
        """Sentinel-fill the unused buffer tail (the shared layout)."""
        bufs, count = state["carry"]
        return _finalize(bufs, count, self.capacity)


class QuantKnnEmitter(SweepEmitter):
    """Per-row quantized top-M selection over the scheduled pairs
    (DESIGN.md section 17.3) — knn.KnnEmitter with the dequantized tile
    score and exact stored norms; the host certifies the resulting
    lists against the row bounds and rescores the candidates exactly.
    """

    def __init__(self, schedule: PairSchedule, mask, topk: int, metric: str,
                 block: int, axis_name: str, meta, batch_fn=None):
        self.schedule = schedule
        self.mask = mask
        self.topk = topk
        self.metric = metric
        self.block = block
        self.axis_name = axis_name
        self.lo, self.hi, self.ga, self.gb, self.nv_lo, self.nv_hi, \
            self.is_self = meta
        self.batch_fn = batch_fn

    def batch(self, quorum: QuantBlocks):
        """Every tile in one batched accumulation — the batched jnp step
        IS the ref oracle (kernels/ref.py pairwise_topk_q), fused kernel
        via ``batch_fn``."""
        meta = jnp.stack([(self.mask > 0).astype(jnp.int32),
                          self.is_self.astype(jnp.int32),
                          self.ga, self.gb, self.nv_lo, self.nv_hi],
                         axis=1)                           # [n_pairs, 6]
        if self.batch_fn is not None:
            return self.batch_fn(quorum, self.lo, self.hi, meta)
        from ..kernels import ref as kref
        return kref.pairwise_topk_q(
            quorum.q, quorum.scale.reshape(-1), quorum.sq,
            self.lo, self.hi, meta, topk=self.topk,
            block_rows=self.block, metric=self.metric)

    def scan_init(self):
        """Sentinel-filled per-slot running lists (varying-marked)."""
        k = self.schedule.k
        shape = (k, self.block, self.topk)
        return (mark_varying(jnp.full(shape, NEG_INF, jnp.float32),
                             self.axis_name),
                mark_varying(jnp.full(shape, jnp.int32(IDX_SENTINEL)),
                             self.axis_name))

    def scan_items(self):
        """Per-pair (slots, mask, self flag, block ids, valid counts)."""
        return (self.lo, self.hi, self.mask, self.is_self, self.ga,
                self.gb, self.nv_lo, self.nv_hi)

    def scan_emit(self, carry, quorum: QuantBlocks, item):
        """Merge one quantized tile's two candidate planes into the
        running per-slot lists."""
        vals, idx = carry
        lo_p, hi_p, m_p, self_p, ga_p, gb_p, nvl_p, nvh_p = item
        fi, fj, s_lo, s_hi, _dl, _dh, _l1i, _l1j, sq_i, sq_j = \
            _q_tile_take(quorum, lo_p, hi_p)
        cv_l, ci_l, cv_h, ci_h = _q_cand_planes(
            fi, fj, s_lo, s_hi, sq_i, sq_j, self.metric, m_p > 0, self_p,
            ga_p, gb_p, nvl_p, nvh_p, self.block)
        mv, mi = _merge_lists(jnp.take(vals, lo_p, axis=0),
                              jnp.take(idx, lo_p, axis=0), cv_l, ci_l,
                              self.topk)
        vals = vals.at[lo_p].set(mv)
        idx = idx.at[lo_p].set(mi)
        mv2, mi2 = _merge_lists(jnp.take(vals, hi_p, axis=0),
                                jnp.take(idx, hi_p, axis=0), cv_h, ci_h,
                                self.topk)
        return (vals.at[hi_p].set(mv2), idx.at[hi_p].set(mi2))

    def overlap_begin(self):
        """Boxed per-slot running lists the unrolled sweep updates."""
        return {"carry": self.scan_init()}

    def overlap_emit(self, state, item_idx, bi: QuantBlocks,
                     bj: QuantBlocks):
        """Merge one quantized tile as soon as its later block lands."""
        lo_s = int(self.schedule.pair_slots[item_idx, 0])
        hi_s = int(self.schedule.pair_slots[item_idx, 1])
        vals, idx = state["carry"]
        fi, fj, s_lo, s_hi, _dl, _dh, _l1i, _l1j, sq_i, sq_j = \
            _q_tile_pair(bi, bj)
        cv_l, ci_l, cv_h, ci_h = _q_cand_planes(
            fi, fj, s_lo, s_hi, sq_i, sq_j, self.metric,
            self.mask[item_idx] > 0, self.is_self[item_idx],
            self.ga[item_idx], self.gb[item_idx], self.nv_lo[item_idx],
            self.nv_hi[item_idx], self.block)
        mv, mi = _merge_lists(vals[lo_s], idx[lo_s], cv_l, ci_l, self.topk)
        vals = vals.at[lo_s].set(mv)
        idx = idx.at[lo_s].set(mi)
        if lo_s != hi_s:  # self tile: one contribution, hi plane sentinel
            mv2, mi2 = _merge_lists(vals[hi_s], idx[hi_s], cv_h, ci_h,
                                    self.topk)
            vals = vals.at[hi_s].set(mv2)
            idx = idx.at[hi_s].set(mi2)
        state["carry"] = (vals, idx)

    def overlap_finalize(self, state):
        """The per-slot running lists, ready for the scatter merge."""
        return state["carry"]


# ---------------------------------------------------------------------------
# Mode selection + device-level entry points (DESIGN.md section 17.3)
# ---------------------------------------------------------------------------

def _gather_payload_bytes(block: int, d: int, mode: str) -> int:
    # per-shift ppermute payload of one QuantBlocks tree: codes + the
    # scale/delta scalars + the l1/sq rows (the obs/comm.py predictor
    # mirrors this formula for its quant accounting)
    return block * d * quant_itemsize(mode) + 8 + 8 * block


def _join_mode_q(schedule: PairSchedule, block: int, d: int, mode_q: str,
                 batch_fn) -> str:
    """The quantized join's ``mode="auto"`` working set fed to the
    shared heuristic (core/sweep.py select_mode; DESIGN.md section
    17.3): the f32 score+id planes per tile plus the smaller resident
    quantized stack."""
    return sweep_mod.select_mode(
        schedule,
        schedule.n_pairs * block * block * 12
        + schedule.k * _gather_payload_bytes(block, d, mode_q), batch_fn)


def _knn_mode_q(schedule: PairSchedule, block: int, d: int, mode_q: str,
                batch_fn) -> str:
    """The quantized k-NN ``mode="auto"`` working set (two f32/i32
    candidate planes per tile + the quantized stack; DESIGN.md section
    17.3)."""
    return sweep_mod.select_mode(
        schedule,
        schedule.n_pairs * block * block * 16
        + schedule.k * _gather_payload_bytes(block, d, mode_q), batch_fn)


def quorum_allpairs_threshold_q(
    qb: QuantBlocks,
    *,
    threshold,
    axis_name: str,
    capacity: int,
    schedule: PairSchedule,
    metric: str = "dot",
    mode: str = "auto",
    mask: jax.Array | None = None,
    n_valid: int | None = None,
    batch_fn: Callable[..., Tuple[jax.Array, ...]] | None = None,
) -> SparseHits:
    """Distributed widened-band threshold join over quantized blocks
    (DESIGN.md section 17.3).

    Must run inside shard_map with ``qb`` the local :class:`QuantBlocks`
    shard.  Emits every global pair whose *quantized* score clears the
    certified band ``threshold - eps(i, j)`` — a superset of the true
    join, resolved exactly by the host rescoring pass in
    :func:`quant_similarity_join`.  ``batch_fn(qb, lo, hi, meta) ->
    (vals, i, j, count)`` is the fused-kernel hook (batched mode only).
    """
    if metric not in JOIN_METRICS:
        raise ValueError(f"metric must be one of {JOIN_METRICS}, "
                         f"got {metric!r}")
    sweep_mod.validate_mode(mode, batch_fn)
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    block, d = qb.q.shape
    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)
    if mode == "auto":
        qmode = "int8" if qb.q.dtype == jnp.int8 else "bf16"
        mode = _join_mode_q(schedule, block, d, qmode, batch_fn)
    lo, hi, ga, gb, nv_lo, nv_hi, is_self, _gblocks, _nv = _pair_meta(
        schedule, axis_name, block, n_valid)
    emitter = QuantThresholdEmitter(
        schedule, mask, jnp.float32(threshold), capacity, metric, block,
        axis_name, (lo, hi, ga, gb, nv_lo, nv_hi, is_self),
        batch_fn=batch_fn)
    return sweep_mod.pair_sweep(emitter, schedule=schedule,
                                axis_name=axis_name, mode=mode, x=qb)


def quorum_allpairs_knn_q(
    qb: QuantBlocks,
    *,
    topk: int,
    axis_name: str,
    schedule: PairSchedule,
    metric: str = "dot",
    mode: str = "auto",
    mask: jax.Array | None = None,
    n_valid: int | None = None,
    batch_fn: Callable[..., Tuple[jax.Array, jax.Array]] | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Distributed quantized top-M candidate lists (DESIGN.md section
    17.3) — knn.quorum_allpairs_knn over a :class:`QuantBlocks` shard.

    Returns each valid local row's quantized top-``topk`` (scores,
    global ids); the host certifies the M-th margin against the row
    bounds and rescores (:func:`quant_knn_graph`).
    """
    if metric not in KNN_METRICS:
        raise ValueError(f"metric must be one of {KNN_METRICS}, "
                         f"got {metric!r}")
    if topk < 1:
        raise ValueError(f"topk must be >= 1, got {topk}")
    sweep_mod.validate_mode(mode, batch_fn)
    block, d = qb.q.shape
    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)
    if mode == "auto":
        qmode = "int8" if qb.q.dtype == jnp.int8 else "bf16"
        mode = _knn_mode_q(schedule, block, d, qmode, batch_fn)
    lo, hi, ga, gb, nv_lo, nv_hi, is_self, _gblocks, _nv = _pair_meta(
        schedule, axis_name, block, n_valid)
    emitter = QuantKnnEmitter(
        schedule, mask, topk, metric, block, axis_name,
        (lo, hi, ga, gb, nv_lo, nv_hi, is_self), batch_fn=batch_fn)
    vals, idx = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                     axis_name=axis_name, mode=mode, x=qb)
    partials = [(vals[s], idx[s]) for s in range(schedule.k)]
    return quorum_scatter(
        partials, schedule, axis_name,
        reduce_fn=lambda a, b: _merge_lists(a[0], a[1], b[0], b[1], topk))


# ---------------------------------------------------------------------------
# Host drivers: quantize, sweep, certify, rescore (DESIGN.md section 17.4)
# ---------------------------------------------------------------------------

def _shard_quant(corpus: np.ndarray, P: int, mode: str):
    # pad to P blocks, quantize, return (qc, device_arrays, n2 host f32
    # squared norms of the padded matrix for rescoring)
    N, d = corpus.shape
    block = -(-N // P)
    x = np.zeros((P * block, d), np.float32)
    x[:N] = corpus
    qc = quantize_corpus(x, P, block, mode)
    n2 = (x * x).sum(axis=1).astype(np.float32)
    return qc, x, n2


def _kernel_sd(qb: QuantBlocks):
    # the [k, 2] (scale, delta) SMEM operand the fused kernels take
    return jnp.stack([qb.scale.reshape(-1), qb.delta.reshape(-1)], axis=1)


@functools.lru_cache(maxsize=64)
def _qjoin_fn(mesh, axis_name: str, N: int, block: int, threshold: float,
              metric: str, mode: str, capacity: int, use_kernel: bool,
              placement, qmode: str):
    """Build (and cache) the jitted quantized band-join program — one
    trace per (mesh, shape, threshold, capacity, quant mode, ...) key
    (DESIGN.md section 17.4)."""
    from jax.sharding import PartitionSpec as PS
    sched = placement.schedule()
    mask_table = jnp.asarray(pair_mask_table(sched))
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched inner step")
        from ..kernels import ops as kops

        def batch_fn(qb, lo, hi, meta):
            return kops.pairwise_threshold_q(
                qb.q, _kernel_sd(qb), qb.l1, qb.sq, lo, hi, meta,
                threshold=threshold, capacity=capacity, block_rows=block,
                metric=metric)

    def body(qarr, sarr, darr, l1arr, sqarr, mb):
        qb = QuantBlocks(q=qarr, scale=sarr, delta=darr, l1=l1arr,
                         sq=sqarr)
        hits = quorum_allpairs_threshold_q(
            qb, threshold=threshold, axis_name=axis_name,
            capacity=capacity, schedule=sched, metric=metric, mode=mode,
            mask=mb, n_valid=N, batch_fn=batch_fn)
        return hits.vals, hits.i, hits.j, hits.count.reshape(1)

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 6,
        out_specs=(spec, spec, spec, spec)))
    return lambda leaves: fn(*leaves, mask_table)


@functools.lru_cache(maxsize=64)
def _qknn_fn(mesh, axis_name: str, N: int, block: int, topk: int,
             metric: str, mode: str, use_kernel: bool, placement,
             qmode: str):
    """Build (and cache) the jitted quantized top-M program (DESIGN.md
    section 17.4)."""
    from jax.sharding import PartitionSpec as PS
    sched = placement.schedule()
    mask_table = jnp.asarray(pair_mask_table(sched))
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched inner step")
        from ..kernels import ops as kops

        def batch_fn(qb, lo, hi, meta):
            return kops.pairwise_topk_q(
                qb.q, _kernel_sd(qb), qb.sq, lo, hi, meta, topk=topk,
                block_rows=block, metric=metric)

    def body(qarr, sarr, darr, l1arr, sqarr, mb):
        qb = QuantBlocks(q=qarr, scale=sarr, delta=darr, l1=l1arr,
                         sq=sqarr)
        return quorum_allpairs_knn_q(
            qb, topk=topk, axis_name=axis_name, schedule=sched,
            metric=metric, mode=mode, mask=mb, n_valid=N,
            batch_fn=batch_fn)

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 6, out_specs=(spec, spec)))
    return lambda leaves: fn(*leaves, mask_table)


def quant_similarity_join(corpus, mesh, *, threshold: float, quant: str,
                          axis_name: str = "q", metric: str = "dot",
                          mode: str = "auto", placement=None,
                          capacity: int | None = None,
                          use_kernel: bool = False, escalate: bool = True,
                          max_doublings: int = 16,
                          stats: dict | None = None) -> JoinResult:
    """Exact similarity join through the quantized band + f32 rescoring
    pipeline (DESIGN.md section 17.4).

    Devices emit the certified band ``score_q >= threshold - eps`` over
    the quantized working set (under the standard capacity/overflow
    escalation contract — counts are *band* counts); the host rescores
    every emitted pair against the f32 corpus and keeps ``score_f32 >=
    threshold``.  The result is bit-identical to
    :func:`core.sparse.similarity_join` (same scores, same (i, j)
    lexsort order).  ``stats`` (optional dict) is filled with the band
    accounting: ``emitted``, ``kept``, ``certain`` (pairs the bound
    alone already proves in), ``borderline``, ``escalations``.
    """
    if quant not in QUANT_DTYPES:
        raise ValueError(
            f"quant must be one of {QUANT_DTYPES}, got {quant!r}")
    corpus = np.asarray(corpus, np.float32)
    N, d = corpus.shape
    if N >= MAX_ROWS_F32_EXACT:
        raise ValueError(
            f"corpus has {N} rows >= 2^24; global row ids would lose "
            "float32 exactness in the fused kernel's compaction")
    P = mesh.shape[axis_name]
    from .placement import placement_from_env, resolve_placement
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    block = -(-N // P)
    qc, x, n2 = _shard_quant(corpus, P, quant)
    leaves = qc.device_arrays()
    sched = plc.schedule()
    n_cand = sched.n_pairs * block * block
    cap = int(capacity) if capacity is not None else default_capacity(n_cand)

    escalations = 0
    while True:
        run = _qjoin_fn(mesh, axis_name, N, block, float(threshold),
                        metric, mode, cap, use_kernel, plc, quant)
        vals, gi, gj, counts = (np.asarray(a) for a in run(leaves))
        counts = counts.reshape(-1)
        overflow = bool((counts > cap).any())
        if not overflow or not escalate or escalations >= max_doublings:
            break
        cap = 2 * cap
        escalations += 1
    if overflow and escalate:
        raise RuntimeError(
            f"quantized band join still overflows capacity {cap} after "
            f"{escalations} doublings; raise `capacity`/`max_doublings` "
            "or the threshold")

    vals = vals.reshape(P, -1)
    gi = gi.reshape(P, -1)
    gj = gj.reshape(P, -1)
    keep_i, keep_j, keep_v = [], [], []
    for dev in range(P):
        n = min(int(counts[dev]), cap)
        keep_i.append(gi[dev, :n])
        keep_j.append(gj[dev, :n])
        keep_v.append(vals[dev, :n])
    ai = np.concatenate(keep_i)
    aj = np.concatenate(keep_j)
    band_v = np.concatenate(keep_v)

    # f32 rescoring: the exact score of every band pair, with the same
    # reduction order as the brute-force oracle's row gathers
    dots = np.einsum("nd,nd->n", x[ai], x[aj]).astype(np.float32)
    if metric == "l2":
        rescored = (2.0 * dots - n2[aj]) - n2[ai]
    else:
        rescored = dots
    keep = rescored >= np.float32(threshold)
    if stats is not None:
        eps = eps_pairs(qc, ai, aj, metric)
        certain = band_v.astype(np.float64) >= float(threshold) + eps
        stats.update(
            emitted=int(ai.shape[0]), kept=int(keep.sum()),
            certain=int((certain & keep).sum()),
            borderline=int(ai.shape[0]) - int((certain & keep).sum()),
            escalations=escalations)
    ai, aj, av = ai[keep], aj[keep], rescored[keep]
    order = np.lexsort((aj, ai))
    return JoinResult(i=ai[order], j=aj[order], scores=av[order],
                      counts=counts, capacity=cap, escalations=escalations,
                      overflow=overflow)


def quant_knn_graph(corpus, mesh, *, topk: int, quant: str,
                    axis_name: str = "q", metric: str = "dot",
                    mode: str = "auto", placement=None,
                    use_kernel: bool = False) -> KnnResult:
    """Exact k-NN graph through quantized top-M candidate generation +
    certified rescoring (DESIGN.md section 17.4).

    Runs the quantized sweep for each row's top-M (M starts at the
    power-of-two bucket of ``topk``), then certifies per row: the list
    is complete (sentinel tail or M covers the corpus) **or** the f32
    k-th rescored candidate beats the quantized M-th score plus the
    row's certified bound — no row outside the list can enter the true
    top-k.  Uncertified rows double M and rerun (terminating at M >=
    N - 1, where the list is exhaustive).  Returns a
    :class:`core.knn.KnnResult` bit-identical to
    :func:`core.knn.knn_graph`.
    """
    if quant not in QUANT_DTYPES:
        raise ValueError(
            f"quant must be one of {QUANT_DTYPES}, got {quant!r}")
    if topk < 1:
        raise ValueError(f"topk must be >= 1, got {topk}")
    from ..serving.engine import quantize_pow2
    corpus = np.asarray(corpus, np.float32)
    N, d = corpus.shape
    P = mesh.shape[axis_name]
    from .placement import placement_from_env, resolve_placement
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    block = -(-N // P)
    qc, x, n2 = _shard_quant(corpus, P, quant)
    leaves = qc.device_arrays()
    eps_row = eps_rows_upper(qc, metric, N)
    total = P * block

    out_v = np.full((N, topk), NEG_INF, np.float32)
    out_i = np.full((N, topk), IDX_SENTINEL, np.int64)
    M = quantize_pow2(topk)
    pending = np.ones((N,), bool)
    while True:
        run = _qknn_fn(mesh, axis_name, N, block, int(M), metric, mode,
                       use_kernel, plc, quant)
        vals_q, idx_q = (np.asarray(a) for a in run(leaves))
        vals_q, idx_q = vals_q[:N], idx_q[:N]
        newly = []
        for r in np.nonzero(pending)[0]:
            cand = idx_q[r][idx_q[r] != IDX_SENTINEL].astype(np.int64)
            complete = (cand.shape[0] < M) or (M >= N - 1)
            dots = (x[cand] @ x[r]).astype(np.float32)
            if metric == "l2":
                s = (2.0 * dots - n2[r]) - n2[cand]
            else:
                s = dots
            order = np.lexsort((cand, -s.astype(np.float64)))
            kth = (float(s[order[min(topk, len(order)) - 1]])
                   if len(order) else NEG_INF)
            c_M = float(vals_q[r, M - 1]) if M <= vals_q.shape[1] else \
                NEG_INF
            certified = complete or (
                len(order) >= topk
                and kth > c_M + float(eps_row[r]))
            if certified:
                take = order[:topk]
                out_v[r, :len(take)] = s[take]
                out_i[r, :len(take)] = cand[take]
                newly.append(r)
        pending[np.asarray(newly, np.int64)] = False
        if not pending.any():
            break
        M = min(quantize_pow2(2 * M), quantize_pow2(total))
    return KnnResult(indices=out_i, scores=out_v, topk=int(topk))


# ---------------------------------------------------------------------------
# Serving path: quantized resident stack + certified query top-k
# (DESIGN.md section 17.4)
# ---------------------------------------------------------------------------

class QuantQueryEmitter(SweepEmitter):
    """Per-query quantized top-M over the resident quantized stack
    (DESIGN.md section 17.4) — serving.engine.QueryTopKEmitter with the
    dequantized slot score; the host certifies the M-th margin against
    :func:`eps_queries` and rescores against its f32 mirror.
    """

    def __init__(self, schedule: PairSchedule, queries, mask, gidx,
                 topk: int, metric: str):
        self.schedule = schedule
        self.queries = queries
        self.mask = mask
        self.gidx = gidx
        self.topk = topk
        self.metric = metric

    def items(self):
        """Slot sweep: one work item per resident slot."""
        from .sweep import slot_items
        return slot_items(self.schedule.k)

    def _slot_scores(self, fq, scale, sq):
        # [Q, block] dequantized scores of one slot (exact stored norms)
        qn = self.queries
        s = (qn @ fq.T) * jnp.asarray(scale).reshape(())
        if self.metric == "l2":
            s = ((2.0 * s - sq[None, :])
                 - jnp.sum(qn * qn, axis=-1)[:, None])
        elif self.metric != "dot":
            raise ValueError(
                f"metric must be one of {QUERY_METRICS}, "
                f"got {self.metric!r}")
        return s

    def batch(self, quorum: QuantBlocks):
        """One einsum over the whole quantized stack + a single top-M
        over all k*block candidates."""
        from .sweep import topk_by_score
        fq = quorum.q.astype(jnp.float32)
        k, block = fq.shape[0], fq.shape[1]
        s = jnp.einsum("qd,sbd->qsb", self.queries, fq) \
            * quorum.scale.reshape(-1)[None, :, None]
        if self.metric == "l2":
            s = ((2.0 * s - quorum.sq[None])
                 - jnp.sum(self.queries * self.queries,
                           axis=-1)[:, None, None])
        elif self.metric != "dot":
            raise ValueError(
                f"metric must be one of {QUERY_METRICS}, "
                f"got {self.metric!r}")
        s = jnp.where(self.mask[None], s, NEG_INF)
        Q = self.queries.shape[0]
        midx = jnp.where(self.mask, self.gidx, IDX_SENTINEL)
        flat_idx = jnp.broadcast_to(midx[None], (Q, k, block))
        return topk_by_score(s.reshape(Q, k * block),
                             flat_idx.reshape(Q, k * block), self.topk)

    def scan_init(self):
        """Sentinel-filled [Q, topk] running lists."""
        Q = self.queries.shape[0]
        return (jnp.full((Q, self.topk), NEG_INF, jnp.float32),
                jnp.full((Q, self.topk), IDX_SENTINEL, jnp.int32))

    def scan_items(self):
        """(slot, mask row, global-id row) per resident slot."""
        k = self.schedule.k
        return (jnp.arange(k, dtype=jnp.int32), self.mask, self.gidx)

    def scan_emit(self, carry, quorum: QuantBlocks, item):
        """Merge one slot's masked dequantized scores into the list."""
        from .sweep import merge_topk
        cv, ci = carry
        slot, vrow, grow = item
        fq = jnp.take(quorum.q, slot, axis=0).astype(jnp.float32)
        s = self._slot_scores(fq, jnp.take(quorum.scale.reshape(-1), slot),
                              jnp.take(quorum.sq, slot, axis=0))
        Q, block = self.queries.shape[0], fq.shape[0]
        s = jnp.where(vrow[None], s, NEG_INF)
        g = jnp.broadcast_to(jnp.where(vrow, grow, IDX_SENTINEL)[None],
                             (Q, block))
        return merge_topk(cv, ci, s, g, self.topk)

    def overlap_begin(self):
        """The per-slot candidate lists the tournament merge folds."""
        return []

    def overlap_emit(self, lists, idx, bi: QuantBlocks, bj: QuantBlocks):
        """Select each slot's local top-M as its scores materialize."""
        from .sweep import topk_by_score
        fq = bi.q.astype(jnp.float32)
        Q, block = self.queries.shape[0], fq.shape[0]
        s = self._slot_scores(fq, bi.scale, bi.sq)
        s = jnp.where(self.mask[idx][None], s, NEG_INF)
        g = jnp.broadcast_to(
            jnp.where(self.mask[idx], self.gidx[idx], IDX_SENTINEL)[None],
            (Q, block))
        lists.append(topk_by_score(s, g, self.topk))

    def overlap_finalize(self, lists):
        """Pairwise tournament merge (log2 k depth)."""
        from .sweep import merge_topk
        while len(lists) > 1:
            nxt = []
            for j in range(0, len(lists) - 1, 2):
                nxt.append(merge_topk(*lists[j], *lists[j + 1], self.topk))
            if len(lists) % 2:
                nxt.append(lists[-1])
            lists = nxt
        return lists[0]


def quorum_query_topk_q(queries, qstack: QuantBlocks, stack_valid,
                        mask_row, *, topk: int, axis_name: str,
                        schedule: PairSchedule, mode: str = "auto",
                        metric: str = "dot"):
    """Quantized query top-M over the resident stack (DESIGN.md section
    17.4) — serving.engine.quorum_query_topk with a :class:`QuantBlocks`
    stack.  Must run inside shard_map; returns per-query quantized
    (scores [Q, M], global ids [Q, M]) identical on every device."""
    from ..serving.engine import _query_geometry, tree_merge_topk
    sweep_mod.validate_mode(mode, None)
    k, block, d = qstack.q.shape
    mask_row = mask_row.reshape(-1)
    if mode == "auto":
        Q = queries.shape[0]
        qmode = "int8" if qstack.q.dtype == jnp.int8 else "bf16"
        mode = sweep_mod.select_mode(
            schedule,
            2 * Q * k * block * 4
            + k * _gather_payload_bytes(block, d, qmode), None)
    gidx, mask = _query_geometry(schedule, axis_name, block, mask_row,
                                 stack_valid)
    emitter = QuantQueryEmitter(schedule, queries, mask, gidx, topk,
                                metric)
    vals, idx = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                     axis_name=axis_name, mode=mode,
                                     stack=qstack)
    return tree_merge_topk(vals, idx, axis_name=axis_name, P=schedule.P,
                           topk=topk)


@functools.lru_cache(maxsize=64)
def _query_q_fn(mesh, axis_name: str, topk: int, mode: str, metric: str,
                placement, qmode: str):
    """Build (and cache) the jitted quantized serving query program
    (DESIGN.md section 17.4) — keyed per (mesh, top-M bucket, mode,
    metric, placement, quant mode) like serving.engine.query_fn."""
    from jax.sharding import PartitionSpec as PS
    from ..serving.cover import build_cover
    P = mesh.shape[axis_name]
    sched = placement.schedule()
    plan = build_cover(P, placement)
    mask_table = jnp.asarray(plan.mask_table())          # [P, k]

    def body(queries, qarr, sarr, darr, l1arr, sqarr, stack_valid,
             mask_row):
        qb = QuantBlocks(q=qarr, scale=sarr, delta=darr, l1=l1arr,
                         sq=sqarr)
        vals, idx = quorum_query_topk_q(
            queries, qb, stack_valid, mask_row, topk=topk,
            axis_name=axis_name, schedule=sched, mode=mode, metric=metric)
        return vals[None], idx[None]        # [1, Q, M] per device

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(PS(),) + (spec,) * 7,
        out_specs=(spec, spec)))

    def run(queries, stacks, stack_valid):
        vals, idx = fn(queries, *stacks, stack_valid, mask_table)
        return vals[0], idx[0]              # all device copies identical

    return run


class QuantServing:
    """The quantized resident state of a serving corpus (DESIGN.md
    section 17.4) — owned by ``serving.engine.ServingCorpus`` when it
    is built with ``quant != "off"``.

    Keeps a [P * block, d] f32 host mirror of the corpus (the exact
    rescoring source), the :class:`QuantizedCorpus` built from it, and
    the device-resident quantized stacks in the streaming layout
    (device-major: device i's slot s holds block ``(i + shifts[s]) %
    P``).  Streamed block updates re-quantize and rebuild the stacks
    from the mirror — the harness simplification this PR documents; a
    per-block ppermute delta path would reuse stream.replace_block.
    """

    def __init__(self, mode: str, mesh, axis_name: str,
                 schedule: PairSchedule, block: int, rows: np.ndarray):
        if mode not in QUANT_DTYPES:
            raise ValueError(
                f"quant must be one of {QUANT_DTYPES}, got {mode!r}")
        self.mode = mode
        self.mesh = mesh
        self.axis_name = axis_name
        self.schedule = schedule
        self.block = block
        self.P = schedule.P
        self.rows = np.asarray(rows, np.float32)          # [P * block, d]
        self.n2 = (self.rows * self.rows).sum(axis=1).astype(np.float32)
        self._requant()

    def _requant(self) -> None:
        # rebuild the quantized corpus + the device-major slot stacks
        P, k = self.P, self.schedule.k
        self.qc = quantize_corpus(self.rows, P, self.block, self.mode)
        order = np.asarray(
            [(i + int(s)) % P for i in range(P)
             for s in self.schedule.shifts], np.int64)    # [P * k]
        qb = self.qc.q.reshape(P, self.block, -1)
        rows_of = order[:, None] * self.block + np.arange(self.block)
        self.stacks = (
            jnp.asarray(qb[order].reshape(P * k, self.block, -1)),
            jnp.asarray(self.qc.scale[order]),
            jnp.asarray(self.qc.delta[order]),
            jnp.asarray(self.qc.l1[rows_of].reshape(P * k, self.block)),
            jnp.asarray(self.qc.sq[rows_of].reshape(P * k, self.block)))

    def update_block(self, b: int, data: np.ndarray, nvalid: int) -> None:
        """Apply a streamed block replace to the mirror and re-quantize
        (full rebuild; DESIGN.md section 17.4)."""
        blk = np.zeros((self.block, self.rows.shape[1]), np.float32)
        blk[:data.shape[0]] = data
        blk[nvalid:] = 0.0
        self.rows[b * self.block:(b + 1) * self.block] = blk
        self.n2 = (self.rows * self.rows).sum(axis=1).astype(np.float32)
        self._requant()


def serving_query(corpus, queries, *, topk: int, mode: str = "auto",
                  metric: str = "dot"):
    """Exact serving top-k through the quantized stack + certified
    rescoring (DESIGN.md section 17.4).

    ``corpus`` is a ``serving.engine.ServingCorpus`` whose ``quant``
    attribute holds a :class:`QuantServing`.  Runs the quantized device
    top-M (M the power-of-two bucket of ``topk``), rescores each
    query's candidates against the f32 host mirror, and certifies: the
    candidate list is exhaustive, or the f32 k-th rescored score beats
    the quantized M-th score plus :func:`eps_queries` — otherwise M
    doubles and the device pass reruns.  Returns (scores [Q, topk],
    global row ids [Q, topk]) bit-identical to the f32
    ``ServingCorpus.query`` path.
    """
    from ..serving.engine import quantize_pow2
    qs = corpus.quant
    if qs is None:
        raise ValueError(
            "serving_query needs a quantized corpus (ServingCorpus.build "
            "with quant='int8'/'bf16'); use ServingCorpus.query for f32")
    if topk < 1:
        raise ValueError(f"topk must be >= 1, got {topk}")
    q = np.asarray(queries, np.float32)
    Q = q.shape[0]
    total = qs.P * qs.block
    valid = np.zeros((total,), bool)
    for b in range(qs.P):
        valid[b * qs.block: b * qs.block + int(corpus.filled[b])] = True
    n_valid_rows = int(valid.sum())
    eps_q = eps_queries(qs.qc, q, metric, total)

    out_v = np.full((Q, topk), NEG_INF, np.float32)
    out_i = np.full((Q, topk), IDX_SENTINEL, np.int64)
    M = quantize_pow2(topk)
    pending = np.ones((Q,), bool)
    qj = jnp.asarray(q)
    while True:
        run = _query_q_fn(corpus.mesh, corpus.axis_name, int(M), mode,
                          metric, corpus.placement, qs.mode)
        vals_q, idx_q = (np.asarray(a)
                         for a in run(qj, qs.stacks,
                                      corpus.state.stack_valid))
        newly = []
        for qi in np.nonzero(pending)[0]:
            cand = idx_q[qi][idx_q[qi] != IDX_SENTINEL].astype(np.int64)
            complete = (cand.shape[0] < M) or (M >= n_valid_rows)
            dots = (qs.rows[cand] @ q[qi]).astype(np.float32)
            if metric == "l2":
                s = ((2.0 * dots - qs.n2[cand])
                     - np.float32((q[qi] * q[qi]).sum()))
            else:
                s = dots
            order = np.lexsort((cand, -s.astype(np.float64)))
            kth = (float(s[order[min(topk, len(order)) - 1]])
                   if len(order) else NEG_INF)
            c_M = float(vals_q[qi, M - 1])
            certified = complete or (
                len(order) >= topk and kth > c_M + float(eps_q[qi]))
            if certified:
                take = order[:topk]
                out_v[qi, :len(take)] = s[take]
                out_i[qi, :len(take)] = cand[take]
                newly.append(qi)
        pending[np.asarray(newly, np.int64)] = False
        if not pending.any():
            break
        M = min(quantize_pow2(2 * M), quantize_pow2(total))
    return out_v, out_i


# ---------------------------------------------------------------------------
# Selfcheck (DESIGN.md section 17.6)
# ---------------------------------------------------------------------------

def _serving_topk_oracle(rows: np.ndarray, valid: np.ndarray,
                         queries: np.ndarray, topk: int, metric: str):
    # host f32 serving oracle: full scores, invalid rows masked, exact
    # (-score, index) selection with sentinel padding
    s = (queries @ rows.T).astype(np.float32)
    if metric == "l2":
        n2 = (rows * rows).sum(axis=1).astype(np.float32)
        qn2 = (queries * queries).sum(axis=1).astype(np.float32)
        s = 2.0 * s - n2[None, :] - qn2[:, None]
    s = np.where(valid[None, :], s, NEG_INF)
    Q, total = s.shape
    out_v = np.full((Q, topk), NEG_INF, np.float32)
    out_i = np.full((Q, topk), IDX_SENTINEL, np.int64)
    for qi in range(Q):
        cand = np.nonzero(valid)[0]
        order = np.lexsort((cand, -s[qi, cand].astype(np.float64)))
        take = order[:topk]
        out_v[qi, :len(take)] = s[qi, cand[take]]
        out_i[qi, :len(take)] = cand[take]
    return out_v, out_i


def selfcheck_main(nblocks: int | None = None, modes=None,
                   placement=None) -> None:
    """Exactness selfcheck of the whole quantized pipeline (DESIGN.md
    section 17.6): for each quant mode x metric, the rescored join,
    k-NN graph, and serving query must be **bit-identical** to the f32
    oracles across every execution mode (plus the fused-kernel batched
    path), including after a streamed block replace on the serving
    side.  ``REPRO_QUANT`` (when set to a non-off mode) restricts the
    swept quant modes — the CI placement-matrix cell sets it."""
    from ..core.placement import placement_from_env, resolve_placement
    from ..core.sparse import brute_force_join, threshold_for_selectivity
    from ..core.knn import brute_force_knn
    from ..serving.engine import ServingCorpus

    Pn = nblocks or max(jax.device_count(), 4)
    if jax.device_count() < Pn:
        raise SystemExit(
            f"need {Pn} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={Pn})")
    if modes is None:
        modes = tuple(ENGINE_MODES) + ("kernel",)
    plc = (placement_from_env(Pn) if placement is None
           else resolve_placement(placement, Pn))
    mesh = jax.make_mesh((Pn,), ("q",), devices=jax.devices()[:Pn])

    block, d, topk = 8, 16, 4
    N = Pn * block - 3
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((N, d)).astype(np.float32)
    corpus[:2 * block] *= 0.05          # vary block scales
    queries = rng.standard_normal((5, d)).astype(np.float32)

    env_q = quant_from_env()
    qmodes = (env_q,) if env_q != "off" else QUANT_DTYPES
    for qm in qmodes:
        for metric in ("dot", "l2"):
            thr = threshold_for_selectivity(corpus, 0.08, metric)
            ref_i, ref_j, ref_s = brute_force_join(corpus, thr, metric)
            ref_knn = brute_force_knn(corpus, topk, metric)
            for mode in modes:
                use_kernel = mode == "kernel"
                m = "batched" if use_kernel else mode
                stats: dict = {}
                res = quant_similarity_join(
                    corpus, mesh, threshold=thr, quant=qm, metric=metric,
                    mode=m, placement=plc, use_kernel=use_kernel,
                    stats=stats)
                assert np.array_equal(res.i, ref_i), \
                    (qm, metric, mode, "join i")
                assert np.array_equal(res.j, ref_j), \
                    (qm, metric, mode, "join j")
                np.testing.assert_allclose(res.scores, ref_s,
                                           rtol=1e-5, atol=1e-5)
                assert stats["emitted"] >= stats["kept"] == res.n_pairs
                knn = quant_knn_graph(
                    corpus, mesh, topk=topk, quant=qm, metric=metric,
                    mode=m, placement=plc, use_kernel=use_kernel)
                assert np.array_equal(knn.indices, ref_knn.indices), \
                    (qm, metric, mode, "knn idx")
                np.testing.assert_allclose(knn.scores, ref_knn.scores,
                                           rtol=1e-5, atol=1e-5)
        # serving: quantized stack + streamed replace, dot metric per
        # mode (the serving engines have no fused-kernel quant path)
        sc = ServingCorpus.build(corpus, mesh, placement=plc, quant=qm)
        total = sc.P * sc.block
        valid = np.zeros((total,), bool)
        valid[:N] = True
        rows = np.zeros((total, d), np.float32)
        rows[:N] = corpus
        for metric in ("dot", "l2"):
            ref_v, ref_i = _serving_topk_oracle(rows, valid, queries,
                                                topk, metric)
            for mode in ENGINE_MODES:
                sv, si = serving_query(sc, queries, topk=topk, mode=mode,
                                       metric=metric)
                assert np.array_equal(si, ref_i), (qm, metric, mode,
                                                   "serving idx")
                np.testing.assert_allclose(sv, ref_v, rtol=1e-5,
                                           atol=1e-5)
        newb = rng.standard_normal((sc.block, d)).astype(np.float32)
        sc.replace_block(1, newb)
        rows[sc.block:2 * sc.block] = newb
        valid[sc.block:2 * sc.block] = True
        ref_v, ref_i = _serving_topk_oracle(rows, valid, queries, topk,
                                            "dot")
        sv, si = serving_query(sc, queries, topk=topk, metric="dot")
        assert np.array_equal(si, ref_i), (qm, "churn serving idx")
        np.testing.assert_allclose(sv, ref_v, rtol=1e-5, atol=1e-5)
    print(f"quant selfcheck OK: P={Pn} placement={plc.name} "
          f"quant={','.join(qmodes)} modes={','.join(modes)}")


if __name__ == "__main__":
    _nb = int(sys.argv[1]) if len(sys.argv) > 1 else None
    _modes = tuple(sys.argv[2].split(",")) if len(sys.argv) > 2 else None
    _plc = sys.argv[3] if len(sys.argv) > 3 else None
    selfcheck_main(_nb, _modes, _plc)

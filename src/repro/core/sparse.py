"""Thresholded sparse all-pairs similarity join over quorum placements.

The batch engine (core.allpairs, DESIGN.md section 2) always reduces dense
O(N^2) pair results back to blocks.  The canonical all-pairs workload in
practice is the *similarity join* — report only the pairs whose score
passes a threshold (Özkural & Aykanat's all-pairs similarity problem;
Ullman's "some pairs") — where most of the pairwise work is a cheap
rejection.  This module plugs :class:`ThresholdJoinEmitter` into the
unified pair-sweep runtime (core/sweep.py, DESIGN.md section 12) so the
join reuses the quorum schedule and every registered placement but emits
only the passing ``(i, j, score)`` triples (DESIGN.md section 11):

  1. **prefilter** — per-slot norm extrema give an upper bound on every
     block-pair tile's best score (``|x·y| <= |x||y|`` for dot; the norm
     interval gap for L2), so whole tiles whose bound misses the
     threshold are skipped before any pairwise work.
  2. **tile compute + threshold compaction** — each scheduled slot pair's
     [block, block] score tile is thresholded and the passing entries are
     cumsum-compacted into a fixed-capacity per-device buffer (jit-safe:
     shapes are static, the count is a traced scalar).  A fused Pallas
     kernel (kernels/pairwise_threshold.py) replaces the batched inner
     step via the ``batch_fn`` hook, mirroring the dense engine.
  3. **exactly-once emission** — the per-difference ownership rule
     (core.scheduler, DESIGN.md section 3.2) plus the engine dedup mask
     partition all unordered pairs across devices; self-pair tiles keep
     only the strict upper triangle, so every passing global pair
     ``i < j`` is reported by exactly one device.  An optional ppermute
     ring gather (:func:`ring_allgather_hits`) replicates the per-device
     sparse buffers everywhere while preserving that partition.

**Capacity / overflow contract** (DESIGN.md section 11.2): buffers hold
``capacity`` triples; ``count`` is always the *true* number of passing
pairs on the device, and entries past ``capacity`` are dropped — never
reordered or wrapped — so ``count > capacity`` (the overflow flag) is an
exact escalation signal and the kept prefix is valid either way.
:func:`similarity_join` implements the documented two-pass escalation:
re-run with doubled capacity until the overflow flag clears.

Execution modes are the runtime's (DESIGN.md section 4) and honor the
same ``REPRO_ALLPAIRS_MODE`` override: ``batched`` (all tiles in one
einsum + one compaction), ``overlap`` (tiles compact incrementally as
their later block lands, so XLA overlaps the remaining gather shifts),
``scan`` (serial per-pair carry; with the prefilter the ``lax.cond``
genuinely skips pruned tiles' compute — the configuration
BENCH_sparse.json measures).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.ref import IDX_SENTINEL, NEG_INF
from ..obs import trace as obs_trace
from . import env as env_mod
from . import sweep as sweep_mod
from .scheduler import PairSchedule
from .sweep import (ENGINE_MODES, SweepEmitter, mark_varying,
                    pair_mask_table)

__all__ = [
    "SparseHits",
    "JoinResult",
    "ThresholdJoinEmitter",
    "default_capacity",
    "pair_score_bounds",
    "quorum_allpairs_threshold",
    "ring_allgather_hits",
    "similarity_join",
    "brute_force_join",
    "threshold_with_gap",
    "threshold_for_selectivity",
    "JOIN_METRICS",
]

JOIN_METRICS = ("dot", "l2")

# global row ids ride through the fused kernel's one-hot matmul compaction
# as exact float32 integers, which caps the corpus size (DESIGN.md 11.2)
MAX_ROWS_F32_EXACT = 1 << 24


class SparseHits(NamedTuple):
    """One device's compacted passing pairs (inside shard_map).

    vals  : [capacity] float32 — passing scores; slots >= min(count,
            capacity) hold ``NEG_INF``.
    i, j  : [capacity] int32 — global row ids with i < j; empty slots
            hold ``IDX_SENTINEL``.
    count : [] int32 — the TRUE number of passing pairs on this device
            (may exceed capacity; see the overflow contract above).
    """

    vals: jax.Array
    i: jax.Array
    j: jax.Array
    count: jax.Array


def default_capacity(n_candidates: int) -> int:
    """Starting per-device buffer capacity (DESIGN.md section 11.2).

    ``REPRO_SPARSE_CAPACITY`` (documented in the README env-var table;
    validated through the core/env.py registry) overrides; otherwise 1/8
    of the device's candidate count, rounded up to a lane-friendly
    multiple of 128 with a floor of 128.  Read at selection time like
    the other ``REPRO_*`` knobs, and only a *start*:
    :func:`similarity_join` doubles it until the overflow flag clears.
    """
    cap = env_mod.read_knob("REPRO_SPARSE_CAPACITY")
    if cap is not None:
        return int(cap)
    cap = max(128, -(-n_candidates // 8))
    return -(-cap // 128) * 128


def _norm_extrema(blk: jax.Array, valid: jax.Array):
    """(max, min) row norm over a block's valid rows; (0, +inf) when the
    block has none (which makes every bound below reject the tile)."""
    norms = jnp.sqrt(jnp.sum(blk * blk, axis=-1))
    return (jnp.max(jnp.where(valid, norms, 0.0), axis=-1),
            jnp.min(jnp.where(valid, norms, jnp.inf), axis=-1))


def _interval_bound(maxn_i, minn_i, maxn_j, minn_j, metric: str):
    """Tile score upper bound from two blocks' norm extrema — the single
    home of the DESIGN.md 11.1 derivation, shared by every mode.

    ``dot``: Cauchy-Schwarz, ``x·y <= max|x| * max|y|``.  ``l2`` (score
    = -|x-y|^2): reverse triangle inequality, ``|x-y| >= gap`` with gap
    the distance between the [min|x|, max|x|] norm intervals, so the
    score is at most ``-gap^2`` (an all-invalid block's +inf min norm
    yields a -inf bound: always skipped).
    """
    if metric == "dot":
        return maxn_i * maxn_j
    gap = jnp.maximum(jnp.maximum(minn_i - maxn_j, minn_j - maxn_i), 0.0)
    return -jnp.where(jnp.isinf(gap), jnp.inf, gap * gap)


def pair_score_bounds(quorum: jax.Array, valid: jax.Array,
                      lo_slots: jax.Array, hi_slots: jax.Array,
                      metric: str) -> jax.Array:
    """Upper bound on each scheduled tile's best score (DESIGN.md 11.1).

    quorum: [k, block, d]; valid: [k, block] row validity; lo/hi_slots:
    [n_pairs] slot ids.  Per-slot norm extrema feed
    :func:`_interval_bound`; a tile whose bound misses the threshold
    contains no passing pair and is skipped whole — the sparse engine's
    prefilter.
    """
    if metric not in JOIN_METRICS:
        raise ValueError(f"metric must be one of {JOIN_METRICS}, "
                         f"got {metric!r}")
    maxn, minn = _norm_extrema(quorum, valid)                    # [k]
    return _interval_bound(maxn[lo_slots], minn[lo_slots],
                           maxn[hi_slots], minn[hi_slots], metric)


def _tile_scores(bi: jax.Array, bj: jax.Array, metric: str) -> jax.Array:
    """[block, d] x [block, d] -> [block, block] under the join metric.

    The L2 score is ``2 x·y - |x|^2 - |y|^2 = -|x - y|^2`` — the same
    formula as the serving engine and the fused kernels, so float
    rounding (and therefore threshold membership) agrees across paths.
    """
    dot = bi @ bj.T
    if metric == "dot":
        return dot
    return (2.0 * dot - jnp.sum(bj * bj, axis=-1)[None, :]
            - jnp.sum(bi * bi, axis=-1)[:, None])


def _tile_emit(scores, keep, ga, gb, block: int):
    """Per-tile global-id planes + canonical (i < j) orientation.

    Blocks are disjoint row ranges, so the elementwise (min, max) of the
    two global ids orients every entry; the self-pair tile is restricted
    to the strict upper triangle by the caller, so i < j always holds.
    """
    r = lax.broadcasted_iota(jnp.int32, keep.shape, 0)
    s = lax.broadcasted_iota(jnp.int32, keep.shape, 1)
    gi = ga * block + r
    gj = gb * block + s
    return jnp.minimum(gi, gj), jnp.maximum(gi, gj)


def _scatter_hits(bufs, count, keep_flat, vals_flat, i_flat, j_flat,
                  capacity: int):
    """Cumsum-compact passing entries into the running (bufs, count).

    Positions are ``count + cumsum(keep) - 1``; entries at or past
    ``capacity`` are dropped by the scatter (``mode="drop"``) while the
    returned count still grows by the true passing total — the overflow
    contract.  jit-safe: every shape is static.
    """
    vbuf, ibuf, jbuf = bufs
    keep_i = keep_flat.astype(jnp.int32)
    pos = count + jnp.cumsum(keep_i) - 1
    pos = jnp.where(keep_flat, pos, capacity)        # parked out of range
    vbuf = vbuf.at[pos].set(vals_flat, mode="drop")
    ibuf = ibuf.at[pos].set(i_flat, mode="drop")
    jbuf = jbuf.at[pos].set(j_flat, mode="drop")
    return (vbuf, ibuf, jbuf), count + jnp.sum(keep_i)


def _empty_bufs(capacity: int, axis_name: str):
    """Varying-marked empty buffers (the scan carry / compaction init)."""
    return (mark_varying(jnp.zeros((capacity,), jnp.float32), axis_name),
            mark_varying(jnp.zeros((capacity,), jnp.int32), axis_name),
            mark_varying(jnp.zeros((capacity,), jnp.int32), axis_name))


def _finalize(bufs, count, capacity: int) -> SparseHits:
    """Sentinel-fill the unused tail so every mode returns the same
    padded layout: (NEG_INF, IDX_SENTINEL) past min(count, capacity)."""
    vbuf, ibuf, jbuf = bufs
    used = lax.broadcasted_iota(jnp.int32, (capacity,), 0) < count
    return SparseHits(
        vals=jnp.where(used, vbuf, NEG_INF),
        i=jnp.where(used, ibuf, jnp.int32(IDX_SENTINEL)),
        j=jnp.where(used, jbuf, jnp.int32(IDX_SENTINEL)),
        count=count,
    )


def _select_mode(schedule: PairSchedule, block: int,
                 batch_fn: Optional[Callable]) -> str:
    """The sparse engine's ``mode="auto"`` working set fed to the shared
    heuristic (core/sweep.py select_mode, DESIGN.md section 4): scores
    f32 + two i32 id planes per [n_pairs, block, block] tile entry."""
    return sweep_mod.select_mode(
        schedule, schedule.n_pairs * block * block * 12, batch_fn)


def _pair_meta(schedule: PairSchedule, axis_name: str, block: int,
               n_valid: Optional[int]):
    """Per-pair traced metadata on this device: global block ids, valid
    row counts, self-pair flags.  ``n_valid`` (static) marks trailing
    padding rows of the global [P * block] numbering invalid."""
    P = schedule.P
    i = lax.axis_index(axis_name)
    shifts = jnp.asarray(schedule.shifts, jnp.int32)
    gblocks = (i + shifts) % P                                    # [k]
    lo = jnp.asarray(schedule.pair_slots[:, 0])
    hi = jnp.asarray(schedule.pair_slots[:, 1])
    ga = gblocks[lo]
    gb = gblocks[hi]
    if n_valid is None:
        nv = jnp.full((schedule.k,), block, jnp.int32)
    else:
        nv = jnp.clip(n_valid - gblocks * block, 0, block).astype(jnp.int32)
    is_self = jnp.asarray(schedule.pair_diff == 0)
    return lo, hi, ga, gb, nv[lo], nv[hi], is_self, gblocks, nv


def _tile_keep(scores, thr, nv_lo, nv_hi, is_self):
    """Threshold + row-validity + self-pair strict-triangle mask."""
    r = lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    s = lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    keep = (scores >= thr) & (r < nv_lo) & (s < nv_hi)
    return keep & jnp.where(is_self, r < s, True)


class ThresholdJoinEmitter(SweepEmitter):
    """Fixed-capacity threshold compaction over the scheduled pairs
    (DESIGN.md sections 11, 12.2 — the similarity-join workload).

    Each active tile is scored, thresholded under the ownership rules
    (row validity, self-pair strict triangle, the engine dedup mask) and
    cumsum-compacted into per-device (vals, i, j) buffers under the
    overflow contract of DESIGN.md 11.2.  The norm-bound prefilter
    (DESIGN.md 11.1) deactivates whole tiles: up-front over the gathered
    stack in batched/scan modes (:meth:`prepare`), incrementally from
    per-slot extrema as blocks land in overlap mode
    (:meth:`overlap_slot`).
    """

    def __init__(self, schedule: PairSchedule, mask, thr, capacity: int,
                 metric: str, block: int, prefilter: bool, axis_name: str,
                 meta, nv, batch_fn=None):
        self.schedule = schedule
        self.mask = mask
        self.thr = thr
        self.capacity = capacity
        self.metric = metric
        self.block = block
        self.prefilter = prefilter
        self.axis_name = axis_name
        self.lo, self.hi, self.ga, self.gb, self.nv_lo, self.nv_hi, \
            self.is_self = meta
        self.nv = nv
        self.batch_fn = batch_fn
        self.active = self.mask > 0           # refined by prepare()

    @staticmethod
    def delta_retract(standing, stale, ctx=None):
        """Retract stale (i, j) rows from a standing sorted hit set
        (DESIGN.md section 16.3).  A global pair lives in exactly one
        tile, so removing the dirty tiles' old rows is an exact set
        difference — no other tile can have contributed them."""
        standing = np.asarray(standing, np.int64).reshape(-1, 2)
        stale = np.asarray(stale, np.int64).reshape(-1, 2)
        if not len(standing) or not len(stale):
            return standing
        key = standing[:, 0] << np.int64(32) | standing[:, 1]
        gone = stale[:, 0] << np.int64(32) | stale[:, 1]
        return standing[~np.isin(key, gone)]

    @staticmethod
    def delta_fold(standing, fresh, ctx=None):
        """Insert fresh (i, j) rows into a standing hit set and restore
        the canonical (lo, hi) lexsort order (DESIGN.md section 16.3) —
        rows are globally unique, so the union re-sorted is bit-equal
        to a from-scratch fold."""
        standing = np.asarray(standing, np.int64).reshape(-1, 2)
        fresh = np.asarray(fresh, np.int64).reshape(-1, 2)
        allr = np.concatenate([standing, fresh], axis=0)
        order = np.lexsort((allr[:, 1], allr[:, 0]))
        return allr[order]

    def prepare(self, quorum):
        """Norm-bound prefilter over the full gathered stack
        (batched/scan modes; DESIGN.md 11.1)."""
        if not self.prefilter:
            return
        valid = (lax.broadcasted_iota(
            jnp.int32, (self.schedule.k, self.block), 1) < self.nv[:, None])
        bounds = pair_score_bounds(quorum, valid, self.lo, self.hi,
                                   self.metric)
        self.active = self.active & (bounds >= self.thr)

    def batch(self, quorum):
        """One compaction over every tile.  The batched jnp step IS the
        ref oracle — one home for the threshold-membership
        compute/compaction (DESIGN.md 11.3), with a fused Pallas kernel
        swapping in through the same hook."""
        batch_fn = self.batch_fn
        if batch_fn is None:
            from ..kernels import ref as kref
            batch_fn = functools.partial(
                kref.pairwise_threshold, threshold=self.thr,
                capacity=self.capacity, block_rows=self.block,
                metric=self.metric)
        meta = jnp.stack([self.active.astype(jnp.int32),
                          self.is_self.astype(jnp.int32),
                          self.ga, self.gb, self.nv_lo, self.nv_hi],
                         axis=1)                           # [n_pairs, 6]
        vals, ei, ej, count = batch_fn(quorum, self.lo, self.hi, meta)
        return SparseHits(vals=vals, i=ei, j=ej,
                          count=count.reshape(()).astype(jnp.int32))

    def scan_init(self):
        """Empty compaction buffers + zero true count (varying-marked)."""
        return (_empty_bufs(self.capacity, self.axis_name),
                mark_varying(jnp.int32(0), self.axis_name))

    def scan_items(self):
        """Per-pair (slots, active, self flag, block ids, valid counts)."""
        return (self.lo, self.hi, self.active, self.is_self, self.ga,
                self.gb, self.nv_lo, self.nv_hi)

    def scan_emit(self, carry, quorum, item):
        """Serial per-pair compaction; pruned/masked tiles skip their
        compute via ``lax.cond`` — with the prefilter this is a real
        FLOP saving, not just a masked multiply (the BENCH_sparse.json
        configuration)."""
        bufs, count = carry
        lo_p, hi_p, act_p, self_p, ga_p, gb_p, nvl_p, nvh_p = item

        def compute(c):
            bufs_c, cnt = c
            bi = jnp.take(quorum, lo_p, axis=0)
            bj = jnp.take(quorum, hi_p, axis=0)
            scores = _tile_scores(bi, bj, self.metric)
            keep = _tile_keep(scores, self.thr, nvl_p, nvh_p, self_p)
            ei, ej = _tile_emit(scores, keep, ga_p, gb_p, self.block)
            return _scatter_hits(bufs_c, cnt, keep.reshape(-1),
                                 scores.reshape(-1).astype(jnp.float32),
                                 ei.reshape(-1), ej.reshape(-1),
                                 self.capacity)

        return lax.cond(act_p, compute, lambda c: c, (bufs, count))

    def scan_finalize(self, carry):
        """Sentinel-fill the unused buffer tail (the shared layout)."""
        bufs, count = carry
        return _finalize(bufs, count, self.capacity)

    def overlap_begin(self):
        """Boxed (bufs, count) carry + the per-slot extrema list the
        incremental prefilter appends into."""
        return {"extrema": [],
                "carry": (_empty_bufs(self.capacity, self.axis_name),
                          mark_varying(jnp.int32(0), self.axis_name))}

    def overlap_slot(self, state, slot, blk):
        """Per-slot norm extrema, computed once at land time, feed the
        shared bound helper (DESIGN.md 11.1)."""
        if self.prefilter:
            vrow = (lax.broadcasted_iota(jnp.int32, (self.block,), 0)
                    < self.nv[slot])
            state["extrema"].append(_norm_extrema(blk, vrow))

    def overlap_emit(self, state, idx, bi, bj):
        """Score/compact one tile as soon as its later block lands, so
        XLA's latency-hiding scheduler overlaps the remaining ppermutes
        with tile compute (the sparse analog of the dense overlap mode,
        DESIGN.md section 4)."""
        l_s = int(self.schedule.pair_slots[idx, 0])
        h_s = int(self.schedule.pair_slots[idx, 1])
        act = self.mask[idx] > 0
        if self.prefilter:
            (mx_i, mn_i) = state["extrema"][l_s]
            (mx_j, mn_j) = state["extrema"][h_s]
            act = act & (_interval_bound(mx_i, mn_i, mx_j, mn_j,
                                         self.metric) >= self.thr)

        def compute(c, bi=bi, bj=bj, idx=idx):
            bufs_c, cnt = c
            scores = _tile_scores(bi, bj, self.metric)
            keep = _tile_keep(scores, self.thr, self.nv_lo[idx],
                              self.nv_hi[idx], self.is_self[idx])
            ei, ej = _tile_emit(scores, keep, self.ga[idx], self.gb[idx],
                                self.block)
            return _scatter_hits(bufs_c, cnt, keep.reshape(-1),
                                 scores.reshape(-1).astype(jnp.float32),
                                 ei.reshape(-1), ej.reshape(-1),
                                 self.capacity)

        state["carry"] = lax.cond(act, compute, lambda c: c, state["carry"])

    def overlap_finalize(self, state):
        """Sentinel-fill the unused buffer tail (the shared layout)."""
        bufs, count = state["carry"]
        return _finalize(bufs, count, self.capacity)


def quorum_allpairs_threshold(
    x: jax.Array,
    *,
    threshold: float,
    axis_name: str,
    capacity: int,
    schedule: PairSchedule | None = None,
    axis_size: int | None = None,
    placement=None,
    metric: str = "dot",
    mode: str = "auto",
    mask: jax.Array | None = None,
    n_valid: int | None = None,
    prefilter: bool = True,
    batch_fn: Callable[..., Tuple[jax.Array, ...]] | None = None,
) -> SparseHits:
    """Distributed thresholded similarity join (DESIGN.md section 11).

    Must run inside shard_map with ``x`` the local [block, d] shard.
    Emits every global pair ``i < j`` with ``score(x_i, x_j) >=
    threshold`` exactly once across devices (the per-difference ownership
    partition; self-pair tiles keep the strict upper triangle, the even-P
    d = P/2 orbit is deduplicated by ``mask`` exactly as in the dense
    engine).  Returns this device's :class:`SparseHits` under the
    capacity/overflow contract in the module docstring.

    ``placement`` / ``schedule`` / ``axis_size`` select the residency
    layer exactly as in :func:`core.allpairs.quorum_allpairs` (env
    ``REPRO_PLACEMENT`` consulted when both are None); a full-replication
    placement runs the same generic pipeline over its A = {0..P-1}
    shifts — no allgather special case, the join output is already
    sparse.  ``mode`` is the runtime's batched/overlap/scan surface of
    DESIGN.md section 4 (``REPRO_ALLPAIRS_MODE`` honored); ``prefilter``
    toggles the norm-bound tile skip (:func:`pair_score_bounds`);
    ``n_valid`` (static int) invalidates global rows >= n_valid (corpus
    padding); ``batch_fn(quorum, lo, hi, meta) -> (vals, i, j, count)``
    is the fused-kernel hook (kernels.ops.pairwise_threshold), batched
    mode only.
    """
    if metric not in JOIN_METRICS:
        raise ValueError(f"metric must be one of {JOIN_METRICS}, "
                         f"got {metric!r}")
    sweep_mod.validate_mode(mode, batch_fn)
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    schedule, placement = sweep_mod.resolve_sweep_placement(
        schedule, axis_size, placement)
    if schedule is None:
        schedule = placement.schedule()

    block = x.shape[0]
    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))   # [P, n_pairs]
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)

    if mode == "auto":
        mode = _select_mode(schedule, block, batch_fn)

    lo, hi, ga, gb, nv_lo, nv_hi, is_self, gblocks, nv = _pair_meta(
        schedule, axis_name, block, n_valid)
    thr = jnp.float32(threshold)

    emitter = ThresholdJoinEmitter(
        schedule, mask, thr, capacity, metric, block, prefilter, axis_name,
        (lo, hi, ga, gb, nv_lo, nv_hi, is_self), nv, batch_fn=batch_fn)
    return sweep_mod.pair_sweep(emitter, schedule=schedule,
                                axis_name=axis_name, mode=mode, x=x)


def ring_allgather_hits(hits: SparseHits, *, axis_name: str,
                        P: int) -> SparseHits:
    """Replicate every device's sparse buffers with a ppermute ring
    (DESIGN.md section 11.3).

    P - 1 single-step ``lax.ppermute`` shifts rotate each device's
    (vals, i, j, count) past every other device; arrivals are placed at
    their source device's row, so all devices end with the identical
    device-ordered [P, capacity] stack — the sparse analog of the dense
    engine's collectives (no ``all_gather``, matching the repo's
    shift-only data plane).  The pair-ownership partition guarantees the
    union of rows lists every passing pair exactly once.
    """
    i = lax.axis_index(axis_name)
    fields = [hits.vals, hits.i, hits.j, hits.count.reshape(1)]
    out = [jnp.zeros((P,) + f.shape, f.dtype).at[i].set(f) for f in fields]
    perm = [(j, (j + 1) % P) for j in range(P)]
    cur = fields
    for step in range(1, P):
        cur = [lax.ppermute(c, axis_name, perm) for c in cur]
        src = (i - step) % P
        out = [o.at[src].set(c) for o, c in zip(out, cur)]
    vals, ei, ej, count = out
    return SparseHits(vals=vals, i=ei, j=ej, count=count.reshape(P))


# ---------------------------------------------------------------------------
# Host-level driver: padding, program cache, capacity escalation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JoinResult:
    """Host-side similarity-join output (:func:`similarity_join`).

    i, j, scores : the passing pairs, sorted by (i, j); i < j, each pair
        exactly once.  ``counts`` is the per-device true passing totals,
        ``capacity`` the final per-device buffer size, ``escalations``
        how many capacity doublings the overflow contract forced, and
        ``overflow`` whether the final pass still overflowed (only with
        ``escalate=False`` — the kept pairs are then a valid prefix).
    """

    i: np.ndarray
    j: np.ndarray
    scores: np.ndarray
    counts: np.ndarray
    capacity: int
    escalations: int
    overflow: bool

    @property
    def n_pairs(self) -> int:
        """Number of passing pairs reported."""
        return int(self.i.shape[0])


@functools.lru_cache(maxsize=64)
def _join_fn(mesh, axis_name: str, N: int, block: int, threshold: float,
             metric: str, mode: str, capacity: int, prefilter: bool,
             use_kernel: bool, placement):
    """Build (and cache) the jitted distributed join program — one trace
    per (mesh, shape, threshold, capacity, ...) key, reused across
    escalation retries at the same capacity and repeated joins."""
    from jax.sharding import PartitionSpec as PS
    sched = placement.schedule()
    mask_table = jnp.asarray(pair_mask_table(sched))
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched inner step")
        from ..kernels import ops as kops
        batch_fn = functools.partial(
            kops.pairwise_threshold, threshold=threshold, capacity=capacity,
            block_rows=block, metric=metric)

    def body(xb, mb):
        hits = quorum_allpairs_threshold(
            xb, threshold=threshold, axis_name=axis_name, capacity=capacity,
            schedule=sched, mask=mb, metric=metric, mode=mode,
            n_valid=N, prefilter=prefilter, batch_fn=batch_fn)
        return hits.vals, hits.i, hits.j, hits.count.reshape(1)

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec, spec, spec, spec)))
    return lambda xs: fn(xs, mask_table)


def similarity_join(corpus, mesh, *, threshold: float, axis_name: str = "q",
                    metric: str = "dot", mode: str = "auto", placement=None,
                    capacity: int | None = None, prefilter: bool = True,
                    use_kernel: bool = False, escalate: bool = True,
                    max_doublings: int = 16,
                    quant: str | None = None) -> JoinResult:
    """All pairs of ``corpus`` rows with score >= threshold, exactly once.

    The host entry point (DESIGN.md section 11): pads the [N, d] corpus
    into P quorum blocks, runs :func:`quorum_allpairs_threshold` under
    the selected placement (None defers to ``REPRO_PLACEMENT``), and
    applies the two-pass capacity escalation — whenever any device's
    overflow flag is set, the per-device ``capacity`` doubles and the
    join re-runs (a fresh jit at each capacity; the kept work is only the
    cheap rejected majority, which is the point of the workload).  With
    ``escalate=False`` an overflowing pass returns its valid prefix with
    ``overflow=True`` instead of retrying.

    ``use_kernel`` routes the batched inner step through the fused Pallas
    kernel (kernels/pairwise_threshold.py); ``prefilter`` toggles the
    norm-bound block-pair skip.  ``quant`` selects the quantized
    band-emit + exact-rescoring path (DESIGN.md section 17): ``"int8"``
    / ``"bf16"`` route through :func:`core.quant.quant_similarity_join`
    (bit-identical results; ``prefilter`` does not apply there — the
    certified band is the selectivity mechanism), ``"off"`` forces the
    pure f32 path, and None defers to ``REPRO_QUANT``.  Returns a
    :class:`JoinResult` with pairs sorted by (i, j).
    """
    if quant is None:
        from .quant import quant_from_env
        quant = quant_from_env()
    if quant != "off":
        from . import quant as quant_mod
        return quant_mod.quant_similarity_join(
            corpus, mesh, threshold=threshold, quant=quant,
            axis_name=axis_name, metric=metric, mode=mode,
            placement=placement, capacity=capacity, use_kernel=use_kernel,
            escalate=escalate, max_doublings=max_doublings)
    corpus = np.asarray(corpus, np.float32)
    N, d = corpus.shape
    if N >= MAX_ROWS_F32_EXACT:
        raise ValueError(
            f"corpus has {N} rows >= 2^24; global row ids would lose "
            "float32 exactness in the fused kernel's compaction")
    P = mesh.shape[axis_name]
    from .placement import placement_from_env, resolve_placement
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    block = -(-N // P)
    x = np.zeros((P * block, d), np.float32)
    x[:N] = corpus
    xs = jnp.asarray(x)
    sched = plc.schedule()
    n_cand = sched.n_pairs * block * block
    cap = int(capacity) if capacity is not None else default_capacity(n_cand)

    escalations = 0
    tr = obs_trace.get_tracer()
    span = tr.span("sparse.join", N=N, P=P, metric=metric, mode=mode,
                   threshold=float(threshold), placement=plc.name) if tr \
        else obs_trace.NOOP.span("")
    with span:
        while True:
            run = _join_fn(mesh, axis_name, N, block, float(threshold),
                           metric, mode, cap, prefilter, use_kernel, plc)
            vals, gi, gj, counts = (np.asarray(a) for a in run(xs))
            counts = counts.reshape(-1)
            overflow = bool((counts > cap).any())
            if (not overflow or not escalate
                    or escalations >= max_doublings):
                break
            cap = 2 * cap
            escalations += 1
    if tr:
        tr.count("sparse.tiles_scheduled", P * sched.n_pairs)
        tr.count("sparse.candidates", P * n_cand)
        if prefilter:
            tr.count("sparse.tiles_pruned",
                     _count_pruned_tiles(x, N, block, sched,
                                         float(threshold), metric))
        tr.count("sparse.escalations", escalations)
    if overflow and escalate:
        raise RuntimeError(
            f"similarity join still overflows capacity {cap} after "
            f"{escalations} doublings; raise `capacity`/`max_doublings` "
            "or the threshold")

    vals = vals.reshape(P, -1)
    gi = gi.reshape(P, -1)
    gj = gj.reshape(P, -1)
    keep_i, keep_j, keep_v = [], [], []
    for dev in range(P):
        n = min(int(counts[dev]), cap)
        keep_i.append(gi[dev, :n])
        keep_j.append(gj[dev, :n])
        keep_v.append(vals[dev, :n])
    ai = np.concatenate(keep_i)
    aj = np.concatenate(keep_j)
    av = np.concatenate(keep_v)
    order = np.lexsort((aj, ai))
    if tr:
        tr.count("sparse.pairs_emitted", int(ai.shape[0]))
    return JoinResult(i=ai[order], j=aj[order], scores=av[order],
                      counts=counts, capacity=cap, escalations=escalations,
                      overflow=overflow)


def _count_pruned_tiles(x: np.ndarray, N: int, block: int,
                        sched: PairSchedule, threshold: float,
                        metric: str) -> int:
    # host-side replay of the DESIGN.md 11.1 interval bound over every
    # device's scheduled tiles — the sparse.tiles_pruned counter
    P = sched.P
    xb = x.reshape(P, block, -1)
    norms = np.sqrt(np.sum(xb * xb, axis=-1))               # [P, block]
    valid = (np.arange(P * block).reshape(P, block) < N)
    maxn = np.where(valid, norms, 0.0).max(axis=-1)
    minn = np.where(valid, norms, np.inf).min(axis=-1)
    pruned = 0
    for i in range(P):
        for s in range(sched.n_pairs):
            a = (i + int(sched.shifts[sched.pair_slots[s, 0]])) % P
            b = (i + int(sched.shifts[sched.pair_slots[s, 1]])) % P
            if metric == "dot":
                bound = maxn[a] * maxn[b]
            else:
                gap = max(minn[a] - maxn[b], minn[b] - maxn[a], 0.0)
                bound = -np.inf if np.isinf(gap) else -(gap * gap)
            if bound < threshold:
                pruned += 1
    return pruned


def _pair_score_matrix(corpus: np.ndarray, metric: str) -> np.ndarray:
    """Host-side [N, N] score matrix with the engine's f32 formulas."""
    if metric not in JOIN_METRICS:
        raise ValueError(f"metric must be one of {JOIN_METRICS}, "
                         f"got {metric!r}")
    c = np.asarray(corpus, np.float32)
    s = c @ c.T
    if metric == "l2":
        n2 = (c * c).sum(-1)
        s = 2.0 * s - n2[None, :] - n2[:, None]
    return s


def brute_force_join(corpus: np.ndarray, threshold: float,
                     metric: str = "dot"):
    """Dense O(N^2) oracle: all (i, j, score) with i < j and score >=
    threshold, sorted by (i, j).  Scores use the same float32 formula as
    the engine (DESIGN.md section 11.3) so membership agrees away from
    exact-threshold ties; tests pick thresholds with a guaranteed gap."""
    s = _pair_score_matrix(corpus, metric)
    iu, ju = np.triu_indices(s.shape[0], k=1)
    keep = s[iu, ju] >= threshold
    return iu[keep], ju[keep], s[iu, ju][keep]


def threshold_with_gap(scores, selectivity: float,
                       min_gap: float = 1e-4) -> float:
    """A threshold passing ~``selectivity`` of ``scores`` (any shape),
    placed at the midpoint of a score gap wider than ``min_gap`` near
    that quantile, so float-rounding differences between engine paths
    cannot flip membership (DESIGN.md section 11.3).  The single home of
    the gap-placement idiom — the pairwise wrapper below and the serving
    selfcheck both use it."""
    flat = np.sort(np.asarray(scores, np.float32).reshape(-1))[::-1]
    target = max(1, min(len(flat) - 2, int(round(selectivity * len(flat)))))
    # widen the search until an adjacent gap exceeds min_gap
    for off in range(0, len(flat) - 1):
        for idx in (target - off, target + off):
            if 0 < idx < len(flat):
                gap = flat[idx - 1] - flat[idx]
                if gap > min_gap:
                    return float((flat[idx - 1] + flat[idx]) / 2.0)
    raise ValueError("no score gap wide enough for a robust threshold")


def threshold_for_selectivity(corpus: np.ndarray, selectivity: float,
                              metric: str = "dot",
                              min_gap: float = 1e-4) -> float:
    """A join threshold passing ~``selectivity`` of all unordered pairs
    of ``corpus`` rows — :func:`threshold_with_gap` over the upper
    triangle of the pairwise score matrix (DESIGN.md section 11.3)."""
    s = _pair_score_matrix(corpus, metric)
    iu, ju = np.triu_indices(s.shape[0], k=1)
    return threshold_with_gap(s[iu, ju], selectivity, min_gap)


# ---------------------------------------------------------------------------
# Selfcheck (subprocess entry point — tests/test_sparse.py sweeps this)
# ---------------------------------------------------------------------------

def selfcheck_main(nblocks: int | None = None,
                   modes: Sequence[str] = ENGINE_MODES + ("kernel",),
                   placement: str | None = None) -> None:
    """Distributed sparse-join selfcheck, mirroring core.selfcheck
    (DESIGN.md section 11.5).

    Run as ``XLA_FLAGS=--xla_force_host_platform_device_count=<P> python
    -m repro.core.sparse [P] [modes] [placement]``.  Asserts index-level
    pair-set equality with the dense brute-force oracle for every
    requested mode (incl. the fused ``kernel`` batched path), both
    metrics, prefilter on/off, plus the ring-gather replication and the
    overflow/escalation contract.
    """
    from .placement import placement_from_env, resolve_placement

    devs = jax.devices()
    Pn = nblocks or len(devs)
    assert len(devs) >= Pn, f"need {Pn} devices, have {len(devs)}"
    plc = (placement_from_env(Pn) if placement is None
           else resolve_placement(placement, Pn))
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    block, d = 8, 16
    rng = np.random.default_rng(0)
    N = Pn * block - 3          # ragged tail: exercises row validity
    corpus = rng.normal(size=(N, d)).astype(np.float32)
    # two low-norm block spans make whole tiles prunable for `dot`
    corpus[: 2 * block] *= 0.05

    for metric in JOIN_METRICS:
        thr = threshold_for_selectivity(corpus, 0.08, metric)
        wi, wj, wv = brute_force_join(corpus, thr, metric)
        label = f"P={Pn} metric={metric}"
        for m in modes:
            mode, uk = ("batched", True) if m == "kernel" else (m, False)
            for pf in (True, False):
                res = similarity_join(corpus, mesh, threshold=thr,
                                      metric=metric, mode=mode,
                                      placement=plc, use_kernel=uk,
                                      prefilter=pf)
                np.testing.assert_array_equal(
                    res.i, wi, err_msg=f"{label} mode={m} prefilter={pf}")
                np.testing.assert_array_equal(
                    res.j, wj, err_msg=f"{label} mode={m} prefilter={pf}")
                np.testing.assert_allclose(
                    res.scores, wv, rtol=1e-5, atol=1e-5,
                    err_msg=f"{label} mode={m} prefilter={pf}")

    # overflow contract: a capacity below the busiest device's true count
    # must flag, keep a valid prefix, and escalate back to the full answer
    thr = threshold_for_selectivity(corpus, 0.08, "dot")
    wi, wj, _ = brute_force_join(corpus, thr, "dot")
    base = similarity_join(corpus, mesh, threshold=thr, placement=plc)
    np.testing.assert_array_equal(base.i, wi)
    np.testing.assert_array_equal(base.j, wj)
    mx = int(base.counts.max())
    assert mx >= 2, (mx, "corpus too small to exercise overflow")
    cap_small = max(1, mx // 2)
    low = similarity_join(corpus, mesh, threshold=thr, capacity=cap_small,
                          placement=plc, escalate=False)
    assert low.overflow and (low.counts > cap_small).any(), low.counts
    got = set(zip(low.i.tolist(), low.j.tolist()))
    assert got <= set(zip(wi.tolist(), wj.tolist())) and len(got) == len(low.i)
    esc = similarity_join(corpus, mesh, threshold=thr, capacity=cap_small,
                          placement=plc)
    assert esc.escalations >= 1, esc.escalations
    np.testing.assert_array_equal(esc.i, wi)
    np.testing.assert_array_equal(esc.j, wj)

    # ppermute ring gather: every device ends with the identical stack
    from jax.sharding import PartitionSpec as PS
    sched = plc.schedule()
    blockc = -(-N // Pn)
    xs = np.zeros((Pn * blockc, d), np.float32)
    xs[:N] = corpus
    mask_table = jnp.asarray(pair_mask_table(sched))
    cap = esc.capacity

    def body(xb, mb):
        hits = quorum_allpairs_threshold(
            xb, threshold=thr, axis_name="q", capacity=cap, schedule=sched,
            mask=mb, n_valid=N)
        g = ring_allgather_hits(hits, axis_name="q", P=Pn)
        return (hits.vals, hits.i, hits.count.reshape(1),
                g.vals[None], g.i[None], g.count[None])

    spec = PS("q")
    out = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec,) * 6))(jnp.asarray(xs), mask_table)
    lv, li, lc, gv, gi_, gc = (np.asarray(a) for a in out)
    lv, li = lv.reshape(Pn, cap), li.reshape(Pn, cap)
    for dev in range(Pn):           # every device's gathered copy agrees
        np.testing.assert_array_equal(gv[dev], lv)
        np.testing.assert_array_equal(gi_[dev], li)
        np.testing.assert_array_equal(gc[dev], lc.reshape(Pn))

    sel = len(wi) / max(1, N * (N - 1) // 2)
    print(f"sparse selfcheck OK: P={Pn} placement={plc.describe()} "
          f"modes={','.join(modes)} hits={len(wi)} "
          f"selectivity={100 * sel:.1f}% capacity={esc.capacity}")


if __name__ == "__main__":
    import sys
    selfcheck_main(
        int(sys.argv[1]) if len(sys.argv) > 1 else None,
        tuple(sys.argv[2].split(",")) if len(sys.argv) > 2
        else ENGINE_MODES + ("kernel",),
        sys.argv[3] if len(sys.argv) > 3 else None)

"""Relaxed (P,k)-difference sets and cyclic quorum sets.

This is the mathematical heart of the paper: a *relaxed (P,k)-difference set*
``A = {a_1..a_k} (mod P)`` is a set such that every residue ``d != 0 (mod P)``
can be written as ``a_i - a_j (mod P)`` for some ``a_i, a_j in A`` (paper
Definition 1).  The cyclic quorum set it generates, ``S_i = {a + i mod P}``,
satisfies the all-pairs property (paper Theorem 1): every unordered pair of
block indices ``(x, y)`` is co-resident in at least one quorum.

Three construction strategies (DESIGN.md section 3.1):
  * exact branch-and-bound (optimal k) for small P,
  * Singer difference sets (perfect, optimal) when ``P = q^2 + q + 1``
    for a prime power q,
  * a guaranteed ``~2*sqrt(P)`` "ladder" cover with greedy local improvement
    for everything else.
Every returned set is verified with :func:`is_difference_cover`; callers never
depend on optimality for correctness, only for the replication factor.
"""

from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

__all__ = [
    "is_difference_cover",
    "difference_set",
    "cyclic_quorums",
    "quorum_size_lower_bound",
    "verify_all_pairs_property",
    "singer_difference_set",
    "ladder_difference_cover",
]


# ---------------------------------------------------------------------------
# Verification
# ---------------------------------------------------------------------------

def is_difference_cover(A: Sequence[int], P: int) -> bool:
    """True iff every residue mod P is a difference of two elements of A."""
    if P <= 0:
        return False
    seen = [False] * P
    A = list(A)
    for ai in A:
        for aj in A:
            seen[(ai - aj) % P] = True
    return all(seen)


def quorum_size_lower_bound(P: int) -> int:
    """Smallest k with k*(k-1) + 1 >= P (paper Eq. 11 / Maekawa)."""
    k = max(1, math.isqrt(P))
    while k * (k - 1) + 1 < P:
        k += 1
    return k


# ---------------------------------------------------------------------------
# Constructions
# ---------------------------------------------------------------------------

def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in range(2, math.isqrt(n) + 1):
        if n % p == 0:
            return False
    return True


def _prime_power_base(q: int) -> int | None:
    """Return prime p if q = p^m for some m >= 1, else None."""
    if q < 2:
        return None
    for p in range(2, math.isqrt(q) + 1):
        if q % p == 0:
            while q % p == 0:
                q //= p
            return p if q == 1 else None
    return q  # q itself prime


class _GF:
    """Tiny GF(q) arithmetic for prime q (enough for Singer sets with prime q)."""

    def __init__(self, q: int):
        assert _is_prime(q), "only prime fields implemented"
        self.q = q

    # GF(q^3) represented as polynomials (c0, c1, c2) over GF(q) modulo a
    # degree-3 irreducible polynomial found by search.
    @functools.cached_property
    def cubic_irreducible(self) -> Tuple[int, int, int]:
        """Coefficients (b0, b1, b2) of monic irreducible x^3 + b2 x^2 + b1 x + b0."""
        q = self.q
        for b2 in range(q):
            for b1 in range(q):
                for b0 in range(1, q):
                    # irreducible over GF(q) iff no root in GF(q) (degree 3)
                    if all((pow(x, 3, q) + b2 * x * x + b1 * x + b0) % q != 0
                           for x in range(q)):
                        return (b0, b1, b2)
        raise RuntimeError("no cubic irreducible found")  # pragma: no cover

    def mul3(self, u: Tuple[int, int, int], v: Tuple[int, int, int]) -> Tuple[int, int, int]:
        q = self.q
        b0, b1, b2 = self.cubic_irreducible
        # schoolbook multiply -> degree-4 poly
        c = [0] * 5
        for i, ui in enumerate(u):
            if ui:
                for j, vj in enumerate(v):
                    c[i + j] = (c[i + j] + ui * vj) % q
        # reduce x^4 then x^3 using x^3 = -(b2 x^2 + b1 x + b0)
        for deg in (4, 3):
            coef = c[deg]
            if coef:
                c[deg] = 0
                c[deg - 1] = (c[deg - 1] - coef * b2) % q
                c[deg - 2] = (c[deg - 2] - coef * b1) % q
                c[deg - 3] = (c[deg - 3] - coef * b0) % q
        return (c[0], c[1], c[2])


def singer_difference_set(q: int) -> List[int] | None:
    """Perfect (q^2+q+1, q+1, 1) Singer difference set, for prime q.

    Construction: GF(q^3)^* / GF(q)^* is cyclic of order P = q^2+q+1.  Pick a
    generator g of GF(q^3)^*; the exponents i (mod P) for which g^i lies in the
    2-dim GF(q)-subspace {c0 + c1*x} form a Singer difference set.
    Returns None if q is not prime (prime-power fields not implemented — the
    caller falls back to search/ladder).
    """
    if not _is_prime(q):
        return None
    P = q * q + q + 1
    gf = _GF(q)
    order = q ** 3 - 1

    def element_order(g: Tuple[int, int, int]) -> int:
        acc = g
        n = 1
        while acc != (1, 0, 0):
            acc = gf.mul3(acc, g)
            n += 1
            if n > order:  # pragma: no cover
                return -1
        return n

    # find a generator of GF(q^3)^* (search small elements; density of
    # generators is phi(order)/order, typically high)
    gen = None
    for c2 in range(q):
        for c1 in range(q):
            for c0 in range(q):
                g = (c0, c1, c2)
                if g == (0, 0, 0):
                    continue
                if element_order(g) == order:
                    gen = g
                    break
            if gen:
                break
        if gen:
            break
    if gen is None:  # pragma: no cover
        return None

    A: List[int] = []
    acc = (1, 0, 0)
    for i in range(order):
        if acc[2] == 0:  # in the 2-dim subspace {c0 + c1 x}
            A.append(i % P)
        if len(set(A)) >= q + 1 and i >= P:
            break
        acc = gf.mul3(acc, gen)
    A = sorted(set(A))[: q + 1]
    return A if len(A) == q + 1 and is_difference_cover(A, P) else None


def ladder_difference_cover(P: int) -> List[int]:
    """Guaranteed difference cover of size ~2*sqrt(P).

    A = {0..r-1} ∪ {q*r + r-1 : q = 1..ceil(P/r)-1}.  Any d = q*r + s
    (0 <= s < r) equals (q*r + r-1) - (r-1-s), both members of A.
    """
    if P == 1:
        return [0]
    r = max(1, math.isqrt(P))
    A = set(range(r))
    m = 1
    while m * r + r - 1 < P + r:  # cover every difference class
        A.add((m * r + r - 1) % P)
        m += 1
    A = sorted(A)
    assert is_difference_cover(A, P), (P, A)
    return A


def _branch_and_bound(P: int, limit_k: int) -> List[int] | None:
    """Exact minimal difference cover search (A always contains 0, then 1 wlog
    is NOT valid for difference covers in general, so only 0 is pinned).

    Prunes on: remaining capacity (adding e more elements covers at most
    e*(2*|A|) + e*(e-1) new differences).
    """
    target = P  # number of residues to cover (0 is always covered)

    best: List[int] | None = None

    def covered_count(mask: int) -> int:
        return bin(mask).count("1")

    full_mask = (1 << P) - 1

    def extend(A: List[int], mask: int, start: int, k: int) -> List[int] | None:
        if mask == full_mask:
            return list(A)
        if len(A) == k:
            return None
        remaining = k - len(A)
        missing = target - covered_count(mask)
        # each new element adds <= 2*|A| + 1 diffs now, and pairs among the
        # remaining elements add <= remaining*(remaining-1) more
        cap = 0
        sz = len(A)
        for t in range(remaining):
            cap += 2 * (sz + t) + 1
        if cap < missing:
            return None
        for nxt in range(start, P):
            new_mask = mask
            for a in A:
                new_mask |= 1 << ((nxt - a) % P)
                new_mask |= 1 << ((a - nxt) % P)
            new_mask |= 1  # self-difference
            A.append(nxt)
            r = extend(A, new_mask, nxt + 1, k)
            if r is not None:
                return r
            A.pop()
        return None

    k = quorum_size_lower_bound(P)
    while k <= limit_k:
        r = extend([0], 1, 1, k)
        if r is not None:
            return r
        k += 1
    return None


def _local_improve(A: List[int], P: int) -> List[int]:
    """Greedy element deletion while the set remains a difference cover."""
    A = list(A)
    improved = True
    while improved:
        improved = False
        for a in list(A):
            cand = [x for x in A if x != a]
            if is_difference_cover(cand, P):
                A = cand
                improved = True
                break
    return sorted(A)


# Exact search is exponential; cap the P for which we run it.  Above the cap we
# use Singer (when applicable) or ladder + local improvement.
_EXACT_SEARCH_MAX_P = 36

_CACHE: dict[int, List[int]] = {}


def difference_set(P: int) -> List[int]:
    """Return a verified relaxed (P,k)-difference set, minimizing k by strategy.

    Deterministic and memo-cached; O(ms) for the P values a launcher touches,
    so elastic re-derivation on pod resize is cheap (DESIGN.md section 8).
    """
    if P < 1:
        raise ValueError(f"P must be >= 1, got {P}")
    if P in _CACHE:
        return list(_CACHE[P])

    A: List[int] | None = None
    if P <= 2:
        A = list(range(P))
    if A is None and P <= _EXACT_SEARCH_MAX_P:
        A = _branch_and_bound(P, limit_k=quorum_size_lower_bound(P) + 3)
    if A is None:
        # Singer: P = q^2 + q + 1?
        q = math.isqrt(P)
        for qq in (q - 1, q, q + 1):
            if qq >= 2 and qq * qq + qq + 1 == P:
                A = singer_difference_set(qq)
                break
    if A is None:
        A = _local_improve(ladder_difference_cover(P), P)

    A = sorted(set(x % P for x in A))
    if not is_difference_cover(A, P):  # pragma: no cover - all paths verified
        raise AssertionError(f"constructed set is not a difference cover: P={P} A={A}")
    _CACHE[P] = list(A)
    return list(A)


# ---------------------------------------------------------------------------
# Quorums
# ---------------------------------------------------------------------------

def cyclic_quorums(P: int) -> List[List[int]]:
    """All P cyclic quorums S_i = {a + i mod P : a in A} (paper Eq. 15)."""
    A = difference_set(P)
    return [sorted((a + i) % P for a in A) for i in range(P)]


def verify_all_pairs_property(quorums: Sequence[Sequence[int]], P: int) -> bool:
    """Check paper Eq. 16: every unordered pair (incl. self-pairs) co-resident."""
    ok = [[False] * P for _ in range(P)]
    for S in quorums:
        for x in S:
            for y in S:
                ok[x][y] = True
    return all(ok[x][y] for x in range(P) for y in range(P))

"""The quorum all-pairs engine: shard_map + jax.lax collectives.

TPU-native realization of the paper's distribution scheme (DESIGN.md
section 2), as a thin adapter over the unified pair-sweep runtime
(core/sweep.py, DESIGN.md section 12):

  1. ``quorum_gather``  — each device pulls its k quorum blocks with k-1
     ``lax.ppermute`` cyclic shifts (quorums are cyclic, so the pattern is
     shift-invariant and identical on every device).  Memory: k*N/P =
     O(N/sqrt(P)) — the paper's headline number.
  2. pair compute       — the runtime's batched/overlap/scan execution
     modes (DESIGN.md section 4) driving :class:`DenseReduceEmitter`, the
     dense monoid scatter-reduce emitter: every scheduled pair's
     ``pair_fn`` output is accumulated into per-slot partials under the
     ownership mask.
  3. ``quorum_scatter`` — per-block partial results routed back to block
     owners with the inverse shifts and reduced (sum or a user monoid).

Plus a reference ``allgather_allpairs`` baseline (the "all data everywhere"
scheme the paper improves on) used by tests and the memory benchmark.
The mode-selection heuristic, env overrides, gather/scatter primitives,
and mask table live in core/sweep.py and are re-exported here unchanged
(the long-standing public API of this module).
"""

from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..obs import trace as obs_trace
from . import sweep as sweep_mod
from .scheduler import PairSchedule
from .sweep import (ENGINE_MODES, SweepEmitter, _DEFAULT_BATCH_BYTES,
                    auto_batch_bytes, env_mode_override, mark_varying,
                    pair_mask_table, pair_ready_order, quorum_gather,
                    quorum_scatter)

__all__ = [
    "quorum_gather",
    "quorum_scatter",
    "quorum_allpairs",
    "allgather_allpairs",
    "pair_mask_table",
    "mark_varying",
    "auto_batch_bytes",
    "env_mode_override",
    "pair_ready_order",
    "DenseReduceEmitter",
    "ENGINE_MODES",
]


def _wmul(out: jax.Array, w: jax.Array) -> jax.Array:
    """Weight a pair output by a scalar or per-pair [n_pairs] mask weight."""
    if w.ndim == 0:
        return out * w.astype(out.dtype)
    return out * w.astype(out.dtype).reshape((-1,) + (1,) * (out.ndim - 1))


def _select_mode(schedule: PairSchedule, x: jax.Array,
                 probe: jax.ShapeDtypeStruct, batch_fn) -> str:
    """The dense engine's ``mode="auto"`` working set fed to the shared
    heuristic (core/sweep.py select_mode, DESIGN.md section 4): the
    [2*n_pairs, block, ...] operand+output bytes of the batched step."""
    out_bytes = math.prod(probe.shape) * jnp.dtype(probe.dtype).itemsize
    in_bytes = x.size * jnp.dtype(x.dtype).itemsize
    ws = 2 * schedule.n_pairs * (in_bytes + out_bytes)
    return sweep_mod.select_mode(schedule, ws, batch_fn)


class DenseReduceEmitter(SweepEmitter):
    """Dense monoid scatter-reduce over the scheduled pairs (DESIGN.md
    section 12.2, the ``quorum_allpairs`` workload).

    Every pair's ``pair_fn(bi, bj) -> (out_i, out_j)`` contribution is
    weighted by the ownership/dedup mask and accumulated into per-slot
    [k, block, ...] partials; self-pairs keep only ``out_i`` (count
    once).  ``quorum_scatter`` then folds the partials at the block
    owners under ``jnp.add``.
    """

    def __init__(self, pair_fn, schedule: PairSchedule, mask: jax.Array,
                 probe, axis_name: str, batch_fn=None):
        self.pair_fn = pair_fn
        self.schedule = schedule
        self.mask = mask
        self.probe = probe
        self.axis_name = axis_name
        self.batch_fn = batch_fn
        self.lo_slots = jnp.asarray(schedule.pair_slots[:, 0])
        self.hi_slots = jnp.asarray(schedule.pair_slots[:, 1])
        self.is_self = jnp.asarray(schedule.pair_diff == 0)

    @staticmethod
    def delta_retract(standing, stale, ctx=None):
        """Subtract a stale tile partial from the running float64 total
        — the additive group's retract (DESIGN.md section 16.2).  The
        delta driver publishes the canonical-order refold of its scalar
        ledger (float addition is not associative), keeping the
        standing result bit-exact; this running total is the O(1)
        fast-path estimate the refold is cross-checked against."""
        return np.float64(standing) - np.float64(stale)

    @staticmethod
    def delta_fold(standing, fresh, ctx=None):
        """Add a fresh tile partial to the running float64 total — the
        additive monoid's fold, the subtract-then-add counterpart of
        :meth:`delta_retract` (DESIGN.md section 16.2)."""
        return np.float64(standing) + np.float64(fresh)

    def batch(self, quorum):
        """All n_pairs interactions in one vmapped call + segment_sum over
        slots; with ``batch_fn`` the whole step (slot gather + pair
        interaction + segment reduction) runs as one fused kernel (e.g.
        kernels.ops.pairwise_batch_forces)."""
        k = self.schedule.k
        wi = self.mask
        # self-pair: count once
        wj = jnp.where(self.is_self, jnp.zeros_like(self.mask), self.mask)
        if self.batch_fn is not None:
            return self.batch_fn(quorum, self.lo_slots, self.hi_slots, wi, wj)
        lhs = jnp.take(quorum, self.lo_slots, axis=0)  # [n_pairs, block, ...]
        rhs = jnp.take(quorum, self.hi_slots, axis=0)
        out_i, out_j = jax.vmap(self.pair_fn)(lhs, rhs)
        data = jnp.concatenate([_wmul(out_i, wi), _wmul(out_j, wj)], axis=0)
        ids = jnp.concatenate([self.lo_slots, self.hi_slots])
        acc = jax.ops.segment_sum(data, ids, num_segments=k)
        return acc.astype(self.probe.dtype)

    def scan_init(self):
        """Zeroed [k, block, ...] slot accumulator (varying-marked)."""
        k = self.schedule.k
        return mark_varying(jnp.zeros((k,) + self.probe.shape,
                                      self.probe.dtype), self.axis_name)

    def scan_items(self):
        """(lo_slot, hi_slot, is_self, mask_weight) per scheduled pair."""
        return (self.lo_slots, self.hi_slots, self.is_self, self.mask)

    def scan_emit(self, acc, quorum, item):
        """Serial per-pair scatter-adds into the [k, block, ...] carry."""
        lo, hi, selfp, w = item
        bi = jnp.take(quorum, lo, axis=0)
        bj = jnp.take(quorum, hi, axis=0)
        out_i, out_j = self.pair_fn(bi, bj)
        out_j = jnp.where(selfp, jnp.zeros_like(out_j), out_j)  # count once
        acc = acc.at[lo].add(_wmul(out_i, w))
        acc = acc.at[hi].add(_wmul(out_j, w))
        return acc

    def overlap_begin(self):
        """Per-slot contribution lists the unrolled sweep appends into."""
        return [[] for _ in range(self.schedule.k)]

    def overlap_emit(self, contribs, idx, bi, bj):
        """Run pair ``idx`` as soon as its later block lands; per-slot
        contributions stay separate so the scatter's inverse shifts can
        pipeline (DESIGN.md section 4)."""
        lo = int(self.schedule.pair_slots[idx, 0])
        hi = int(self.schedule.pair_slots[idx, 1])
        w = self.mask[idx]
        out_i, out_j = self.pair_fn(bi, bj)
        contribs[lo].append(_wmul(out_i, w))
        if lo != hi:  # self-pair (lo == hi, d = 0): count once
            contribs[hi].append(_wmul(out_j, w))

    def overlap_finalize(self, contribs):
        """Fold each slot's contributions; returns the per-slot partials
        list quorum_scatter pipelines."""
        def fold(parts):
            if not parts:  # gathered slot with no scheduled pair
                return mark_varying(jnp.zeros(self.probe.shape,
                                              self.probe.dtype),
                                    self.axis_name)
            return functools.reduce(jnp.add, parts).astype(self.probe.dtype)

        return [fold(c) for c in contribs]


def quorum_allpairs(
    pair_fn: Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]],
    x: jax.Array,
    *,
    axis_name: str,
    schedule: PairSchedule | None = None,
    axis_size: int | None = None,
    mask: jax.Array | None = None,
    mode: str = "auto",
    batch_fn: Callable[..., jax.Array] | None = None,
    placement=None,
):
    """Compute a symmetric all-pairs reduction with quorum replication.

    ``pair_fn(bi, bj) -> (out_i, out_j)`` returns the contribution of the
    interaction to block i and to block j (e.g. forces f_ij and -f_ij; the
    paper's Fig. 1 "pair formed once" symmetry).  Required consistency:
    ``out_j(bi, bj) == out_i(bj, bi)``.  Self-pairs call ``pair_fn(b, b)``
    and keep only ``out_i``.

    Must be called inside shard_map with ``x`` the local block.  ``mask`` is
    this device's [n_pairs] dedup/validity mask; defaults to this device's
    pair_mask_table row (selected by axis_index), so the doubly-generated
    d = P/2 orbit on even P is deduplicated out of the box.  Pass it
    explicitly (a sharded operand) to avoid embedding the [P, n_pairs]
    table as a constant, or to add app-specific pair validity.

    ``mode`` selects the execution engine (DESIGN.md section 4):
      * ``"batched"`` — gather once, evaluate all pairs in one vmapped call,
        reduce with a slot segment_sum (fastest; O(n_pairs) extra memory).
      * ``"overlap"`` — double-buffered gather: each pair computes as soon as
        its later block lands, hiding the k-1 shifts behind compute, and the
        scatter's inverse shifts pipeline symmetrically (O(k) memory).
      * ``"scan"``    — serial per-pair lax.scan (lowest memory; oracle).
      * ``"auto"``    — heuristic: batched while its working set fits a byte
        budget, else overlap when k >= 3, else scan; overridable with the
        ``REPRO_ALLPAIRS_MODE`` env var.
    ``batch_fn(quorum, lo_slots, hi_slots, wi, wj) -> [k, block, ...]`` is an
    optional fused replacement for the batched inner step (a Pallas kernel
    such as kernels.ops.pairwise_batch_forces); implies ``mode="batched"``
    under ``auto``.

    ``placement`` (core.placement.Placement) selects the block-placement
    layer (DESIGN.md section 10): residency and routing come from the
    placement's shift structure instead of the default cyclic difference
    set.  A *full-replication* placement short-circuits to
    :func:`allgather_allpairs` (the degenerate oracle — no quorum pipeline,
    so ``mode``/``mask`` don't apply and a ``batch_fn`` is rejected).  When
    neither ``schedule`` nor ``placement`` is given, the placement is
    selected by ``REPRO_PLACEMENT`` (default ``auto`` == cyclic, bit-exact
    with the pre-placement behavior).

    Returns the per-block reduced output, shape/type of ``pair_fn``'s out_i.
    """
    sweep_mod.validate_mode(mode, batch_fn)
    schedule, placement = sweep_mod.resolve_sweep_placement(
        schedule, axis_size, placement)
    if placement is not None and placement.full:
        if batch_fn is not None:
            raise ValueError(
                "batch_fn fuses the quorum batched step; the full-replication "
                "placement routes through allgather_allpairs — drop batch_fn "
                "or pick a quorum placement")
        if mask is not None:
            raise ValueError(
                "mask expresses per-pair validity over the quorum schedule; "
                "the full-replication placement routes through "
                "allgather_allpairs, which would silently ignore it — drop "
                "the mask or pick a quorum placement")
        return allgather_allpairs(pair_fn, x, axis_name=axis_name,
                                  axis_size=placement.P)
    if schedule is None:
        schedule = placement.schedule()

    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))  # [P, n_pairs]
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)  # accept [1, n_pairs] shard_map leftovers

    # probe output structure once (shapes are static)
    sds = jax.ShapeDtypeStruct(x.shape, x.dtype)
    probe, _ = jax.eval_shape(pair_fn, sds, sds)
    if mode == "auto":
        mode = _select_mode(schedule, x, probe, batch_fn)

    emitter = DenseReduceEmitter(pair_fn, schedule, mask, probe, axis_name,
                                 batch_fn=batch_fn)
    partials = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                    axis_name=axis_name, mode=mode, x=x)
    return quorum_scatter(partials, schedule, axis_name)


def allgather_allpairs(
    pair_fn: Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]],
    x: jax.Array,
    *,
    axis_name: str,
    axis_size: int,
):
    """Baseline: replicate ALL blocks on every device (paper section 1.1
    schemes; DESIGN.md section 2).

    Each device all-gathers the full dataset (N elements of memory — what the
    paper's method avoids) and computes every interaction involving its own
    block.  Used as the correctness oracle and the memory-benchmark baseline.

    Contract shared with quorum_allpairs: ``out_j(bi, bj) == out_i(bj, bi)``
    (the contribution to a block does not depend on which side of the pair it
    was visited from — true for forces, correlations, and similarity sums).
    """
    tr = obs_trace.get_tracer()
    if tr:  # (P-1) peer blocks land per device; exact at jit-trace time
        tr.count("comm.allgather.bytes",
                 (axis_size - 1) * obs_trace.nbytes_of(x))
    i = lax.axis_index(axis_name)
    allblocks = lax.all_gather(x, axis_name)  # [P, block, ...] — full data
    mine = x

    def body(acc, j):
        other = jnp.take(allblocks, j, axis=0)
        out_i, _ = pair_fn(mine, other)
        return acc + jnp.where(j == i, jnp.zeros_like(out_i), out_i), None

    self_out, _ = pair_fn(mine, mine)
    acc, _ = lax.scan(body, jnp.zeros_like(self_out), jnp.arange(axis_size))
    return acc + self_out

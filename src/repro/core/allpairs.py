"""The quorum all-pairs engine: shard_map + jax.lax collectives.

TPU-native realization of the paper's distribution scheme (DESIGN.md section 2):

  1. ``quorum_gather``  — each device pulls its k quorum blocks with k-1
     ``lax.ppermute`` cyclic shifts (quorums are cyclic, so the pattern is
     shift-invariant and identical on every device).  Memory: k*N/P =
     O(N/sqrt(P)) — the paper's headline number.
  2. pair compute       — one of three execution modes (DESIGN.md section 4):
       * ``batched`` — one vmapped ``pair_fn`` call over all n_pairs
         interactions + a ``segment_sum`` over slot ids, so the MXU sees a
         single big batch instead of n_pairs tiny launches,
       * ``overlap`` — double-buffered: each pair is computed as soon as its
         later-arriving block lands, so XLA's latency-hiding scheduler can
         run the remaining ppermutes concurrently with compute (and start the
         inverse scatter shifts for slots whose pairs are already done),
       * ``scan``    — the serial per-pair ``lax.scan`` (low-memory fallback
         and correctness oracle),
     selected by a size heuristic when ``mode="auto"``.
  3. ``quorum_scatter`` — per-block partial results routed back to block
     owners with the inverse shifts and reduced (sum or a user monoid).

Plus a reference ``allgather_allpairs`` baseline (the "all data everywhere"
scheme the paper improves on) used by tests and the memory benchmark.
"""

from __future__ import annotations

import functools
import math
import os
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .scheduler import PairSchedule

__all__ = [
    "quorum_gather",
    "quorum_scatter",
    "quorum_allpairs",
    "allgather_allpairs",
    "pair_mask_table",
    "mark_varying",
    "auto_batch_bytes",
    "env_mode_override",
    "pair_ready_order",
    "ENGINE_MODES",
]

ENGINE_MODES = ("batched", "overlap", "scan")

# auto-mode switches away from `batched` when its [2*n_pairs, block, ...]
# working set would exceed this budget (bytes; overridable for small-VMEM or
# huge-HBM parts)
_DEFAULT_BATCH_BYTES = 1 << 28


def auto_batch_bytes() -> int:
    """The auto-mode byte budget (DESIGN.md section 4), read from
    ``REPRO_BATCH_BYTES_LIMIT`` at *selection* time (every ``mode="auto"``
    trace), not at import — setting the env var after ``import repro``
    works.  Shared by the batch engine's heuristic, the serving query
    engine's, and the sparse join's."""
    env = os.environ.get("REPRO_BATCH_BYTES_LIMIT", "").strip()
    return int(env) if env else _DEFAULT_BATCH_BYTES


def _shift_perm(P: int, shift: int) -> list[tuple[int, int]]:
    """ppermute permutation delivering block (i + shift) % P to device i."""
    return [(j, (j - shift) % P) for j in range(P)]


def quorum_gather(x: jax.Array, schedule: PairSchedule, axis_name: str,
                  *, overlap_fn: Callable[[int, jax.Array], Any] | None = None):
    """Gather this device's quorum blocks (DESIGN.md section 2, phase 1).

    Args:
      x: the local block, shape [block, ...] (inside shard_map).
      schedule: PairSchedule for the quorum axis size P.
      axis_name: mesh axis the blocks are sharded over.
      overlap_fn: optional ``f(slot, block)`` called as each block lands —
        lets callers overlap compute with the next in-flight permute (the
        double-buffered mode; XLA's latency-hiding scheduler interleaves the
        independent ppermutes and per-slot compute).

    Returns:
      stacked quorum blocks [k, block, ...]; slot s holds global block
      (i + shifts[s]) % P.  If overlap_fn is given, returns the list of its
      results instead.
    """
    P = schedule.P
    shifts = [int(s) for s in schedule.shifts]
    blocks = []
    results = []
    for slot, a in enumerate(shifts):
        blk = x if a == 0 else lax.ppermute(x, axis_name, _shift_perm(P, a))
        if overlap_fn is not None:
            results.append(overlap_fn(slot, blk))
        else:
            blocks.append(blk)
    if overlap_fn is not None:
        return results
    return jnp.stack(blocks, axis=0)


def quorum_scatter(partials, schedule: PairSchedule, axis_name: str,
                   *, reduce_fn: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add):
    """Route per-slot partial results back to block owners and reduce
    (DESIGN.md section 2, phase 3).

    partials: [k, block, ...] stacked, or a length-k sequence of [block, ...]
    arrays; slot s is a partial result for global block (i + shifts[s]) % P.
    Sends slot s with the inverse shift so the owner receives it, then folds
    with ``reduce_fn`` (default sum).  The per-slot sequence form is what the
    overlap engine mode produces: each slot's inverse shift depends only on
    that slot's pair results, so the scheduler can start early slots' sends
    while later pairs are still computing (the pipelined scatter).
    Returns the reduced [block, ...] result for the local block.
    """
    P = schedule.P
    shifts = [int(s) for s in schedule.shifts]
    acc = None
    for slot, a in enumerate(shifts):
        part = partials[slot]
        arrived = part if a == 0 else lax.ppermute(part, axis_name, _shift_perm(P, -a))
        acc = arrived if acc is None else reduce_fn(acc, arrived)
    return acc


def pair_mask_table(schedule: PairSchedule) -> np.ndarray:
    """[P, n_pairs] float mask deduplicating the d = P/2 orbit for even P
    (DESIGN.md section 3.2).

    Each unordered pair with difference P/2 is generated by exactly two
    devices (i and i + P/2); the device with the smaller canonical lower
    endpoint keeps it.  All other entries are 1.  The mask rides into
    shard_map as a sharded operand, so control flow stays uniform.
    """
    P, n = schedule.P, schedule.n_pairs
    mask = np.ones((P, n), dtype=np.float32)
    if P % 2 == 0 and P > 1:
        d_half = P // 2
        idx = np.nonzero(schedule.pair_diff == d_half)[0]
        if idx.size:
            s = int(idx[0])
            a_lo = int(schedule.shifts[schedule.pair_slots[s, 0]])
            for i in range(P):
                lo = (i + a_lo) % P
                hi = (lo + d_half) % P
                # keeper: the generating device whose lower endpoint is the
                # canonical (smaller) block id of the orbit
                mask[i, s] = 1.0 if lo == min(lo, hi) else 0.0
    return mask


def mark_varying(x: jax.Array, axis_name: str) -> jax.Array:
    """Mark x as varying over the quorum axis (jax >= 0.7 VMA tracking;
    the shard_map plumbing every engine-internal constant goes through —
    DESIGN.md section 2)."""
    try:
        return lax.pcast(x, axis_name, to="varying")
    except (AttributeError, TypeError):  # pragma: no cover - older jax
        return x


def env_mode_override() -> str | None:
    """The validated ``REPRO_ALLPAIRS_MODE`` forced mode, or None if unset
    (DESIGN.md section 4).

    The benchmark / CI A/B hook, consulted by every ``mode="auto"``
    selection (engine, PCIT tile phases, serving scoring, sparse join).  Read at trace time — set it
    before the first jitted call; already-compiled auto-mode programs keep
    their baked-in choice.  Unknown values raise rather than silently
    falling through to the heuristic.
    """
    env = os.environ.get("REPRO_ALLPAIRS_MODE", "").strip().lower()
    if not env:
        return None
    if env not in ENGINE_MODES:
        raise ValueError(
            f"REPRO_ALLPAIRS_MODE must be one of {ENGINE_MODES}, got {env!r}")
    return env


def pair_ready_order(schedule: PairSchedule) -> list[list[int]]:
    """Pair indices grouped by *ready slot* for the overlap modes
    (DESIGN.md section 4).

    A pair (lo, hi) can compute once its later block lands in the gather
    shift sequence, i.e. at slot max(lo, hi); ready[s] lists the pairs that
    become computable when slot s arrives.
    """
    lo_np = schedule.pair_slots[:, 0]
    hi_np = schedule.pair_slots[:, 1]
    ready: list[list[int]] = [[] for _ in range(schedule.k)]
    for idx in range(schedule.n_pairs):
        ready[max(int(lo_np[idx]), int(hi_np[idx]))].append(idx)
    return ready


def _wmul(out: jax.Array, w: jax.Array) -> jax.Array:
    """Weight a pair output by a scalar or per-pair [n_pairs] mask weight."""
    if w.ndim == 0:
        return out * w.astype(out.dtype)
    return out * w.astype(out.dtype).reshape((-1,) + (1,) * (out.ndim - 1))


def _select_mode(schedule: PairSchedule, x: jax.Array,
                 probe: jax.ShapeDtypeStruct, batch_fn) -> str:
    """The ``mode="auto"`` heuristic (DESIGN.md section 4).

    Environment override first (:func:`env_mode_override`; conflicts with a
    fused ``batch_fn`` — which only exists for the batched step — raise
    instead of silently dropping the kernel), then: a fused batch kernel
    always means ``batched``; otherwise ``batched`` while its
    [2*n_pairs, block, ...] operand+output working set fits the byte
    budget, ``overlap`` when there are enough shifts to hide (k >= 3),
    ``scan`` as the low-memory last resort.
    """
    env = env_mode_override()
    if env is not None:
        if batch_fn is not None and env != "batched":
            raise ValueError(
                f"REPRO_ALLPAIRS_MODE={env} conflicts with a fused batch_fn "
                "(the kernel only replaces the batched inner step)")
        return env
    if batch_fn is not None:
        return "batched"
    out_bytes = math.prod(probe.shape) * jnp.dtype(probe.dtype).itemsize
    in_bytes = x.size * jnp.dtype(x.dtype).itemsize
    if 2 * schedule.n_pairs * (in_bytes + out_bytes) <= auto_batch_bytes():
        return "batched"
    if schedule.k >= 3:
        return "overlap"
    return "scan"


def _scan_accumulate(pair_fn, quorum, schedule: PairSchedule, mask, probe,
                     axis_name: str) -> jax.Array:
    """Serial per-pair scan with scatter-adds into the [k, block, ...] carry."""
    k = schedule.k
    lo_slots = jnp.asarray(schedule.pair_slots[:, 0])
    hi_slots = jnp.asarray(schedule.pair_slots[:, 1])
    is_self = jnp.asarray(schedule.pair_diff == 0)

    def body(acc, inputs):
        lo, hi, selfp, w = inputs
        bi = jnp.take(quorum, lo, axis=0)
        bj = jnp.take(quorum, hi, axis=0)
        out_i, out_j = pair_fn(bi, bj)
        out_j = jnp.where(selfp, jnp.zeros_like(out_j), out_j)  # self-pair: count once
        acc = acc.at[lo].add(_wmul(out_i, w))
        acc = acc.at[hi].add(_wmul(out_j, w))
        return acc, None

    acc0 = mark_varying(jnp.zeros((k,) + probe.shape, probe.dtype), axis_name)
    acc, _ = lax.scan(body, acc0, (lo_slots, hi_slots, is_self, mask))
    return acc


def _batched_accumulate(pair_fn, quorum, schedule: PairSchedule, mask, probe,
                        batch_fn) -> jax.Array:
    """All n_pairs interactions in one vmapped call + segment_sum over slots.

    With ``batch_fn`` the whole step (slot gather + pair interaction +
    segment reduction) runs as one fused kernel (e.g.
    kernels.ops.pairwise_batch_forces).
    """
    k = schedule.k
    lo_slots = jnp.asarray(schedule.pair_slots[:, 0])
    hi_slots = jnp.asarray(schedule.pair_slots[:, 1])
    is_self = jnp.asarray(schedule.pair_diff == 0)
    wi = mask
    wj = jnp.where(is_self, jnp.zeros_like(mask), mask)  # self-pair: count once
    if batch_fn is not None:
        return batch_fn(quorum, lo_slots, hi_slots, wi, wj)
    lhs = jnp.take(quorum, lo_slots, axis=0)          # [n_pairs, block, ...]
    rhs = jnp.take(quorum, hi_slots, axis=0)
    out_i, out_j = jax.vmap(pair_fn)(lhs, rhs)        # [n_pairs, block, ...]
    data = jnp.concatenate([_wmul(out_i, wi), _wmul(out_j, wj)], axis=0)
    ids = jnp.concatenate([lo_slots, hi_slots])
    acc = jax.ops.segment_sum(data, ids, num_segments=k)
    return acc.astype(probe.dtype)


def _overlap_accumulate(pair_fn, x, schedule: PairSchedule, mask, probe,
                        axis_name: str) -> list[jax.Array]:
    """Double-buffered gather/compute: each pair runs at its ready slot.

    A pair (lo, hi) is ready once its later block lands, i.e. at slot
    max(lo, hi) of the gather shift sequence — so the compute for slot s's
    pairs is independent of ppermutes s+1..k-1 and XLA's latency-hiding
    scheduler overlaps them.  Returns per-slot partials (list of length k)
    so quorum_scatter can likewise start early slots' inverse shifts before
    late pairs finish.
    """
    k = schedule.k
    lo_np = schedule.pair_slots[:, 0]
    hi_np = schedule.pair_slots[:, 1]
    ready = pair_ready_order(schedule)

    landed: list[jax.Array] = []
    contribs: list[list[jax.Array]] = [[] for _ in range(k)]

    def on_land(slot: int, blk: jax.Array) -> None:
        landed.append(blk)
        for idx in ready[slot]:
            lo, hi = int(lo_np[idx]), int(hi_np[idx])
            w = mask[idx]
            out_i, out_j = pair_fn(landed[lo], landed[hi])
            contribs[lo].append(_wmul(out_i, w))
            if lo != hi:  # self-pair (lo == hi, d = 0): count once
                contribs[hi].append(_wmul(out_j, w))

    quorum_gather(x, schedule, axis_name, overlap_fn=on_land)

    def fold(parts: list[jax.Array]) -> jax.Array:
        if not parts:  # gathered slot with no scheduled pair
            return mark_varying(jnp.zeros(probe.shape, probe.dtype), axis_name)
        return functools.reduce(jnp.add, parts).astype(probe.dtype)

    return [fold(c) for c in contribs]


def quorum_allpairs(
    pair_fn: Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]],
    x: jax.Array,
    *,
    axis_name: str,
    schedule: PairSchedule | None = None,
    axis_size: int | None = None,
    mask: jax.Array | None = None,
    mode: str = "auto",
    batch_fn: Callable[..., jax.Array] | None = None,
    placement=None,
):
    """Compute a symmetric all-pairs reduction with quorum replication.

    ``pair_fn(bi, bj) -> (out_i, out_j)`` returns the contribution of the
    interaction to block i and to block j (e.g. forces f_ij and -f_ij; the
    paper's Fig. 1 "pair formed once" symmetry).  Required consistency:
    ``out_j(bi, bj) == out_i(bj, bi)``.  Self-pairs call ``pair_fn(b, b)``
    and keep only ``out_i``.

    Must be called inside shard_map with ``x`` the local block.  ``mask`` is
    this device's [n_pairs] dedup/validity mask; defaults to this device's
    pair_mask_table row (selected by axis_index), so the doubly-generated
    d = P/2 orbit on even P is deduplicated out of the box.  Pass it
    explicitly (a sharded operand) to avoid embedding the [P, n_pairs]
    table as a constant, or to add app-specific pair validity.

    ``mode`` selects the execution engine (DESIGN.md section 4):
      * ``"batched"`` — gather once, evaluate all pairs in one vmapped call,
        reduce with a slot segment_sum (fastest; O(n_pairs) extra memory).
      * ``"overlap"`` — double-buffered gather: each pair computes as soon as
        its later block lands, hiding the k-1 shifts behind compute, and the
        scatter's inverse shifts pipeline symmetrically (O(k) memory).
      * ``"scan"``    — serial per-pair lax.scan (lowest memory; oracle).
      * ``"auto"``    — heuristic: batched while its working set fits a byte
        budget, else overlap when k >= 3, else scan; overridable with the
        ``REPRO_ALLPAIRS_MODE`` env var.
    ``batch_fn(quorum, lo_slots, hi_slots, wi, wj) -> [k, block, ...]`` is an
    optional fused replacement for the batched inner step (a Pallas kernel
    such as kernels.ops.pairwise_batch_forces); implies ``mode="batched"``
    under ``auto``.

    ``placement`` (core.placement.Placement) selects the block-placement
    layer (DESIGN.md section 10): residency and routing come from the
    placement's shift structure instead of the default cyclic difference
    set.  A *full-replication* placement short-circuits to
    :func:`allgather_allpairs` (the degenerate oracle — no quorum pipeline,
    so ``mode``/``mask`` don't apply and a ``batch_fn`` is rejected).  When
    neither ``schedule`` nor ``placement`` is given, the placement is
    selected by ``REPRO_PLACEMENT`` (default ``auto`` == cyclic, bit-exact
    with the pre-placement behavior).

    Returns the per-block reduced output, shape/type of ``pair_fn``'s out_i.
    """
    if mode not in ENGINE_MODES + ("auto",):
        raise ValueError(f"mode must be one of {ENGINE_MODES + ('auto',)}, "
                         f"got {mode!r}")
    if batch_fn is not None and mode not in ("batched", "auto"):
        raise ValueError(
            f"batch_fn only replaces the batched inner step (got "
            f"mode={mode!r}); drop it or use mode='batched'")
    if placement is not None:
        if axis_size is not None and placement.P != axis_size:
            raise ValueError(
                f"placement is for P={placement.P} but axis_size={axis_size}")
        if schedule is not None and schedule.P != placement.P:
            raise ValueError(
                f"placement is for P={placement.P} but schedule.P="
                f"{schedule.P}")
    if placement is None and schedule is None:
        assert axis_size is not None, "need schedule, placement, or axis_size"
        from .placement import placement_from_env
        placement = placement_from_env(axis_size)
    if placement is not None and placement.full:
        if batch_fn is not None:
            raise ValueError(
                "batch_fn fuses the quorum batched step; the full-replication "
                "placement routes through allgather_allpairs — drop batch_fn "
                "or pick a quorum placement")
        if mask is not None:
            raise ValueError(
                "mask expresses per-pair validity over the quorum schedule; "
                "the full-replication placement routes through "
                "allgather_allpairs, which would silently ignore it — drop "
                "the mask or pick a quorum placement")
        return allgather_allpairs(pair_fn, x, axis_name=axis_name,
                                  axis_size=placement.P)
    if schedule is None:
        schedule = placement.schedule()

    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))  # [P, n_pairs]
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)  # accept [1, n_pairs] shard_map leftovers

    # probe output structure once (shapes are static)
    sds = jax.ShapeDtypeStruct(x.shape, x.dtype)
    probe, _ = jax.eval_shape(pair_fn, sds, sds)
    if mode == "auto":
        mode = _select_mode(schedule, x, probe, batch_fn)

    if mode == "overlap":
        partials = _overlap_accumulate(pair_fn, x, schedule, mask, probe,
                                       axis_name)
    else:
        quorum = quorum_gather(x, schedule, axis_name)  # [k, block, ...]
        if mode == "batched":
            partials = _batched_accumulate(pair_fn, quorum, schedule, mask,
                                           probe, batch_fn)
        else:
            partials = _scan_accumulate(pair_fn, quorum, schedule, mask,
                                        probe, axis_name)
    return quorum_scatter(partials, schedule, axis_name)


def allgather_allpairs(
    pair_fn: Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]],
    x: jax.Array,
    *,
    axis_name: str,
    axis_size: int,
):
    """Baseline: replicate ALL blocks on every device (paper section 1.1
    schemes; DESIGN.md section 2).

    Each device all-gathers the full dataset (N elements of memory — what the
    paper's method avoids) and computes every interaction involving its own
    block.  Used as the correctness oracle and the memory-benchmark baseline.

    Contract shared with quorum_allpairs: ``out_j(bi, bj) == out_i(bj, bi)``
    (the contribution to a block does not depend on which side of the pair it
    was visited from — true for forces, correlations, and similarity sums).
    """
    i = lax.axis_index(axis_name)
    allblocks = lax.all_gather(x, axis_name)  # [P, block, ...] — full data
    mine = x

    def body(acc, j):
        other = jnp.take(allblocks, j, axis=0)
        out_i, _ = pair_fn(mine, other)
        return acc + jnp.where(j == i, jnp.zeros_like(out_i), out_i), None

    self_out, _ = pair_fn(mine, mine)
    acc, _ = lax.scan(body, jnp.zeros_like(self_out), jnp.arange(axis_size))
    return acc + self_out

"""Fault injection and fault-tolerant sweep execution (DESIGN.md
section 13).

The paper's redundancy claim — every block replicated in exactly k
quorums (Eq. 13) — is what makes an all-pairs sweep *survivable*, and
this module is where the repo finally executes a recovery instead of
just planning one.  The engines themselves are jit-traced SPMD programs
(a traced program cannot observe a device death mid-collective), so the
failure-detection boundary is the **round**: the synchronization points
:func:`core.sweep.sweep_rounds` derives from each engine mode (batched:
one fused round; overlap: one round per gather shift; scan: one round
per pair).  Between rounds a host-side driver — the same simulated-
cluster style as ``launch/dryrun.py`` — consults a deterministic,
seeded :class:`FaultPlan` and reacts to what it injects:

  * **kill d** — device d's store and non-durable partials are gone.
    The driver pauses, calls ``core.scheduler.reassign`` with the dead
    device's *remaining* pair tiles (tier 1: live co-resident peer;
    tier 2: live holder of one block fetches the other), executes the
    tier-2 fetches, then **re-replicates** the under-replicated blocks
    from surviving holders (``launch.elastic.plan_replication_repair``)
    so the k-residency invariant is restored — after repair, another
    ``k - 1`` failures are survivable again.  Partials the dead device
    computed since the last checkpoint are recomputed by the new
    owners; durable partials (saved by the ``REPRO_CKPT_EVERY``
    round-boundary checkpoints through ``ckpt/checkpoint.py``) are not.
  * **slow d by f** — device d's virtual per-pair busy time is scaled
    by f from this round on (the heterogeneity signal, recorded in
    ``RecoveryStats.busy_by_device``); ``obs.feedback`` turns it into
    the capacity weights of ``core.placement.weighted_owner_table`` —
    the Rocket loop, DESIGN.md section 14.5.
  * **drop** — one block-transfer message this round is lost and
    retransmitted (the ppermute-message drop of the fault model).

When *all* holders of a block die, ``reassign`` refuses ("block lost")
and the driver restores from the latest complete checkpoint — blocks
re-seeded onto live devices, durable partials kept, only the
non-durable tail recomputed — and resumes.  No full restart, and the
final output is **bit-exact**: partials are pure functions of block
contents, and the final fold always runs in canonical pair order, so
neither the fault history nor the engine mode can change a single bit.

The headline check is the chaos selfcheck (``python -m
repro.core.faults``): kill a random live device every N rounds across
every registered placement x engine mode x P in {5, 7, 8, 12, 13} and
all three workloads (dense reduce, sparse similarity join, k-NN graph),
asserting the faulted output is bit-identical to the fault-free run,
the fault-free run matches an independent brute-force oracle, and the
residency invariant holds after every repair.
"""

from __future__ import annotations

import contextlib
import dataclasses
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..ckpt.checkpoint import restore_or_none, save_checkpoint
from ..launch.elastic import plan_replication_repair
from ..obs import trace as obs_trace
from . import env as env_mod
from .delta import dirty_tiles, owner_partition
from .placement import Placement, get_placement, registered_placements
from .scheduler import PairSchedule, reassign
from .sparse import threshold_with_gap
from .sweep import ENGINE_MODES, sweep_rounds

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "RecoveryStats",
    "PairWorkload",
    "DenseReduceWorkload",
    "SparseJoinWorkload",
    "KnnGraphWorkload",
    "WORKLOADS",
    "run_fault_tolerant_sweep",
    "residency_invariant_ok",
    "chaos_selfcheck",
    "CHAOS_P",
]

# the chaos matrix: covers odd/even P, the projective planes 7 and 13,
# and the affine plane 12 (ISSUE acceptance set)
CHAOS_P = (5, 7, 8, 12, 13)

_KINDS = ("kill", "slow", "drop")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault (DESIGN.md section 13): ``kind`` is ``kill``
    (device dies at the start of ``round``), ``slow`` (device runs
    ``factor`` x slower from this round on), or ``drop`` (one block
    transfer this round is lost and retransmitted)."""
    kind: str
    round: int
    device: int = -1          # -1 for drop (the link, not a device)
    factor: float = 1.0       # slow only

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded fault schedule the driver consults at
    every round boundary (DESIGN.md section 13).  Pure data: the same
    plan replayed against the same workload yields the same recovery
    actions, which is what makes chaos failures debuggable."""
    events: Tuple[FaultEvent, ...] = ()

    def events_at(self, rnd: int) -> List[FaultEvent]:
        """Events firing at the start of round ``rnd`` (kills first, so
        a killed device never services this round's transfers)."""
        order = {"kill": 0, "drop": 1, "slow": 2}
        return sorted((e for e in self.events if e.round == rnd),
                      key=lambda e: (order[e.kind], e.device))

    @property
    def n_kills(self) -> int:
        """Total device kills in the plan."""
        return sum(1 for e in self.events if e.kind == "kill")

    @classmethod
    def random_kills(cls, P: int, n_rounds: int, every: int = 2,
                     seed: int = 0, chaos: bool = True) -> "FaultPlan":
        """Kill a random live device every ``every`` rounds (never the
        last survivor), deterministically from ``seed``; with ``chaos``
        also inject a message drop at each kill round and a slowdown on
        a random live device between kills (DESIGN.md section 13)."""
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        rng = np.random.RandomState(seed)
        alive = list(range(P))
        events: List[FaultEvent] = []
        for rnd in range(n_rounds):
            # short sweeps (batched: one round) still get their one kill
            kill_here = ((rnd + 1) % every == 0
                         or (n_rounds < every and rnd == 0))
            if kill_here and len(alive) > 1:
                victim = alive[int(rng.randint(len(alive)))]
                alive.remove(victim)
                events.append(FaultEvent("kill", rnd, victim))
                if chaos:
                    events.append(FaultEvent("drop", rnd))
            elif chaos and rnd % every == 0 and alive:
                dev = alive[int(rng.randint(len(alive)))]
                events.append(FaultEvent(
                    "slow", rnd, dev, factor=float(1.25 + rng.rand())))
        return cls(events=tuple(events))


@dataclasses.dataclass
class RecoveryStats:
    """Counters the driver accumulates while recovering (DESIGN.md
    sections 13, 14) — the quantities ``benchmarks/bench_faults.py``
    reports and ``obs.feedback`` turns into capacity weights."""
    rounds: int = 0
    n_kills: int = 0
    n_slow: int = 0
    n_drops: int = 0
    n_drop_retries: int = 0
    n_reassigned: int = 0          # pairs moved to new owners
    n_fetches: int = 0             # tier-2 / weighted-owner block pulls
    n_rereplicated: int = 0        # block copies restoring k-residency
    n_restores: int = 0            # checkpoint restores (block loss)
    n_recomputed: int = 0          # non-durable partials recomputed
    n_checkpoints: int = 0
    bytes_fetched: int = 0         # tier-2 fetch traffic
    bytes_rereplicated: int = 0    # repair-copy traffic
    # per-device work accounting: pairs computed, deterministic virtual
    # busy time (rows_x * rows_y * slow_factor per pair — the obs.feedback
    # throughput signal), and measured wall-clock busy time
    pairs_by_device: Dict[int, int] = dataclasses.field(default_factory=dict)
    busy_by_device: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    busy_s_by_device: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    # recovery latency breakdown: seconds per phase
    # (reassign / rereplicate / restore / checkpoint)
    recovery_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        """The counters as a plain dict (for JSON benchmark output)."""
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Workloads: pure per-pair partials + a canonical fold
# ---------------------------------------------------------------------------
#
# Bit-exactness across fault histories and engine modes rests on two
# properties every workload here maintains: (1) a pair's partial is a
# pure function of the two block contents (numpy f32 host math — the
# same bits no matter which device computes or recomputes it), and
# (2) the final fold consumes partials in canonical (x, y), x <= y
# order, never in completion order.

class PairWorkload:
    """Base class: a corpus split into P blocks plus the three hooks the
    fault-tolerant driver needs — ``pair_partial`` (pure), ``fold``
    (canonical-order combine), and ``check_oracle`` (an independent
    brute-force cross-check); DESIGN.md section 13."""

    name = "abstract"

    def __init__(self, P: int, n_items: Optional[int] = None, dim: int = 8,
                 seed: int = 0):
        self.P = P
        self.n = int(n_items) if n_items is not None else 3 * P + 2
        rng = np.random.RandomState(seed + 101 * P)
        self.corpus = rng.randn(self.n, dim).astype(np.float32)
        self.blocks: List[np.ndarray] = [
            np.ascontiguousarray(b) for b in np.array_split(self.corpus, P)]
        starts = np.cumsum([0] + [len(b) for b in self.blocks])
        self.offsets = [int(s) for s in starts[:-1]]

    # -- the driver-facing hooks ------------------------------------------
    def pair_partial(self, x: int, y: int, bx: np.ndarray,
                     by: np.ndarray) -> Any:
        """Pure partial result for block pair (x, y) — same bits on any
        device, any number of recomputations."""
        raise NotImplementedError

    def fold(self, partials: Dict[Tuple[int, int], Any]) -> Any:
        """Combine all partials in canonical (x, y), x <= y order."""
        raise NotImplementedError

    def check_oracle(self, result: Any) -> None:
        """Assert ``result`` matches an independent brute-force oracle."""
        raise NotImplementedError

    def equal(self, a: Any, b: Any) -> bool:
        """Bitwise result equality (ints exact, floats by bit pattern)."""
        raise NotImplementedError

    # -- checkpoint encoding (npz-able dicts) -----------------------------
    def encode_partial(self, partial: Any) -> Dict[str, np.ndarray]:
        """A partial as an npz-able array dict (for checkpointing)."""
        raise NotImplementedError

    def decode_partial(self, enc: Dict[str, np.ndarray]) -> Any:
        """Inverse of :meth:`encode_partial`."""
        raise NotImplementedError

    def canonical_pairs(self) -> List[Tuple[int, int]]:
        """All unordered block pairs in the canonical fold order."""
        return [(x, y) for x in range(self.P) for y in range(x, self.P)]


class DenseReduceWorkload(PairWorkload):
    """Global all-pairs reduction: the sum of every pairwise dot product
    block pair by block pair, folded in canonical order (DESIGN.md
    section 13).  The faulted run must reproduce the fault-free float64
    sum bit-for-bit; the brute-force full-Gram oracle is matched to
    float tolerance (a different summation order)."""

    name = "dense"

    def pair_partial(self, x, y, bx, by):
        """Float64 sum of the pair's dot products (triu within-block)."""
        s = bx.astype(np.float32) @ by.astype(np.float32).T
        if x == y:  # within-block: each unordered item pair once
            s = np.triu(s)
        return np.float64(np.sum(s, dtype=np.float64))

    def fold(self, partials):
        """Accumulate partial sums in canonical pair order."""
        acc = np.float64(0.0)
        for p in self.canonical_pairs():
            acc = acc + partials[p]
        return acc

    def check_oracle(self, result):
        """Compare against the full-Gram upper-triangle sum."""
        g = self.corpus @ self.corpus.T  # [N, N] f32
        iu, ju = np.triu_indices(self.n)
        want = np.sum(g[iu, ju], dtype=np.float64)
        np.testing.assert_allclose(float(result), float(want), rtol=1e-5)

    def equal(self, a, b):
        """Bit-pattern equality of the float64 totals."""
        return np.float64(a).tobytes() == np.float64(b).tobytes()

    def encode_partial(self, partial):
        """Scalar partial as a one-entry array dict."""
        return {"v": np.float64(partial)}

    def decode_partial(self, enc):
        """Inverse of :meth:`encode_partial`."""
        return np.float64(enc["v"])


class SparseJoinWorkload(PairWorkload):
    """Thresholded similarity join: all global item pairs (i, j), i < j,
    with dot score >= a gap-protected threshold (DESIGN.md section 13).
    Output is the sorted (i, j) index array — discrete, so bit-exact
    equality is set equality, and the threshold gap
    (``core.sparse.threshold_with_gap``) keeps borderline rounding from
    flipping membership."""

    name = "sparse"

    def __init__(self, P, n_items=None, dim=8, seed=0):
        super().__init__(P, n_items, dim, seed)
        g = self.corpus @ self.corpus.T
        iu, ju = np.triu_indices(self.n, k=1)
        self.threshold = threshold_with_gap(g[iu, ju], selectivity=0.15)

    def pair_partial(self, x, y, bx, by):
        """Sorted global (i, j) rows of the pair's above-threshold hits."""
        s = bx.astype(np.float32) @ by.astype(np.float32).T
        ox, oy = self.offsets[x], self.offsets[y]
        if x == y:
            ii, jj = np.nonzero(np.triu(s >= self.threshold, k=1))
        else:
            ii, jj = np.nonzero(s >= self.threshold)
        gi, gj = ii.astype(np.int64) + ox, jj.astype(np.int64) + oy
        lo, hi = np.minimum(gi, gj), np.maximum(gi, gj)
        order = np.lexsort((hi, lo))
        return np.stack([lo[order], hi[order]], axis=1)

    def fold(self, partials):
        """Concatenate and lexsort all index rows into one join result."""
        rows = [partials[p] for p in self.canonical_pairs()]
        allr = (np.concatenate(rows, axis=0) if rows
                else np.zeros((0, 2), np.int64))
        order = np.lexsort((allr[:, 1], allr[:, 0]))
        return allr[order]

    def check_oracle(self, result):
        """Compare against ``core.sparse.brute_force_join`` exactly."""
        from .sparse import brute_force_join
        iu, ju, _ = brute_force_join(self.corpus, self.threshold, "dot")
        want = np.stack([iu.astype(np.int64), ju.astype(np.int64)], axis=1)
        np.testing.assert_array_equal(result, want)

    def equal(self, a, b):
        """Exact equality of the sorted index arrays."""
        return a.shape == b.shape and bool(np.array_equal(a, b))

    def encode_partial(self, partial):
        """Index rows as a one-entry array dict."""
        return {"ij": np.asarray(partial, np.int64)}

    def decode_partial(self, enc):
        """Inverse of :meth:`encode_partial`."""
        return np.asarray(enc["ij"], np.int64).reshape(-1, 2)


class KnnGraphWorkload(PairWorkload):
    """All-pairs k-nearest-neighbor graph: per item, the top-k other
    items by dot score under the total order (-score, index), merged
    from per-pair candidate lists in canonical order (DESIGN.md
    section 13).  Output is the [N, topk] neighbor index matrix —
    integer, so bitwise equality; the oracle recomputes it blockwise
    with the identical float ops, so even near-ties cannot diverge."""

    name = "knn"
    topk = 3

    def _candidates(self, x, y, bx, by):
        """Per-row (scores, global idx) of block x's items vs block y."""
        s = bx.astype(np.float32) @ by.astype(np.float32).T
        if x == y:
            np.fill_diagonal(s, -np.inf)
        idx = np.arange(by.shape[0], dtype=np.int64) + self.offsets[y]
        return s, np.broadcast_to(idx, s.shape)

    def _row_topk(self, scores, idx):
        """[n, topk] best-by-(-score, idx) selection, sentinel-padded."""
        n, topk = scores.shape[0], self.topk
        out_s = np.full((n, topk), -np.inf, np.float32)
        out_i = np.full((n, topk), np.iinfo(np.int64).max, np.int64)
        for r in range(n):
            order = np.lexsort((idx[r], -scores[r].astype(np.float64)))
            take = [o for o in order if np.isfinite(scores[r, o])][:topk]
            out_s[r, :len(take)] = scores[r, take]
            out_i[r, :len(take)] = idx[r, take]
        return out_s, out_i

    def pair_partial(self, x, y, bx, by):
        """Per-row top-k candidates of each side of the block pair."""
        sx, ix = self._candidates(x, y, bx, by)
        xs, xi = self._row_topk(sx, ix)
        if x == y:
            return {"xs": xs, "xi": xi}
        sy, iy = self._candidates(y, x, by, bx)
        ys, yi = self._row_topk(sy, iy)
        return {"xs": xs, "xi": xi, "ys": ys, "yi": yi}

    def _merge(self, s_a, i_a, s_b, i_b):
        s = np.concatenate([s_a, s_b], axis=1)
        i = np.concatenate([i_a, i_b], axis=1)
        return self._row_topk(s, i)

    def fold(self, partials):
        """Merge per-pair candidates into the [N, topk] index matrix."""
        topk = self.topk
        best_s = np.full((self.n, topk), -np.inf, np.float32)
        best_i = np.full((self.n, topk), np.iinfo(np.int64).max, np.int64)
        for (x, y) in self.canonical_pairs():
            part = partials[(x, y)]
            ox = self.offsets[x]
            nx = self.blocks[x].shape[0]
            best_s[ox:ox + nx], best_i[ox:ox + nx] = self._merge(
                best_s[ox:ox + nx], best_i[ox:ox + nx],
                part["xs"], part["xi"])
            if x != y:
                oy = self.offsets[y]
                ny = self.blocks[y].shape[0]
                best_s[oy:oy + ny], best_i[oy:oy + ny] = self._merge(
                    best_s[oy:oy + ny], best_i[oy:oy + ny],
                    part["ys"], part["yi"])
        return best_i

    def check_oracle(self, result):
        """Blockwise recompute plus ``core.knn.brute_force_knn`` check."""
        # blockwise-identical float ops -> bitwise-identical scores ->
        # the same (-score, idx) ranking, even at near-ties
        want_s = np.full((self.n, self.topk), -np.inf, np.float32)
        want_i = np.full((self.n, self.topk), np.iinfo(np.int64).max,
                         np.int64)
        for (x, y) in self.canonical_pairs():
            part = self.pair_partial(x, y, self.blocks[x], self.blocks[y])
            ox, nx = self.offsets[x], self.blocks[x].shape[0]
            want_s[ox:ox + nx], want_i[ox:ox + nx] = self._merge(
                want_s[ox:ox + nx], want_i[ox:ox + nx],
                part["xs"], part["xi"])
            if x != y:
                oy, ny = self.offsets[y], self.blocks[y].shape[0]
                want_s[oy:oy + ny], want_i[oy:oy + ny] = self._merge(
                    want_s[oy:oy + ny], want_i[oy:oy + ny],
                    part["ys"], part["yi"])
        np.testing.assert_array_equal(result, want_i)
        # and the ranking itself is right: cross-check vs the repo's
        # dense brute-force k-NN (scores from one full Gram matrix)
        from .knn import brute_force_knn
        ref = brute_force_knn(self.corpus, self.topk, metric="dot")
        np.testing.assert_array_equal(result, ref.indices.astype(np.int64))

    def equal(self, a, b):
        """Exact equality of the neighbor index matrices."""
        return bool(np.array_equal(a, b))

    def encode_partial(self, partial):
        """Candidate arrays as an npz-able dict (keys pass through)."""
        return {k: np.asarray(v) for k, v in partial.items()}

    def decode_partial(self, enc):
        """Inverse of :meth:`encode_partial`."""
        return {k: np.asarray(v) for k, v in enc.items()}


WORKLOADS = (DenseReduceWorkload, SparseJoinWorkload, KnnGraphWorkload)


# ---------------------------------------------------------------------------
# The fault-tolerant driver
# ---------------------------------------------------------------------------

class _ResidencyView:
    """A minimal placement stand-in carrying the cluster's *current*
    residency sets (they drift after repairs), for reassign()."""

    def __init__(self, P: int, sets: Sequence[set]):
        self.P = P
        self.residency_sets = tuple(frozenset(s) for s in sets)


def residency_invariant_ok(placement: Placement,
                           residency: Sequence[set],
                           alive: Sequence[bool]) -> bool:
    """True iff every block has ``min(placement copy count, live
    devices)`` live replicas — the invariant re-replication restores
    after each failure (DESIGN.md section 13)."""
    P = placement.P
    orig = [0] * P
    for S in placement.residency_sets:
        for b in S:
            orig[b] += 1
    n_live = sum(1 for a in alive if a)
    for b in range(P):
        have = sum(1 for i in range(P) if alive[i] and b in residency[i])
        if have < min(orig[b], n_live):
            return False
    return True


def _ckpt_every_default() -> int:
    val = env_mod.read_knob("REPRO_CKPT_EVERY")
    return 1 if val is None else int(val)


def run_fault_tolerant_sweep(workload: PairWorkload, placement: Placement,
                             mode: str, plan: Optional[FaultPlan] = None,
                             *, ckpt_dir: Optional[str] = None,
                             ckpt_every: Optional[int] = None,
                             weights: Optional[Sequence[float]] = None
                             ) -> Tuple[Any, RecoveryStats]:
    """Execute ``workload`` over ``placement`` in engine ``mode``'s round
    structure, surviving the faults ``plan`` injects (DESIGN.md
    section 13).

    A host-side simulated cluster (the ``launch/dryrun.py`` idiom):
    device stores hold numpy blocks per the placement's residency, pair
    partials are computed by their owner — ``weights`` switches
    ownership to :func:`core.placement.weighted_owner_table` — and at
    every round boundary the driver consults ``plan``, reassigns a dead
    device's remaining tiles, executes tier-2 fetches, re-replicates
    lost blocks back to the k-residency invariant (asserted), and
    checkpoints partials every ``ckpt_every`` rounds (default: the
    ``REPRO_CKPT_EVERY`` knob, else 1) when ``ckpt_dir`` is given.
    Block loss (all holders dead) restores from the latest checkpoint —
    durable partials are kept, only the non-durable tail is recomputed —
    and without any checkpoint directory falls back to re-seeding from
    the pristine input blocks.  Returns ``(result, RecoveryStats)``;
    the result is bit-identical to the fault-free run of the same
    workload in any mode.
    """
    if mode not in ENGINE_MODES:
        raise ValueError(f"mode must be one of {ENGINE_MODES}, got {mode!r}")
    plc = placement
    P = plc.P
    if workload.P != P:
        raise ValueError(f"workload P={workload.P} != placement P={P}")
    schedule: PairSchedule = plc.schedule()
    rounds = sweep_rounds(schedule, mode)
    every = _ckpt_every_default() if ckpt_every is None else int(ckpt_every)
    if every < 1:
        raise ValueError(f"ckpt_every must be >= 1, got {every}")
    stats = RecoveryStats()
    tr = obs_trace.get_tracer()
    slow = [1.0] * P  # current slowdown factor per device (slow events)

    @contextlib.contextmanager
    def phase(name: str):
        # time one recovery phase into stats.recovery_s (+ the tracer)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            stats.recovery_s[name] = stats.recovery_s.get(name, 0.0) + dt
            if tr:
                tr.record("faults." + name, dt, placement=plc.name, P=P,
                          mode=mode)

    # canonical pair -> round, via the pair's difference class slot
    sidx_of_diff = {int(d): s for s, d in enumerate(schedule.pair_diff)}
    round_of_sidx = {s: r for r, grp in enumerate(rounds) for s in grp}
    all_pairs = workload.canonical_pairs()

    def pair_round(p: Tuple[int, int]) -> int:
        d = (p[1] - p[0]) % P
        dd = min(d, P - d) if P > 1 else 0
        return round_of_sidx[sidx_of_diff[dd]]

    # ownership: the shared exactly-once partition (core/delta.py) —
    # the placement's owner_of, or the capacity-weighted table
    owner_map = owner_partition(plc, all_pairs, weights=weights)

    orig_count = [0] * P
    for S in plc.residency_sets:
        for b in S:
            orig_count[b] += 1

    alive = [True] * P
    lost_res: Dict[int, List[int]] = {}  # residency at death, per victim
    res_sets: List[set] = [set(plc.residency(i)) for i in range(P)]
    stores: List[Dict[int, np.ndarray]] = [
        {b: workload.blocks[b] for b in res_sets[i]} for i in range(P)]
    partials: Dict[Tuple[int, int], Any] = {}
    computed_by: Dict[Tuple[int, int], int] = {}
    durable: set = set()
    drops_pending = 0

    def transfer(src: int) -> None:
        """Account one block message; consume a pending drop as a
        retransmit."""
        nonlocal drops_pending
        if drops_pending > 0:
            drops_pending -= 1
            stats.n_drop_retries += 1

    def get_block(dev: int, b: int) -> np.ndarray:
        if b in stores[dev]:
            return stores[dev][b]
        holders = sorted(i for i in range(P) if alive[i] and b in stores[i])
        if not holders:
            raise RuntimeError(f"block {b} lost: no live holder")
        src = holders[0]
        transfer(src)
        stats.n_fetches += 1
        stats.bytes_fetched += int(stores[src][b].nbytes)
        return stores[src][b]

    def apply_reassign(rplan) -> None:
        # tier 1 moves the pair; tier 2 moves it to a one-block holder
        # whose missing block get_block() pulls at compute time
        for tgt, prs in sorted(rplan.extra_pairs.items()):
            for p in prs:
                owner_map[p] = tgt
                stats.n_reassigned += 1
        for tgt, entries in sorted(rplan.fetch_pairs.items()):
            for (p, _missing, _src) in entries:
                owner_map[p] = tgt
                stats.n_reassigned += 1

    def rereplicate(dead: List[int]) -> None:
        rplan = plan_replication_repair(plc, dead, residency=res_sets)
        for (b, src, tgt) in rplan.actions:
            transfer(src)
            stats.bytes_rereplicated += int(stores[src][b].nbytes)
            stores[tgt][b] = stores[src][b]
            res_sets[tgt].add(b)
        stats.n_rereplicated += rplan.n_copies
        assert residency_invariant_ok(plc, res_sets, alive)

    def restore_from_checkpoint(dead: List[int]) -> None:
        """Block loss: rebuild from the latest durable state (DESIGN.md
        section 13) — the no-full-restart path."""
        nonlocal partials, computed_by, durable
        stats.n_restores += 1
        if tr:
            tr.count("ckpt.restores")
        ck = restore_or_none(ckpt_dir) if ckpt_dir is not None else None
        if ck is not None:
            tree, _step = ck
            block_data = {int(b): np.asarray(a)
                          for b, a in tree.get("blocks", {}).items()}
            partials = {
                (int(k.split("_")[0]), int(k.split("_")[1])):
                    workload.decode_partial(v)
                for k, v in tree.get("partials", {}).items()}
        else:
            # no durable state yet: re-seed from the pristine input
            # blocks (stable storage), recompute everything
            block_data = {b: workload.blocks[b] for b in range(P)}
            partials = {}
        durable = set(partials)
        computed_by = {}
        n_live = sum(1 for a in alive if a)
        live = [i for i in range(P) if alive[i]]
        for i in range(P):
            res_sets[i] = set(plc.residency(i)) if alive[i] else set()
            stores[i] = ({b: block_data[b] for b in res_sets[i]}
                         if alive[i] else {})
        # blocks whose placement holders all died: seed them onto the
        # least-loaded live devices up to the invariant count
        for b in range(P):
            holders = [i for i in live if b in res_sets[i]]
            want = min(orig_count[b], n_live)
            while len(holders) < want:
                tgt = min((i for i in live if b not in res_sets[i]),
                          key=lambda i: (len(res_sets[i]), i))
                res_sets[tgt].add(b)
                stores[tgt][b] = block_data[b]
                holders.append(tgt)
                stats.n_rereplicated += 1
        assert residency_invariant_ok(plc, res_sets, alive)
        # every pending pair owned by a dead device gets a live owner
        todo = {f: [p for p in all_pairs
                    if p not in partials and owner_map[p] == f]
                for f in dead}
        rplan = reassign(schedule, dead, placement=_ResidencyView(
            P, res_sets), weights=weights, pairs=todo)
        apply_reassign(rplan)

    def on_kills(victims: List[int], dead: List[int]) -> None:
        """One recovery for all devices that died at this boundary — a
        correlated (rack-loss-style) failure is a single batch, which is
        exactly what can defeat k-replication and force the checkpoint
        path."""
        todo: Dict[int, List[Tuple[int, int]]] = {}
        for victim in victims:
            # a dead device's lost work is just another dirty set: every
            # pair it can have owned or computed has >= 1 endpoint among
            # the blocks it held at death, so the delta scheduler's
            # dirty-tile enumeration (core/delta.py, DESIGN.md section
            # 16.1) is the recovery scan — not the full O(P^2) pair list
            universe = dirty_tiles(plc, lost_res[victim], P=P)
            pending = [p for p in universe
                       if p not in partials and owner_map.get(p) == victim]
            lost_done = sorted(p for p in universe
                               if computed_by.get(p) == victim
                               and p not in durable)
            for p in lost_done:
                del partials[p]
                del computed_by[p]
            stats.n_recomputed += len(lost_done)
            todo[victim] = pending + lost_done
        try:
            with phase("reassign"):
                rplan = reassign(schedule, dead, placement=_ResidencyView(
                    P, res_sets), weights=weights, pairs=todo)
                apply_reassign(rplan)
            with phase("rereplicate"):
                rereplicate(dead)
        except RuntimeError:
            with phase("restore"):
                restore_from_checkpoint(dead)

    for rnd in range(len(rounds)):
        rnd_t0 = time.perf_counter()
        drops_pending = 0
        victims: List[int] = []
        for ev in (plan.events_at(rnd) if plan is not None else []):
            if ev.kind == "slow":
                if alive[ev.device]:
                    stats.n_slow += 1
                    slow[ev.device] *= float(ev.factor)
            elif ev.kind == "drop":
                drops_pending += 1
                stats.n_drops += 1
            elif ev.kind == "kill" and alive[ev.device]:
                alive[ev.device] = False
                lost_res[ev.device] = sorted(res_sets[ev.device])
                stores[ev.device] = {}
                res_sets[ev.device] = set()
                stats.n_kills += 1
                victims.append(ev.device)
        if victims:
            if not any(alive):
                raise RuntimeError("all devices dead: unrecoverable")
            on_kills(victims, [i for i in range(P) if not alive[i]])
        # compute everything due by this round (incl. recovery recompute)
        for p in all_pairs:
            if p in partials or pair_round(p) > rnd:
                continue
            o = owner_map[p]
            assert alive[o], (p, o)
            bx = get_block(o, p[0])
            by = get_block(o, p[1])
            t0 = time.perf_counter()
            partials[p] = workload.pair_partial(p[0], p[1], bx, by)
            dt = time.perf_counter() - t0
            computed_by[p] = o
            stats.pairs_by_device[o] = stats.pairs_by_device.get(o, 0) + 1
            # virtual cost: work scales with the pair's item count, and a
            # slowed device takes factor x longer — deterministic, so the
            # obs.feedback weights it produces are reproducible
            cost = float(bx.shape[0] * by.shape[0]) * slow[o]
            stats.busy_by_device[o] = stats.busy_by_device.get(o, 0.0) + cost
            stats.busy_s_by_device[o] = (
                stats.busy_s_by_device.get(o, 0.0) + dt * slow[o])
        stats.rounds += 1
        if ckpt_dir is not None and (rnd + 1) % every == 0:
            with phase("checkpoint"):
                tree: Dict[str, Any] = {
                    "round": np.int64(rnd + 1),
                    "blocks": {str(b): workload.blocks[b]
                               for b in range(P)},
                }
                if partials:
                    tree["partials"] = {
                        f"{p[0]}_{p[1]}": workload.encode_partial(v)
                        for p, v in partials.items()}
                save_checkpoint(ckpt_dir, rnd + 1, tree)
            durable = set(partials)
            stats.n_checkpoints += 1
            if tr:
                tr.count("ckpt.saves")
        if tr:
            tr.record("faults.round", time.perf_counter() - rnd_t0,
                      round=rnd, mode=mode, placement=plc.name, P=P,
                      kills=len(victims))

    assert len(partials) == len(all_pairs)
    return workload.fold(partials), stats


# ---------------------------------------------------------------------------
# Chaos selfcheck
# ---------------------------------------------------------------------------

def _chaos_placements(P: int) -> List[Placement]:
    return [get_placement(name, P)
            for name, cls in sorted(registered_placements().items())
            if cls.supports(P)]


def chaos_selfcheck(Ps: Sequence[int] = CHAOS_P,
                    modes: Sequence[str] = ENGINE_MODES,
                    placements: Optional[Sequence[str]] = None,
                    kill_every: Optional[int] = None,
                    seed: Optional[int] = None,
                    verbose: bool = True) -> int:
    """The headline chaos check (DESIGN.md section 13): for every
    registered placement x engine mode x P in ``Ps`` and all three
    workloads, kill a random live device every ``kill_every`` rounds
    (default: ``REPRO_FAULT_KILL_EVERY``, else 2; seed from
    ``REPRO_FAULT_SEED``, else 0) with message drops and slowdowns mixed
    in, and assert: the faulted output is bit-identical to the
    fault-free run, the fault-free run matches the brute-force oracle,
    and at least the planned kills actually fired.  Returns the number
    of faulted cases checked."""
    if kill_every is None:
        val = env_mod.read_knob("REPRO_FAULT_KILL_EVERY")
        kill_every = 2 if val is None else int(val)
    if seed is None:
        val = env_mod.read_knob("REPRO_FAULT_SEED")
        seed = 0 if val is None else int(val)
    n_cases = 0
    for P in Ps:
        for plc in _chaos_placements(P):
            if placements is not None and plc.name not in placements:
                continue
            for wl_cls in WORKLOADS:
                wl = wl_cls(P, seed=seed)
                baseline, base_stats = run_fault_tolerant_sweep(
                    wl, plc, "batched", plan=None)
                assert base_stats.n_kills == 0
                wl.check_oracle(baseline)
                for mode in modes:
                    n_rounds = len(sweep_rounds(plc.schedule(), mode))
                    fplan = FaultPlan.random_kills(
                        P, n_rounds, every=kill_every,
                        seed=seed + 7 * P + len(mode))
                    with tempfile.TemporaryDirectory() as d:
                        out, stats = run_fault_tolerant_sweep(
                            wl, plc, mode, fplan,
                            ckpt_dir=str(Path(d) / "ckpt"))
                    assert stats.n_kills == fplan.n_kills, (
                        plc.name, P, mode, wl.name)
                    assert wl.equal(out, baseline), (
                        plc.name, P, mode, wl.name)
                    n_cases += 1
                    if verbose:
                        print(f"  chaos {wl.name:6s} {plc.name:10s} "
                              f"P={P:<3d} {mode:7s}: kills="
                              f"{stats.n_kills} reassigned="
                              f"{stats.n_reassigned} rerepl="
                              f"{stats.n_rereplicated} restores="
                              f"{stats.n_restores} bit-exact OK")
    if verbose:
        print(f"chaos selfcheck OK ({n_cases} faulted cases, "
              f"P in {tuple(Ps)})")
    return n_cases


def _main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: ``python -m repro.core.faults [--P 5 8] [--modes scan]
    [--placements cyclic] [--kill-every 2] [--seed 0] [--quiet]``
    (DESIGN.md section 13)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="chaos selfcheck: fault-injected sweeps must be "
                    "bit-exact vs fault-free runs")
    ap.add_argument("--P", type=int, nargs="*", default=list(CHAOS_P))
    ap.add_argument("--modes", nargs="*", default=list(ENGINE_MODES),
                    choices=list(ENGINE_MODES))
    ap.add_argument("--placements", nargs="*", default=None)
    ap.add_argument("--kill-every", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    chaos_selfcheck(Ps=args.P, modes=args.modes,
                    placements=args.placements,
                    kill_every=args.kill_every, seed=args.seed,
                    verbose=not args.quiet)
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())

"""The single registry of ``REPRO_*`` environment knobs (DESIGN.md
section 12.4).

Every runtime override the repo honors is declared here — name, type,
valid values, and the one-line description the README env-var table
mirrors.  The readers that used to be scattered across the engines
(``core.sweep.env_mode_override`` / ``auto_batch_bytes``,
``core.placement.placement_from_env``, ``core.sparse.default_capacity``)
all route through :func:`read_knob`, so validation, error wording, and
typo detection live in exactly one place.

Contract shared by every knob:

  * read at **selection time** (each heuristic consult / placement
    resolution), never at import — setting a variable after ``import
    repro`` works; already-compiled programs keep their baked-in choice;
  * an unset or empty variable means "no override" (``read_knob``
    returns None and the caller's default applies);
  * an invalid value **raises** ``ValueError`` — never a silent
    fallthrough to the default;
  * an environment variable starting with ``REPRO_`` that matches no
    registered knob triggers a one-time ``RuntimeWarning`` naming the
    closest registered knob (typo detection — ``REPRO_ALLPAIRS_MODES=``
    silently doing nothing is the failure mode this kills).
"""

from __future__ import annotations

import dataclasses
import difflib
import os
import warnings
from typing import Callable, Optional, Tuple, Union

__all__ = [
    "EnvKnob",
    "ENV_KNOBS",
    "QUANT_MODES",
    "read_knob",
    "check_unknown_knobs",
    "describe_knobs",
]


@dataclasses.dataclass(frozen=True)
class EnvKnob:
    """One registered ``REPRO_*`` environment variable (DESIGN.md
    section 12.4).

    ``kind`` is ``"choice"`` (valid values from the ``choices`` thunk,
    lowercased before matching), ``"int"`` (integer with an inclusive
    ``minimum``), or ``"str"`` (any non-empty value passes through
    verbatim — e.g. a trace-file path).  ``description`` is the
    README-table one-liner.
    """

    name: str
    kind: str                                   # "choice" | "int" | "str"
    description: str
    choices: Optional[Callable[[], Tuple[str, ...]]] = None
    minimum: Optional[int] = None

    def parse(self, raw: str) -> Union[str, int]:
        """Validate and convert ``raw`` (non-empty, stripped); raises
        ``ValueError`` with the knob's canonical message on bad values
        (DESIGN.md section 12.4)."""
        if self.kind == "str":
            return raw
        if self.kind == "choice":
            val = raw.lower()
            valid = self.choices()
            if val not in valid:
                raise ValueError(
                    f"{self.name} must be one of {valid}, got {val!r}")
            return val
        try:
            val = int(raw)
        except ValueError:
            raise ValueError(
                f"{self.name} must be an integer, got {raw!r}") from None
        if self.minimum is not None and val < self.minimum:
            raise ValueError(
                f"{self.name} must be >= {self.minimum}, got {val}")
        return val


def _mode_choices() -> Tuple[str, ...]:
    from .sweep import ENGINE_MODES
    return ENGINE_MODES


def _placement_choices() -> Tuple[str, ...]:
    from .placement import registered_placements
    return ("auto", "plane") + tuple(sorted(registered_placements()))


#: valid values of ``REPRO_QUANT`` (core/quant.py; DESIGN.md section 17)
QUANT_MODES: Tuple[str, ...] = ("off", "int8", "bf16")


ENV_KNOBS = {
    "REPRO_ALLPAIRS_MODE": EnvKnob(
        name="REPRO_ALLPAIRS_MODE", kind="choice", choices=_mode_choices,
        description="force the execution mode everywhere mode='auto' is "
                    "consulted (batch engine, PCIT tiles, serving scoring, "
                    "sparse join, k-NN)"),
    "REPRO_PLACEMENT": EnvKnob(
        name="REPRO_PLACEMENT", kind="choice", choices=_placement_choices,
        description="select the block placement everywhere one is chosen "
                    "implicitly"),
    "REPRO_BATCH_BYTES_LIMIT": EnvKnob(
        name="REPRO_BATCH_BYTES_LIMIT", kind="int", minimum=1,
        description="auto-mode working-set byte budget shared by every "
                    "engine heuristic (default 2^28)"),
    "REPRO_SPARSE_CAPACITY": EnvKnob(
        name="REPRO_SPARSE_CAPACITY", kind="int", minimum=1,
        description="starting per-device buffer capacity of the sparse "
                    "join / range query before overflow escalation"),
    "REPRO_CKPT_EVERY": EnvKnob(
        name="REPRO_CKPT_EVERY", kind="int", minimum=1,
        description="rounds between mid-sweep partial checkpoints in the "
                    "fault-tolerant driver (default 1: every round is "
                    "durable)"),
    "REPRO_FAULT_KILL_EVERY": EnvKnob(
        name="REPRO_FAULT_KILL_EVERY", kind="int", minimum=1,
        description="chaos selfcheck: kill a random live device every N "
                    "sweep rounds (default 2)"),
    "REPRO_FAULT_SEED": EnvKnob(
        name="REPRO_FAULT_SEED", kind="int", minimum=0,
        description="chaos selfcheck: seed of the deterministic fault "
                    "plan RNG (default 0)"),
    "REPRO_DELTA_UPDATES": EnvKnob(
        name="REPRO_DELTA_UPDATES", kind="int", minimum=1,
        description="churn selfcheck: random replace/append updates "
                    "applied per case (default 3)"),
    "REPRO_DELTA_SEED": EnvKnob(
        name="REPRO_DELTA_SEED", kind="int", minimum=0,
        description="churn selfcheck: seed of the deterministic update "
                    "RNG (default 0)"),
    "REPRO_DELTA_MAX_DIRTY_PCT": EnvKnob(
        name="REPRO_DELTA_MAX_DIRTY_PCT", kind="int", minimum=0,
        description="delta index: dirty-block percentage above which an "
                    "update falls back to a full rebuild instead of a "
                    "dirty-tile sweep (default 50)"),
    "REPRO_SERVE_MAX_BATCH": EnvKnob(
        name="REPRO_SERVE_MAX_BATCH", kind="int", minimum=1,
        description="continuous batcher: max requests packed per "
                    "scheduler iteration (default 32)"),
    "REPRO_SERVE_QUEUE_DEPTH": EnvKnob(
        name="REPRO_SERVE_QUEUE_DEPTH", kind="int", minimum=1,
        description="continuous batcher: admission-control bound on "
                    "waiting requests before submits are rejected "
                    "(default 1024)"),
    "REPRO_QUANT": EnvKnob(
        name="REPRO_QUANT", kind="choice", choices=lambda: QUANT_MODES,
        description="quantized scoring path with error-bounded exact "
                    "rescoring: off (default, pure f32), int8 (per-block "
                    "symmetric int8), bf16"),
    "REPRO_TRACE": EnvKnob(
        name="REPRO_TRACE", kind="str",
        description="structured tracing: 0/unset off, 1 on (Chrome-trace "
                    "JSON to repro_trace.json at exit), any other value "
                    "is the output path"),
    "REPRO_METRICS": EnvKnob(
        name="REPRO_METRICS", kind="int", minimum=0,
        description="counters-only tracing (no span events, no trace "
                    "file): 1 on, 0/unset off"),
}

_warned_unknown: set = set()
_seen_env_keys: frozenset = frozenset()


def check_unknown_knobs() -> None:
    """Warn (once per variable per process) about ``REPRO_*`` variables
    in the environment that match no registered knob, suggesting the
    closest registered name — the typo detector (DESIGN.md section
    12.4).  Warn-once is keyed on the variable *name* (not warning
    machinery state, so it survives ``warnings.simplefilter('always')``),
    and an unchanged ``REPRO_*`` keyset skips the environment scan
    entirely — every knob read pays one frozenset compare."""
    global _seen_env_keys
    keys = frozenset(k for k in os.environ if k.startswith("REPRO_"))
    if keys == _seen_env_keys:
        return
    _seen_env_keys = keys
    for key in sorted(keys):
        if key in ENV_KNOBS or key in _warned_unknown:
            continue
        _warned_unknown.add(key)
        hint = difflib.get_close_matches(key, ENV_KNOBS, n=1)
        suggest = f"; did you mean {hint[0]}?" if hint else ""
        warnings.warn(
            f"environment variable {key} matches no registered REPRO_* "
            f"knob and is ignored{suggest} (known: "
            f"{tuple(sorted(ENV_KNOBS))})", RuntimeWarning, stacklevel=3)


def read_knob(name: str) -> Union[str, int, None]:
    """Read and validate one registered knob (DESIGN.md section 12.4).

    Returns None when the variable is unset or empty (caller default
    applies); raises ``ValueError`` on invalid values; also runs the
    unknown-variable typo check as a side effect.
    """
    knob = ENV_KNOBS[name]
    check_unknown_knobs()
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    return knob.parse(raw)


def describe_knobs() -> str:
    """The registry rendered one knob per line (debug / docs aid;
    DESIGN.md section 12.4)."""
    return "\n".join(f"{k.name}: {k.description}"
                     for k in ENV_KNOBS.values())

"""All-pairs k-NN graph construction over quorum placements.

The workload none of the previous engines could express: for *every*
corpus row, the top-k nearest other rows — a per-row top-k selection
over the full O(N^2) pair sweep (the k-NN graph behind graph-based ANN
indexes, dedup clustering, and spectral methods).  It is ~200 lines on
the unified pair-sweep runtime (core/sweep.py) precisely because the
runtime already owns the schedule, the gather shifts, the execution
modes, and the kernel-hook dispatch; this module only supplies the
emitter and the reduction monoid (DESIGN.md section 12.3):

  * **emitter** — :class:`KnnEmitter`: each scheduled tile's [block,
    block] scores feed *both* endpoints' neighbor lists (rows of the
    ``lo`` block receive the ``hi`` block's rows as candidates and vice
    versa; self tiles exclude the diagonal and contribute one side),
    masked by the ownership rules (the engine dedup mask, row validity)
    and folded into per-slot running [k, block, topk] lists under the
    (-score, index) total order.
  * **monoid** — the scatter reduction is a top-k *merge*, not a sum:
    ``quorum_scatter`` routes each slot's partial lists back to the
    block owner with the inverse shifts and folds arrivals with the
    selection merge — the first non-additive monoid through the shared
    scatter, which is exactly what the Emitter/Combiner split buys.

Exactly-once coverage: the per-difference ownership partition schedules
every unordered block pair once (the even-P d = P/2 orbit deduplicated
by the mask), so every candidate row v != u reaches u's list exactly
once globally; selection by a strict total order makes the merges
associative, so all three execution modes, the fused kernel
(kernels/pairwise_topk.py), and the scatter order produce identical
indices.  Scores use the orientation-consistent L2 subtraction order of
ref.pairwise_topk so both sides of a tile match the host oracle's
matrix bitwise.

Verification mirrors the sparse engine: ``python -m repro.core.knn``
asserts exact index equality with the dense brute-force oracle for
every mode (incl. the fused kernel), both metrics, ragged corpora, and
underfull neighbor lists; tests/test_knn.py sweeps it over every
registered placement at P in {4, 5, 7, 8, 12, 13}.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.ref import IDX_SENTINEL, NEG_INF, QUERY_METRICS as KNN_METRICS
from . import sweep as sweep_mod
from .scheduler import PairSchedule
from .sparse import _pair_meta, _pair_score_matrix
from .sweep import (ENGINE_MODES, SweepEmitter, mark_varying,
                    pair_mask_table, quorum_scatter)

__all__ = [
    "KnnEmitter",
    "KnnResult",
    "quorum_allpairs_knn",
    "knn_graph",
    "brute_force_knn",
    "KNN_METRICS",
]


def _merge_lists(cv, ci, sv, si, topk: int):
    """Fold candidate (scores, ids) into running [..., topk] lists by the
    (-score, index) total order — the k-NN selection monoid (associative
    and commutative under a strict total order, so every mode and the
    scatter fold select identically).  Delegates to the runtime's shared
    two-key selection (core/sweep.py topk_by_score)."""
    return sweep_mod.topk_by_score(jnp.concatenate([cv, sv], axis=-1),
                                   jnp.concatenate([ci, si], axis=-1), topk)


def _item_candidates(bi, bj, metric: str, active, is_self, ga, gb,
                     nv_lo, nv_hi, block_rows: int):
    """Both orientations' masked candidate planes for one tile — the
    single home of the k-NN tile math (bit-parity with
    ref.pairwise_topk): (lo-side scores [block, block], lo-side ids,
    hi-side scores, hi-side ids); the hi side is all-sentinel for self
    tiles (one contribution per pair)."""
    dots = bi @ bj.T                                      # [block, block]
    if metric == "l2":
        bin2 = jnp.sum(bi * bi, axis=-1)
        bjn2 = jnp.sum(bj * bj, axis=-1)
        t_lo = (2.0 * dots - bjn2[None, :]) - bin2[:, None]
        t_hi = (2.0 * dots - bin2[:, None]) - bjn2[None, :]
    else:
        t_lo = t_hi = dots
    block = bi.shape[0]
    sent = jnp.int32(IDX_SENTINEL)
    r = lax.broadcasted_iota(jnp.int32, (block, block), 0)
    s = lax.broadcasted_iota(jnp.int32, (block, block), 1)
    keep = active & (s < nv_hi) & jnp.where(is_self, r != s, True)
    cv_l = jnp.where(keep, t_lo, NEG_INF)
    ci_l = jnp.where(keep, gb * block_rows + s, sent)
    keep_t = (active & jnp.logical_not(is_self) & (r < nv_lo)).T
    cv_h = jnp.where(keep_t, t_hi.T, NEG_INF)
    ci_h = jnp.where(keep_t, (ga * block_rows + r).T, sent)
    return cv_l, ci_l, cv_h, ci_h


def _select_mode(schedule: PairSchedule, block: int,
                 batch_fn: Optional[Callable]) -> str:
    """The k-NN engine's ``mode="auto"`` working set fed to the shared
    heuristic (core/sweep.py select_mode): two [n_pairs, block, block]
    candidate planes (f32 scores + i32 ids) per tile orientation."""
    return sweep_mod.select_mode(
        schedule, schedule.n_pairs * block * block * 16, batch_fn)


class KnnEmitter(SweepEmitter):
    """Per-row top-k selection over the scheduled pairs (DESIGN.md
    section 12.3 — the k-NN graph workload).

    Folds every tile's two candidate planes into per-slot running
    [k, block, topk] (value, index) lists; the adapter then scatter-
    *merges* the per-slot partials at the block owners (the non-additive
    monoid of DESIGN.md section 12.2).
    """

    def __init__(self, schedule: PairSchedule, mask, topk: int, metric: str,
                 block: int, axis_name: str, meta, batch_fn=None):
        self.schedule = schedule
        self.mask = mask
        self.topk = topk
        self.metric = metric
        self.block = block
        self.axis_name = axis_name
        self.lo, self.hi, self.ga, self.gb, self.nv_lo, self.nv_hi, \
            self.is_self = meta
        self.batch_fn = batch_fn

    @staticmethod
    def delta_retract(standing, stale, ctx=None):
        """Report the rows whose standing neighbor list cites a
        retracted source (DESIGN.md section 16.4).  Top-k selection is
        not invertible — a removed neighbor can expose a candidate the
        list already discarded — so retraction returns the *refresh
        set*: ``standing`` is the ``(scores, indices)`` pair, ``stale``
        the dirty global-id ``(starts, stops)`` ranges, and the result
        a boolean row mask the delta driver rebuilds from its per-tile
        candidate ledger."""
        _, best_i = standing
        starts, stops = (np.asarray(stale[0], np.int64),
                         np.asarray(stale[1], np.int64))
        hit = ((best_i[:, :, None] >= starts[None, None, :])
               & (best_i[:, :, None] < stops[None, None, :]))
        return hit.any(axis=(1, 2))

    @staticmethod
    def delta_fold(standing, fresh, ctx=None):
        """Merge fresh per-row candidates into standing neighbor lists
        under the strict (-score, index) total order (DESIGN.md
        section 16.4) — an associative, commutative monoid, so the
        merged top-k is bit-equal to a from-scratch fold whenever the
        standing list already equals the top-k of its unretracted
        sources.  Both arguments are ``(scores [n, k], indices [n, k])``
        with the (-inf, int64 max) sentinel padding every candidate
        plane in this repo uses."""
        s = np.concatenate([standing[0], fresh[0]], axis=1)
        i = np.concatenate([standing[1], fresh[1]], axis=1)
        order = np.lexsort((i, -s.astype(np.float64)), axis=1)
        topk = standing[0].shape[1]
        return (np.take_along_axis(s, order, axis=1)[:, :topk],
                np.take_along_axis(i, order, axis=1)[:, :topk])

    def batch(self, quorum):
        """Every tile in one batched accumulation.  The batched jnp step
        IS the ref oracle (kernels/ref.py pairwise_topk), with the fused
        Pallas kernel swapping in through the same hook."""
        batch_fn = self.batch_fn
        if batch_fn is None:
            from ..kernels import ref as kref
            batch_fn = functools.partial(
                kref.pairwise_topk, topk=self.topk, block_rows=self.block,
                metric=self.metric)
        meta = jnp.stack([(self.mask > 0).astype(jnp.int32),
                          self.is_self.astype(jnp.int32),
                          self.ga, self.gb, self.nv_lo, self.nv_hi],
                         axis=1)                           # [n_pairs, 6]
        return batch_fn(quorum, self.lo, self.hi, meta)

    def scan_init(self):
        """Sentinel-filled per-slot running lists (varying-marked)."""
        k = self.schedule.k
        shape = (k, self.block, self.topk)
        return (mark_varying(jnp.full(shape, NEG_INF, jnp.float32),
                             self.axis_name),
                mark_varying(jnp.full(shape, jnp.int32(IDX_SENTINEL)),
                             self.axis_name))

    def scan_items(self):
        """Per-pair (slots, mask, self flag, block ids, valid counts)."""
        return (self.lo, self.hi, self.mask, self.is_self, self.ga,
                self.gb, self.nv_lo, self.nv_hi)

    def scan_emit(self, carry, quorum, item):
        """Merge one tile's two candidate planes into the running
        lists (serial per-pair; the low-memory oracle)."""
        vals, idx = carry
        lo_p, hi_p, m_p, self_p, ga_p, gb_p, nvl_p, nvh_p = item
        bi = jnp.take(quorum, lo_p, axis=0)
        bj = jnp.take(quorum, hi_p, axis=0)
        cv_l, ci_l, cv_h, ci_h = _item_candidates(
            bi, bj, self.metric, m_p > 0, self_p, ga_p, gb_p, nvl_p, nvh_p,
            self.block)
        mv, mi = _merge_lists(jnp.take(vals, lo_p, axis=0),
                              jnp.take(idx, lo_p, axis=0), cv_l, ci_l,
                              self.topk)
        vals = vals.at[lo_p].set(mv)
        idx = idx.at[lo_p].set(mi)
        mv2, mi2 = _merge_lists(jnp.take(vals, hi_p, axis=0),
                                jnp.take(idx, hi_p, axis=0), cv_h, ci_h,
                                self.topk)
        return (vals.at[hi_p].set(mv2), idx.at[hi_p].set(mi2))

    def overlap_begin(self):
        """Boxed per-slot running lists the unrolled sweep updates."""
        return {"carry": self.scan_init()}

    def overlap_emit(self, state, item_idx, bi, bj):
        """Merge one tile as soon as its later block lands (static slot
        indices, so early slots' scatter shifts can pipeline)."""
        lo_s = int(self.schedule.pair_slots[item_idx, 0])
        hi_s = int(self.schedule.pair_slots[item_idx, 1])
        vals, idx = state["carry"]
        cv_l, ci_l, cv_h, ci_h = _item_candidates(
            bi, bj, self.metric, self.mask[item_idx] > 0,
            self.is_self[item_idx], self.ga[item_idx], self.gb[item_idx],
            self.nv_lo[item_idx], self.nv_hi[item_idx], self.block)
        mv, mi = _merge_lists(vals[lo_s], idx[lo_s], cv_l, ci_l, self.topk)
        vals = vals.at[lo_s].set(mv)
        idx = idx.at[lo_s].set(mi)
        if lo_s != hi_s:  # self tile: one contribution, hi plane is sentinel
            mv2, mi2 = _merge_lists(vals[hi_s], idx[hi_s], cv_h, ci_h,
                                    self.topk)
            vals = vals.at[hi_s].set(mv2)
            idx = idx.at[hi_s].set(mi2)
        state["carry"] = (vals, idx)

    def overlap_finalize(self, state):
        """The per-slot running lists, ready for the scatter merge."""
        return state["carry"]


def quorum_allpairs_knn(
    x: jax.Array,
    *,
    topk: int,
    axis_name: str,
    schedule: PairSchedule | None = None,
    axis_size: int | None = None,
    placement=None,
    metric: str = "dot",
    mode: str = "auto",
    mask: jax.Array | None = None,
    n_valid: int | None = None,
    batch_fn: Callable[..., Tuple[jax.Array, jax.Array]] | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Distributed all-pairs k-NN graph construction (DESIGN.md section
    12.3).

    Must run inside shard_map with ``x`` the local [block, d] shard.
    Returns ``(scores [block, topk], indices [block, topk])`` — each
    *valid* local row's top-k nearest other valid rows (self excluded)
    by the (-score, index) total order, with (NEG_INF, IDX_SENTINEL)
    sentinels when fewer than ``topk`` candidates exist; rows beyond
    ``n_valid`` carry unspecified lists (the host wrapper slices them).

    ``placement`` / ``schedule`` / ``axis_size`` select the residency
    layer exactly as in the other engines (``REPRO_PLACEMENT`` consulted
    when both are None); a full-replication placement runs the same
    generic pipeline over its A = {0..P-1} shifts.  ``mode`` is the
    runtime's batched/overlap/scan surface (``REPRO_ALLPAIRS_MODE``
    honored); ``batch_fn(quorum, lo, hi, meta) -> (vals, idx)`` is the
    fused-kernel hook (kernels.ops.pairwise_topk), batched mode only.
    """
    if metric not in KNN_METRICS:
        raise ValueError(f"metric must be one of {KNN_METRICS}, "
                         f"got {metric!r}")
    if topk < 1:
        raise ValueError(f"topk must be >= 1, got {topk}")
    sweep_mod.validate_mode(mode, batch_fn)
    schedule, placement = sweep_mod.resolve_sweep_placement(
        schedule, axis_size, placement)
    if schedule is None:
        schedule = placement.schedule()

    block = x.shape[0]
    if mask is None:
        table = jnp.asarray(pair_mask_table(schedule))   # [P, n_pairs]
        mask = jnp.take(table, lax.axis_index(axis_name), axis=0)
    mask = mask.reshape(-1)

    if mode == "auto":
        mode = _select_mode(schedule, block, batch_fn)

    lo, hi, ga, gb, nv_lo, nv_hi, is_self, _gblocks, _nv = _pair_meta(
        schedule, axis_name, block, n_valid)
    emitter = KnnEmitter(schedule, mask, topk, metric, block, axis_name,
                         (lo, hi, ga, gb, nv_lo, nv_hi, is_self),
                         batch_fn=batch_fn)
    vals, idx = sweep_mod.pair_sweep(emitter, schedule=schedule,
                                     axis_name=axis_name, mode=mode, x=x)
    partials = [(vals[s], idx[s]) for s in range(schedule.k)]
    mv, mi = quorum_scatter(
        partials, schedule, axis_name,
        reduce_fn=lambda a, b: _merge_lists(a[0], a[1], b[0], b[1], topk))
    return mv, mi


# ---------------------------------------------------------------------------
# Host-level driver + oracle (DESIGN.md section 12.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KnnResult:
    """Host-side k-NN graph (:func:`knn_graph`).

    ``indices[r]`` lists row r's ``topk`` nearest other rows (ascending
    by the (-score, index) order, i.e. best first); ``scores`` the
    matching similarity scores.  When the corpus has fewer than
    ``topk`` other rows, the tail is (IDX_SENTINEL, NEG_INF) padding.
    """

    indices: np.ndarray
    scores: np.ndarray
    topk: int

    @property
    def n_rows(self) -> int:
        """Number of corpus rows in the graph."""
        return int(self.indices.shape[0])


@functools.lru_cache(maxsize=64)
def _knn_fn(mesh, axis_name: str, N: int, block: int, topk: int,
            metric: str, mode: str, use_kernel: bool, placement):
    """Build (and cache) the jitted distributed k-NN program — one trace
    per (mesh, shape, topk, ...) key, reused across repeated graphs."""
    from jax.sharding import PartitionSpec as PS
    sched = placement.schedule()
    mask_table = jnp.asarray(pair_mask_table(sched))
    batch_fn = None
    if use_kernel:
        if mode not in ("batched", "auto"):
            raise ValueError(
                f"use_kernel needs the batched mode (got mode={mode!r}); "
                "the fused kernel only replaces the batched inner step")
        from ..kernels import ops as kops
        batch_fn = functools.partial(kops.pairwise_topk, topk=topk,
                                     block_rows=block, metric=metric)

    def body(xb, mb):
        return quorum_allpairs_knn(
            xb, topk=topk, axis_name=axis_name, schedule=sched, mask=mb,
            metric=metric, mode=mode, n_valid=N, batch_fn=batch_fn)

    spec = PS(axis_name)
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)))
    return lambda xs: fn(xs, mask_table)


def knn_graph(corpus, mesh, *, topk: int, axis_name: str = "q",
              metric: str = "dot", mode: str = "auto", placement=None,
              use_kernel: bool = False,
              quant: str | None = None) -> KnnResult:
    """The k-NN graph of ``corpus`` rows, exactly (DESIGN.md section
    12.3).

    The host entry point: pads the [N, d] corpus into P quorum blocks,
    runs :func:`quorum_allpairs_knn` under the selected placement (None
    defers to ``REPRO_PLACEMENT``), and slices the padding rows back
    off.  ``use_kernel`` routes the batched inner step through the fused
    Pallas kernel (kernels/pairwise_topk.py).  ``quant`` selects the
    quantized candidate-generation + certified-rescoring path (DESIGN.md
    section 17): ``"int8"`` / ``"bf16"`` route through
    :func:`core.quant.quant_knn_graph` (bit-identical results),
    ``"off"`` forces pure f32, None defers to ``REPRO_QUANT``.  Returns
    a :class:`KnnResult` with each row's exact top-k neighbors.
    """
    if quant is None:
        from .quant import quant_from_env
        quant = quant_from_env()
    if quant != "off":
        from . import quant as quant_mod
        return quant_mod.quant_knn_graph(
            corpus, mesh, topk=topk, quant=quant, axis_name=axis_name,
            metric=metric, mode=mode, placement=placement,
            use_kernel=use_kernel)
    corpus = np.asarray(corpus, np.float32)
    N, d = corpus.shape
    P = mesh.shape[axis_name]
    from .placement import placement_from_env, resolve_placement
    plc = (placement_from_env(P) if placement is None
           else resolve_placement(placement, P))
    block = -(-N // P)
    x = np.zeros((P * block, d), np.float32)
    x[:N] = corpus
    run = _knn_fn(mesh, axis_name, N, block, int(topk), metric, mode,
                  use_kernel, plc)
    vals, idx = (np.asarray(a) for a in run(jnp.asarray(x)))
    return KnnResult(indices=idx[:N], scores=vals[:N], topk=int(topk))


def brute_force_knn(corpus: np.ndarray, topk: int,
                    metric: str = "dot") -> KnnResult:
    """Dense O(N^2) oracle: each row's top-k other rows by the engine's
    (-score, index) total order, same float32 score formulas (DESIGN.md
    section 12.3), sentinel-padded when topk > N - 1."""
    s = _pair_score_matrix(corpus, metric)
    N = s.shape[0]
    eff = min(topk, N - 1)
    idx = np.full((N, topk), np.int32(IDX_SENTINEL), np.int32)
    vals = np.full((N, topk), np.float32(NEG_INF), np.float32)
    for r in range(N):
        cand = np.concatenate([np.arange(r), np.arange(r + 1, N)])
        order = np.lexsort((cand, -s[r, cand]))[:eff]
        idx[r, :eff] = cand[order]
        vals[r, :eff] = s[r, cand[order]]
    return KnnResult(indices=idx, scores=vals, topk=int(topk))


# ---------------------------------------------------------------------------
# Selfcheck (subprocess entry point — tests/test_knn.py sweeps this)
# ---------------------------------------------------------------------------

def selfcheck_main(nblocks: int | None = None,
                   modes: Sequence[str] = ENGINE_MODES + ("kernel",),
                   placement: str | None = None) -> None:
    """Distributed k-NN graph selfcheck, mirroring core.sparse's
    (DESIGN.md section 12.3).

    Run as ``XLA_FLAGS=--xla_force_host_platform_device_count=<P> python
    -m repro.core.knn [P] [modes] [placement]``.  Asserts exact
    neighbor-index equality with the dense brute-force oracle for every
    requested mode (incl. the fused ``kernel`` batched path), both
    metrics, a ragged corpus tail, and an underfull (topk > N - 1)
    neighbor list with sentinel padding.
    """
    from .placement import placement_from_env, resolve_placement

    devs = jax.devices()
    Pn = nblocks or len(devs)
    assert len(devs) >= Pn, f"need {Pn} devices, have {len(devs)}"
    plc = (placement_from_env(Pn) if placement is None
           else resolve_placement(placement, Pn))
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    block, d, topk = 8, 16, 4
    rng = np.random.default_rng(0)
    N = Pn * block - 3          # ragged tail: exercises row validity
    corpus = rng.normal(size=(N, d)).astype(np.float32)

    for metric in KNN_METRICS:
        want = brute_force_knn(corpus, topk, metric)
        label = f"P={Pn} metric={metric}"
        for m in modes:
            mode, uk = ("batched", True) if m == "kernel" else (m, False)
            got = knn_graph(corpus, mesh, topk=topk, metric=metric,
                            mode=mode, placement=plc, use_kernel=uk)
            np.testing.assert_array_equal(
                got.indices, want.indices, err_msg=f"{label} mode={m}")
            np.testing.assert_allclose(
                got.scores, want.scores, rtol=1e-5, atol=1e-5,
                err_msg=f"{label} mode={m}")

    # underfull lists: topk exceeds the candidate count; the tail must
    # be exact (IDX_SENTINEL, NEG_INF) padding in every mode
    tiny = rng.normal(size=(Pn + 2, d)).astype(np.float32)
    want = brute_force_knn(tiny, Pn + 4, "dot")
    for m in modes:
        mode, uk = ("batched", True) if m == "kernel" else (m, False)
        got = knn_graph(tiny, mesh, topk=Pn + 4, mode=mode, placement=plc,
                        use_kernel=uk)
        np.testing.assert_array_equal(got.indices, want.indices,
                                      err_msg=f"underfull mode={m}")

    print(f"knn selfcheck OK: P={Pn} placement={plc.describe()} "
          f"modes={','.join(modes)} N={N} topk={topk} "
          f"metrics={','.join(KNN_METRICS)}")


if __name__ == "__main__":
    import sys
    selfcheck_main(
        int(sys.argv[1]) if len(sys.argv) > 1 else None,
        tuple(sys.argv[2].split(",")) if len(sys.argv) > 2
        else ENGINE_MODES + ("kernel",),
        sys.argv[3] if len(sys.argv) > 3 else None)

"""The unified pair-sweep runtime: one engine core under every workload.

The paper's contribution is a *single* distribution scheme — cyclic
quorums with O(N/sqrt(P)) residency — and all four workloads the repo
ships (dense all-pairs reduction, thresholded similarity join, online
top-k / range-query serving, all-pairs k-NN graphs) run the *same*
schedule → gather → pair-compute → emit loop over it.  This module owns
that loop once (DESIGN.md section 12):

  * **data plane** — :func:`quorum_gather` pulls the k resident blocks
    with k-1 ``lax.ppermute`` cyclic shifts; :func:`quorum_scatter`
    routes per-slot partials back to block owners with the inverse
    shifts and folds them under a caller-chosen monoid (sum for dense
    reductions, a top-k merge for k-NN — partials may be arbitrary
    pytrees).
  * **execution modes** — ``batched`` (one vectorized step over every
    work item), ``overlap`` (each item computes as soon as its later
    block lands, so XLA's latency-hiding scheduler overlaps the
    remaining shifts), ``scan`` (serial ``lax.scan``, the low-memory
    oracle); :func:`select_mode` is the single ``mode="auto"``
    heuristic, :func:`validate_mode` the single argument contract.
  * **work items** — by default the schedule's per-difference slot
    pairs; an emitter may substitute a per-slot sweep (``lo == hi ==
    arange(k)``), which is how the serving engines ride the same driver
    over a *resident* stack instead of a gathered one.
  * **emitter protocol** — :class:`SweepEmitter` is the plug-in seam: a
    workload supplies the per-item compute and the carry it folds into
    (a monoid accumulator, a fixed-capacity compaction buffer, a
    per-row top-k list), and :func:`pair_sweep` runs it under any mode.
    Adding a workload is one emitter + one thin adapter (core/knn.py is
    the worked example), not a fork of the loop.

The shared top-k selection helpers (:func:`topk_by_score`,
:func:`merge_topk`) live here because two emitter families (serving
query, k-NN graph) select by the same (-score, index) total order.
``core.allpairs`` re-exports the long-standing public names so existing
imports keep working; outputs of the ported engines are bit-exact with
the pre-runtime implementations (the tier-1 suite is the oracle).
"""

from __future__ import annotations

import abc
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.ref import IDX_SENTINEL, NEG_INF
from ..obs import trace as obs_trace
from . import env as env_mod
from .scheduler import PairSchedule

__all__ = [
    "ENGINE_MODES",
    "SweepEmitter",
    "pair_sweep",
    "slot_items",
    "ready_order",
    "pair_ready_order",
    "sweep_rounds",
    "quorum_gather",
    "quorum_scatter",
    "pair_mask_table",
    "mark_varying",
    "auto_batch_bytes",
    "env_mode_override",
    "validate_mode",
    "select_mode",
    "resolve_sweep_placement",
    "topk_by_score",
    "merge_topk",
]

ENGINE_MODES = ("batched", "overlap", "scan")

# auto-mode switches away from `batched` when the workload's working set
# would exceed this budget (bytes; overridable for small-VMEM or huge-HBM
# parts via REPRO_BATCH_BYTES_LIMIT)
_DEFAULT_BATCH_BYTES = 1 << 28


def auto_batch_bytes() -> int:
    """The auto-mode byte budget (DESIGN.md section 4), read from
    ``REPRO_BATCH_BYTES_LIMIT`` at *selection* time (every ``mode="auto"``
    trace), not at import — setting the env var after ``import repro``
    works.  Shared by every engine heuristic through
    :func:`select_mode`."""
    val = env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT")
    return _DEFAULT_BATCH_BYTES if val is None else int(val)


def env_mode_override() -> str | None:
    """The validated ``REPRO_ALLPAIRS_MODE`` forced mode, or None if unset
    (DESIGN.md section 4).

    The benchmark / CI A/B hook, consulted by every ``mode="auto"``
    selection (engine, PCIT tile phases, serving scoring, sparse join,
    k-NN).  Read at trace time — set it before the first jitted call;
    already-compiled auto-mode programs keep their baked-in choice.
    Unknown values raise rather than silently falling through to the
    heuristic (core/env.py is the registry).
    """
    return env_mod.read_knob("REPRO_ALLPAIRS_MODE")


def validate_mode(mode: str, batch_fn) -> None:
    """The shared mode/kernel argument contract (DESIGN.md section 12.1):
    ``mode`` must be an engine mode or ``auto``, and a fused ``batch_fn``
    only replaces the batched inner step."""
    if mode not in ENGINE_MODES + ("auto",):
        raise ValueError(f"mode must be one of {ENGINE_MODES + ('auto',)}, "
                         f"got {mode!r}")
    if batch_fn is not None and mode not in ("batched", "auto"):
        raise ValueError(
            f"batch_fn only replaces the batched inner step (got "
            f"mode={mode!r}); drop it or use mode='batched'")


def select_mode(schedule: PairSchedule, working_set_bytes: int,
                batch_fn) -> str:
    """The single ``mode="auto"`` heuristic (DESIGN.md sections 4, 12.1).

    Environment override first (:func:`env_mode_override`; conflicts with
    a fused ``batch_fn`` — which only exists for the batched step — raise
    instead of silently dropping the kernel), then: a fused batch kernel
    always means ``batched``; otherwise ``batched`` while the workload's
    ``working_set_bytes`` fits the :func:`auto_batch_bytes` budget,
    ``overlap`` when there are enough shifts to hide (k >= 3), ``scan``
    as the low-memory last resort.  Each engine supplies its own
    working-set formula; the policy lives only here.
    """
    env = env_mode_override()
    if env is not None:
        if batch_fn is not None and env != "batched":
            raise ValueError(
                f"REPRO_ALLPAIRS_MODE={env} conflicts with a fused batch_fn "
                "(the kernel only replaces the batched inner step)")
        return env
    if batch_fn is not None:
        return "batched"
    if working_set_bytes <= auto_batch_bytes():
        return "batched"
    if schedule.k >= 3:
        return "overlap"
    return "scan"


def resolve_sweep_placement(schedule, axis_size, placement):
    """The shared placement-threading step of every engine entry point
    (DESIGN.md sections 10, 12.1).

    Validates P-consistency between ``schedule`` / ``axis_size`` /
    ``placement``; when both schedule and placement are None, consults
    ``REPRO_PLACEMENT`` at ``axis_size``.  Returns ``(schedule,
    placement)`` — schedule may still be None (callers that special-case
    e.g. full replication derive it afterwards via
    ``placement.schedule()``).
    """
    if placement is not None:
        if axis_size is not None and placement.P != axis_size:
            raise ValueError(
                f"placement is for P={placement.P} but axis_size={axis_size}")
        if schedule is not None and schedule.P != placement.P:
            raise ValueError(
                f"placement is for P={placement.P} but schedule.P="
                f"{schedule.P}")
    if placement is None and schedule is None:
        assert axis_size is not None, "need schedule, placement, or axis_size"
        from .placement import placement_from_env
        placement = placement_from_env(axis_size)
    return schedule, placement


# ---------------------------------------------------------------------------
# Data plane: cyclic-shift gather / scatter, masks (DESIGN.md section 2)
# ---------------------------------------------------------------------------

def _shift_perm(P: int, shift: int) -> list[tuple[int, int]]:
    """ppermute permutation delivering block (i + shift) % P to device i."""
    return [(j, (j - shift) % P) for j in range(P)]


def _tree_nbytes(tree) -> int:
    """Static payload bytes of a pytree (every leaf's size x itemsize —
    exact during a jit trace, where shapes are static)."""
    return sum(obs_trace.nbytes_of(leaf) for leaf in jax.tree.leaves(tree))


def quorum_gather(x, schedule: PairSchedule, axis_name: str,
                  *, overlap_fn: Callable[[int, Any], Any] | None = None):
    """Gather this device's quorum blocks (DESIGN.md section 2, phase 1).

    Args:
      x: the local block, shape [block, ...] (inside shard_map), or an
        arbitrary pytree of per-block arrays — every leaf rides the same
        cyclic shifts, which is how the quantized corpus threads its
        per-block scale/norm side arrays through the data plane
        (core/quant.py, DESIGN.md section 17).
      schedule: PairSchedule for the quorum axis size P.
      axis_name: mesh axis the blocks are sharded over.
      overlap_fn: optional ``f(slot, block)`` called as each block lands —
        lets callers overlap compute with the next in-flight permute (the
        double-buffered mode; XLA's latency-hiding scheduler interleaves the
        independent ppermutes and per-slot compute).

    Returns:
      stacked quorum blocks [k, block, ...] (pytree x: each leaf gains the
      leading slot axis); slot s holds global block (i + shifts[s]) % P.
      If overlap_fn is given, returns the list of its results instead.
    """
    P = schedule.P
    shifts = [int(s) for s in schedule.shifts]
    # comm accounting fires at jit-trace time: shapes are static, so the
    # counted bytes are exact, once per compiled program (DESIGN.md 14.2);
    # _tree_nbytes degenerates to nbytes_of for a plain array
    tr = obs_trace.get_tracer()
    if tr:
        nz = sum(1 for a in shifts if a % P != 0)
        tr.count("comm.ppermute.gather_hops", nz)
        tr.count("comm.ppermute.gather_bytes", nz * _tree_nbytes(x))
    span = tr.span("sweep.gather", P=P, k=len(shifts)) if tr \
        else obs_trace.NOOP.span("")
    with span:
        blocks = []
        results = []
        for slot, a in enumerate(shifts):
            blk = x if a == 0 else jax.tree.map(
                lambda leaf, a=a: lax.ppermute(leaf, axis_name,
                                               _shift_perm(P, a)), x)
            if overlap_fn is not None:
                results.append(overlap_fn(slot, blk))
            else:
                blocks.append(blk)
        if overlap_fn is not None:
            return results
        return jax.tree.map(lambda *leaves: jnp.stack(leaves, axis=0),
                            *blocks)


def quorum_scatter(partials, schedule: PairSchedule, axis_name: str,
                   *, reduce_fn: Callable[[Any, Any], Any] = jnp.add):
    """Route per-slot partial results back to block owners and reduce
    (DESIGN.md section 2, phase 3).

    partials: [k, block, ...] stacked, or a length-k sequence of per-slot
    partials; slot s is a partial result for global block
    (i + shifts[s]) % P.  Each per-slot partial may be an arbitrary
    pytree (every leaf is ppermuted with the inverse shift) — the k-NN
    emitter scatters (values, indices) pairs this way.  Arrivals fold
    with ``reduce_fn`` (default elementwise sum; pass a top-k merge or
    any other monoid for non-additive reductions, DESIGN.md section
    12.2).  The per-slot sequence form is what the overlap engine mode
    produces: each slot's inverse shift depends only on that slot's pair
    results, so the scheduler can start early slots' sends while later
    pairs are still computing (the pipelined scatter).
    Returns the reduced per-block result for the local block.
    """
    P = schedule.P
    shifts = [int(s) for s in schedule.shifts]
    tr = obs_trace.get_tracer()
    span = tr.span("sweep.scatter", P=P, k=len(shifts)) if tr \
        else obs_trace.NOOP.span("")
    with span:
        acc = None
        for slot, a in enumerate(shifts):
            part = partials[slot]
            if a == 0:
                arrived = part
            else:
                if tr:  # exact: per-slot pytree leaf bytes, counted at
                    # jit-trace time (DESIGN.md 14.2)
                    tr.count("comm.ppermute.scatter_hops")
                    tr.count("comm.ppermute.scatter_bytes",
                             _tree_nbytes(part))
                arrived = jax.tree.map(
                    lambda leaf: lax.ppermute(leaf, axis_name,
                                              _shift_perm(P, -a)), part)
            acc = arrived if acc is None else reduce_fn(acc, arrived)
        return acc


def pair_mask_table(schedule: PairSchedule) -> np.ndarray:
    """[P, n_pairs] float mask deduplicating the d = P/2 orbit for even P
    (DESIGN.md section 3.2).

    Each unordered pair with difference P/2 is generated by exactly two
    devices (i and i + P/2); the device with the smaller canonical lower
    endpoint keeps it.  All other entries are 1.  The mask rides into
    shard_map as a sharded operand, so control flow stays uniform.
    """
    P, n = schedule.P, schedule.n_pairs
    mask = np.ones((P, n), dtype=np.float32)
    if P % 2 == 0 and P > 1:
        d_half = P // 2
        idx = np.nonzero(schedule.pair_diff == d_half)[0]
        if idx.size:
            s = int(idx[0])
            a_lo = int(schedule.shifts[schedule.pair_slots[s, 0]])
            for i in range(P):
                lo = (i + a_lo) % P
                hi = (lo + d_half) % P
                # keeper: the generating device whose lower endpoint is the
                # canonical (smaller) block id of the orbit
                mask[i, s] = 1.0 if lo == min(lo, hi) else 0.0
    return mask


def mark_varying(x: jax.Array, axis_name: str) -> jax.Array:
    """Mark x as varying over the quorum axis (jax >= 0.7 VMA tracking;
    the shard_map plumbing every engine-internal constant goes through —
    DESIGN.md section 2)."""
    try:
        return lax.pcast(x, axis_name, to="varying")
    except (AttributeError, TypeError):  # pragma: no cover - older jax
        return x


# ---------------------------------------------------------------------------
# Work items (DESIGN.md section 12.1)
# ---------------------------------------------------------------------------

def ready_order(lo: Sequence[int], hi: Sequence[int],
                k: int) -> List[List[int]]:
    """Work items grouped by *ready slot* for the overlap mode
    (DESIGN.md sections 4, 12.1).

    An item referencing slots (lo, hi) can compute once its later block
    lands in the gather shift sequence, i.e. at slot max(lo, hi);
    ready[s] lists the items that become computable when slot s arrives.
    """
    out: List[List[int]] = [[] for _ in range(k)]
    for idx in range(len(lo)):
        out[max(int(lo[idx]), int(hi[idx]))].append(idx)
    return out


def pair_ready_order(schedule: PairSchedule) -> list[list[int]]:
    """Pair indices grouped by ready slot for the schedule's slot pairs
    (:func:`ready_order` over ``schedule.pair_slots``; DESIGN.md
    section 4)."""
    return ready_order(schedule.pair_slots[:, 0], schedule.pair_slots[:, 1],
                       schedule.k)


def sweep_rounds(schedule: PairSchedule, mode: str) -> List[List[int]]:
    """Pair indices grouped into the mode's synchronization rounds — the
    boundaries where a fault-tolerant driver may observe failures and
    checkpoint partials (DESIGN.md section 13).

    Mirrors each engine mode's real synchronization structure: ``batched``
    materializes every pair in one fused step (a single round), ``overlap``
    synchronizes once per gather shift as blocks land (the non-empty
    :func:`pair_ready_order` groups), and ``scan`` carries state through
    one pair per step (one round per pair).  Round lists concatenate to
    ``range(schedule.n_pairs)`` reordered — every pair appears exactly
    once, so replaying rounds in order folds partials in a
    mode-independent canonical pair order.
    """
    if mode not in ENGINE_MODES:
        raise ValueError(f"mode must be one of {ENGINE_MODES}, got {mode!r}")
    n = schedule.n_pairs
    if mode == "batched":
        return [list(range(n))] if n else []
    if mode == "scan":
        return [[i] for i in range(n)]
    return [grp for grp in pair_ready_order(schedule) if grp]


def slot_items(k: int) -> Tuple[np.ndarray, np.ndarray]:
    """The per-slot work-item list (``lo == hi == arange(k)``) used by
    emitters that sweep a resident stack slot-by-slot instead of the
    schedule's slot pairs — the serving query engines (DESIGN.md
    section 12.2)."""
    slots = np.arange(k, dtype=np.int32)
    return slots, slots


# ---------------------------------------------------------------------------
# Emitter protocol + driver (DESIGN.md section 12.1)
# ---------------------------------------------------------------------------

class SweepEmitter(abc.ABC):
    """The workload plug-in seam of the pair-sweep runtime (DESIGN.md
    section 12.1).

    An emitter owns the *per-item compute* and the *carry* it folds item
    results into; :func:`pair_sweep` owns mode dispatch and the data
    plane.  One emitter instance is built per trace (its fields may hold
    traced arrays).  Contract, per mode:

      * ``batched``  — :meth:`prepare` (optional, sees the gathered
        stack), then :meth:`batch` computes every item in one vectorized
        step (routing through ``self.batch_fn`` when a fused kernel is
        attached).
      * ``scan``     — :meth:`prepare`, then ``lax.scan`` of
        :meth:`scan_emit` over :meth:`scan_items` starting from
        :meth:`scan_init`, then :meth:`scan_finalize`.
      * ``overlap``  — :meth:`overlap_begin` builds a host-side state
        object; :meth:`overlap_slot` observes each block as it lands;
        :meth:`overlap_emit` runs each item at its ready slot (items and
        slot indices are *static* here — the loop is unrolled);
        :meth:`overlap_finalize` folds the state into the output.

    All three modes must produce index-identical results (scores to
    float tolerance) — the workload selfchecks assert it.
    """

    #: optional fused-kernel hook replacing the batched inner step
    #: (forces ``batched`` under ``mode="auto"``; see :func:`select_mode`)
    batch_fn = None

    def items(self) -> Tuple[np.ndarray, np.ndarray]:
        """(lo, hi) slot indices of each work item — default: the
        schedule's per-difference slot pairs (DESIGN.md section 3.2);
        slot-sweep emitters override with :func:`slot_items`."""
        return (self.schedule.pair_slots[:, 0],
                self.schedule.pair_slots[:, 1])

    def prepare(self, quorum: jax.Array) -> None:
        """Optional hook run after the gather in batched/scan modes —
        e.g. the sparse engine computes its norm-bound prefilter over the
        full stack here (DESIGN.md section 11.1)."""

    @abc.abstractmethod
    def batch(self, quorum: jax.Array):
        """Compute every work item in one vectorized step over the
        gathered [k, block, ...] stack; returns the sweep output."""

    @abc.abstractmethod
    def scan_init(self):
        """The (varying-marked) carry the serial scan starts from."""

    @abc.abstractmethod
    def scan_items(self):
        """Per-item traced arrays ``lax.scan`` iterates over."""

    @abc.abstractmethod
    def scan_emit(self, carry, quorum: jax.Array, item):
        """Fold one work item into the scan carry."""

    def scan_finalize(self, carry):
        """Turn the final scan carry into the sweep output (default:
        the carry itself)."""
        return carry

    @abc.abstractmethod
    def overlap_begin(self):
        """Build the host-side state object the unrolled overlap sweep
        mutates (lists of per-slot contributions, a boxed carry, ...)."""

    def overlap_slot(self, state, slot: int, blk: jax.Array) -> None:
        """Optional hook observing each block as it lands (e.g. per-slot
        norm extrema for the incremental prefilter)."""

    @abc.abstractmethod
    def overlap_emit(self, state, idx: int, bi: jax.Array,
                     bj: jax.Array) -> None:
        """Run work item ``idx`` (static int) on its two landed blocks,
        folding the result into ``state``."""

    @abc.abstractmethod
    def overlap_finalize(self, state):
        """Fold the overlap state into the sweep output."""

    # -- delta maintenance (DESIGN.md section 16) -------------------------
    # Host-side monoid patch rules over *standing* outputs, consumed by
    # core/delta.py's DeltaIndex: retract a dirty tile's stale
    # contribution, fold its fresh one.  Static numpy functions — they
    # act on folded host results, not traced arrays — so any driver can
    # call them without constructing a traced emitter.

    @staticmethod
    def delta_retract(standing, stale, ctx=None):
        """Remove a stale contribution from a standing output (the
        delta-sweep retract hook, DESIGN.md section 16).  Emitters with
        an invertible (or patchable) output monoid override this; the
        base protocol does not support delta maintenance."""
        raise NotImplementedError(
            "this emitter does not support delta maintenance "
            "(no delta_retract rule; see DESIGN.md section 16)")

    @staticmethod
    def delta_fold(standing, fresh, ctx=None):
        """Fold a fresh contribution into a standing output (the
        delta-sweep fold hook, DESIGN.md section 16).  Emitters with a
        delta-maintainable output monoid override this; the base
        protocol does not support delta maintenance."""
        raise NotImplementedError(
            "this emitter does not support delta maintenance "
            "(no delta_fold rule; see DESIGN.md section 16)")


def pair_sweep(emitter: SweepEmitter, *, schedule: PairSchedule,
               axis_name: str, mode: str, x: jax.Array | None = None,
               stack: jax.Array | None = None):
    """Run one emitter over the schedule under a resolved execution mode
    (DESIGN.md section 12.1) — the single home of the schedule → gather
    → pair-compute → emit loop.

    Exactly one of ``x`` (the local block: the stack is gathered with
    the schedule's ppermute shifts) or ``stack`` (an already-resident
    [k, block, ...] stack, the serving path) must be given.  ``mode``
    must be a concrete engine mode — resolve ``auto`` first with
    :func:`select_mode` (each adapter supplies its working-set bytes).
    Returns whatever the emitter's finalize step produces.
    """
    tr = obs_trace.get_tracer()
    if not tr:
        return _pair_sweep_impl(emitter, schedule=schedule,
                                axis_name=axis_name, mode=mode, x=x,
                                stack=stack)
    lo, _hi = emitter.items()
    with tr.span("sweep.pair_compute", mode=mode, P=schedule.P,
                 k=schedule.k, n_items=int(len(lo))):
        tr.count("sweep.pair_tiles", int(len(lo)))
        return _pair_sweep_impl(emitter, schedule=schedule,
                                axis_name=axis_name, mode=mode, x=x,
                                stack=stack)


def _pair_sweep_impl(emitter: SweepEmitter, *, schedule: PairSchedule,
                     axis_name: str, mode: str, x: jax.Array | None = None,
                     stack: jax.Array | None = None):
    # the un-instrumented driver body (pair_sweep is the traced wrapper)
    assert (x is None) != (stack is None), "need exactly one of x / stack"
    assert mode in ENGINE_MODES, mode
    if mode == "overlap":
        lo, hi = emitter.items()
        ready = ready_order(lo, hi, schedule.k)
        state = emitter.overlap_begin()
        landed: list = []

        def on_land(slot: int, blk: jax.Array) -> None:
            landed.append(blk)
            emitter.overlap_slot(state, slot, blk)
            for idx in ready[slot]:
                emitter.overlap_emit(state, idx,
                                     landed[int(lo[idx])],
                                     landed[int(hi[idx])])

        if stack is None:
            quorum_gather(x, schedule, axis_name, overlap_fn=on_land)
        else:
            for slot in range(schedule.k):
                on_land(slot, jax.tree.map(lambda l: l[slot], stack))
        return emitter.overlap_finalize(state)

    quorum = stack if stack is not None else quorum_gather(x, schedule,
                                                           axis_name)
    emitter.prepare(quorum)
    if mode == "batched":
        return emitter.batch(quorum)

    def body(carry, item):
        return emitter.scan_emit(carry, quorum, item), None

    carry, _ = lax.scan(body, emitter.scan_init(), emitter.scan_items())
    return emitter.scan_finalize(carry)


# ---------------------------------------------------------------------------
# Shared top-k selection monoid (DESIGN.md sections 9.2, 12.2)
# ---------------------------------------------------------------------------

def topk_by_score(vals: jax.Array, idx: jax.Array, topk: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Top-k along the last axis by the (-score, index) total order
    (DESIGN.md section 9.2).

    Pads with (NEG_INF, IDX_SENTINEL) when fewer than ``topk`` candidates.
    """
    n = vals.shape[-1]
    if n < topk:
        pad = [(0, 0)] * (vals.ndim - 1) + [(0, topk - n)]
        vals = jnp.pad(vals, pad, constant_values=NEG_INF)
        idx = jnp.pad(idx, pad, constant_values=IDX_SENTINEL)
    sv, si = lax.sort((-vals, idx.astype(jnp.int32)), num_keys=2)
    return -sv[..., :topk], si[..., :topk]


def merge_topk(va, ia, vb, ib, topk: int) -> Tuple[jax.Array, jax.Array]:
    """Merge two candidate lists, deduplicating repeated corpus indices
    (DESIGN.md section 9.2).

    Duplicates only arise from merge windows that overlap (the serving
    tree merge's wraparound; every sweep emitter *scores* each candidate
    once), so copies carry identical scores and land adjacent under the
    two-key sort — the second copy is demoted to a sentinel and a
    re-sort restores order.  Selection by a strict total order makes
    this merge associative and commutative: it is the monoid the k-NN
    scatter reduces under (DESIGN.md section 12.2).
    """
    vals = jnp.concatenate([va, vb], axis=-1)
    idx = jnp.concatenate([ia, ib], axis=-1).astype(jnp.int32)
    sv, si = lax.sort((-vals, idx), num_keys=2)
    dup = jnp.concatenate(
        [jnp.zeros_like(si[..., :1], bool),
         (si[..., 1:] == si[..., :-1]) & (sv[..., 1:] == sv[..., :-1])],
        axis=-1)
    sv = jnp.where(dup, -NEG_INF, sv)          # sv holds negated scores
    si = jnp.where(dup, IDX_SENTINEL, si)
    sv, si = lax.sort((sv, si), num_keys=2)
    return -sv[..., :topk], si[..., :topk]

"""Incremental delta-sweep: dirty-block scheduling that maintains
standing sweep outputs under churn (DESIGN.md section 16).

The batch workloads recompute all C(P,2)+P pair tiles whenever a block
changes, even though ``serving/stream.py`` already delivers block-level
updates.  Ullman's output-sensitive "Some Pairs" framing
(arXiv:1602.01443) says the correct cost is proportional to the pairs
actually touched: a set D of dirty blocks invalidates exactly the tiles
with >= 1 endpoint in D — ``|D|*P - C(|D|,2) <= |D|*P`` tiles, not
O(P^2).  This module owns that schedule and the drivers around it:

  * :func:`dirty_tiles` — the one shared dirty-tile enumerator (sorted,
    deterministic, canonical (x, y) x <= y order) that both the delta
    scheduler here and the failure-recovery path of ``core/faults.py``
    use (a dead device's lost partials are just another dirty set).
  * :func:`owner_partition` — the exactly-once tile -> owner partition
    over the k holder quorums (``Placement.owner_of`` /
    ``weighted_owner_table``), shared with the fault-tolerant driver.
  * :func:`delta_sweep` — run only the dirty tiles, grouped into the
    engine mode's round structure (:func:`core.sweep.sweep_rounds`).
  * :class:`DeltaIndex` — a continuously maintained standing output:
    a per-tile partials ledger plus each emitter's monoid patch rule
    (``delta_retract``/``delta_fold`` on the ``SweepEmitter`` classes):
    subtract-then-add for the additive dense reduce (published via a
    canonical-order refold of the ledger, which is what keeps the
    result bit-exact under float non-associativity), a hit-set patch
    for the threshold join, and the per-row candidate-refresh rule for
    the k-NN graph (rows whose neighbor list cites a dirty block are
    rebuilt from the retained per-tile candidate ledger — standing-list
    survivors alone are *not* sufficient, DESIGN.md section 16.4).

The headline check is the churn-chaos differential selfcheck
(``python -m repro.core.delta``): R random replace/append updates
across every registered placement x engine mode x P in
{4, 5, 7, 8, 12, 13} and all three workloads, asserting after every
update that the incrementally maintained output is bit-identical to a
from-scratch recompute and that the delta sweep touched at most
``|dirty| * P`` tiles.
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Dict, Iterable, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from . import env as env_mod
from .allpairs import DenseReduceEmitter
from .knn import KnnEmitter
from .placement import (Placement, get_placement, registered_placements,
                        resolve_placement, weighted_owner_table)
from .sparse import ThresholdJoinEmitter
from .sweep import ENGINE_MODES, sweep_rounds

__all__ = [
    "DELTA_P",
    "dirty_tiles",
    "owner_partition",
    "delta_rounds",
    "delta_sweep",
    "DeltaStats",
    "DeltaIndex",
    "churn_workload",
    "churn_selfcheck",
]

# the churn matrix: odd/even P, the projective planes 7 and 13, the
# affine plane 12, and the small even P=4 (ISSUE acceptance set)
DELTA_P = (4, 5, 7, 8, 12, 13)

_SENT_I = np.iinfo(np.int64).max

# workload name -> the SweepEmitter class carrying its monoid patch rule
_EMITTER_OF = {
    "dense": DenseReduceEmitter,
    "sparse": ThresholdJoinEmitter,
    "knn": KnnEmitter,
}


def dirty_tiles(placement: Optional[Placement], dirty: Iterable[int],
                P: Optional[int] = None) -> List[Tuple[int, int]]:
    """All pair tiles (x, y), x <= y, with at least one endpoint block
    in ``dirty``, in sorted canonical order (DESIGN.md section 16.1).

    The one shared dirty-tile enumerator: the delta scheduler runs
    exactly these tiles, and the failure recovery of ``core/faults.py``
    scans the same set for a dead device's lost work (every pair a
    device can own or compute has >= 1 endpoint among its resident
    blocks).  Deterministic: sorted ascending, the same canonical
    (x, y) x <= y order ``PairWorkload.canonical_pairs`` folds in and
    the same tie-breaks ``scheduler.reassign`` sees (sorted candidate
    lists), so plans built on top of it are stable.  Tile count is
    ``|D|*P - C(|D|, 2) <= |D|*P`` — never O(P^2) for ``|D| < P/2``.

    ``P`` defaults to ``placement.P`` (pass it explicitly when no
    placement object is at hand — enumeration needs only the block
    count).
    """
    if P is None:
        if placement is None:
            raise ValueError("need a placement or an explicit P")
        P = placement.P
    D = {int(b) for b in dirty}
    for b in D:
        if not 0 <= b < P:
            raise ValueError(f"dirty block {b} outside [0, {P})")
    return [(x, y) for x in range(P) for y in range(x, P)
            if x in D or y in D]


def owner_partition(placement: Placement,
                    pairs: Optional[Sequence[Tuple[int, int]]] = None, *,
                    weights: Optional[Sequence[float]] = None
                    ) -> Dict[Tuple[int, int], int]:
    """The exactly-once tile -> owner map over the k holder quorums
    (DESIGN.md section 16.1).

    Every tile is assigned to exactly one device that holds both
    endpoint blocks — ``Placement.owner_of`` (or the capacity-weighted
    ``weighted_owner_table`` when ``weights`` is given), the same
    partition the batch engines and the fault-tolerant driver of
    ``core/faults.py`` execute under.  ``pairs`` defaults to every
    canonical tile; pass a dirty-tile subset to partition just a delta
    schedule.
    """
    P = placement.P
    if pairs is None:
        pairs = [(x, y) for x in range(P) for y in range(x, P)]
    if weights is not None:
        table = weighted_owner_table(placement, weights)
        return {(x, y): int(table[x, y]) for (x, y) in pairs}
    return {(x, y): int(placement.owner_of(x, y)) for (x, y) in pairs}


def delta_rounds(placement: Placement, tiles: Sequence[Tuple[int, int]],
                 mode: str) -> List[List[Tuple[int, int]]]:
    """Group dirty tiles into the engine mode's synchronization rounds
    (DESIGN.md section 16.1).

    A tile lands in the round its difference class occupies under
    :func:`core.sweep.sweep_rounds` — batched: one fused round, overlap:
    the gather-shift ready groups, scan: one round per tile — so a delta
    sweep observes the same failure/checkpoint boundaries as a full
    sweep in the same mode.  Within a round tiles stay in canonical
    sorted order; empty rounds are dropped.  Outputs are mode-invariant
    (the fold is canonical-order), which the churn selfcheck asserts.
    """
    if mode not in ENGINE_MODES:
        raise ValueError(f"mode must be one of {ENGINE_MODES}, got {mode!r}")
    P = placement.P
    sched = placement.schedule()
    rounds = sweep_rounds(sched, mode)
    sidx_of_diff = {int(d): s for s, d in enumerate(sched.pair_diff)}
    round_of_sidx = {s: r for r, grp in enumerate(rounds) for s in grp}
    if mode == "scan":
        # one tile per round, canonical order (the scan carries state
        # through one pair per step — the round index is the step)
        return [[t] for t in sorted(tiles)]
    grouped: Dict[int, List[Tuple[int, int]]] = {}
    for t in tiles:
        d = (t[1] - t[0]) % P
        dd = min(d, P - d) if P > 1 else 0
        grouped.setdefault(round_of_sidx[sidx_of_diff[dd]], []).append(t)
    return [sorted(grouped[r]) for r in sorted(grouped)]


def delta_sweep(workload, placement: Placement, dirty: Iterable[int], *,
                mode: str = "batched",
                owner_map: Optional[Mapping[Tuple[int, int], int]] = None,
                stats: Optional["DeltaStats"] = None
                ) -> Dict[Tuple[int, int], Any]:
    """Recompute only the dirty tiles' partials (DESIGN.md section 16.2).

    Enumerates :func:`dirty_tiles`, groups them into ``mode``'s round
    structure (:func:`delta_rounds`), and computes each tile's fresh
    partial at its owner (:func:`owner_partition` when ``owner_map`` is
    not supplied), accounting tiles swept and per-device work into
    ``stats``.  Returns ``{tile: fresh partial}`` — the ledger patch a
    :class:`DeltaIndex` folds into its standing output.  Partials are
    pure functions of block contents (``PairWorkload.pair_partial``),
    so the patch is bit-identical no matter which mode shaped the
    rounds.
    """
    tiles = dirty_tiles(placement, dirty)
    if owner_map is None:
        owner_map = owner_partition(placement, tiles)
    fresh: Dict[Tuple[int, int], Any] = {}
    for rnd in delta_rounds(placement, tiles, mode):
        for t in rnd:
            x, y = t
            fresh[t] = workload.pair_partial(
                x, y, workload.blocks[x], workload.blocks[y])
            if stats is not None:
                o = int(owner_map[t])
                stats.tiles_by_device[o] = stats.tiles_by_device.get(o, 0) + 1
    if stats is not None:
        stats.tiles_swept += len(fresh)
        stats.last_tiles = len(fresh)
    return fresh


@dataclasses.dataclass
class DeltaStats:
    """Counters a :class:`DeltaIndex` accumulates across updates — the
    quantities ``benchmarks/bench_delta.py`` reports (DESIGN.md
    section 16.5)."""

    updates: int = 0               # apply() calls that saw dirty blocks
    tiles_swept: int = 0           # dirty tiles recomputed, total
    last_tiles: int = 0            # tiles swept by the latest apply()
    tiles_full: int = 0            # C(P,2)+P — the full-sweep tile count
    full_rebuilds: int = 0         # max-dirty fallbacks to a full sweep
    rows_refreshed: int = 0        # k-NN rows rebuilt from the ledger
    rows_merged: int = 0           # k-NN rows patched by the fast merge
    hits_retracted: int = 0        # join rows retracted from the hit set
    hits_inserted: int = 0         # join rows inserted into the hit set
    tiles_by_device: Dict[int, int] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        """The counters as a plain dict (for JSON benchmark output)."""
        return dataclasses.asdict(self)


def _max_dirty_pct_default() -> int:
    val = env_mod.read_knob("REPRO_DELTA_MAX_DIRTY_PCT")
    return 50 if val is None else int(val)


class DeltaIndex:
    """A continuously maintained sweep output (DESIGN.md section 16).

    Holds a per-tile partials **ledger** for one ``PairWorkload``
    (``core/faults.py``'s dense reduce, threshold join, or k-NN graph)
    plus the standing folded output.  Block updates arrive through
    :meth:`replace_block` (new contents for one block — an append is a
    replace that grows the block within its capacity span) or
    :meth:`mark_dirty` (the ``serving/stream.py`` listener form, when
    the caller mutates ``workload.blocks`` itself); :meth:`apply` then
    recomputes only the dirty tiles (:func:`delta_sweep`) and patches
    the standing output under the workload emitter's monoid:

      * dense — ``DenseReduceEmitter.delta_retract``/``delta_fold``
        subtract-then-add a running total; the *published* result is
        the canonical-order refold of the scalar ledger, which is what
        keeps it bit-exact vs a from-scratch recompute (float addition
        is not associative; DESIGN.md section 16.2).
      * join — ``ThresholdJoinEmitter`` retracts the stale (i, j) rows
        of the dirty tiles from the hit set and inserts the fresh ones
        (a pair's tile is unique, so the patch is an exact set
        difference/union; DESIGN.md section 16.3).
      * k-NN — rows living in a dirty block, and rows whose standing
        neighbor list cites one, are rebuilt from the retained per-tile
        candidate ledger; every other row merges the fresh dirty-tile
        candidates into its standing list (``KnnEmitter.delta_fold``,
        exact because top-k under the strict (-score, index) order is
        an associative-commutative monoid; DESIGN.md section 16.4).

    When more than ``max_dirty_pct`` percent of the blocks are dirty
    (``REPRO_DELTA_MAX_DIRTY_PCT``, default 50), the delta schedule
    approaches the full O(P^2) sweep and the index falls back to a full
    rebuild — same bits, fewer bookkeeping passes.
    """

    def __init__(self, workload, placement: Placement, *,
                 mode: str = "batched",
                 weights: Optional[Sequence[float]] = None,
                 max_dirty_pct: Optional[int] = None):
        if mode not in ENGINE_MODES:
            raise ValueError(
                f"mode must be one of {ENGINE_MODES}, got {mode!r}")
        if workload.P != placement.P:
            raise ValueError(
                f"workload P={workload.P} != placement P={placement.P}")
        if workload.name not in _EMITTER_OF:
            raise ValueError(
                f"workload {workload.name!r} has no delta emitter rule "
                f"(supported: {tuple(_EMITTER_OF)})")
        self.workload = workload
        self.placement = placement
        self.mode = mode
        self.owner_map = owner_partition(placement, weights=weights)
        self.max_dirty_pct = (
            _max_dirty_pct_default() if max_dirty_pct is None
            else int(max_dirty_pct))
        if not 0 <= self.max_dirty_pct <= 100:
            raise ValueError(
                f"max_dirty_pct must be in [0, 100], got {self.max_dirty_pct}")
        self._emitter = _EMITTER_OF[workload.name]
        self.stats = DeltaStats(
            tiles_full=len(workload.canonical_pairs()))
        self.pending: set = set()
        self.ledger: Dict[Tuple[int, int], Any] = {}
        self._standing: Any = None
        self._running_total: Optional[np.float64] = None  # dense fast path
        self._best_s: Optional[np.ndarray] = None         # knn standing
        self._best_i: Optional[np.ndarray] = None
        self._rebuild_all()

    # -- block geometry ---------------------------------------------------
    def span_of(self, b: int) -> int:
        """Block ``b``'s capacity span in global-index space — the id
        range ``[offsets[b], offsets[b] + span)`` stays stable under
        churn, so appends never renumber other blocks (DESIGN.md
        section 16.1)."""
        wl = self.workload
        if not 0 <= b < wl.P:
            raise ValueError(f"block {b} outside [0, {wl.P})")
        end = wl.offsets[b + 1] if b + 1 < wl.P else wl.n
        return int(end - wl.offsets[b])

    # -- update intake ----------------------------------------------------
    def mark_dirty(self, b: int) -> None:
        """Record block ``b`` as dirty without staging data — the
        ``serving.stream.register_dirty_listener`` callback form; the
        caller is responsible for refreshing ``workload.blocks[b]``
        before :meth:`apply` (DESIGN.md section 16.5)."""
        if not 0 <= int(b) < self.workload.P:
            raise ValueError(f"block {b} outside [0, {self.workload.P})")
        self.pending.add(int(b))

    def replace_block(self, b: int, data: np.ndarray) -> None:
        """Stage new contents for block ``b`` (rows <= the block's
        capacity span) and mark it dirty; an append is a replace with
        the grown row set (DESIGN.md section 16.1).  The sweep itself
        runs at the next :meth:`apply`."""
        wl = self.workload
        data = np.ascontiguousarray(np.asarray(data, np.float32))
        if data.ndim != 2 or data.shape[1] != wl.blocks[0].shape[1]:
            raise ValueError(
                f"block data must be [rows, {wl.blocks[0].shape[1]}], "
                f"got {data.shape}")
        span = self.span_of(b)
        if data.shape[0] > span:
            raise ValueError(
                f"block {b} holds at most {span} rows, got {data.shape[0]}")
        wl.blocks[b] = data
        self.pending.add(int(b))

    # -- the delta update -------------------------------------------------
    def apply(self) -> Any:
        """Fold all pending dirty blocks into the standing output and
        return it (DESIGN.md section 16.2): sweep only the dirty tiles
        (:func:`delta_sweep` under this index's engine mode), patch the
        ledger, and run the workload's retract/fold rule — or a full
        rebuild when the dirty fraction exceeds ``max_dirty_pct``.  The
        result is bit-identical to a from-scratch recompute of the
        current blocks (the churn selfcheck's differential contract)."""
        dirty = sorted(self.pending)
        self.pending.clear()
        if not dirty:
            return self.result
        self.stats.updates += 1
        P = self.workload.P
        if 100 * len(dirty) > self.max_dirty_pct * P:
            self.stats.full_rebuilds += 1
            self._rebuild_all()
            return self.result
        fresh = delta_sweep(self.workload, self.placement, dirty,
                            mode=self.mode, owner_map=self.owner_map,
                            stats=self.stats)
        patch = getattr(self, "_patch_" + self.workload.name)
        patch(dirty, fresh)
        return self.result

    @property
    def result(self) -> Any:
        """The standing output, always equal to a from-scratch fold of
        the current blocks (DESIGN.md section 16): the dense float64
        total, the sorted (i, j) join hit set, or the [N, topk] k-NN
        index matrix."""
        if self.workload.name == "knn":
            return self._best_i
        return self._standing

    # -- full (re)build ---------------------------------------------------
    def _rebuild_all(self) -> None:
        wl = self.workload
        pairs = wl.canonical_pairs()
        self.ledger = {
            (x, y): wl.pair_partial(x, y, wl.blocks[x], wl.blocks[y])
            for (x, y) in pairs}
        self.stats.tiles_swept += len(pairs)
        self.stats.last_tiles = len(pairs)
        if wl.name == "knn":
            n, topk = wl.n, wl.topk
            self._best_s = np.full((n, topk), -np.inf, np.float32)
            self._best_i = np.full((n, topk), _SENT_I, np.int64)
            self._knn_rebuild_rows(np.ones(n, bool))
        else:
            self._standing = wl.fold(self.ledger)
            if wl.name == "dense":
                self._running_total = np.float64(self._standing)

    # -- per-workload patch rules ----------------------------------------
    def _patch_dense(self, dirty: List[int],
                     fresh: Dict[Tuple[int, int], Any]) -> None:
        # subtract-then-add keeps an O(|delta|) running total (the
        # additive monoid); the published standing result is the
        # canonical-order refold of the scalar ledger — bit-exact under
        # float non-associativity (DESIGN.md section 16.2)
        emit = self._emitter
        total = self._running_total
        for t in sorted(fresh):
            total = emit.delta_retract(total, self.ledger[t])
            total = emit.delta_fold(total, fresh[t])
            self.ledger[t] = fresh[t]
        self._running_total = np.float64(total)
        self._standing = self.workload.fold(self.ledger)

    def _patch_sparse(self, dirty: List[int],
                      fresh: Dict[Tuple[int, int], Any]) -> None:
        # hit-set patch: a global pair (i, j) lives in exactly one tile,
        # so retract-stale / insert-fresh is an exact set difference and
        # union (DESIGN.md section 16.3)
        emit = self._emitter
        order = sorted(fresh)
        stale_rows = [self.ledger[t] for t in order]
        fresh_rows = [fresh[t] for t in order]
        stale = (np.concatenate(stale_rows, axis=0) if stale_rows
                 else np.zeros((0, 2), np.int64))
        ins = (np.concatenate(fresh_rows, axis=0) if fresh_rows
               else np.zeros((0, 2), np.int64))
        standing = emit.delta_retract(self._standing, stale)
        self._standing = emit.delta_fold(standing, ins)
        self.stats.hits_retracted += int(stale.shape[0])
        self.stats.hits_inserted += int(ins.shape[0])
        for t in order:
            self.ledger[t] = fresh[t]

    def _patch_knn(self, dirty: List[int],
                   fresh: Dict[Tuple[int, int], Any]) -> None:
        # per-row candidate refresh (DESIGN.md section 16.4): rows in a
        # dirty block, and rows whose standing list cites one, rebuild
        # from the per-tile candidate ledger; everyone else merges the
        # fresh dirty-tile candidates into their standing list
        wl = self.workload
        emit = self._emitter
        for t in sorted(fresh):
            self.ledger[t] = fresh[t]
        starts = np.asarray([wl.offsets[b] for b in dirty], np.int64)
        stops = starts + np.asarray([self.span_of(b) for b in dirty],
                                    np.int64)
        refresh = emit.delta_retract((self._best_s, self._best_i),
                                     (starts, stops))
        for b, lo, hi in zip(dirty, starts, stops):
            refresh[lo:hi] = True
        self.stats.rows_refreshed += int(refresh.sum())
        self._knn_rebuild_rows(refresh)
        dirty_set = set(dirty)
        for t in sorted(fresh):
            x, y = t
            part = fresh[t]
            for side, b in (("x", x), ("y", y)):
                if b in dirty_set:
                    continue  # rebuilt above
                if side == "y" and x == y:
                    continue  # self tile carries only the x plane
                ps = part["xs"] if side == "x" else part["ys"]
                pi = part["xi"] if side == "x" else part["yi"]
                off = int(wl.offsets[b])
                nb = wl.blocks[b].shape[0]
                view_s = self._best_s[off:off + nb]
                view_i = self._best_i[off:off + nb]
                m = ~refresh[off:off + nb]
                if not m.any():
                    continue
                ms, mi = emit.delta_fold((view_s[m], view_i[m]),
                                         (ps[m], pi[m]))
                view_s[m] = ms
                view_i[m] = mi
                self.stats.rows_merged += int(m.sum())

    def _knn_rebuild_rows(self, mask: np.ndarray) -> None:
        # exact per-row refold from the per-tile candidate ledger: the
        # global top-k of a row is always contained in the union of its
        # per-tile top-k lists (DESIGN.md section 16.4)
        wl = self.workload
        emit = self._emitter
        topk = wl.topk
        for x in range(wl.P):
            off = int(wl.offsets[x])
            span = self.span_of(x)
            msl = mask[off:off + span]
            if not msl.any():
                continue
            # capacity rows past the block's valid count pin to sentinel
            self._best_s[off:off + span][msl] = -np.inf
            self._best_i[off:off + span][msl] = _SENT_I
            nx = wl.blocks[x].shape[0]
            m = msl[:nx]
            if not m.any():
                continue
            nm = int(m.sum())
            acc_s = np.full((nm, topk), -np.inf, np.float32)
            acc_i = np.full((nm, topk), _SENT_I, np.int64)
            for y in range(wl.P):
                part = self.ledger[(min(x, y), max(x, y))]
                if x <= y:
                    ps, pi = part["xs"], part["xi"]
                else:
                    ps, pi = part["ys"], part["yi"]
                acc_s, acc_i = emit.delta_fold((acc_s, acc_i),
                                               (ps[m], pi[m]))
            rows = off + np.nonzero(m)[0]
            self._best_s[rows] = acc_s
            self._best_i[rows] = acc_i


# ---------------------------------------------------------------------------
# Churn-chaos differential selfcheck
# ---------------------------------------------------------------------------

def churn_workload(wl_cls, P: int, *, seed: int = 0, spare: int = 2):
    """Build a churn-capable instance of a ``core/faults.py`` workload
    (DESIGN.md section 16.1).

    Re-blocks the workload's corpus onto fixed per-block capacity
    spans — every block keeps its initial rows and gains ``spare``
    empty capacity rows, global index = block offset + row — so a
    replace or append changes one block's contents without renumbering
    any other block's rows (the serving-tier indexing discipline of
    ``serving/stream.py``).  Offsets, ``n``, and blocks are rewritten
    in place; partials, folds, and the differential oracle all run on
    the current ragged blocks.
    """
    if spare < 0:
        raise ValueError(f"spare must be >= 0, got {spare}")
    wl = wl_cls(P, seed=seed)
    spans = [b.shape[0] + spare for b in wl.blocks]
    starts = np.cumsum([0] + spans)
    wl.offsets = [int(s) for s in starts[:-1]]
    wl.n = int(starts[-1])
    wl.blocks = [np.ascontiguousarray(b) for b in wl.blocks]
    return wl


def scratch_fold(workload) -> Any:
    """From-scratch oracle: recompute every tile's partial from the
    current blocks and fold in canonical order — the reference the
    churn selfcheck holds a :class:`DeltaIndex` bit-exactly to
    (DESIGN.md section 16.6)."""
    return workload.fold({
        (x, y): workload.pair_partial(
            x, y, workload.blocks[x], workload.blocks[y])
        for (x, y) in workload.canonical_pairs()})


def _random_update(wl, rng: np.random.RandomState,
                   span_of) -> Tuple[int, np.ndarray]:
    """One random replace-or-append: returns (block, new contents)."""
    P = wl.P
    dim = wl.blocks[0].shape[1]
    b = int(rng.randint(P))
    cur = wl.blocks[b]
    span = span_of(b)
    free = span - cur.shape[0]
    if free > 0 and rng.rand() < 0.4:
        # append: grow the block within its capacity span
        extra = int(rng.randint(1, free + 1))
        new = np.concatenate(
            [cur, rng.randn(extra, dim).astype(np.float32)], axis=0)
    else:
        # replace: fresh contents, possibly a different valid count
        rows = int(rng.randint(1, span + 1))
        new = rng.randn(rows, dim).astype(np.float32)
    return b, new


def _delta_placements(P: int,
                      names: Optional[Sequence[str]] = None
                      ) -> List[Placement]:
    if names is None:
        return [get_placement(name, P)
                for name, cls in sorted(registered_placements().items())
                if cls.supports(P)]
    out: List[Placement] = []
    for name in names:
        plc = resolve_placement(name, P)
        if all(p.name != plc.name for p in out):
            out.append(plc)
    return out


def churn_selfcheck(Ps: Sequence[int] = DELTA_P,
                    modes: Sequence[str] = ENGINE_MODES,
                    placements: Optional[Sequence[str]] = None,
                    n_updates: Optional[int] = None,
                    seed: Optional[int] = None,
                    verbose: bool = True) -> int:
    """The churn-chaos differential check (DESIGN.md section 16.6): for
    every registered placement x engine mode x P in ``Ps`` and all
    three workloads, apply R random replace/append updates
    (``n_updates``, default ``REPRO_DELTA_UPDATES`` else 3; seed from
    ``REPRO_DELTA_SEED`` else 0) to a standing :class:`DeltaIndex` —
    every third update dirties two blocks at once — asserting after
    each update that the incrementally maintained output is bit-exact
    vs a from-scratch recompute and that the delta sweep touched at
    most ``|dirty| * P`` tiles.  Returns the number of cases checked.
    """
    if n_updates is None:
        val = env_mod.read_knob("REPRO_DELTA_UPDATES")
        n_updates = 3 if val is None else int(val)
    if seed is None:
        val = env_mod.read_knob("REPRO_DELTA_SEED")
        seed = 0 if val is None else int(val)
    from .faults import WORKLOADS  # faults imports delta: keep it lazy here
    n_cases = 0
    for P in Ps:
        for plc in _delta_placements(P, placements):
            for wl_cls in WORKLOADS:
                for mode in modes:
                    wl = churn_workload(wl_cls, P, seed=seed)
                    index = DeltaIndex(wl, plc, mode=mode)
                    rng = np.random.RandomState(
                        seed + 7 * P + len(mode) + sum(map(ord, plc.name)))
                    for u in range(n_updates):
                        n_dirty = 2 if (u % 3 == 2 and P > 2) else 1
                        seen: set = set()
                        while len(seen) < n_dirty:
                            b, data = _random_update(wl, rng, index.span_of)
                            index.replace_block(b, data)
                            seen.add(b)
                        out = index.apply()
                        assert index.stats.last_tiles <= len(seen) * P, (
                            plc.name, P, mode, wl.name, index.stats)
                        want = scratch_fold(wl)
                        assert wl.equal(out, want), (
                            plc.name, P, mode, wl.name, u)
                    n_cases += 1
                    if verbose:
                        st = index.stats
                        print(f"  churn {wl.name:6s} {plc.name:10s} "
                              f"P={P:<3d} {mode:7s}: updates={st.updates} "
                              f"tiles={st.tiles_swept - st.tiles_full}"
                              f"/{st.tiles_full} bit-exact OK")
    if verbose:
        print(f"churn selfcheck OK ({n_cases} cases, P in {tuple(Ps)})")
    return n_cases


def _main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: ``python -m repro.core.delta [--P 5 8] [--modes scan]
    [--placements cyclic] [--updates 3] [--seed 0] [--quiet]``
    (DESIGN.md section 16.6)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="churn selfcheck: delta-maintained outputs must be "
                    "bit-exact vs from-scratch recomputes")
    ap.add_argument("--P", type=int, nargs="*", default=list(DELTA_P))
    ap.add_argument("--modes", nargs="*", default=list(ENGINE_MODES),
                    choices=list(ENGINE_MODES))
    ap.add_argument("--placements", nargs="*", default=None)
    ap.add_argument("--updates", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    churn_selfcheck(Ps=args.P, modes=args.modes,
                    placements=args.placements, n_updates=args.updates,
                    seed=args.seed, verbose=not args.quiet)
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())

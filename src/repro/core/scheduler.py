"""Static all-pairs work schedules over cyclic quorums.

The paper distributes the P*(P+1)/2 block pairings across P processes and
relies on quorum symmetry for "equal work" (paper Eq. 12-13).  We make that
static and exact with the *per-difference ownership rule* (DESIGN.md 3.2):

For every cyclic difference ``d`` pick one canonical pair
``(a_hi, a_lo) in A x A`` with ``a_hi - a_lo = d (mod P)`` (it exists by the
difference-cover property).  Block pair ``(j, j+d)`` is then owned by device
``i = (j - a_lo) mod P`` — device i holds both blocks since
``j = i + a_lo in S_i`` and ``j + d = i + a_hi in S_i``.

Consequences (all verified in tests):
  * each device owns exactly one ordered pair per difference d, i.e.
    perfect static balance across devices: same pair count, same local
    quorum slot indices, zero control-flow divergence — pure SPMD,
  * unordered coverage: scheduling d in {0..floor(P/2)} covers every
    unordered pair exactly once (d and P-d name the same unordered pair),
  * all schedules are pure functions of P — elastic resize just recomputes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .quorum import cyclic_quorums, difference_set

__all__ = [
    "PairSchedule",
    "build_schedule",
    "build_causal_schedule",
    "reassign",
    "ReassignPlan",
    "FETCH_LOAD_WEIGHT",
]

# load-model weight of a tier-2 recovery pair: the reassigned compute plus
# the one extra block transfer it costs (DESIGN.md section 13) — exposed so
# ReassignPlan.weighted_load and the greedy assignment agree by construction
FETCH_LOAD_WEIGHT = 1.5


@dataclasses.dataclass(frozen=True)
class PairSchedule:
    """A static all-pairs schedule for P devices.

    Attributes
    ----------
    P : number of block/devices on the quorum axis.
    A : the relaxed (P,k)-difference set (sorted).
    k : quorum size len(A).
    shifts : np.ndarray [k] — cyclic shifts a device pulls its quorum blocks
        from; local slot s of device i holds global block (i + shifts[s]) % P.
    pair_slots : np.ndarray [n_pairs, 2] int32 — *local slot* index pairs
        (lo_slot, hi_slot) each device computes.  Identical on every device
        (SPMD); device i's s-th pair is global blocks
        ((i + shifts[lo_slot]) % P, (i + shifts[hi_slot]) % P).
    pair_diff : np.ndarray [n_pairs] — the cyclic difference each pair covers.
    self_pair_index : position in pair_slots of the (0,0) self-pair.
    """

    P: int
    A: Tuple[int, ...]
    shifts: np.ndarray
    pair_slots: np.ndarray
    pair_diff: np.ndarray

    @property
    def k(self) -> int:
        """Quorum size (blocks resident per device)."""
        return len(self.A)

    @property
    def n_pairs(self) -> int:
        """Scheduled slot pairs per device (one per difference)."""
        return int(self.pair_slots.shape[0])

    def owner_of(self, x: int, y: int) -> int:
        """Global owner device of unordered block pair (x, y).

        The schedule entry for difference dd = min(d, P-d) is the canonical
        (a_lo, a_hi) with a_hi - a_lo = dd (mod P); the owner is the device i
        whose quorum places the pair's lower endpoint (in the canonical
        direction) at slot a_lo, i.e. i = j - a_lo (mod P) with j the
        endpoint satisfying (other - j) % P == dd.  For the doubly-owned
        d = P/2 orbit (even P) both endpoints qualify; this returns one of
        the two owners (the engine mask dedups the actual compute).
        """
        d = (y - x) % self.P
        dd = min(d, (self.P - d) % self.P)
        # find the schedule entry covering difference dd
        idx = int(np.nonzero(self.pair_diff == dd)[0][0])
        lo_slot = int(self.pair_slots[idx, 0])
        a_lo = int(self.shifts[lo_slot])
        j = x if d == dd else y  # lower endpoint of the canonical direction
        return (j - a_lo) % self.P

    def global_pairs_of(self, i: int) -> List[Tuple[int, int]]:
        """The global block pairs device i computes (for tests/debug)."""
        out = []
        for s in range(self.n_pairs):
            lo = (i + int(self.shifts[self.pair_slots[s, 0]])) % self.P
            hi = (i + int(self.shifts[self.pair_slots[s, 1]])) % self.P
            out.append((lo, hi))
        return out


def _canonical_pairs(P: int, A: Sequence[int]) -> Dict[int, Tuple[int, int]]:
    """difference d -> canonical (a_lo, a_hi) with a_hi - a_lo = d (mod P).

    Chosen deterministically; preferring pairs that reuse low slot indices
    keeps the gathered working set warm.
    """
    A = sorted(A)
    table: Dict[int, Tuple[int, int]] = {}
    for a_lo in A:
        for a_hi in A:
            d = (a_hi - a_lo) % P
            if d not in table:
                table[d] = (a_lo, a_hi)
    missing = [d for d in range(P) if d not in table]
    if missing:  # pragma: no cover - A is verified upstream
        raise AssertionError(f"A not a difference cover, missing {missing}")
    return table


def _placement_cover(P: int, placement) -> List[int]:
    """The difference cover a schedule derives from: ``difference_set(P)``
    for the default (bit-exact cyclic behavior), or the placement's shift
    structure.  Duck-typed on ``.shifts`` / ``.P`` so this module needs no
    import of core.placement (which imports us)."""
    if placement is None:
        return difference_set(P)
    if getattr(placement, "P", P) != P:
        raise ValueError(f"placement {placement!r} does not match P={P}")
    shifts = placement.shifts
    if shifts is None:
        raise ValueError(
            f"placement {getattr(placement, 'name', placement)!r} has no "
            "cyclic shift structure; the shift-based scheduler cannot use it")
    return [int(a) % P for a in shifts]


def build_schedule(P: int, placement=None) -> PairSchedule:
    """Full (symmetric) all-pairs schedule: one entry per d in 0..floor(P/2).

    Every unordered pair {x, y} (including self-pairs x==y via d=0) is computed
    by exactly one device, except d = P/2 for even P which is owned twice (the
    cyclic rule cannot halve an odd orbit); the engine halves that pair's work
    by masking (see core.allpairs), keeping exact single-coverage semantics.

    ``placement`` (a core.placement.Placement) substitutes its shift
    structure for the default ``difference_set(P)`` — the schedule machinery
    is placement-agnostic as long as residency is cyclic.
    """
    A = _placement_cover(P, placement)
    table = _canonical_pairs(P, A)
    slot_of = {a: s for s, a in enumerate(sorted(A))}

    pair_slots: List[Tuple[int, int]] = []
    pair_diff: List[int] = []
    for d in range(P // 2 + 1):
        a_lo, a_hi = table[d]
        pair_slots.append((slot_of[a_lo], slot_of[a_hi]))
        pair_diff.append(d)

    return PairSchedule(
        P=P,
        A=tuple(sorted(A)),
        shifts=np.asarray(sorted(A), dtype=np.int32),
        pair_slots=np.asarray(pair_slots, dtype=np.int32),
        pair_diff=np.asarray(pair_diff, dtype=np.int32),
    )


@dataclasses.dataclass(frozen=True)
class CausalSchedule:
    """Causal (triangular) all-pairs schedule for block attention.

    Unlike the cyclic case, causality breaks shift invariance: pair (q, kv)
    exists only for kv <= q, so per-device pair lists differ in *validity* but
    not in length — we keep the SPMD one-pair-per-difference structure and mask
    invalid pairs (valid[i, s] below), preserving uniform control flow.
    """

    P: int
    A: Tuple[int, ...]
    shifts: np.ndarray          # [k]
    pair_slots: np.ndarray      # [n_pairs, 2] (kv_slot, q_slot) local slots
    pair_diff: np.ndarray       # [n_pairs] difference d = q - kv >= 0
    valid: np.ndarray           # [P, n_pairs] bool — device i computes pair s?

    @property
    def k(self) -> int:
        """Quorum size (blocks resident per device)."""
        return len(self.A)

    @property
    def n_pairs(self) -> int:
        """Candidate slot pairs per device (validity-masked)."""
        return int(self.pair_slots.shape[0])


def build_causal_schedule(P: int, placement=None) -> CausalSchedule:
    """Schedule every causal block pair (q, kv), kv <= q, exactly once.

    Differences d = q - kv range over 0..P-1 (no modular wraparound in
    validity).  Device i's candidate pair for difference d is
    q = (i + a_hi) % P, kv = (i + a_lo) % P with the canonical (a_lo, a_hi);
    it is valid iff q - kv == d exactly (no wrap) — i.e. kv + d < P.
    Each difference d has exactly P - d valid (q, kv) pairs and the cyclic
    rule assigns each to a distinct device, so coverage is exact.
    Load per device = sum over d of [valid] ~ (P+1)/2 on average; worst-case
    imbalance is bounded by the quorum structure and reported by tests.
    ``placement`` substitutes its shift structure, as in build_schedule.
    """
    A = _placement_cover(P, placement)
    table = _canonical_pairs(P, A)
    slot_of = {a: s for s, a in enumerate(sorted(A))}
    shifts = np.asarray(sorted(A), dtype=np.int32)

    pair_slots: List[Tuple[int, int]] = []
    pair_diff: List[int] = []
    valid = np.zeros((P, P), dtype=bool)
    for d in range(P):
        a_lo, a_hi = table[d]
        pair_slots.append((slot_of[a_lo], slot_of[a_hi]))
        pair_diff.append(d)
        for i in range(P):
            kv = (i + a_lo) % P
            q = (i + a_hi) % P
            valid[i, d] = (q - kv) == d  # no wraparound => causal pair exists
    return CausalSchedule(
        P=P,
        A=tuple(sorted(A)),
        shifts=shifts,
        pair_slots=np.asarray(pair_slots, dtype=np.int32),
        pair_diff=np.asarray(pair_diff, dtype=np.int32),
        valid=valid,
    )


# ---------------------------------------------------------------------------
# Fault tolerance: straggler / failure reassignment (paper section 6 future work)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReassignPlan:
    """Recovery plan after device failures.

    extra_pairs[i]   — pairs device i recomputes that are already co-resident
                       in its quorum (zero extra communication).
    fetch_pairs[i]   — (pair, missing_block, source_device) entries where
                       device i holds one block and pulls the other from a
                       live holder (one extra block transfer each).

    Two cost views (DESIGN.md section 13): :attr:`n_recovered` counts
    *pairs* (every tier-1 and tier-2 entry is one recovered pair —
    coverage accounting), :attr:`weighted_load` totals the greedy *load
    model* (tier-2 entries cost ``FETCH_LOAD_WEIGHT`` because they also
    move a block).  The two used to be conflated; they answer different
    questions and are both exposed.
    """

    extra_pairs: Dict[int, List[Tuple[int, int]]]
    fetch_pairs: Dict[int, List[Tuple[Tuple[int, int], int, int]]]

    @property
    def n_recovered(self) -> int:
        """Pairs this plan reassigns across both tiers (each counted
        once — the coverage view)."""
        return (sum(len(v) for v in self.extra_pairs.values())
                + sum(len(v) for v in self.fetch_pairs.values()))

    @property
    def weighted_load(self) -> float:
        """Total extra load under the greedy cost model: 1.0 per tier-1
        pair, ``FETCH_LOAD_WEIGHT`` per tier-2 pair (compute + one block
        transfer) — the quantity the min-load assignment balances."""
        return (sum(len(v) for v in self.extra_pairs.values())
                + FETCH_LOAD_WEIGHT
                * sum(len(v) for v in self.fetch_pairs.values()))

    @property
    def fetched_blocks(self) -> List[Tuple[int, int, int]]:
        """The (block, source, target) transfers tier 2 executes, in
        deterministic plan order."""
        return [(missing, src, tgt)
                for tgt in sorted(self.fetch_pairs)
                for (_pair, missing, src) in self.fetch_pairs[tgt]]


def _capacity(weights: Optional[Sequence[float]], P: int) -> List[float]:
    """Validated per-device capacity weights (default: uniform 1.0)."""
    if weights is None:
        return [1.0] * P
    w = [float(v) for v in weights]
    if len(w) != P:
        raise ValueError(f"weights must have length P={P}, got {len(w)}")
    if any(v <= 0 for v in w):
        raise ValueError(f"weights must be positive, got {w}")
    return w


def reassign(schedule: PairSchedule, failed: Sequence[int],
             placement=None, *, weights: Optional[Sequence[float]] = None,
             pairs: Optional[Dict[int, List[Tuple[int, int]]]] = None
             ) -> ReassignPlan:
    """Reassign failed devices' pair lists to quorum peers.

    Two tiers (DESIGN.md sections 8 and 13):
      1. the pair is co-resident in a live quorum -> free reassignment.  The
         all-pairs property guarantees >= 1 co-resident quorum; it may be
         exactly the failed one, hence tier 2.
      2. otherwise a live device holding one block fetches the other from any
         live holder (each block lives in exactly k quorums, paper Eq. 13, so
         a block is lost only if all k of its holders fail simultaneously —
         then restart-from-checkpoint is the only correct response).

    Greedy min-load assignment in both tiers, fully deterministic: ties
    on load break by smallest device id (candidate lists are sorted), so
    a given (schedule, failed, placement, weights) always produces the
    same plan — the mid-sweep recovery of core/faults.py depends on plan
    stability.  ``weights`` are per-device capacity weights (Rocket's
    heterogeneity model): the greedy minimizes load *normalized by
    capacity*, so a 2x-capacity device absorbs ~2x the recovered pairs;
    None means uniform.

    ``placement`` supplies the residency sets (any core.placement.Placement,
    not just cyclic — reassignment itself only needs *sets*); the schedule
    must derive from the same placement or coverage claims break.
    ``pairs`` optionally overrides the per-failed-device pair lists
    (default: ``schedule.global_pairs_of``) — the fault-tolerant driver
    passes the *remaining* mid-sweep tiles, and a weighted-ownership
    assignment passes its own partition.
    """
    failed_set = set(failed)
    P = schedule.P
    if placement is None:
        quorums: Sequence[Sequence[int]] = cyclic_quorums(P)
    else:
        if getattr(placement, "P", P) != P:
            raise ValueError(f"placement {placement!r} does not match P={P}")
        quorums = [sorted(S) for S in placement.residency_sets]
    cap = _capacity(weights, P)
    pair_holders: Dict[Tuple[int, int], List[int]] = {}
    block_holders: Dict[int, List[int]] = {}
    for i, S in enumerate(quorums):
        if i in failed_set:
            continue
        sset = set(S)
        for x in sset:
            block_holders.setdefault(x, []).append(i)
            for y in sset:
                if x <= y:
                    pair_holders.setdefault((x, y), []).append(i)

    load = {i: float(schedule.n_pairs) for i in range(P) if i not in failed_set}

    def eff(c: int) -> float:
        return load[c] / cap[c]

    extra: Dict[int, List[Tuple[int, int]]] = {i: [] for i in load}
    fetch: Dict[int, List[Tuple[Tuple[int, int], int, int]]] = {i: [] for i in load}
    for f in sorted(failed_set):
        todo = (pairs.get(f, []) if pairs is not None
                else schedule.global_pairs_of(f))
        for (x, y) in todo:
            key = (min(x, y), max(x, y))
            cands = pair_holders.get(key, [])
            if cands:
                tgt = min(sorted(cands), key=lambda c: (eff(c), c))
                load[tgt] += 1.0
                extra[tgt].append(key)
                continue
            hx = block_holders.get(key[0], [])
            hy = block_holders.get(key[1], [])
            if not hx or not hy:
                lost = key[0] if not hx else key[1]
                raise RuntimeError(
                    f"block {lost} lost: all {schedule.k} holding quorums "
                    "failed; restore from checkpoint")
            # device holding one block pulls the other; a tier-2 pair costs
            # FETCH_LOAD_WEIGHT in the load model (compute + one transfer).
            # hx and hy are disjoint (a holder of both would be tier 1), so
            # the (eff, c) key is a strict total order over the candidates.
            cands2 = sorted([(c, key[1]) for c in hx]
                            + [(c, key[0]) for c in hy])
            tgt, missing = min(cands2, key=lambda t: (eff(t[0]), t[0]))
            src = min(sorted(block_holders[missing]),
                      key=lambda c: (eff(c), c))
            load[tgt] += FETCH_LOAD_WEIGHT
            fetch[tgt].append((key, missing, src))
    return ReassignPlan(
        extra_pairs={i: v for i, v in extra.items() if v},
        fetch_pairs={i: v for i, v in fetch.items() if v},
    )

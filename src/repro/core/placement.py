"""Pluggable block-placement layer: blocks -> device residency + routing.

The paper's cyclic quorums are one point in a design space of all-pairs
data placements.  Hall, Kelly & Tian ("Optimal Data Distribution for
Big-Data All-to-All Comparison using Finite Projective and Affine
Planes", 2023) show plane-based distributions hit the sqrt(P) replication
optimum exactly where generic cyclic difference covers can pay up to
~2*sqrt(P).  This module makes the placement a first-class, swappable
object so the scheduler, engine, serving cover, and elastic rescale all
work over *any* registered placement (DESIGN.md section 10).

A :class:`Placement` maps P block ids onto P devices and answers three
questions:

  * **residency** — ``residency(i)`` is the set of blocks device i keeps
    resident; every unordered block pair (including self-pairs) must be
    co-resident on at least one device (the all-pairs property, paper
    Theorem 1).
  * **ownership** — ``owner_of(x, y)`` names the one canonical device
    that computes pair {x, y}: a partition of all C(P,2) + P unordered
    pairs with per-device load balanced to within one pair.
  * **route structure** — ``shifts`` is the cyclic difference cover
    realizing residency with ``lax.ppermute`` shifts (slot s of device i
    holds block ``(i + shifts[s]) % P``, exactly the layout
    ``core.allpairs.quorum_gather`` produces).  All placements
    registered here are shift-structured; a future non-cyclic placement
    returns ``shifts = None`` and supplies its own data plane.

Registered implementations (``tests/test_placement_conformance.py`` is
the executable interface contract — every registered placement must pass
it for every P where it is defined):

  * ``cyclic``     — :class:`CyclicQuorumPlacement`, the paper's relaxed
    (P,k)-difference sets (``quorum.difference_set``), defined for every
    P >= 1.  Bit-exact with the pre-placement behavior.
  * ``projective`` — :class:`ProjectivePlanePlacement` for
    P = q^2 + q + 1: the lines of PG(2, q) realized cyclically through a
    Singer difference set; replication is *exactly* q + 1, the
    theoretical optimum (k(k-1) + 1 = P with every difference covered
    exactly once — a perfect difference set, verified at construction).
  * ``affine``     — :class:`AffinePlanePlacement` for P = q^2 + q
    (prime-power q): the affine-parameter analog, replication exactly
    q + 1.  See the feasibility note below.
  * ``full``       — :class:`FullReplicationPlacement`: every block on
    every device (``shifts = 0..P-1``), the "all data everywhere" scheme
    the paper improves on, kept as the degenerate oracle; the engine
    routes it to ``allgather_allpairs``.

Affine feasibility note: with P co-equal blocks and devices, replication
q + 1 at P = q^2 + q requires an *almost perfect* cyclic difference
cover — q(q+1) ordered differences for q^2 + q - 1 nonzero residues,
i.e. a single collision.  These exist for q = 2 ({0,1,3} mod 6) and
q = 3 ({0,1,3,7} mod 12) but provably not for q = 4 or q = 5 (the
exact branch-and-bound search is exhaustive there; cf. the covering
number C(20,5,2) = 21 > 20), so ``supports`` reports exactly the
constructible P and ``auto`` falls back to cyclic elsewhere.

Selection: :func:`auto_placement` picks the smallest-replication
placement defined at P (ties prefer ``cyclic``, keeping default behavior
bit-exact), and the ``REPRO_PLACEMENT`` env var overrides it everywhere
a placement is chosen implicitly — mirroring ``REPRO_ALLPAIRS_MODE``.
``REPRO_PLACEMENT=plane`` prefers projective, then affine, then falls
back to cyclic (so a CI matrix can sweep P values where no plane
exists); any other name must be defined at P or selection raises.
"""

from __future__ import annotations

import abc
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from .quorum import (_prime_power_base, difference_set, is_difference_cover,
                     singer_difference_set)
from .scheduler import (CausalSchedule, PairSchedule, _canonical_pairs,
                        build_causal_schedule, build_schedule)

__all__ = [
    "Placement",
    "ShiftPlacement",
    "CyclicQuorumPlacement",
    "ProjectivePlanePlacement",
    "AffinePlanePlacement",
    "FullReplicationPlacement",
    "register_placement",
    "registered_placements",
    "weighted_owner_table",
    "get_placement",
    "supported_placements",
    "auto_placement",
    "plane_placement",
    "resolve_placement",
    "placement_from_env",
]


_REGISTRY: Dict[str, Type["Placement"]] = {}


def register_placement(cls: Type["Placement"]) -> Type["Placement"]:
    """Class decorator: add ``cls`` to the placement registry under
    ``cls.name`` (DESIGN.md section 10).  Registered placements are what
    the conformance suite sweeps and what ``REPRO_PLACEMENT`` / ``auto``
    select among."""
    assert cls.name and cls.name not in ("abstract", "plane", "auto"), cls
    _REGISTRY[cls.name] = cls
    return cls


def registered_placements() -> Dict[str, Type["Placement"]]:
    """Snapshot of the registry: name -> placement class (DESIGN.md
    section 10)."""
    return dict(_REGISTRY)


class Placement(abc.ABC):
    """A data placement of P blocks over P devices (see module docstring).

    Instances are cheap value objects hashed on ``(name, P)`` —
    :func:`get_placement` memoizes them so they are safe lru_cache keys
    for jitted-program caches (serving ``query_fn`` / ``update_fn``).
    """

    name: str = "abstract"

    def __init__(self, P: int):
        if P < 1:
            raise ValueError(f"P must be >= 1, got {P}")
        if not self.supports(P):
            raise ValueError(
                f"{type(self).__name__} ({self.name!r}) is not defined for "
                f"P={P}; check supports(P) or use auto_placement(P)")
        self.P = int(P)

    # -- definition domain ------------------------------------------------

    @classmethod
    @abc.abstractmethod
    def supports(cls, P: int) -> bool:
        """True iff this placement is defined (constructible) for P."""

    # -- residency --------------------------------------------------------

    @property
    def n_blocks(self) -> int:
        """Number of data blocks placed (equal to P for every registered
        placement: block i's canonical owner is device i)."""
        return self.P

    @abc.abstractmethod
    def residency(self, i: int) -> frozenset:
        """The set of global block ids resident on device ``i``."""

    @functools.cached_property
    def residency_sets(self) -> Tuple[frozenset, ...]:
        """``residency(i)`` for every device, as a tuple (memoized)."""
        return tuple(self.residency(i) for i in range(self.P))

    def block_holders(self, b: int) -> Tuple[int, ...]:
        """The devices holding block ``b`` (sorted)."""
        return tuple(i for i, S in enumerate(self.residency_sets) if b in S)

    @functools.cached_property
    def replication(self) -> int:
        """Copies of the most-replicated block — the storage headline."""
        counts = [0] * self.n_blocks
        for S in self.residency_sets:
            for b in S:
                counts[b] += 1
        return max(counts)

    @functools.cached_property
    def max_residency(self) -> int:
        """Largest per-device residency (blocks a device must store)."""
        return max(len(S) for S in self.residency_sets)

    # -- route structure --------------------------------------------------

    @property
    def shifts(self) -> Optional[Tuple[int, ...]]:
        """The cyclic difference cover realizing residency with ppermute
        shifts, or None for a placement with no cyclic route structure."""
        return None

    @property
    def full(self) -> bool:
        """True for full replication — the engine then routes the
        computation through ``allgather_allpairs`` instead of the quorum
        gather/compute/scatter pipeline."""
        return False

    def schedule(self) -> PairSchedule:
        """The SPMD all-pairs schedule over this placement's residency."""
        if self.shifts is None:
            raise NotImplementedError(
                f"placement {self.name!r} has no cyclic route structure; "
                "the shift-based engine cannot schedule it")
        return build_schedule(self.P, placement=self)

    def causal_schedule(self) -> CausalSchedule:
        """The causal (triangular) schedule over this placement."""
        if self.shifts is None:
            raise NotImplementedError(
                f"placement {self.name!r} has no cyclic route structure; "
                "the shift-based engine cannot schedule it")
        return build_causal_schedule(self.P, placement=self)

    # -- ownership --------------------------------------------------------

    @abc.abstractmethod
    def owner_of(self, x: int, y: int, *,
                 weights: Optional[Sequence[float]] = None) -> int:
        """Canonical owner device of unordered block pair {x, y}.

        Must be symmetric (``owner_of(x, y) == owner_of(y, x)``), the
        owner must hold both blocks, and per-device owned-pair counts
        must balance to within one pair (the conformance contract).

        ``weights`` is an optional length-P capacity-weight vector
        (measured device throughput, Rocket's heterogeneity model —
        DESIGN.md section 13): ownership then partitions the pairs
        *proportionally to capacity* via :func:`weighted_owner_table`,
        still assigning every pair to a device holding both blocks.
        None or a uniform vector is bit-identical to the unweighted
        partition.
        """

    # -- identity ---------------------------------------------------------

    def describe(self) -> str:
        """One-line summary for logs/selfchecks."""
        return (f"{self.name}(P={self.P}, replication={self.replication})")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{type(self).__name__}(P={self.P})"

    def __eq__(self, other) -> bool:
        return (isinstance(other, Placement)
                and other.name == self.name and other.P == self.P)

    def __hash__(self) -> int:
        return hash((self.name, self.P))


class ShiftPlacement(Placement):
    """Base for placements realized by a cyclic difference cover A:
    device i holds blocks ``{(i + a) % P : a in A}`` and the engine
    routes with the existing ppermute shifts.  Subclasses supply the
    cover via ``_cover()``."""

    @abc.abstractmethod
    def _cover(self) -> Tuple[int, ...]:
        """The verified difference cover (sorted, residues mod P)."""

    @functools.cached_property
    def shifts(self) -> Tuple[int, ...]:  # type: ignore[override]
        """The verified difference cover, sorted (the ppermute routes)."""
        A = tuple(sorted(a % self.P for a in self._cover()))
        assert is_difference_cover(A, self.P), (self.name, self.P, A)
        return A

    def residency(self, i: int) -> frozenset:
        """Cyclic translate residency: device i holds ``A + i mod P``."""
        return frozenset((i + a) % self.P for a in self.shifts)

    @functools.cached_property
    def replication(self) -> int:  # type: ignore[override]
        """k = |A|: every block lands in exactly k translates (Eq. 13)."""
        return len(self.shifts)

    @functools.cached_property
    def _canonical(self) -> Dict[int, Tuple[int, int]]:
        return _canonical_pairs(self.P, list(self.shifts))

    def owner_of(self, x: int, y: int, *,
                 weights: Optional[Sequence[float]] = None) -> int:
        """The engine-consistent canonical owner: the device whose quorum
        places the pair's canonical lower endpoint at slot ``a_lo`` of
        the per-difference rule (scheduler docstring), with the even-P
        d = P/2 orbit resolved by the keeper rule of
        ``core.allpairs.pair_mask_table`` (the generating device whose
        lower endpoint is the smaller block id keeps it) — so ownership
        here is exactly the pair the engine actually computes post-mask.

        With a non-uniform ``weights`` capacity vector the partition is
        :func:`weighted_owner_table`'s proportional assignment instead
        (DESIGN.md section 13); uniform weights (or None) keep the
        bit-exact historical partition.
        """
        P = self.P
        x, y = x % P, y % P
        if weights is not None:
            w = _validate_weights(weights, P)
            if len(set(w)) > 1:
                return int(weighted_owner_table(self, w)[x, y])
        d = (y - x) % P
        dd = min(d, (P - d) % P)
        a_lo, _ = self._canonical[dd]
        if dd == 0:
            j = x
        elif d == dd == (P - d) % P:      # even-P half orbit: keeper rule
            j = min(x, y)
        else:
            j = x if d == dd else y       # lower endpoint, canonical direction
        return (j - a_lo) % P


# ---------------------------------------------------------------------------
# Weighted ownership (DESIGN.md section 13)
# ---------------------------------------------------------------------------

def _validate_weights(weights: Sequence[float], P: int) -> Tuple[float, ...]:
    """Validated capacity-weight tuple: length P, all positive."""
    w = tuple(float(v) for v in weights)
    if len(w) != P:
        raise ValueError(f"weights must have length P={P}, got {len(w)}")
    if any(v <= 0 for v in w):
        raise ValueError(f"weights must be positive, got {w}")
    return w


@functools.lru_cache(maxsize=128)
def _weighted_owner_table(plc: "Placement",
                          weights: Tuple[float, ...]) -> np.ndarray:
    """The memoized table behind :func:`weighted_owner_table` (placements
    are hashable value objects, so (placement, weights) is a cache key)."""
    P = plc.P
    sets = plc.residency_sets
    total = P * (P + 1) // 2
    wsum = sum(weights)
    target = [total * v / wsum for v in weights]
    ceil_t = [math.ceil(t) for t in target]
    load = [0.0] * P
    table = np.full((P, P), -1, dtype=np.int32)
    cand_of: Dict[Tuple[int, int], List[int]] = {}
    for x in range(P):
        for y in range(x, P):
            # a weighted owner must hold >= 1 of the two blocks (the other
            # is a tier-2 fetch, DESIGN.md section 13); co-resident holders
            # win deficit ties so fetches only happen when capacity demands
            cands = sorted(i for i in range(P)
                           if x in sets[i] or y in sets[i])
            cand_of[(x, y)] = cands
            owner = max(cands, key=lambda c: (
                target[c] - load[c],
                1 if (x in sets[c] and y in sets[c]) else 0,
                -c))
            load[owner] += 1.0
            table[x, y] = table[y, x] = owner
    # repair pass: the greedy can overshoot a ceil target by one pair near
    # the end of the visit order; move pairs from over-ceil devices onto
    # under-ceil candidates until every load fits its ceil target
    for _ in range(2 * P):
        over = [c for c in range(P) if load[c] > ceil_t[c]]
        if not over:
            break
        moved = False
        for c in over:
            for (x, y), cands in sorted(cand_of.items()):
                if table[x, y] != c:
                    continue
                under = [d for d in cands if load[d] + 1 <= ceil_t[d]]
                if under:
                    d = max(under, key=lambda u: (target[u] - load[u], -u))
                    table[x, y] = table[y, x] = d
                    load[c] -= 1.0
                    load[d] += 1.0
                    moved = True
                    if load[c] <= ceil_t[c]:
                        break
        if not moved:  # pragma: no cover - no feasible move left
            break
    return table


def weighted_owner_table(placement: "Placement",
                         weights: Sequence[float]) -> np.ndarray:
    """[P, P] owner table partitioning all unordered block pairs
    proportionally to per-device capacity weights (DESIGN.md section 13
    — Rocket's heterogeneous-throughput direction).

    Deterministic deficit-greedy with a repair pass: pairs are visited
    in canonical ``(x, y)``, ``x <= y`` order and each is assigned to
    the candidate with the largest remaining capacity deficit
    ``target_c - load_c`` (``target_c = total * w_c / sum(w)``); ties
    prefer a co-resident holder, then the smallest device id.  A
    *candidate* is any device holding at least one of the two blocks:
    most pairs are co-resident on exactly one device (λ = 1 on the
    planes), so proportionality is unreachable over both-block holders
    alone — the missing block of a single-block owner rides the same
    tier-2 fetch path the failure recovery uses, which is exactly
    Rocket's "fast devices pull extra data" trade.  A final repair pass
    moves boundary pairs off over-target devices, so per-device loads
    satisfy ``load_c <= ceil(target_c)`` for every registered placement
    (the weighted conformance suite pins it at every P <= 64).  Uniform
    weights reproduce the unweighted ``owner_of`` partition
    bit-identically (the callers short-circuit before reaching here).
    The table is memoized on (placement, weights) — placements are
    hashable value objects.
    """
    w = _validate_weights(weights, placement.P)
    if len(set(w)) <= 1:
        # uniform: the historical partition, bit-exact by construction
        P = placement.P
        table = np.full((P, P), -1, dtype=np.int32)
        for x in range(P):
            for y in range(x, P):
                table[x, y] = table[y, x] = placement.owner_of(x, y)
        return table
    return _weighted_owner_table(placement, w)


# ---------------------------------------------------------------------------
# Registered placements
# ---------------------------------------------------------------------------

@register_placement
class CyclicQuorumPlacement(ShiftPlacement):
    """The paper's cyclic quorums from a relaxed (P,k)-difference set —
    the universal default (defined for every P; optimal k for P <= 36 by
    exact search, Singer where P = q^2+q+1, ~2*sqrt(P) ladder beyond).
    Bit-exact with the pre-placement-layer behavior: ``shifts`` is
    ``difference_set(P)`` itself."""

    name = "cyclic"

    @classmethod
    def supports(cls, P: int) -> bool:
        """Defined for every P >= 1 (the universal fallback)."""
        return P >= 1

    def _cover(self) -> Tuple[int, ...]:
        return tuple(difference_set(self.P))


def _plane_order_projective(P: int) -> Optional[int]:
    """q >= 2 with q^2 + q + 1 == P, else None."""
    q = (math.isqrt(4 * P - 3) - 1) // 2
    for qq in (q, q + 1):
        if qq >= 2 and qq * qq + qq + 1 == P:
            return qq
    return None


def _plane_order_affine(P: int) -> Optional[int]:
    """q >= 2 with q^2 + q == P, else None."""
    q = (math.isqrt(4 * P + 1) - 1) // 2
    for qq in (q, q + 1):
        if qq >= 2 and qq * qq + qq == P:
            return qq
    return None


def _is_perfect_difference_set(A: Tuple[int, ...], P: int) -> bool:
    """Every nonzero residue mod P is a difference of A *exactly once*
    (lambda = 1 — the planar/Singer property, not just a cover)."""
    seen = [0] * P
    for ai in A:
        for aj in A:
            if ai != aj:
                seen[(ai - aj) % P] += 1
    return all(c == 1 for c in seen[1:])


@functools.lru_cache(maxsize=None)
def _projective_cover(P: int) -> Optional[Tuple[int, ...]]:
    """A perfect (q+1)-element difference set mod P = q^2+q+1, or None.

    Singer construction for prime q (a genuinely plane-derived set, which
    may differ from ``difference_set(P)`` — e.g. P = 31); for prime-power
    q the prime-field Singer is unavailable, so fall back to the exact
    search (optimal => perfect here) when P is within its cap.
    """
    q = _plane_order_projective(P)
    if q is None or _prime_power_base(q) is None:
        return None
    A = singer_difference_set(q)
    if A is None:
        cand = difference_set(P)
        A = cand if len(cand) == q + 1 else None
    if A is None:
        return None
    A = tuple(sorted(a % P for a in A))
    return A if _is_perfect_difference_set(A, P) else None


@register_placement
class ProjectivePlanePlacement(ShiftPlacement):
    """Lines of the projective plane PG(2, q) as quorums, P = q^2+q+1.

    The Singer cycle makes the line set cyclic: the P translates of a
    perfect (P, q+1, 1)-difference set are exactly the P lines, every
    pair of blocks (points) is co-resident on exactly one device (line),
    and replication is exactly q + 1 — the sqrt(P) optimum of Hall,
    Kelly & Tian.  Defined for prime-power q with a constructible Singer
    set (q prime, or q = 4 via exact search): P in {7, 13, 21, 31, 57}
    for P <= 64.
    """

    name = "projective"

    @classmethod
    def supports(cls, P: int) -> bool:
        """True iff P = q^2+q+1 with a constructible Singer set."""
        return P >= 1 and _projective_cover(P) is not None

    @property
    def order(self) -> int:
        """The plane order q (replication is q + 1)."""
        return _plane_order_projective(self.P)

    def _cover(self) -> Tuple[int, ...]:
        return _projective_cover(self.P)


@functools.lru_cache(maxsize=None)
def _affine_cover(P: int) -> Optional[Tuple[int, ...]]:
    """An almost-perfect (q+1)-element difference cover mod P = q^2+q,
    or None when none exists (see module docstring feasibility note).

    ``difference_set`` runs the exact branch-and-bound for P <= 36, so a
    q+1-sized result there is a proof of constructibility and a larger
    result a proof of impossibility; beyond the exact cap no affine
    cover is attempted (the ladder fallback is never q+1-sized).
    """
    q = _plane_order_affine(P)
    if q is None or _prime_power_base(q) is None:
        return None
    A = tuple(difference_set(P))
    return A if len(A) == q + 1 else None


@register_placement
class AffinePlanePlacement(ShiftPlacement):
    """Affine-parameter placement, P = q^2 + q, replication exactly q+1.

    The affine analog of the Singer realization: an almost-perfect
    difference cover of size q + 1 mod q^2 + q (q(q+1) ordered
    differences for q^2+q-1 residues — one collision).  Constructible
    for q in {2, 3} (P = 6, 12); provably nonexistent for q in {4, 5}
    and not attempted beyond the exact-search cap, so those P fall back
    to ``cyclic`` under ``auto`` / ``plane`` selection.
    """

    name = "affine"

    @classmethod
    def supports(cls, P: int) -> bool:
        """True iff P = q^2+q with a constructible almost-perfect cover."""
        return P >= 1 and _affine_cover(P) is not None

    @property
    def order(self) -> int:
        """The plane order q (replication is q + 1)."""
        return _plane_order_affine(self.P)

    def _cover(self) -> Tuple[int, ...]:
        return _affine_cover(self.P)


@register_placement
class FullReplicationPlacement(ShiftPlacement):
    """Every block on every device — the "all data everywhere" scheme the
    paper improves on (section 1.1), kept as the degenerate oracle.

    Shift-structured with A = {0..P-1} so every generic consumer (covers,
    reassign, rescale, serving stacks) works unchanged; the batch engine
    special-cases ``full`` and routes through ``allgather_allpairs``.
    The serving cover collapses to a single device.
    """

    name = "full"

    @classmethod
    def supports(cls, P: int) -> bool:
        """Defined for every P >= 1 (the all-gather baseline)."""
        return P >= 1

    @property
    def full(self) -> bool:  # type: ignore[override]
        """True: the batch engine routes through allgather_allpairs."""
        return True

    def _cover(self) -> Tuple[int, ...]:
        return tuple(range(self.P))


# ---------------------------------------------------------------------------
# Selection: registry lookup, auto, env override
# ---------------------------------------------------------------------------

# auto tie-break order: cyclic first keeps default selection bit-exact with
# the pre-placement behavior wherever replication ties (it always does at
# plane-friendly P <= 36, where the exact search is optimal too)
_AUTO_ORDER = ("cyclic", "projective", "affine", "full")


def _selection_order() -> Tuple[str, ...]:
    """Registry names in selection order: the built-in tie-break order
    first, then any later-registered placements alphabetically — so a
    downstream ``@register_placement`` class really is swept by ``auto``
    / ``supported_placements`` without touching this module."""
    extra = sorted(name for name in _REGISTRY if name not in _AUTO_ORDER)
    return tuple(n for n in _AUTO_ORDER if n in _REGISTRY) + tuple(extra)


@functools.lru_cache(maxsize=512)
def get_placement(name: str, P: int) -> Placement:
    """Memoized placement instances — the canonical constructor
    (DESIGN.md section 10).  Raises ``ValueError`` for unknown names or
    P outside the definition domain."""
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown placement {name!r}; registered: {sorted(_REGISTRY)}")
    return cls(P)


def supported_placements(P: int) -> List[Placement]:
    """All registered placements defined at P, in selection order
    (DESIGN.md section 10)."""
    return [get_placement(name, P) for name in _selection_order()
            if _REGISTRY[name].supports(P)]


def auto_placement(P: int) -> Placement:
    """The smallest-replication placement defined at P, ties -> cyclic
    (DESIGN.md section 10 "Selection").

    Deliberately not memoized on P alone: the winner depends on the
    registry, so a placement registered after a first selection still
    takes effect (the per-placement construction underneath is cached).
    """
    best = None
    for rank, name in enumerate(_selection_order()):
        if _REGISTRY[name].supports(P):
            plc = get_placement(name, P)
            key = (plc.replication, rank)
            if best is None or key < best[0]:
                best = (key, plc)
    assert best is not None, P  # cyclic supports every P >= 1
    return best[1]


def plane_placement(P: int) -> Optional[Placement]:
    """The plane placement at P — projective first, then affine — or
    None when neither plane is defined at P (DESIGN.md section 10)."""
    for name in ("projective", "affine"):
        if _REGISTRY[name].supports(P):
            return get_placement(name, P)
    return None


def resolve_placement(spec, P: int) -> Placement:
    """Resolve a placement spec for P (DESIGN.md section 10 "Selection").

    ``spec`` may be a Placement instance (P must match), a registered
    name, ``"auto"`` (smallest replication), ``"plane"`` (projective ->
    affine -> cyclic fallback, so matrix sweeps can include plane-less
    P), or None/"" (same as ``"auto"``).
    """
    if isinstance(spec, Placement):
        if spec.P != P:
            raise ValueError(f"placement {spec.describe()} does not match P={P}")
        return spec
    name = (spec or "auto").strip().lower()
    if name == "auto":
        return auto_placement(P)
    if name == "plane":
        return plane_placement(P) or get_placement("cyclic", P)
    return get_placement(name, P)


def placement_from_env(P: int) -> Placement:
    """The placement selected by ``REPRO_PLACEMENT`` (default ``auto``;
    DESIGN.md section 10 "Selection").

    Mirrors ``core.sweep.env_mode_override``: read at selection time
    through the core/env.py registry (setting the env var after import
    works; already-compiled programs keep their baked-in placement), and
    unknown values raise instead of silently falling back.  With the
    variable unset, ``auto`` resolves to the cyclic construction at
    every P (the tie-break keeps default behavior bit-exact).
    """
    from . import env as env_mod
    return resolve_placement(env_mod.read_knob("REPRO_PLACEMENT"), P)

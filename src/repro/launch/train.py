"""Training driver: config -> mesh -> sharded init -> step loop with async
checkpointing, restart, and failure handling.

CPU-scale usage (examples/train_lm.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real pod the same entry point runs under the production mesh
(--mesh data,model=16,16); this container runs the smoke configs on 1 CPU
device.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..configs.registry import ARCHS
from ..data import DataConfig, make_pipeline
from ..models import lm
from ..optim import AdamWConfig, adamw_init
from . import steps as steps_mod
from .mesh import make_mesh


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, ckpt_every: int = 25,
          mesh_spec: str | None = None, lr: float = 3e-4,
          log_every: int = 10, resume: bool = True, seed: int = 0):
    """Train ``arch`` for ``steps`` on synthetic data; returns losses."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.encdec:
        raise SystemExit("use examples/train_lm.py families; enc-dec training "
                         "is exercised by tests/smoke")

    if mesh_spec:
        names, sizes = zip(*(kv.split("=") for kv in mesh_spec.split(",")))
        mesh = make_mesh(tuple(int(s) for s in sizes), tuple(names))
    else:
        mesh = make_mesh((len(jax.devices()),), ("data",))
    cfg = steps_mod.prepare_config(cfg, mesh, seq_shard=False)

    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(2, steps // 20),
                          total_steps=steps)
    train_step = steps_mod.build_train_step(cfg, opt_cfg)

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume:
        try:
            (params, opt_state), start = mgr.restore_latest((params, opt_state))
            print(f"resumed from step {start}")
        except FileNotFoundError:
            pass

    dcfg = DataConfig(seed=seed, vocab_size=cfg.vocab_size, batch=batch,
                      seq_len=seq, frontend=cfg.frontend,
                      d_model=cfg.d_model, vis_tokens=min(cfg.vis_tokens, 8),
                      dec_ratio=cfg.dec_ratio)
    pipe = make_pipeline(dcfg, start_step=start)

    jstep = jax.jit(train_step, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start, steps):
            batch_arrs = next(pipe)
            params, opt_state, metrics = jstep(params, opt_state, batch_arrs)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
            if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, (params, opt_state))
    if mgr:
        mgr.wait()
        mgr.save_async(steps, (params, opt_state))
        mgr.wait()
    return losses


def main(argv=None):
    """CLI driver for :func:`train`."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", dest="mesh_spec", default=None,
                    help='e.g. "data=16,model=16"')
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          mesh_spec=args.mesh_spec, lr=args.lr)


if __name__ == "__main__":
    main()

"""Request-batching driver for the online query subsystem.

Simulates the serving tier in front of ``serving.ServingCorpus``: requests
drain from a queue into fixed-size microbatches (the last one padded with
zero queries whose results are dropped), each microbatch runs one
cover-routed top-k program, and steady-state throughput is reported after
a warmup that absorbs compile time.  ``--stream-every`` interleaves
streamed block replacements with query traffic to exercise the online
update path under load.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.query_serve --requests 512
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..obs import trace as obs_trace
from ..serving import ServingCorpus


def serve_queries(sc: ServingCorpus, queries: np.ndarray, *, microbatch: int,
                  topk: int, mode: str = "auto", metric: str = "dot",
                  use_kernel: bool = False, warmup_batches: int = 2,
                  stream_every: int = 0, rng=None):
    """Drain ``queries`` [R, d] through microbatches; returns (scores
    [R, topk], ids [R, topk], queries/sec over the steady-state tail)."""
    R, d = queries.shape
    rng = rng if rng is not None else np.random.default_rng(0)
    vals_out, idx_out = [], []
    n_batches = -(-R // microbatch)
    warmup_batches = min(warmup_batches, n_batches - 1)  # measure >= 1 batch
    done = served = stream_updates = 0
    t0 = time.perf_counter() if warmup_batches == 0 else None
    for bi in range(n_batches):
        q = queries[done:done + microbatch]
        n = len(q)
        if n < microbatch:  # pad the tail batch; padded rows are dropped
            q = np.concatenate(
                [q, np.zeros((microbatch - n, d), np.float32)])
        if stream_every and bi and bi % stream_every == 0:
            # online update under load: re-stream a random block with
            # fresh vectors through the ppermute push path
            b = int(rng.integers(sc.P))
            sc.replace_block(b, rng.normal(size=(sc.block, d))
                             .astype(np.float32))
            stream_updates += 1
        v, i = sc.query(q, topk=topk, mode=mode, metric=metric,
                        use_kernel=use_kernel)
        v, i = np.asarray(v), np.asarray(i)  # block until ready
        vals_out.append(v[:n])
        idx_out.append(i[:n])
        done += n
        if bi + 1 == warmup_batches:         # compile/warm caches absorbed
            t0 = time.perf_counter()
            served = 0
        elif bi + 1 > warmup_batches:
            served += n
    dt = (time.perf_counter() - t0) if t0 and served else float("nan")
    qps = served / dt if served else float("nan")
    tr = obs_trace.get_tracer()
    if tr:
        tr.count("serve.batches", n_batches)
        tr.count("serve.queries", R)
        tr.count("serve.stream_updates", stream_updates)
    return np.concatenate(vals_out), np.concatenate(idx_out), qps


def main(argv=None):
    """CLI driver: steady-state queries/sec report (see module doc)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096, help="corpus rows")
    ap.add_argument("--d", type=int, default=64, help="embedding dim")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "batched", "overlap", "scan"])
    ap.add_argument("--metric", default="dot", choices=["dot", "l2"])
    ap.add_argument("--kernel", action="store_true",
                    help="route the batched local step through the fused "
                         "Pallas query_score kernel")
    ap.add_argument("--stream-every", type=int, default=0,
                    help="re-stream a random block every N microbatches")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    P = len(jax.devices())
    mesh = jax.make_mesh((P,), ("q",))
    rng = np.random.default_rng(args.seed)
    corpus = rng.normal(size=(args.n, args.d)).astype(np.float32)
    queries = rng.normal(size=(args.requests, args.d)).astype(np.float32)

    sc = ServingCorpus.build(corpus, mesh)
    plan = sc.plan
    print(f"corpus N={args.n} d={args.d} -> P={P} blocks of {sc.block} "
          f"(quorum k={plan.k}, cover {plan.n_cover}/{P} devices)")
    vals, idx, qps = serve_queries(
        sc, queries, microbatch=args.microbatch, topk=args.topk,
        mode=args.mode, metric=args.metric, use_kernel=args.kernel,
        stream_every=args.stream_every, rng=rng)
    print(f"served {args.requests} requests in microbatches of "
          f"{args.microbatch}: {qps:.1f} queries/sec steady-state "
          f"(mode={args.mode} kernel={args.kernel})")
    print(f"first request top-{args.topk}: ids={idx[0].tolist()} "
          f"scores={np.round(vals[0], 3).tolist()}")
    return vals, idx


if __name__ == "__main__":
    main()

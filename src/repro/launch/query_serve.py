"""Request-serving driver: a thin client of the continuous batcher.

Historically this module *was* the serving loop — a synchronous
fixed-microbatch drain.  It is now a thin client of
``serving.batching.BatchScheduler`` (DESIGN.md section 15): each
microbatch of requests is submitted to the scheduler's admission queue
and one scheduler iteration packs and launches it, with
``pad_queries_to=microbatch`` pinning the legacy launch shape so the
drain contract stays bit-exact with the original loop (and with
per-microbatch ``ServingCorpus.query`` calls).  ``--stream-every``
interleaves streamed block replacements with query traffic to exercise
the online update path under load.

Throughput accounting (DESIGN.md section 15.4): steady-state qps is
measured after a warmup that absorbs compile time, and the blocking
stream updates are timed *separately* and excluded from the query
window — so ``--stream-every`` no longer deflates the reported query
throughput; both figures are printed.  Per-request p50/p99 latency
comes from the scheduler's latency trace.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.query_serve --requests 512
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..obs import trace as obs_trace
from ..serving import ServingCorpus
from ..serving.batching import BatchScheduler, latency_summary


def serve_queries(sc: ServingCorpus, queries: np.ndarray, *, microbatch: int,
                  topk: int, mode: str = "auto", metric: str = "dot",
                  use_kernel: bool = False, warmup_batches: int = 2,
                  stream_every: int = 0, rng=None,
                  scheduler: BatchScheduler | None = None):
    """Drain ``queries`` [R, d] through the continuous batcher in
    fixed-size microbatches; returns (scores [R, topk], ids [R, topk],
    queries/sec over the steady-state tail).

    Each microbatch is submitted as ``n`` top-k requests and resolved by
    one scheduler iteration, so results are bit-identical to the
    original per-microbatch ``sc.query`` loop (the launch payload is the
    same zero-padded [microbatch, d] array).  The qps window starts
    after ``warmup_batches`` and excludes the separately-timed stream
    updates (DESIGN.md section 15.4); pass ``scheduler`` to reuse an
    externally-built :class:`BatchScheduler` (its latency trace then
    covers this drain).
    """
    R, d = queries.shape
    rng = rng if rng is not None else np.random.default_rng(0)
    sched = scheduler if scheduler is not None else BatchScheduler(
        sc, max_batch=microbatch, mode=mode, use_kernel=use_kernel,
        pad_queries_to=microbatch)
    vals_out, idx_out = [], []
    n_batches = -(-R // microbatch)
    warmup_batches = min(warmup_batches, n_batches - 1)  # measure >= 1 batch
    done = served = stream_updates = 0
    stream_s = stream_s_measured = 0.0
    t0 = time.perf_counter() if warmup_batches == 0 else None
    for bi in range(n_batches):
        q = queries[done:done + microbatch]
        n = len(q)
        if stream_every and bi and bi % stream_every == 0:
            # online update under load: re-stream a random block with
            # fresh vectors through the ppermute push path.  Timed
            # separately — the blocking push must not deflate query qps.
            ts = time.perf_counter()
            b = int(rng.integers(sc.P))
            sc.replace_block(b, rng.normal(size=(sc.block, d))
                             .astype(np.float32))
            dt_stream = time.perf_counter() - ts
            stream_s += dt_stream
            if t0 is not None:
                stream_s_measured += dt_stream
            stream_updates += 1
        reqs = [sched.submit(q[j], kind="topk", topk=topk, metric=metric)
                for j in range(n)]
        sched.step()
        results = [r.result(timeout=0) for r in reqs]
        vals_out.append(np.stack([res.scores for res in results]))
        idx_out.append(np.stack([res.indices for res in results]))
        done += n
        if bi + 1 == warmup_batches:         # compile/warm caches absorbed
            t0 = time.perf_counter()
            served = 0
        elif warmup_batches == 0 or bi + 1 > warmup_batches:
            served += n
    dt = ((time.perf_counter() - t0 - stream_s_measured)
          if t0 is not None and served else float("nan"))
    qps = served / dt if served and dt > 0 else float("nan")
    tr = obs_trace.get_tracer()
    if tr:
        tr.count("serve.batches", n_batches)
        tr.count("serve.queries", R)
        tr.count("serve.stream_updates", stream_updates)
        if stream_s:
            tr.count("serve.stream_update_s", stream_s)
    return np.concatenate(vals_out), np.concatenate(idx_out), qps


def main(argv=None):
    """CLI driver: steady-state queries/sec + per-request p50/p99 report
    (see module doc)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096, help="corpus rows")
    ap.add_argument("--d", type=int, default=64, help="embedding dim")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "batched", "overlap", "scan"])
    ap.add_argument("--metric", default="dot", choices=["dot", "l2"])
    ap.add_argument("--kernel", action="store_true",
                    help="route the batched local step through the fused "
                         "Pallas query_score kernel")
    ap.add_argument("--stream-every", type=int, default=0,
                    help="re-stream a random block every N microbatches")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    P = len(jax.devices())
    mesh = jax.make_mesh((P,), ("q",))
    rng = np.random.default_rng(args.seed)
    corpus = rng.normal(size=(args.n, args.d)).astype(np.float32)
    queries = rng.normal(size=(args.requests, args.d)).astype(np.float32)

    sc = ServingCorpus.build(corpus, mesh)
    plan = sc.plan
    print(f"corpus N={args.n} d={args.d} -> P={P} blocks of {sc.block} "
          f"(quorum k={plan.k}, cover {plan.n_cover}/{P} devices)")
    sched = BatchScheduler(sc, max_batch=args.microbatch, mode=args.mode,
                           use_kernel=args.kernel,
                           pad_queries_to=args.microbatch)
    t_start = time.perf_counter()
    vals, idx, qps = serve_queries(
        sc, queries, microbatch=args.microbatch, topk=args.topk,
        mode=args.mode, metric=args.metric, use_kernel=args.kernel,
        stream_every=args.stream_every, rng=rng, scheduler=sched)
    wall = time.perf_counter() - t_start
    print(f"served {args.requests} requests in microbatches of "
          f"{args.microbatch}: {qps:.1f} queries/sec steady-state "
          f"(mode={args.mode} kernel={args.kernel})")
    lat = latency_summary(sched.latencies_s)
    if lat.get("n"):
        print(f"per-request latency: p50={lat['p50_s'] * 1e3:.2f}ms "
              f"p99={lat['p99_s'] * 1e3:.2f}ms over {int(lat['n'])} "
              f"requests ({wall:.2f}s wall)")
    if args.stream_every:
        tr = obs_trace.get_tracer()
        detail = (f" ({tr.counter_total('serve.stream_update_s'):.3f}s "
                  "total)" if tr else "")
        print(f"stream updates: every {args.stream_every} batches, timed "
              f"separately and excluded from the qps window{detail}")
    print(f"first request top-{args.topk}: ids={idx[0].tolist()} "
          f"scores={np.round(vals[0], 3).tolist()}")
    return vals, idx


if __name__ == "__main__":
    main()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective-schedule data.

The XLA_FLAGS default below MUST stay ahead of every jax import: jax
locks the device count at first initialization, and the 512 placeholder
host devices exist only inside this process.  It applies ONLY when this
module is the entrypoint (``python -m repro.launch.dryrun``) and only if
XLA_FLAGS is not already set — importing dryrun as a library leaves the
environment untouched (tests and benches see the single real CPU
device), and a user-set XLA_FLAGS always wins (run with 512 devices
unset if you want the full production meshes).

Per cell this produces:
  * full compile  — the real scanned model; proves sharding coherence and
    gives memory_analysis (argument/temp bytes per device).
  * cost compiles — unrolled 1- and 2-superblock variants; XLA's
    cost_analysis does NOT multiply while-loop trip counts (verified), so
    FLOPs/bytes/collective-bytes are extrapolated linearly:
        cost(n_sup) = cost(1) + (n_sup - 1) * (cost(2) - cost(1))
    Collective bytes are parsed from the optimized HLO (operand sizes of
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).

Usage:
  python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out results/dryrun]
"""

import os


def _apply_default_xla_flags(is_entrypoint: bool) -> bool:
    """Install the 512-placeholder-device XLA_FLAGS, but only when this
    module IS the entrypoint (``python -m repro.launch.dryrun``) and the
    user has not set XLA_FLAGS themselves — importing dryrun as a
    library must never mutate the environment (tests and benches need
    the single real CPU device), and a user-chosen device count must
    never be clobbered.  Returns whether the default was applied.
    """
    if is_entrypoint and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        return True
    return False


_apply_default_xla_flags(__name__ == "__main__")

import argparse
import dataclasses
import json
import re
import sys
import time
from pathlib import Path
from typing import Any, Dict

import jax
import numpy as np

from ..configs import SHAPES, get_config, shape_cells
from ..configs.registry import ARCHS
from ..models import lm, whisper
from ..optim import AdamWConfig
from . import steps as steps_mod
from .mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(tok_dtype)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * b


_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUP_LIST_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Per-device wire bytes of every collective, derived from the op's
    RESULT shape (optimized HLO prints operands untyped) and replica-group
    size g:
      all-gather       wire = result * (g-1)/g      (operand = result/g)
      all-reduce       wire = 2 * result * (g-1)/g  (rs + ag ring)
      reduce-scatter   wire = result * (g-1)        (operand = result*g)
      all-to-all       wire = result * (g-1)/g
      collective-permute wire = result
    Shapes here are already per-device (SPMD-partitioned module).
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    out["wire_total"] = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b([a-z\-]+(?:-start)?)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        base = next((c for c in _COLLECTIVES
                     if op == c or op == c + "-start"), None)
        if base is None:
            continue
        shapes = _SHAPE_RE.findall(rhs[: opm.start()])  # result type(s)
        rbytes = sum(_shape_bytes(t, d) for t, d in shapes)
        g = _group_size(stripped)
        if base == "all-gather":
            wire = rbytes * (g - 1) // max(g, 1)
        elif base == "all-reduce":
            wire = 2 * rbytes * (g - 1) // max(g, 1)
        elif base == "reduce-scatter":
            wire = rbytes * (g - 1)
        elif base == "all-to-all":
            wire = rbytes * (g - 1) // max(g, 1)
        else:  # collective-permute
            wire = rbytes
        out[base] += wire
        out["count"] += 1
        out["wire_total"] += wire
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _mem_dict(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }


def _reduced_layers(cfg, n_sup: int):
    """Config with n_sup superblocks, unrolled scans (for cost compiles)."""
    pat_len = len(cfg.pattern())
    return dataclasses.replace(
        cfg, n_layers=pat_len * n_sup,
        n_enc_layers=(n_sup if cfg.encdec else cfg.n_enc_layers),
        unroll_inner=True, scan_layers=False)


def _jit_for_cell(cfg, shape, mesh, opt_cfg, *, accum: int = 1):
    """Build (jitted fn, example args as SDS) for a cell's kind."""
    from jax.sharding import NamedSharding as NS

    ns = lambda spec: NS(mesh, spec)  # noqa: E731
    p_specs, o_specs = steps_mod.param_and_opt_specs(cfg, mesh)
    params_sds = steps_mod.param_shapes(cfg)

    if shape.kind == "train":
        batch_sds, batch_specs_ = steps_mod.batch_specs(cfg, shape, mesh,
                                                        with_labels=True)
        opt_sds = steps_mod.opt_shapes(params_sds)
        fn = steps_mod.build_train_step(cfg, opt_cfg, accum=accum)
        jfn = jax.jit(fn, in_shardings=(
            jax.tree.map(ns, p_specs),
            jax.tree.map(ns, o_specs),
            jax.tree.map(ns, batch_specs_)),
            donate_argnums=(0, 1))
        return jfn, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        batch_sds, batch_specs_ = steps_mod.batch_specs(cfg, shape, mesh,
                                                        with_labels=False)
        fn = steps_mod.build_prefill_step(cfg)
        jfn = jax.jit(fn, in_shardings=(
            jax.tree.map(ns, p_specs), jax.tree.map(ns, batch_specs_)))
        return jfn, (params_sds, batch_sds)

    # decode
    state_sds, state_specs, tok_sds, tok_spec = steps_mod.decode_state_specs(
        cfg, shape, mesh)
    fn = steps_mod.build_serve_step(cfg)
    jfn = jax.jit(fn, in_shardings=(
        jax.tree.map(ns, p_specs),
        jax.tree.map(ns, state_specs),
        ns(tok_spec)),
        donate_argnums=(1,))
    return jfn, (params_sds, state_sds, tok_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             cost_variants: bool = True, verbose: bool = True,
             overrides: Dict[str, Any] | None = None) -> Dict[str, Any]:
    """Compile one (arch, shape) cell; returns its metrics dict."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    base_cfg = get_config(arch)
    if overrides:
        base_cfg = dataclasses.replace(base_cfg, **overrides)
    opt_cfg = AdamWConfig()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "kind": shape.kind,
    }

    # ---- full compile (sharding + memory proof) --------------------------
    # Auto-fit: grad-accumulation microbatching is the activation-memory
    # knob; double until the per-device footprint fits HBM (v5e: 16 GiB).
    HBM_BUDGET = 15.5 * 2 ** 30
    cfg = steps_mod.prepare_config(base_cfg, mesh)
    dp = int(np.prod([mesh.shape[a] for a in cfg.dp_axes]))
    max_accum = max(1, shape.global_batch // dp) if shape.kind == "train" else 1
    accum = 1
    t0 = time.time()
    while True:
        with mesh:
            jfn, args = _jit_for_cell(cfg, shape, mesh, opt_cfg, accum=accum)
            lowered = jfn.lower(*args)
            compiled = lowered.compile()
        mem = _mem_dict(compiled)
        footprint = (mem["argument_bytes"] + mem["temp_bytes"]
                     + mem["output_bytes"] - mem["alias_bytes"])
        if footprint <= HBM_BUDGET or accum * 2 > max_accum:
            break
        accum *= 2
    result["compile_s"] = round(time.time() - t0, 1)
    result["accum"] = accum
    result["memory"] = mem
    result["fits_hbm"] = bool(footprint <= HBM_BUDGET)
    result["footprint_bytes"] = int(footprint)
    result["cost_raw"] = _cost_dict(compiled)   # undercounts scans; reference
    result["collectives_raw"] = collective_bytes(compiled.as_text())

    if verbose:
        print(f"[{arch} x {shape_name} mp={multi_pod}] compiled in "
              f"{result['compile_s']}s; accum={accum} "
              f"args={mem['argument_bytes']/2**30:.2f}GiB "
              f"temp={mem['temp_bytes']/2**30:.2f}GiB "
              f"fits={result['fits_hbm']}", flush=True)

    # ---- cost extrapolation compiles -------------------------------------
    # cost(n_sup) = cost(0) + n_sup * (cost(1) - cost(0)): the 0-superblock
    # compile isolates the embed/head/optimizer base, the 1-superblock
    # compile (inner scans unrolled so trip counts are visible) gives the
    # per-superblock delta.  (Equivalent to the (1,2) scheme but the heavy
    # unrolled compile happens once, not twice.)
    if cost_variants:
        n_sup = cfg.n_superblocks
        costs = {}
        for n in (0, 1):
            ccfg = steps_mod.prepare_config(_reduced_layers(base_cfg, n), mesh,
                                            unroll_inner=True)
            with mesh:
                jfn, args = _jit_for_cell(ccfg, shape, mesh, opt_cfg)
                comp = jfn.lower(*args).compile()
            costs[n] = {**_cost_dict(comp),
                        "coll": collective_bytes(comp.as_text())}
        def _extrap(key):
            c0, c1 = costs[0][key], costs[1][key]
            return c0 + n_sup * (c1 - c0)
        coll = {k: costs[0]["coll"][k] + n_sup *
                (costs[1]["coll"][k] - costs[0]["coll"][k])
                for k in costs[0]["coll"]}
        result["cost"] = {"flops": _extrap("flops"), "bytes": _extrap("bytes"),
                          "collectives": coll,
                          "per_superblock": costs, "n_superblocks": n_sup}

    # model flops (6ND / 6 N_active D)
    mod = whisper if cfg.encdec else lm
    n_active = (whisper.count_params(cfg) if cfg.encdec
                else lm.count_active_params(cfg))
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch  # one token per sequence
    mult = 6 if shape.kind == "train" else 2
    result["model_flops"] = float(mult * n_active * tokens)
    result["tokens"] = tokens
    return result


def main(argv=None):
    """CLI driver (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[*ARCHS], default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (hillclimb runs), e.g. "
                         "--override ssm_chunk=64 --override fsdp=False")
    args = ap.parse_args(argv)

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        key, val = ov.split("=", 1)
        overrides[key] = json.loads(val.lower()) if val.lower() in (
            "true", "false") else (int(val) if val.lstrip("-").isdigit()
                                   else val)

    results = []
    done = set()
    if args.all and args.out and Path(args.out).exists():
        results = [c for c in json.loads(Path(args.out).read_text())
                   if "error" not in c]
        done = {(c["arch"], c["shape"], c["multi_pod"]) for c in results}
        print(f"resuming: {len(done)} cells already recorded")
    if args.all:
        for arch in ARCHS:
            for shape in shape_cells(arch):
                for mp in (False, True):
                    if (arch, shape.name, mp) in done:
                        continue
                    try:
                        results.append(run_cell(arch, shape.name, multi_pod=mp,
                                                cost_variants=not args.no_cost))
                    except Exception as e:  # record, keep sweeping
                        print(f"FAILED [{arch} x {shape.name} mp={mp}]: "
                              f"{type(e).__name__}: {str(e)[:300]}", flush=True)
                        results.append({"arch": arch, "shape": shape.name,
                                        "multi_pod": mp,
                                        "error": f"{type(e).__name__}: {str(e)[:500]}"})
                    if args.out:  # checkpoint partial results
                        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
                        Path(args.out).write_text(json.dumps(results, indent=1))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        results.append(run_cell(args.arch, args.shape,
                                multi_pod=args.multi_pod,
                                cost_variants=not args.no_cost,
                                overrides=overrides))

    if args.out:
        path = Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(results, indent=1))
        print(f"wrote {path}")
    else:
        print(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()

"""Serving driver: batched greedy decoding against a sharded KV cache.

examples/serve_lm.py drives this on a smoke config; the decode_32k /
long_500k dry-run cells lower the same serve_step on the production meshes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.registry import ARCHS
from ..models import lm
from . import steps as steps_mod
from .mesh import make_mesh


def serve(arch: str, *, smoke: bool = True, batch: int = 4, prompt_len: int = 16,
          gen_len: int = 32, seed: int = 0, greedy: bool = True):
    """Prefill + decode ``gen_len`` tokens with the arch's LM."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving is exercised in tests (whisper)")
    mesh = make_mesh((len(jax.devices()),), ("data",))
    cfg = steps_mod.prepare_config(cfg, mesh, seq_shard=False)

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen_len
    state = lm.init_decode_state(cfg, batch, max_len)
    step = jax.jit(steps_mod.build_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len))
    toks = jnp.asarray(prompt[:, :1], jnp.int32)
    out = [np.asarray(toks)]
    t0 = time.time()
    with mesh:
        for t in range(max_len - 1):
            logits, state = step(params, state, toks)
            if t + 1 < prompt_len:           # teacher-forced prompt phase
                toks = jnp.asarray(prompt[:, t + 1:t + 2], jnp.int32)
            else:                            # greedy generation
                toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(toks))
    dt = time.time() - t0
    seqs = np.concatenate(out, axis=1)
    tps = batch * (max_len - 1) / dt
    print(f"decoded {batch}x{max_len} tokens in {dt:.2f}s ({tps:.1f} tok/s)")
    return seqs


def main(argv=None):
    """CLI driver for :func:`serve`."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args(argv)
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen_len=args.gen_len)


if __name__ == "__main__":
    main()

"""Step builders + ShapeDtypeStruct input specs for every (arch x shape) cell.

Three lowered entry points per the shape kinds:
  train   -> train_step(params, opt_state, batch)   (loss, grads, AdamW)
  prefill -> prefill_step(params, batch)            (last-position logits)
  decode  -> serve_step(params, state, tokens)      (one token, cached)

All functions are pure and jit-able; the dry-run lowers them with
ShapeDtypeStruct stand-ins (no allocation) under the production mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as PS

from ..models import lm, whisper
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update
from .mesh import dp_axes, resolve_spec_tree

Tree = Any


def model_module(cfg: ModelConfig):
    """The model family module (lm or whisper) for this config."""
    return whisper if cfg.encdec else lm


def prepare_config(cfg: ModelConfig, mesh: Mesh, *, unroll_inner=False,
                   seq_shard=True) -> ModelConfig:
    """Launcher-side config fixup: wire mesh axes into the model."""
    return dataclasses.replace(
        cfg, dp_axes=dp_axes(mesh), seq_shard=seq_shard,
        unroll_inner=unroll_inner)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, accum: int = 1):
    """One optimizer step; ``accum`` > 1 scans gradient-accumulation
    microbatches (the activation-memory knob for the big train cells — see
    EXPERIMENTS.md section Dry-run for the per-cell choice)."""
    mod = model_module(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: mod.loss_fn(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            split = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
                batch)

            def micro(carry, mb):
                gacc, loss_acc = carry
                (loss, metrics), g = grads_of(params, mb)
                gacc = jax.tree.map(
                    lambda acc, gg: acc + gg.astype(jnp.float32) / accum,
                    gacc, g)
                return (gacc, loss_acc + loss / accum), metrics

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_all = jax.lax.scan(
                micro, (gacc0, jnp.zeros((), jnp.float32)), split)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig):
    """Returns last-position logits (the sampled-token distribution)."""
    if cfg.encdec:
        def prefill_step(params, batch):
            memory = whisper.encode(cfg, params, batch["frames"])
            logits = whisper.decode_train(cfg, params, batch["tokens"], memory)
            return logits[:, -1]
        return prefill_step

    def prefill_step(params, batch):
        x, _aux = lm.forward_hidden(cfg, params, batch)
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return (x[:, -1] @ unembed).astype(jnp.float32)

    return prefill_step


def build_serve_step(cfg: ModelConfig):
    """One-token decode step closure over the model family."""
    mod = model_module(cfg)

    def serve_step(params, state, tokens):
        return mod.decode_step(cfg, params, state, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct + PartitionSpec), per shape kind
# ---------------------------------------------------------------------------

def _dp(mesh: Mesh) -> Tuple[str, ...]:
    return dp_axes(mesh)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp(mesh)]))


def _ax_if_div(n: int, axes, mesh: Mesh):
    sz = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                              else (axes,))]))
    return axes if n % sz == 0 and n >= sz else None


def batch_specs(cfg: ModelConfig, shape, mesh: Mesh, *, with_labels: bool):
    """ShapeDtypeStructs + PartitionSpecs for a train/prefill batch."""
    B, T = shape.global_batch, shape.seq_len
    dp = _ax_if_div(B, _dp(mesh), mesh)
    sds: Dict[str, jax.ShapeDtypeStruct] = {}
    specs: Dict[str, PS] = {}
    if cfg.frontend == "audio_frames":
        Td = max(1, T // cfg.dec_ratio)
        sds["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
        specs["frames"] = PS(dp, _ax_if_div(T, "model", mesh), None)
        sds["tokens"] = jax.ShapeDtypeStruct((B, Td), jnp.int32)
        specs["tokens"] = PS(dp, None)
        if with_labels:
            sds["labels"] = jax.ShapeDtypeStruct((B, Td), jnp.int32)
            specs["labels"] = PS(dp, None)
        return sds, specs
    Tt = T
    if cfg.frontend == "vision_patches":
        vis = min(cfg.vis_tokens, T // 2)
        Tt = T - vis
        sds["vision_embeds"] = jax.ShapeDtypeStruct((B, vis, cfg.d_model),
                                                    jnp.bfloat16)
        specs["vision_embeds"] = PS(dp, None, None)
    sds["tokens"] = jax.ShapeDtypeStruct((B, Tt), jnp.int32)
    specs["tokens"] = PS(dp, None)
    if with_labels:
        sds["labels"] = jax.ShapeDtypeStruct((B, Tt), jnp.int32)
        specs["labels"] = PS(dp, None)
    return sds, specs


def _cache_spec(cfg: ModelConfig, shape, mesh: Mesh, rank5: bool = True) -> PS:
    """KV cache [n_sup, B, S, KV, hd] sharding for a decode cell.

    B over dp when divisible (decode_32k); otherwise S over dp (long_500k).
    Head sharding: KV axis over model if divisible, else head_dim (always a
    multiple of 16 in the assigned archs).
    """
    B = shape.global_batch
    dp = _ax_if_div(B, _dp(mesh), mesh)
    seq_ax = None if dp is not None else _dp(mesh)
    kv_ax = _ax_if_div(cfg.n_kv_heads, "model", mesh)
    hd_ax = None if kv_ax is not None else "model"
    return PS(None, dp, seq_ax, kv_ax, hd_ax)


def decode_state_specs(cfg: ModelConfig, shape, mesh: Mesh):
    """(state ShapeDtypeStruct tree, state PartitionSpec tree, token specs)."""
    B, S = shape.global_batch, shape.seq_len
    dp = _ax_if_div(B, _dp(mesh), mesh)

    if cfg.encdec:
        params_sds = jax.eval_shape(
            functools.partial(whisper.init_params, cfg), jax.random.key(0))
        mem_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
        max_dec = 1024
        state_sds = jax.eval_shape(
            lambda p, m: whisper.init_decode_state(cfg, p, B, max_dec, m),
            params_sds, mem_sds)
        cache = _cache_spec(cfg, shape, mesh)
        state_specs = {
            "pos": PS(),
            "k": cache, "v": cache,
            # cross K/V [L, B, S_enc, KV, hd]: S_enc over dp when B == 1
            "xk": cache, "xv": cache,
        }
        tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return state_sds, state_specs, tok_sds, PS(dp, None)

    state_sds = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, B, S))
    cache = _cache_spec(cfg, shape, mesh)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    layer_specs: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.pattern()):
        if kind == "A":
            layer_specs[f"pos{j}"] = {"k": cache, "v": cache}
        else:
            layer_specs[f"pos{j}"] = {
                "conv": PS(None, dp, None, _ax_if_div(conv_ch, "model", mesh)),
                "ssm": PS(None, dp, _ax_if_div(cfg.ssm_heads, "model", mesh),
                          None, None),
            }
    state_specs = {"pos": PS(), "layers": layer_specs}
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return state_sds, state_specs, tok_sds, PS(dp, None)


def param_and_opt_specs(cfg: ModelConfig, mesh: Mesh):
    """Resolved (param, optimizer-state) PartitionSpec trees."""
    from .mesh import fix_spec_tree
    mod = model_module(cfg)
    placeholders = mod.param_specs(cfg)
    sds = param_shapes(cfg)
    p_specs = fix_spec_tree(
        sds, resolve_spec_tree(placeholders, cfg, mesh, zero1=False), mesh)
    o_inner = fix_spec_tree(
        sds, resolve_spec_tree(placeholders, cfg, mesh, zero1=True), mesh)
    o_specs = {"m": o_inner, "v": o_inner, "count": PS()}
    return p_specs, o_specs


def param_shapes(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    mod = model_module(cfg)
    return jax.eval_shape(functools.partial(mod.init_params, cfg),
                          jax.random.key(0))


def opt_shapes(params_sds):
    """AdamW state ShapeDtypeStructs matching ``params_sds``."""
    return jax.eval_shape(adamw_init, params_sds)

"""Production meshes and ParamDef placeholder-spec resolution.

Mesh shapes (TPU v5e pods, 256 chips each):
  single pod : (data=16, model=16)
  two pods   : (pod=2, data=16, model=16)  — "pod" extends data parallelism
               across the inter-pod (DCN/ICI) boundary.

Importing this module never touches jax device state; meshes are built by
functions only (the dry-run forces a 512-device host platform before any
jax import — see dryrun.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as PS


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The 256-device (or 512, multi-pod) production dry-run mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices=None) -> Mesh:
    """jax.make_mesh with every axis in Auto mode (the repo default)."""
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch: ("pod", "data") on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def resolve_spec(placeholder, cfg, mesh: Mesh, *, zero1: bool = False) -> PS:
    """Map a ParamDef placeholder tuple to a PartitionSpec.

    "T" -> model axis; "F" -> "data" if (cfg.fsdp or zero1) else replicated;
    "D" -> the dp axes; None -> replicated.
    """
    fsdp_axes = dp_axes(mesh)  # ("pod", "data") on multi-pod: a 400B model's
    # params+optimizer exceed one pod's HBM, so FSDP spans pods there
    if len(fsdp_axes) == 1:
        fsdp_axes = fsdp_axes[0]
    out = []
    for dim in placeholder:
        if dim == "T":
            out.append(cfg.tp_axis)
        elif dim == "F":
            out.append(fsdp_axes if (cfg.fsdp or zero1) else None)
        elif dim == "D":
            out.append(dp_axes(mesh))
        else:
            out.append(None)
    return PS(*out)


def resolve_spec_tree(placeholders, cfg, mesh: Mesh, *, zero1: bool = False):
    """Map resolve_spec over a placeholder pytree."""
    return jax.tree.map(
        lambda ph: resolve_spec(ph, cfg, mesh, zero1=zero1), placeholders,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, (str, tuple)) for e in x))


def named(mesh: Mesh, spec: PS) -> NamedSharding:
    """Shorthand NamedSharding constructor."""
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def fix_spec_for_shape(shape: Tuple[int, ...], spec: PS, mesh: Mesh) -> PS:
    """jax.jit requires dims divisible by their mesh-axis extents; when a
    config dimension (24 heads, 51866 vocab, ...) does not divide, relocate
    the axis to another still-unsharded dim of the same tensor that does
    (e.g. heads -> head_dim), else drop it (replicate).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = list(entries)
    for i, ax in enumerate(entries):
        if ax is None:
            continue
        if shape[i] % _axis_size(mesh, ax) == 0:
            continue
        out[i] = None
        for j in range(len(shape) - 1, -1, -1):
            if out[j] is None and j != i and shape[j] % _axis_size(mesh, ax) == 0 \
                    and shape[j] >= _axis_size(mesh, ax):
                out[j] = ax
                break
    return PS(*out)


def fix_spec_tree(sds_tree, spec_tree, mesh: Mesh):
    """Map fix_spec_for_shape over matching (shape, spec) pytrees."""
    return jax.tree.map(
        lambda sds, spec: fix_spec_for_shape(sds.shape, spec, mesh),
        sds_tree, spec_tree)

"""Elastic scaling + failure handling for the quorum all-pairs runtime.

The schedule and residency are pure functions of (P, placement)
(core.placement; difference-set construction is O(ms), memo-cached), so
the control plane here is small:

  * ``rescale(P_old, P_new, ...)`` — derive the new schedule + the minimal
    block-movement plan (which devices must fetch which blocks to satisfy
    their new residency), used when a pod grows/shrinks — and, at equal P,
    when the *placement* changes (e.g. a live cyclic -> projective-plane
    migration): block ids keep their meaning, so each device fetches only
    its residency delta.
  * ``failover(schedule, failed)`` — wrap core.scheduler.reassign into a
    runnable plan (paper section 6 "quorum redundancy" future work).

Both return plain data (no jax state) — the launcher applies them by
re-sharding with jax.device_put under the new mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from ..core.placement import (Placement, placement_from_env,
                              resolve_placement)
from ..core.scheduler import PairSchedule, ReassignPlan, reassign


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """A quorum-axis resize / placement-migration plan (DESIGN.md
    section 8): per-device new residency and the blocks to fetch."""
    P_old: int
    P_new: int
    schedule: PairSchedule
    # device -> global block ids it must hold afterwards (its new residency)
    new_quorums: List[List[int]]
    # device -> blocks it needs but cannot derive locally (must fetch)
    fetches: Dict[int, List[int]]
    # the placements the plan moves between (equal => pure resize logic)
    placement_old: Placement | None = None
    placement_new: Placement | None = None

    @property
    def total_fetch_blocks(self) -> int:
        """Blocks moved across devices by this plan (the cost)."""
        return sum(len(v) for v in self.fetches.values())

    @property
    def is_migration(self) -> bool:
        """True when the plan changes placement at constant P (block ids
        keep their meaning; only the residency delta moves)."""
        return (self.P_old == self.P_new
                and self.placement_old != self.placement_new)


def rescale(P_old: int, P_new: int, placement_old=None,
            placement_new=None) -> RescalePlan:
    """Plan a quorum-axis resize and/or placement migration.

    Placement specs default to the ``REPRO_PLACEMENT`` selection at each
    P (auto == cyclic when unset — the historical behavior).  Three
    regimes, by (P, placement) delta:

      * identity (same P, same placement) — a no-op: block ids keep their
        meaning and every device already holds its full residency, so the
        fetch plan is empty.
      * migration (same P, different placement) — block ids keep their
        meaning, so device i fetches exactly ``new_residency(i) -
        old_residency(i)``: a cyclic -> plane migration at a
        plane-friendly P moves only the residency delta, not the corpus.
      * resize (different P) — blocks are re-chunked to P_new equal parts
        by the data layer, nothing previously held is reusable, and every
        device fetches its whole new residency (an upper bound when old
        shards can be reused).
    """
    plc_old = (placement_from_env(P_old) if placement_old is None
               else resolve_placement(placement_old, P_old))
    plc_new = (placement_from_env(P_new) if placement_new is None
               else resolve_placement(placement_new, P_new))
    sched = plc_new.schedule()
    new_res = [sorted(plc_new.residency(i)) for i in range(P_new)]
    fetches: Dict[int, List[int]] = {}
    if P_old == P_new:
        for i in range(P_new):
            delta = sorted(set(new_res[i]) - plc_old.residency(i))
            if delta:
                fetches[i] = delta
    else:
        fetches = {i: list(S) for i, S in enumerate(new_res)}
    return RescalePlan(P_old=P_old, P_new=P_new, schedule=sched,
                       new_quorums=new_res, fetches=fetches,
                       placement_old=plc_old, placement_new=plc_new)


def failover(schedule: PairSchedule, failed: Sequence[int],
             placement=None) -> ReassignPlan:
    """Work reassignment after device failure (no resize): peers that
    co-hold a failed device's pairs absorb them; pairs whose co-residency
    died fetch one block from a surviving holder.  ``placement`` supplies
    the residency sets when the schedule derives from a non-default
    placement.  See scheduler.reassign."""
    return reassign(schedule, failed, placement=placement)

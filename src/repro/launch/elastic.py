"""Elastic scaling + failure handling for the quorum all-pairs runtime.

The quorum schedule is a pure function of P (core.quorum difference-set
construction is O(ms), memo-cached), so the control plane here is small:

  * ``rescale(P_old, P_new)``    — derive the new schedule + the minimal
    block-movement plan (which devices must fetch which blocks to satisfy
    their new quorum), used when a pod grows/shrinks.
  * ``failover(schedule, failed)`` — wrap core.scheduler.reassign into a
    runnable plan (paper section 6 "quorum redundancy" future work).

Both return plain data (no jax state) — the launcher applies them by
re-sharding with jax.device_put under the new mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from ..core.quorum import cyclic_quorums
from ..core.scheduler import PairSchedule, ReassignPlan, build_schedule, reassign


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    P_old: int
    P_new: int
    schedule: PairSchedule
    # device -> global block ids it must hold afterwards (its new quorum)
    new_quorums: List[List[int]]
    # device -> blocks it needs but cannot derive locally (must fetch)
    fetches: Dict[int, List[int]]

    @property
    def total_fetch_blocks(self) -> int:
        return sum(len(v) for v in self.fetches.values())


def rescale(P_old: int, P_new: int) -> RescalePlan:
    """Plan a quorum-axis resize.  Blocks are re-chunked to P_new equal
    parts by the data layer; this plan reports which *new* quorum members
    each device must obtain (an upper bound when old shards can be reused).

    An identity rescale (P_old == P_new) is a no-op: block ids keep their
    meaning and every device already holds its full quorum, so the fetch
    plan is empty.  Across a real resize block ids are re-chunked and
    nothing previously held is reusable, so every device fetches its whole
    new quorum.
    """
    sched = build_schedule(P_new)
    quorums = cyclic_quorums(P_new)
    fetches: Dict[int, List[int]] = {}
    if P_old != P_new:
        fetches = {i: list(S) for i, S in enumerate(quorums)}
    return RescalePlan(P_old=P_old, P_new=P_new, schedule=sched,
                       new_quorums=quorums, fetches=fetches)


def failover(schedule: PairSchedule, failed: Sequence[int]) -> ReassignPlan:
    """Work reassignment after device failure (no resize): quorum peers that
    co-hold a failed device's pairs absorb them; pairs whose co-residency
    died fetch one block from a surviving holder.  See scheduler.reassign."""
    return reassign(schedule, failed)

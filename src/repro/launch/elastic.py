"""Elastic scaling + failure handling for the quorum all-pairs runtime.

The schedule and residency are pure functions of (P, placement)
(core.placement; difference-set construction is O(ms), memo-cached), so
the control plane here is small:

  * ``rescale(P_old, P_new, ...)`` — derive the new schedule + the minimal
    block-movement plan (which devices must fetch which blocks to satisfy
    their new residency), used when a pod grows/shrinks — and, at equal P,
    when the *placement* changes (e.g. a live cyclic -> projective-plane
    migration): block ids keep their meaning, so each device fetches only
    its residency delta.
  * ``failover(schedule, failed)`` — wrap core.scheduler.reassign into a
    runnable plan (paper section 6 "quorum redundancy" future work).
  * ``plan_replication_repair(placement, dead)`` — after failures, copy
    each under-replicated block from a surviving holder onto live
    non-holders until the k-residency invariant is restored (DESIGN.md
    section 13) — the re-replication half of mid-sweep recovery that
    ``core.faults`` executes between rounds.

All return plain data (no jax state) — the launcher applies them by
re-sharding with jax.device_put under the new mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple

from ..core.placement import (Placement, placement_from_env,
                              resolve_placement)
from ..core.scheduler import PairSchedule, ReassignPlan, reassign
from ..obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """A quorum-axis resize / placement-migration plan (DESIGN.md
    section 8): per-device new residency and the blocks to fetch."""
    P_old: int
    P_new: int
    schedule: PairSchedule
    # device -> global block ids it must hold afterwards (its new residency)
    new_quorums: List[List[int]]
    # device -> blocks it needs but cannot derive locally (must fetch)
    fetches: Dict[int, List[int]]
    # the placements the plan moves between (equal => pure resize logic)
    placement_old: Placement | None = None
    placement_new: Placement | None = None

    @property
    def total_fetch_blocks(self) -> int:
        """Blocks moved across devices by this plan (the cost)."""
        return sum(len(v) for v in self.fetches.values())

    @property
    def is_migration(self) -> bool:
        """True when the plan changes placement at constant P (block ids
        keep their meaning; only the residency delta moves)."""
        return (self.P_old == self.P_new
                and self.placement_old != self.placement_new)


def rescale(P_old: int, P_new: int, placement_old=None,
            placement_new=None) -> RescalePlan:
    """Plan a quorum-axis resize and/or placement migration (DESIGN.md
    sections 8, 13).

    Placement specs default to the ``REPRO_PLACEMENT`` selection at each
    P (auto == cyclic when unset — the historical behavior).  Three
    regimes, by (P, placement) delta:

      * identity (same P, same placement) — a no-op: block ids keep their
        meaning and every device already holds its full residency, so the
        fetch plan is empty.
      * migration (same P, different placement) — block ids keep their
        meaning, so device i fetches exactly ``new_residency(i) -
        old_residency(i)``: a cyclic -> plane migration at a
        plane-friendly P moves only the residency delta, not the corpus.
      * resize (different P) — blocks are re-chunked to P_new equal
        parts by the data layer.  When the sizes divide evenly
        (``P_new % P_old == 0`` or ``P_old % P_new == 0``) the chunk
        boundaries nest, so a surviving device re-chunks what it already
        holds locally — on grow, old block b splits into new blocks
        ``b*m .. b*m+m-1``; on shrink, new block b is derivable iff all
        of old blocks ``b*m .. b*m+m-1`` were held — and fetches only
        the delta.  Non-divisible resizes keep the conservative
        full-residency fetch (chunk boundaries don't align).
    """
    plc_old = (placement_from_env(P_old) if placement_old is None
               else resolve_placement(placement_old, P_old))
    plc_new = (placement_from_env(P_new) if placement_new is None
               else resolve_placement(placement_new, P_new))
    sched = plc_new.schedule()
    new_res = [sorted(plc_new.residency(i)) for i in range(P_new)]
    fetches: Dict[int, List[int]] = {}
    if P_old == P_new:
        for i in range(P_new):
            delta = sorted(set(new_res[i]) - plc_old.residency(i))
            if delta:
                fetches[i] = delta
    elif P_new % P_old == 0:
        m = P_new // P_old
        for i in range(P_new):
            if i < P_old:
                derivable = {b * m + j for b in plc_old.residency(i)
                             for j in range(m)}
            else:
                derivable = set()  # a freshly-joined device holds nothing
            delta = sorted(set(new_res[i]) - derivable)
            if delta:
                fetches[i] = delta
    elif P_old % P_new == 0:
        m = P_old // P_new
        for i in range(P_new):
            old = plc_old.residency(i)
            derivable = {b for b in range(P_new)
                         if all(b * m + j in old for j in range(m))}
            delta = sorted(set(new_res[i]) - derivable)
            if delta:
                fetches[i] = delta
    else:
        fetches = {i: list(S) for i, S in enumerate(new_res)}
    plan = RescalePlan(P_old=P_old, P_new=P_new, schedule=sched,
                       new_quorums=new_res, fetches=fetches,
                       placement_old=plc_old, placement_new=plc_new)
    tr = obs_trace.get_tracer()
    if tr:
        tr.count("elastic.fetch_blocks", plan.total_fetch_blocks)
    return plan


def failover(schedule: PairSchedule, failed: Sequence[int],
             placement=None) -> ReassignPlan:
    """Work reassignment after device failure (no resize; DESIGN.md
    section 13): peers that co-hold a failed device's pairs absorb them;
    pairs whose co-residency died fetch one block from a surviving
    holder.  ``placement`` supplies the residency sets when the schedule
    derives from a non-default placement.  See scheduler.reassign."""
    return reassign(schedule, failed, placement=placement)


@dataclasses.dataclass(frozen=True)
class ReplicationRepairPlan:
    """Block copies restoring the k-residency invariant after failures
    (DESIGN.md section 13): each ``(block, src, tgt)`` action copies
    ``block`` from live holder ``src`` onto live non-holder ``tgt``."""
    P: int
    dead: Tuple[int, ...]
    # ordered copy actions; deterministic for a given (placement, dead)
    actions: Tuple[Tuple[int, int, int], ...]
    # per-block live copy count after the plan is applied
    copies_after: Tuple[int, ...]

    @property
    def n_copies(self) -> int:
        """Blocks moved across devices by this plan (the cost)."""
        return len(self.actions)

    @property
    def blocks_repaired(self) -> Tuple[int, ...]:
        """Distinct block ids the plan re-replicates, ascending."""
        return tuple(sorted({b for (b, _s, _t) in self.actions}))


def plan_replication_repair(placement: Placement, dead: Sequence[int],
                            residency: Sequence[set] | None = None
                            ) -> ReplicationRepairPlan:
    """Plan the re-replication restoring each block to its pre-failure
    copy count after ``dead`` devices fail (DESIGN.md section 13).

    For every block the failures under-replicated, copy it from the
    smallest-id surviving holder onto surviving non-holders — fewest
    repair copies received first, then smallest id, so repair load
    spreads deterministically — until the block again has
    ``min(original copy count, live devices)`` live replicas.  This is
    the invariant the chaos selfcheck asserts between rounds: after
    repair, another ``replication - 1`` failures are survivable again.
    ``residency`` overrides the placement's residency sets with the
    cluster's *current* ones (they drift after earlier repairs); the
    per-block target count always comes from the placement.  A block
    whose holders all died cannot be repaired from residency and raises
    ``RuntimeError`` (restore it from a checkpoint first — the path
    ``core.faults`` drives).
    """
    t0 = time.perf_counter()
    P = placement.P
    dead_set = set(int(d) for d in dead)
    live = [i for i in range(P) if i not in dead_set]
    if not live:
        raise ValueError("all devices dead: nothing to repair onto")
    if residency is None:
        sets = [set(S) for S in placement.residency_sets]
    else:
        sets = [set(S) for S in residency]
    orig_count = [0] * P
    for S in placement.residency_sets:
        for b in S:
            orig_count[b] += 1
    live_holders = {b: sorted(i for i in live if b in sets[i])
                    for b in range(P)}
    lost = [b for b in range(P) if not live_holders[b]]
    if lost:
        raise RuntimeError(
            f"block {lost[0]} lost: all {orig_count[lost[0]]} holders "
            f"failed; restore from checkpoint before repairing")
    actions: List[Tuple[int, int, int]] = []
    received = [0] * P
    for b in range(P):
        target = min(orig_count[b], len(live))
        holders = list(live_holders[b])
        src = holders[0]
        while len(holders) < target:
            cands = [i for i in live if i not in holders]
            tgt = min(cands, key=lambda i: (received[i], i))
            actions.append((b, src, tgt))
            holders.append(tgt)
            received[tgt] += 1
    copies_after = [0] * P
    for b in range(P):
        copies_after[b] = len(live_holders[b]) + sum(
            1 for (bb, _s, _t) in actions if bb == b)
    plan = ReplicationRepairPlan(
        P=P, dead=tuple(sorted(dead_set)), actions=tuple(actions),
        copies_after=tuple(copies_after))
    tr = obs_trace.get_tracer()
    if tr:
        tr.count("elastic.rereplicated_blocks", plan.n_copies)
        tr.record("elastic.plan_repair", time.perf_counter() - t0,
                  P=P, dead=len(dead_set), copies=plan.n_copies)
    return plan

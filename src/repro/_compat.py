"""JAX version-compat shims.

The codebase targets the current jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.lax.pcast``, ``jax.make_mesh(axis_types=...)``).
On older runtimes (jax 0.4.x) those names are missing; :func:`install` maps
each one onto its available equivalent so every module, test subprocess, and
benchmark child runs unmodified on both.  Idempotent; invoked from
``repro/__init__.py`` so any ``import repro.*`` installs it first.

Shim semantics (all no-ops on new-enough jax):
  * ``jax.shard_map``           -> ``jax.experimental.shard_map.shard_map``
    with ``check_rep=False`` (the old replication checker predates the
    collective patterns the engine uses; the new checker is unaffected).
  * ``jax.sharding.AxisType``   -> a placeholder enum; pre-explicit-sharding
    jax treats every mesh axis as Auto, which is exactly what callers request.
  * ``jax.make_mesh``           -> wrapper dropping the unsupported
    ``axis_types`` kwarg (see above: Auto is the old default behavior).
  * ``jax.tree.flatten_with_path`` -> ``jax.tree_util.tree_flatten_with_path``.
  * ``jax.lax.pcast``           -> identity; varying-manual-axes tracking does
    not exist before jax 0.7, so there is nothing to cast.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.lax


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, **kw):
            kw.setdefault("check_rep", False)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types  # pre-explicit-sharding jax: every axis is Auto
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax.tree, "flatten_with_path"):
        jax.tree.flatten_with_path = jax.tree_util.tree_flatten_with_path

    if not hasattr(jax.lax, "pcast"):
        def pcast(x, axis_name=None, *, to=None):
            del axis_name, to
            return x

        jax.lax.pcast = pcast

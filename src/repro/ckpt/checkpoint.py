"""Fault-tolerant checkpointing: sharded npz files, async writer, atomic
commit, automatic latest-valid resume.

Layout:  <dir>/step_<k>/arrays.npz + MANIFEST.json (commit marker written
last — a crash mid-write leaves no MANIFEST and the step is ignored on
resume).  On multi-host deployments each host writes its addressable shards
to arrays_h<host>.npz; this container is single-host so one file is emitted.

The async mode snapshots arrays to host memory synchronously (cheap, device
->host copy) and runs the compress+write on a background thread, overlapping
I/O with the next training steps — checkpoint stalls drop to the device->
host copy time (DESIGN.md section 8).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Tree = Any


def _flatten(tree: Tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree: Tree):
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path))
            for path, _ in jax.tree.flatten_with_path(tree)[0]]


def save_checkpoint(directory: str | Path, step: int, tree: Tree) -> Path:
    """Synchronous sharded save with atomic commit."""
    directory = Path(directory)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(tree)
    names = _paths(tree)

    def to_np(leaf):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no bf16; store the lossless f32 upcast (dtype is
            # restored from the target structure on load)
            arr = np.asarray(leaf).astype(np.float32)
        return arr

    arrays = {n: to_np(l) for n, l in zip(names, leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "MANIFEST.json").write_text(json.dumps({
        "step": step, "n_arrays": len(arrays), "time": time.time(),
        "names": names}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    """Largest step with a complete (manifest-bearing) checkpoint."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "MANIFEST.json").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def load_checkpoint(directory: str | Path, tree_like: Tree,
                    step: Optional[int] = None,
                    shardings: Optional[Tree] = None) -> tuple[Tree, int]:
    """Restore into the structure (and shardings) of ``tree_like``."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    data = np.load(directory / f"step_{step}" / "arrays.npz")
    names = _paths(tree_like)
    leaves, treedef = _flatten(tree_like)
    # None entries mean "default placement" for that leaf; flatten must
    # keep them (default flattening would drop None subtrees and desync
    # the leaf zip below)
    shard_leaves = (jax.tree.flatten(shardings,
                                     is_leaf=lambda x: x is None)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for name, like, shd in zip(names, leaves, shard_leaves):
        arr = data[name]
        if hasattr(like, "dtype") and arr.dtype != like.dtype:
            import ml_dtypes  # noqa: F401 - registers bf16 casts
            arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step


def load_named_tree(directory: str | Path,
                    step: Optional[int] = None) -> tuple[Dict, int]:
    """Reconstruct a checkpoint as a nested dict keyed by the manifest's
    "/"-joined leaf names, without a ``tree_like`` template.

    The mid-sweep partial store (DESIGN.md section 13) needs this:
    which pairs have durable partials varies between checkpoints, so the
    restoring driver cannot know the tree structure up front — the
    manifest names carry it.  Arrays come back as host numpy (recovery
    is host-side; no device placement implied).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    data = np.load(directory / f"step_{step}" / "arrays.npz")
    tree: Dict = {}
    for name in data.files:
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[name]
    return tree, step


def restore_or_none(directory: str | Path
                    ) -> Optional[tuple[Dict, int]]:
    """``load_named_tree`` of the latest complete step, or None when the
    directory holds no valid checkpoint yet — the mid-sweep recovery
    convenience (DESIGN.md section 13): a fault-tolerant driver probes
    for durable partials without special-casing the cold start."""
    if latest_step(directory) is None:
        return None
    return load_named_tree(directory)


class CheckpointManager:
    """Async checkpointing with bounded retention and resume."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        """Block until the in-flight async save finishes (re-raising
        any error it hit)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Tree):
        """Snapshot to host now; compress+write on a background thread."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # D2H copy (synchronous)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def restore_latest(self, tree_like: Tree, shardings=None):
        """Load the newest complete checkpoint into tree_like's shape."""
        return load_checkpoint(self.directory, tree_like, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.directory.iterdir()
            if d.name.startswith("step_") and (d / "MANIFEST.json").exists())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)

from .checkpoint import (CheckpointManager, load_checkpoint,  # noqa: F401
                         save_checkpoint)

"""Shared building blocks: ParamDef tables, norms, positions, MLPs.

Sharding placeholders used in ParamDef specs (resolved by launch/mesh.py):
  "T"  -> the tensor-model axis ("model")
  "F"  -> the fsdp axis ("data") when cfg.fsdp else replicated
  "D"  -> data-parallel axes for activations (("pod","data") on multi-pod)
  None -> replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape, placeholder spec, init recipe."""
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]      # placeholder spec, same rank as shape
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 1.0                   # stddev multiplier for "normal"
    fan_in: int = 0                      # contraction size; 0 -> shape[-2]
    # (3-D projections like wq [d, H, hd] contract shape[0], NOT shape[-2]
    # — the heuristic gave wv [d, KV, hd] an std of 1/sqrt(KV) = 12x too
    # hot, saturating attention at init; EXPERIMENTS.md Perf E1.)

    def with_leading(self, n: int) -> "ParamDef":
        """Stack n copies along a new leading (scan) axis."""
        return ParamDef((n,) + self.shape, (None,) + self.spec, self.init,
                        self.scale, self.fan_in)


def init_tree(defs: Tree, key: jax.Array, dtype) -> Tree:
    """Materialize a ParamDef tree into arrays (deterministic key split)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        assert isinstance(d, ParamDef), d
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dtype)
        elif d.init == "embed":
            # T5-style: std 1/sqrt(d_model) with sqrt(d_model)-scaled lookup,
            # so the residual stream starts at rms ~1.  (fan_in-of-vocab init
            # gave rms(x0) ~ 1/sqrt(V) and the first rmsnorm's backward then
            # amplified the embedding gradient ~sqrt(V)x — measured 1.7e8
            # grad norm on the 100M example; EXPERIMENTS.md Perf E1.)
            std = d.scale / math.sqrt(max(1, d.shape[-1]))
            a = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        else:
            fan_in = d.fan_in or (d.shape[-2] if len(d.shape) >= 2
                                  else d.shape[-1])
            std = d.scale / math.sqrt(max(1, fan_in))
            a = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def spec_tree(defs: Tree) -> Tree:
    """Extract the placeholder spec tree (same structure as params)."""
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps=1e-6):
    """RMSNorm in f32 accumulation, cast back to x.dtype."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm in f32 accumulation, cast back to x.dtype."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + (
        beta if beta is not None else 0.0)


def norm_defs(cfg) -> Tree:
    """ParamDefs for the config's norm flavor."""
    if cfg.norm == "layernorm":
        return {"gamma": ParamDef((cfg.d_model,), (None,), "ones"),
                "beta": ParamDef((cfg.d_model,), (None,), "zeros")}
    return {"gamma": ParamDef((cfg.d_model,), (None,), "ones")}


def apply_norm(cfg, p: Tree, x):
    """Apply the config's norm flavor with params ``p``."""
    if cfg.norm == "layernorm":
        return layernorm(x, p["gamma"], p["beta"])
    return rmsnorm(x, p["gamma"])


# ---------------------------------------------------------------------------
# Positions: RoPE / M-RoPE / sinusoidal
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    """RoPE inverse frequencies for ``head_dim`` (numpy, host-side)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)     # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """M-RoPE (qwen2-vl): rotary over 3 position streams (t, h, w).

    positions3: [..., seq, 3].  Each frequency slot is assigned to one of the
    three sections; text tokens use identical t=h=w positions, which makes
    M-RoPE degenerate to 1-D RoPE exactly (as in the paper).
    """
    hd = x.shape[-1]
    half = hd // 2
    sec = np.asarray(sections, np.int32)
    assert sec.sum() == half, (sections, hd)
    # frequency slot -> section id
    sid = np.concatenate([np.full(s, i, np.int32) for i, s in enumerate(sec)])
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    pos = positions3.astype(jnp.float32)[..., sid]               # [..., seq, half]
    ang = pos * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(seq: int, d_model: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings [seq, d_model]."""
    pos = np.arange(seq)[:, None]
    i = np.arange(d_model // 2)[None, :]
    ang = pos / (10_000 ** (2 * i / d_model))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(cfg, d_ff: Optional[int] = None) -> Tree:
    """MLP ParamDefs (swiglu or gelu layout per config)."""
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp == "swiglu":
        return {
            "wi": ParamDef((d, f), ("F", "T")),
            "wg": ParamDef((d, f), ("F", "T")),
            "wo": ParamDef((f, d), ("T", "F"), scale=cfg.out_scale),
        }
    return {
        "wi": ParamDef((d, f), ("F", "T")),
        "wo": ParamDef((f, d), ("T", "F"), scale=cfg.out_scale),
    }


def apply_mlp(cfg, p: Tree, x):
    """Apply the config's MLP flavor with params ``p``."""
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]

"""GQA attention: training forward, cross-attention, and cached decode.

The inner block-pair computation maps to the flash-attention Pallas kernel
(kernels/flash_attention.py) on TPU; this module is the reference jnp path
with identical semantics (used on CPU and as the kernel oracle).
Sequence-parallel execution for long-context cells is provided by
apps/attention.py (quorum schedule) and wired in at the launch layer.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamDef, Tree, apply_mrope, apply_rope, rmsnorm

NEG_INF = -1e30


def attn_defs(cfg) -> Tree:
    """Attention block ParamDefs (GQA q/k/v/o + norms)."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, H, hd), ("F", "T", None), fan_in=d),
        "wk": ParamDef((d, KV, hd), ("F", "T", None), fan_in=d),
        "wv": ParamDef((d, KV, hd), ("F", "T", None), fan_in=d),
        "wo": ParamDef((H, hd, d), ("T", None, "F"), scale=cfg.out_scale,
                       fan_in=H * hd),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), "ones")
        defs["k_norm"] = ParamDef((hd,), (None,), "ones")
    return defs


def causal_window_bias(Tq: int, Tk: int, *, causal: bool,
                       window: Optional[int], q_offset=0) -> jnp.ndarray:
    """[Tq, Tk] additive float32 mask.  q_offset = abs position of query 0
    minus abs position of key 0 (decode / blockwise)."""
    q = jnp.arange(Tq)[:, None] + q_offset
    k = jnp.arange(Tk)[None, :]
    ok = jnp.ones((Tq, Tk), bool)
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def qkv_project(cfg, p: Tree, x, positions):
    """x: [B, T, d] -> q [B, T, H, hd], k/v [B, T, KV, hd] with pos encoding.

    positions: [B, T] int32, or [B, T, 3] for M-RoPE.
    """
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        pos3 = positions if positions.ndim == 3 else jnp.repeat(
            positions[..., None], 3, axis=-1)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def sdpa(q, k, v, bias: Optional[jnp.ndarray] = None):
    """Grouped scaled-dot-product attention.

    q: [B, Tq, H, hd]; k/v: [B, Tk, KV, hd]; H % KV == 0.
    bias: additive float32, broadcastable to [Tq, Tk] over trailing dims
    (leading dims broadcast against [B, KV, G]).
    Returns [B, Tq, H, hd] in q.dtype.
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs",
                        qg.astype(jnp.float32) / np.sqrt(hd),
                        k.astype(jnp.float32))        # [B, KV, G, Tq, Tk]
    if bias is not None:
        while bias.ndim < 5:
            bias = bias[None]
        logits = logits + bias
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, hd).astype(q.dtype)


def blocked_sdpa(q, k, v, *, causal: bool, window: Optional[int],
                 block_k: int, unroll: bool):
    """Flash-style online-softmax attention scanned over kv blocks.

    Never materializes [Tq, Tk]; peak intermediate is [B, KV, G, Tq, bk].
    Rectangular over kv blocks (causal masking inside the block) — the Pallas
    kernel skips fully-masked blocks; XLA here does not, which the roofline
    MODEL_FLOPS/HLO_FLOPs ratio exposes (see EXPERIMENTS.md section Perf).
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, Tk)
    assert Tk % bk == 0, (Tk, bk)
    nb = Tk // bk
    qg = (q.reshape(B, Tq, KV, G, hd).astype(jnp.float32) / np.sqrt(hd))
    kb = k.reshape(B, nb, bk, KV, hd)
    vb = v.reshape(B, nb, bk, KV, hd)
    q_pos = jnp.arange(Tq)[:, None]

    def step(carry, inp):
        acc, m, l = carry
        kc, vc, bi = inp
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc.astype(jnp.float32))
        k_pos = bi * bk + jnp.arange(bk)[None, :]
        ok = jnp.ones((Tq, bk), bool)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        c = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l = l * c + jnp.sum(p_, axis=-1)
        acc = acc * c[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p_,
                                              vc.astype(jnp.float32))
        return (acc, m_new, l), None

    acc0 = (jnp.zeros((B, KV, G, Tq, hd), jnp.float32),
            jnp.full((B, KV, G, Tq), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G, Tq), jnp.float32))
    xs = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb))
    (acc, m, l), _ = jax.lax.scan(step, acc0, xs,
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, KV * G, Tq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def banded_sdpa(q, k, v, *, window: int):
    """Sliding-window attention in O(T * 2W): q blocks of W attend to the
    (previous, self) kv blocks only — the roll trick keeps everything dense
    and MXU-shaped while cutting the 32k/500k cells to linear compute."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    W = window
    assert T % W == 0, (T, W)
    nb = T // W
    qg = (q.reshape(B, nb, W, KV, G, hd).astype(jnp.float32) / np.sqrt(hd))
    kb = k.reshape(B, nb, W, KV, hd)
    vb = v.reshape(B, nb, W, KV, hd)
    # previous block (zeros before block 0)
    kprev = jnp.pad(kb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kb], axis=2)           # [B, nb, 2W, KV, hd]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    s = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg, k2.astype(jnp.float32))
    q_pos = jnp.arange(W)[:, None] + W                  # within [0, 2W)
    k_pos = jnp.arange(2 * W)[None, :]
    ok = (k_pos <= q_pos) & (k_pos > q_pos - W)
    first = jnp.arange(nb)[:, None, None] == 0          # block 0 has no prev
    ok = ok[None] & (~first | (k_pos >= W))             # [nb, W, 2W]
    s = jnp.where(ok[None, :, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnkgqs,bnskh->bnkgqh", w, v2.astype(jnp.float32))
    o = o.reshape(B, nb, KV * G, W, hd).transpose(0, 1, 3, 2, 4)
    return o.reshape(B, T, KV * G, hd).astype(q.dtype)


def attention(cfg, p: Tree, x, positions, *, causal=True,
              window: Optional[int] = None):
    """Training-time self attention over [B, T, d].

    Path selection: banded for SWA at long T; blocked (online softmax) at
    long T; plain masked sdpa otherwise.
    """
    T = x.shape[1]
    q, k, v = qkv_project(cfg, p, x, positions)
    if window is not None and causal and T >= 2 * window and T % window == 0:
        ctx = banded_sdpa(q, k, v, window=window)
    elif T >= cfg.attn_block_threshold:
        ctx = blocked_sdpa(q, k, v, causal=causal, window=window,
                           block_k=cfg.attn_block_k, unroll=cfg.unroll_inner)
    else:
        bias = causal_window_bias(T, T, causal=causal, window=window)
        ctx = sdpa(q, k, v, bias)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


def cross_attention(cfg, p: Tree, x, memory_kv: Tuple[jnp.ndarray, jnp.ndarray]):
    """Decoder cross-attention; memory_kv = (k, v) [B, S, KV, hd] precomputed."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    ctx = sdpa(q, *memory_kv)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


def cross_kv(cfg, p: Tree, memory):
    """Precompute cross-attention K/V from encoder output [B, S, d]."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    return k, v


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def decode_attention(cfg, p: Tree, x, cache_k, cache_v, pos, *,
                     window: Optional[int] = None):
    """One-token decode: x [B, 1, d]; cache_k/v [B, S, KV, hd]; pos scalar.

    Ring-buffer cache: the new K/V lands at slot ``pos % S``; slot s holds
    absolute position ``pos - ((pos - s) mod S)`` which unifies the plain
    (S >= max_len) and sliding-window (S >= window) layouts — SWA archs keep
    only O(window) cache at 500k context.  RoPE is applied at the absolute
    position before caching, so wrapped slots stay correct.
    """
    B = x.shape[0]
    S = cache_k.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = qkv_project(cfg, p, x, positions)
    slot = jnp.mod(pos, S)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    kpos = jnp.arange(S)
    abs_pos = pos - jnp.mod(pos - kpos, S)
    ok = abs_pos >= 0
    if window is not None:
        ok &= abs_pos > pos - window
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # [1, S]
    ctx = sdpa(q, cache_k, cache_v, bias)
    out = jnp.einsum("bthk,hkd->btd", ctx, p["wo"])
    return out, cache_k, cache_v

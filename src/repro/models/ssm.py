"""Mamba2 (SSD — state-space duality) block: chunked training forward and
O(1)-state decode step.

Per head (scalar A, state size N, head dim P):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T      (h: [N, P])
    y_t = C_t^T h_t + D * x_t
The chunked algorithm (arXiv:2405.21060) computes within-chunk interactions
as masked matmuls (MXU-friendly; the Pallas ssd_chunk kernel implements the
intra-chunk part) and carries chunk-final states with an associative pass —
here a lax.scan over chunks, which XLA pipelines.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamDef, Tree, rmsnorm


def ssm_defs(cfg) -> Tree:
    """Mamba2 block ParamDefs (in/out proj, conv, dt/A/D)."""
    d, di = cfg.d_model, cfg.d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N  # conv over x, B, C streams (mamba2 layout)
    return {
        "in_proj": ParamDef((d, 2 * di + 2 * N + H), ("F", "T")),  # z,x,B,C,dt
        "conv_w": ParamDef((cfg.ssm_conv, conv_ch), (None, "T"), scale=1.0),
        "conv_b": ParamDef((conv_ch,), ("T",), "zeros"),
        "A_log": ParamDef((H,), (None,), "ones"),
        "D": ParamDef((H,), (None,), "ones"),
        "dt_bias": ParamDef((H,), (None,), "zeros"),
        "norm": ParamDef((di,), (None,), "ones"),
        "out_proj": ParamDef((di, d), ("T", "F"), scale=cfg.out_scale),
    }


def _split_proj(cfg, proj):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xBC_dt = jnp.split(proj, [di], axis=-1)
    xBC, dt = jnp.split(xBC_dt, [di + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(cfg, p, xBC, conv_state=None):
    """Depthwise causal conv width W over [B, T, C]; optional carried state
    [B, W-1, C] for decode.  Returns (out, new_state)."""
    W = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xBC], axis=1)            # [B, T+W-1, C]
    out = sum(xp[:, i:i + xBC.shape[1]] * p["conv_w"][i] for i in range(W))
    out = jax.nn.silu(out + p["conv_b"])
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None, *, unroll: bool = False):
    """Chunked SSD scan.

    x: [B, T, H, P]; dt: [B, T, H] (>0); A: [H] (<0); Bm/Cm: [B, T, N].
    Returns y [B, T, H, P] and final state [B, H, N, P].
    """
    Bb, T, H, Pd = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    xc = x.reshape(Bb, nc, chunk, H, Pd)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = Bm.reshape(Bb, nc, chunk, N)
    Cc = Cm.reshape(Bb, nc, chunk, N)

    # per-step log decay a_t = dt_t * A  (A negative)
    la = dtc * A[None, None, None, :]                   # [B, nc, L, H]
    cums = jnp.cumsum(la, axis=2)                       # inclusive cumsum

    # intra-chunk: y_intra[t] = sum_{s<=t} C_t.B_s exp(cums_t - cums_s) dt_s x_s
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)          # [B, nc, L, L]
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # [B,nc,L,L,H]
    mask = np.tril(np.ones((chunk, chunk), np.bool_))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    # contraction order matters: fold the scalar factors into one
    # [B,nc,L,L,H] weight and contract m in a single matmul-like einsum.
    # The naive 4-operand einsum materialized [.,L,H,P,L] cubes (2 GiB each
    # on the jamba train cell — see EXPERIMENTS.md Perf C2).
    W = CB[..., None] * decay * dtc[:, :, None, :, :]   # [B, nc, L, L, H]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", W, xc)

    # chunk-final states: S_c = sum_s exp(cums_L - cums_s) dt_s B_s x_s^T
    decay_end = jnp.exp(cums[:, :, -1:, :] - cums)      # [B, nc, L, H]
    dBx = jnp.einsum("bclh,bcln,bclhp->bchnp", dtc * decay_end, Bc, xc)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cums[:, :, -1, :])            # [B, nc, H]

    def step(h, inp):
        dbx, cd, cc, dec_in = inp
        # y_inter[t] = C_t exp(cums_t) h_prev
        y_int = jnp.einsum("bln,blh,bhnp->blhp", cc, dec_in, h)
        h = cd[:, :, None, None] * h + dbx
        return h, y_int

    h0 = jnp.zeros((Bb, H, N, Pd), jnp.float32) if h0 is None else h0
    dec_in_all = jnp.exp(cums)                          # [B, nc, L, H]
    xs = (jnp.moveaxis(dBx, 1, 0).astype(jnp.float32),
          jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dec_in_all, 1, 0).astype(jnp.float32))
    hT, y_inter = jax.lax.scan(step, h0, xs, unroll=nc if unroll else 1)
    y_inter = jnp.moveaxis(y_inter, 0, 1)               # [B, nc, L, H, P]

    y = (y_intra + y_inter).reshape(Bb, T, H, Pd)
    return y, hT


def mamba_block(cfg, p: Tree, x, *, state=None):
    """Full Mamba2 block over [B, T, d].  state=None for training.

    Returns (out [B, T, d], new_state dict) — state carries (conv, ssm) for
    decode continuation.
    """
    B, T, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]                             # [B, T, 2di+2N+H]
    z, xBC, dt = _split_proj(cfg, proj)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(cfg, p, xBC, conv_state)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, T, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [H] negative
    xh = xs.reshape(B, T, H, Pd).astype(jnp.float32)

    chunk = min(cfg.ssm_chunk, T)
    h0 = None if state is None else state["ssm"]
    y, hT = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                        Cm.astype(jnp.float32), chunk, h0=h0,
                        unroll=cfg.unroll_inner)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B, T, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": hT}


def init_ssm_state(cfg, batch: int):
    """Zeroed decode-time SSM carry (conv tail + state)."""
    di, N = cfg.d_inner, cfg.ssm_state
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), cfg.dtype),
        "ssm": jnp.zeros((batch, H, N, Pd), jnp.float32),
    }


def mamba_decode_step(cfg, p: Tree, x, state):
    """One-token decode [B, 1, d] with carried (conv, ssm) state."""
    return mamba_block(cfg, p, x, state=state)

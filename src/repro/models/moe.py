"""Mixture-of-experts layer: top-k routing with capacity, scatter/gather
dispatch (MaxText-style) — no [n_tokens, E, capacity] one-hot cube is ever
materialized, so 1M-token batches with 128 experts stay tractable.

Dispatch: each (token, choice) gets a slot = its rank among same-expert
choices (capacity-clipped); tokens scatter-add into the [E*C, d] expert
buffer, experts run batched matmuls [E, C, d] x [E, d, f], and outputs
gather back per (token, choice) weighted by the normalized gate.

Expert parallelism: expert weights carry the "T" (model-axis) placeholder on
the E dim; under pjit the scatter/gather lower to collective exchanges along
that axis.  FLOPs ~ cf * tokens * top_k * 3 * d * ff (active-expert compute).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, Tree


def moe_defs(cfg) -> Tree:
    """MoE block ParamDefs (router + expert-stacked MLPs)."""
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    defs = {
        "router": ParamDef((d, E), (None, None), scale=0.1),
        "wi": ParamDef((E, d, f), ("T", "F", None)),
        "wg": ParamDef((E, d, f), ("T", "F", None)),
        "wo": ParamDef((E, f, d), ("T", None, "F"), scale=cfg.out_scale),
    }
    if cfg.moe_shared:
        defs["shared"] = {
            "wi": ParamDef((d, f), ("F", "T")),
            "wg": ParamDef((d, f), ("F", "T")),
            "wo": ParamDef((f, d), ("T", "F"), scale=cfg.out_scale),
        }
    return defs


def apply_moe(cfg, p: Tree, x):
    """x: [B, T, d] -> ([B, T, d], aux load-balance loss scalar)."""
    B, T, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    n = B * T
    xt = x.reshape(n, d)

    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [n, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [n, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e (token fraction_e * mean prob_e)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (n * k)
    aux = E * jnp.sum(me * ce)

    C = int(max(1, round(cfg.capacity_factor * n * k / E)))

    eidx = gate_idx.reshape(-1)                              # [n*k]
    # slot = rank of this (token, choice) within its expert
    onehot_cols = jax.nn.one_hot(eidx, E, dtype=jnp.int32)   # [n*k, E]
    ranks = jnp.cumsum(onehot_cols, axis=0) - onehot_cols    # [n*k, E]
    slot = jnp.take_along_axis(ranks, eidx[:, None], axis=-1)[:, 0]
    keep = slot < C
    flat_idx = jnp.where(keep, eidx * C + jnp.minimum(slot, C - 1), E * C)

    def ec_constraint(t):
        """[E, C, d] expert-buffer constraint.

        Two variants measured on the MoE train cells (EXPERIMENTS.md Perf):
          * E over the EP/model axis (moe_ec_constraint="ep"): adds 4.3 GiB
            of reshard copies on the 128-expert cell — refuted;
          * C over the dp axes, E replicated on activations
            (moe_ec_constraint="cap"): keeps the dispatch scatter aligned
            with the token sharding so the [n*k, d] buffers stay sharded.
        Weights remain E-sharded over the model axis in both cases.
        """
        mode = getattr(cfg, "moe_ec_constraint", None)
        if not mode or not cfg.seq_shard:
            return t
        from jax.sharding import PartitionSpec as PS
        if mode == "ep":
            return jax.lax.with_sharding_constraint(
                t, PS(cfg.tp_axis, cfg.dp_axes, None))
        return jax.lax.with_sharding_constraint(
            t, PS(None, cfg.dp_axes, None))

    def tok_constraint(t):
        if not getattr(cfg, "moe_ec_constraint", None) or not cfg.seq_shard:
            return t
        from jax.sharding import PartitionSpec as PS
        return jax.lax.with_sharding_constraint(t, PS(cfg.dp_axes, None))

    x_rep = tok_constraint(jnp.repeat(xt, k, axis=0))        # [n*k, d]
    buf = jnp.zeros((E * C + 1, d), xt.dtype)                # +1 overflow row
    buf = buf.at[flat_idx].add(x_rep)
    expert_in = ec_constraint(buf[: E * C].reshape(E, C, d))

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])
    expert_out = ec_constraint(jnp.einsum("ecf,efd->ecd", h, p["wo"]))
    expert_out = expert_out.reshape(E * C, d)
    expert_out = jnp.concatenate([expert_out, jnp.zeros((1, d), xt.dtype)])

    gathered = expert_out[flat_idx]                          # [n*k, d]
    w = (gate_vals.reshape(-1) * keep).astype(xt.dtype)[:, None]
    out = (gathered * w).reshape(n, k, d).sum(axis=1)

    if cfg.moe_shared:
        s = p["shared"]
        out = out + (jax.nn.silu(xt @ s["wg"]) * (xt @ s["wi"])) @ s["wo"]
    return out.reshape(B, T, d), aux

"""Unified decoder-only LM covering dense / GQA / MoE / SSM / hybrid / VLM.

Layer stack = repeating "superblock" pattern (e.g. Jamba's 7 Mamba + 1
attention), scanned over ``n_superblocks`` repeats with optional remat, so the
lowered HLO contains each distinct layer body once regardless of depth.

Params are dict pytrees built from ParamDef tables; ``param_specs`` yields the
matching PartitionSpec placeholder tree for pjit (resolved in launch/mesh.py).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (ParamDef, Tree, apply_mlp, apply_norm, init_tree,
                     mlp_defs, norm_defs, spec_tree)
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Parameter tables
# ---------------------------------------------------------------------------

def _layer_defs(cfg: ModelConfig, kind: str, j: int) -> Tree:
    """One layer's params.  kind: 'A' attention or 'M' mamba; j = index in
    the superblock pattern (controls MoE placement)."""
    defs: Tree = {"norm1": norm_defs(cfg)}
    if kind == "A":
        defs["attn"] = attn.attn_defs(cfg)
        defs["norm2"] = norm_defs(cfg)
        if cfg.is_moe_layer(j):
            defs["moe"] = moe_mod.moe_defs(cfg)
        elif cfg.d_ff > 0:
            defs["mlp"] = mlp_defs(cfg)
    else:  # Mamba layer: its block includes gating; optional MoE/MLP after
        defs["ssm"] = ssm_mod.ssm_defs(cfg)
        if cfg.is_moe_layer(j):
            defs["norm2"] = norm_defs(cfg)
            defs["moe"] = moe_mod.moe_defs(cfg)
        elif cfg.d_ff > 0 and cfg.family in ("hybrid",):
            defs["norm2"] = norm_defs(cfg)
            defs["mlp"] = mlp_defs(cfg)
    return defs


def model_defs(cfg: ModelConfig) -> Tree:
    """The full LM ParamDef tree (embed, layers, final norm)."""
    V, d = cfg.vocab_size, cfg.d_model
    defs: Tree = {
        "embed": ParamDef((V, d), ("T", "F"), "embed"),
        "final_norm": norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, V), ("F", "T"))
    pat = cfg.pattern()
    n_sup = cfg.n_superblocks
    defs["layers"] = {
        f"pos{j}": jax.tree.map(
            lambda pd: pd.with_leading(n_sup), _layer_defs(cfg, kind, j),
            is_leaf=lambda x: isinstance(x, ParamDef))
        for j, kind in enumerate(pat)
    }
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    """Materialize model_defs with the config init recipes."""
    return init_tree(model_defs(cfg), key, cfg.dtype)


def param_specs(cfg: ModelConfig) -> Tree:
    """Placeholder PartitionSpec tree matching model_defs."""
    return spec_tree(model_defs(cfg))


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count from the def tree (no allocation)."""
    leaves = jax.tree.leaves(model_defs(cfg),
                             is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE experts counted at top_k of E)."""
    total = count_params(cfg)
    if cfg.moe_experts == 0:
        return total
    # subtract inactive expert weights
    pat = cfg.pattern()
    n_moe_layers = sum(cfg.n_superblocks for j, _ in enumerate(pat)
                       if cfg.is_moe_layer(j))
    per_expert = 3 * cfg.d_model * cfg.d_ff  # wi, wg, wo
    inactive = n_moe_layers * (cfg.moe_experts - cfg.moe_top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, kind: str, j: int, p: Tree, x, positions):
    """Training-time layer.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "A":
        h = apply_norm(cfg, p["norm1"], x)
        x = x + attn.attention(cfg, p["attn"], h, positions,
                               causal=True, window=cfg.window)
        h = apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            y, aux = moe_mod.apply_moe(cfg, p["moe"], h)
            x = x + y
        elif "mlp" in p:
            x = x + apply_mlp(cfg, p["mlp"], h)
    else:
        h = apply_norm(cfg, p["norm1"], x)
        y, _state = ssm_mod.mamba_block(cfg, p["ssm"], h)
        x = x + y
        if "moe" in p:
            h = apply_norm(cfg, p["norm2"], x)
            y, aux = moe_mod.apply_moe(cfg, p["moe"], h)
            x = x + y
        elif "mlp" in p:
            h = apply_norm(cfg, p["norm2"], x)
            x = x + apply_mlp(cfg, p["mlp"], h)
    return x, aux


def _sp_constraint(cfg: ModelConfig, x):
    """Megatron-style sequence parallelism: between blocks, activations are
    sharded over the tensor axis along T; GSPMD inserts the all-gather /
    reduce-scatter pair around each TP region."""
    if not cfg.seq_shard or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as PS
    return jax.lax.with_sharding_constraint(
        x, PS(cfg.dp_axes, cfg.tp_axis, None))


def _superblock(cfg: ModelConfig, params_sb: Tree, x, positions):
    aux = jnp.zeros((), jnp.float32)
    multi = len(cfg.pattern()) > 1
    for j, kind in enumerate(cfg.pattern()):
        x = _sp_constraint(cfg, x)
        if cfg.remat and multi:
            # nested per-layer remat: without it the backward of a long
            # superblock (Jamba: 8 layers) materializes every layer's
            # intermediates at once — measured 35.8 GiB/device on the
            # jamba train_4k cell vs ~1 layer's worth with this (section
            # Perf iteration 1).
            x, a = jax.checkpoint(
                lambda p, xx, jj=j, kk=kind: _apply_layer(
                    cfg, kk, jj, p, xx, positions),
                policy=jax.checkpoint_policies.nothing_saveable,
            )(params_sb[f"pos{j}"], x)
        else:
            x, a = _apply_layer(cfg, kind, j, params_sb[f"pos{j}"], x,
                                positions)
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array]):
    """Token (+ optional modality-stub) embedding.  Returns (x, positions)."""
    if cfg.frontend == "audio_frames":
        # whisper-style: frames are already d_model embeddings (conv stub)
        x = batch["frames"].astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        return x, positions
    tokens = batch["tokens"]
    x = (jnp.take(params["embed"], tokens, axis=0)
         * math.sqrt(cfg.d_model)).astype(cfg.dtype)
    if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T), x.shape[:2])
    return x, positions


def forward(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array]):
    """Training forward -> (logits [B, T, V] float32, aux_loss scalar).

    Materializes full logits — use only for small T (tests, smoke); training
    and prefill go through forward_hidden/chunked_ce.
    """
    x, aux = forward_hidden(cfg, params, batch)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    return logits, aux


def chunked_ce(x_final, unembed, labels, *, chunk: int = 512,
               z_weight: float = 1e-4, unroll: bool = False):
    """Cross-entropy scanned over T chunks so the full [B, T, V] logits are
    never materialized (V runs to 202k in the assigned archs).

    x_final: [B, T, d] post-final-norm activations; labels [B, T] (<0 masked).
    Returns (nll_sum, z_sum, count).
    """
    B, T, d = x_final.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    xs = (x_final.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3),
          labels.reshape(B, nc, chunk).transpose(1, 0, 2))

    def body(carry, inp):
        nll_s, z_s, cnt = carry
        xc, lc = inp
        logits = (xc @ unembed).astype(jnp.float32)       # [B, chunk, V]
        mask = (lc >= 0).astype(jnp.float32)
        safe = jnp.maximum(lc, 0)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_s = nll_s + jnp.sum((logz - gold) * mask)
        z_s = z_s + jnp.sum((logz * mask) ** 2)
        cnt = cnt + jnp.sum(mask)
        return (nll_s, z_s, cnt), None

    init = (jnp.zeros((), jnp.float32),) * 3
    (nll_s, z_s, cnt), _ = jax.lax.scan(body, init, xs,
                                        unroll=nc if unroll else 1)
    return nll_s, z_weight * z_s, cnt


def forward_hidden(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array]):
    """Forward up to (and incl.) the final norm -> (x [B,T,d], aux)."""
    x, positions = embed_inputs(cfg, params, batch)

    def body(carry, params_sb):
        x, aux = carry
        if cfg.remat:
            x, a = jax.checkpoint(
                lambda p, xx: _superblock(cfg, p, xx, positions),
                policy=jax.checkpoint_policies.nothing_saveable,
            )(params_sb, x)
        else:
            x, a = _superblock(cfg, params_sb, x, positions)
        return (x, aux + a), None

    if cfg.scan_layers and cfg.n_superblocks > 1:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"],
            unroll=cfg.n_superblocks if cfg.unroll_inner else 1)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = body((x, aux), sb)
    return apply_norm(cfg, params["final_norm"], x), aux


def loss_fn(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01, z_weight: float = 1e-4):
    """Causal LM loss with label masking (labels < 0 are ignored)."""
    x, aux = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
        pad = -jnp.ones(labels.shape[:1] + (x.shape[1] - labels.shape[1],),
                        labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    nll_s, z_s, cnt = chunked_ce(x, unembed, labels, z_weight=z_weight,
                                 unroll=cfg.unroll_inner)
    denom = jnp.maximum(cnt, 1.0)
    ce = nll_s / denom
    zloss = z_s / denom
    return ce + zloss + aux_weight * aux, {"ce": ce, "aux": aux, "zloss": zloss}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    """Per-pattern-position caches stacked over superblocks."""
    n_sup = cfg.n_superblocks
    state: Tree = {"pos": jnp.zeros((), jnp.int32), "layers": {}}
    for j, kind in enumerate(cfg.pattern()):
        if kind == "A":
            KV, hd = cfg.n_kv_heads, cfg.head_dim
            S = max_len if cfg.window is None else min(max_len, cfg.window)
            state["layers"][f"pos{j}"] = {
                "k": jnp.zeros((n_sup, batch, S, KV, hd), cfg.dtype),
                "v": jnp.zeros((n_sup, batch, S, KV, hd), cfg.dtype),
            }
        else:
            s = ssm_mod.init_ssm_state(cfg, batch)
            state["layers"][f"pos{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_sup,) + a.shape), s)
    return state


def decode_step(cfg: ModelConfig, params: Tree, state: Tree,
                tokens: jax.Array) -> Tuple[jax.Array, Tree]:
    """One decode step: tokens [B, 1] -> (logits [B, 1, V], new state).

    KV caches use the full-length layout; SWA archs still mask to the window
    (ring-buffer compaction is an orthogonal serving optimization, noted in
    DESIGN.md).  ``state['pos']`` is the write position.
    """
    pos = state["pos"]
    x = (jnp.take(params["embed"], tokens, axis=0)
         * math.sqrt(cfg.d_model)).astype(cfg.dtype)
    pat = cfg.pattern()

    def apply_sb(x, params_sb, cache_sb):
        """One superblock at decode time -> (x, new per-layer caches)."""
        new_cache = {}
        for j, kind in enumerate(pat):
            p = params_sb[f"pos{j}"]
            c = cache_sb[f"pos{j}"]
            h = apply_norm(cfg, p["norm1"], x)
            if kind == "A":
                y, ck, cv = attn.decode_attention(
                    cfg, p["attn"], h, c["k"], c["v"], pos, window=cfg.window)
                x = x + y
                new_cache[f"pos{j}"] = {"k": ck, "v": cv}
                h = apply_norm(cfg, p["norm2"], x)
                if "moe" in p:
                    y, _ = moe_mod.apply_moe(cfg, p["moe"], h)
                    x = x + y
                elif "mlp" in p:
                    x = x + apply_mlp(cfg, p["mlp"], h)
            else:
                y, new_s = ssm_mod.mamba_block(cfg, p["ssm"], h, state=c)
                x = x + y
                new_cache[f"pos{j}"] = new_s
                if "moe" in p:
                    h = apply_norm(cfg, p["norm2"], x)
                    y, _ = moe_mod.apply_moe(cfg, p["moe"], h)
                    x = x + y
                elif "mlp" in p:
                    h = apply_norm(cfg, p["norm2"], x)
                    x = x + apply_mlp(cfg, p["mlp"], h)
        return x, new_cache

    if cfg.scan_layers and cfg.n_superblocks > 1:
        # The stacked caches ride in the CARRY and are updated in place with
        # per-superblock dynamic_update_slice — passing them as scan xs/ys
        # makes XLA materialize a second cache-sized buffer (measured 2.5x
        # cache bytes of temp on the 72B decode cell).
        def body(carry, inp):
            x, caches = carry
            params_sb, i = inp
            cache_sb = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                caches)
            x, new_cache = apply_sb(x, params_sb, cache_sb)
            caches = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                caches, new_cache)
            return (x, caches), None

        (x, new_layers), _ = jax.lax.scan(
            body, (x, state["layers"]),
            (params["layers"], jnp.arange(cfg.n_superblocks)),
            unroll=cfg.n_superblocks if cfg.unroll_inner else 1)
    elif cfg.n_superblocks == 0:
        new_layers = state["layers"]
    else:
        new_list = []
        for i in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[i], params["layers"])
            cb = jax.tree.map(lambda a: a[i], state["layers"])
            x, nc = apply_sb(x, sb, cb)
            new_list.append(nc)
        new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)

    x = apply_norm(cfg, params["final_norm"], x)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    new_state = {"pos": pos + 1, "layers": new_layers}
    return logits, new_state

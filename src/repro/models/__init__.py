"""Model substrate: unified LM stack covering all assigned architectures.

Pure JAX (no flax): params are nested dict pytrees, built from declarative
ParamDef tables so init, sharding specs, and counting share one source of
truth.  Layers are scanned (lax.scan over stacked params) so HLO size is
O(1) in depth — essential for the 512-device dry-run compiles.
"""

from .config import ModelConfig  # noqa: F401

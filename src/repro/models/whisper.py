"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, T_enc, d_model] (post-conv).  Sinusoidal
positions on the encoder, learned-equivalent RoPE-free sinusoidal on the
decoder (backbone exercise — fidelity target is the transformer stack).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (ParamDef, Tree, apply_mlp, apply_norm, init_tree,
                     mlp_defs, norm_defs, sincos_positions, spec_tree)
from .config import ModelConfig


def _enc_layer_defs(cfg) -> Tree:
    return {"norm1": norm_defs(cfg), "attn": attn.attn_defs(cfg),
            "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}


def _dec_layer_defs(cfg) -> Tree:
    return {"norm1": norm_defs(cfg), "self_attn": attn.attn_defs(cfg),
            "norm2": norm_defs(cfg), "cross_attn": attn.attn_defs(cfg),
            "norm3": norm_defs(cfg), "mlp": mlp_defs(cfg)}


def model_defs(cfg: ModelConfig) -> Tree:
    """Encoder-decoder ParamDef tree (embed, enc/dec stacks, norms)."""
    n_enc = cfg.n_enc_layers or cfg.n_layers
    n_dec = cfg.n_layers
    lead = lambda defs, n: jax.tree.map(  # noqa: E731
        lambda pd: pd.with_leading(n), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("T", "F"), "embed"),
        "enc_layers": lead(_enc_layer_defs(cfg), n_enc),
        "enc_norm": norm_defs(cfg),
        "dec_layers": lead(_dec_layer_defs(cfg), n_dec),
        "final_norm": norm_defs(cfg),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    """Materialize model_defs with the config init recipes."""
    return init_tree(model_defs(cfg), key, cfg.dtype)


def param_specs(cfg: ModelConfig) -> Tree:
    """Placeholder PartitionSpec tree matching model_defs."""
    return spec_tree(model_defs(cfg))


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count from the def tree (no allocation)."""
    leaves = jax.tree.leaves(model_defs(cfg),
                             is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def encode(cfg: ModelConfig, params: Tree, frames: jax.Array) -> jax.Array:
    """frames: [B, T_enc, d] (conv-stub output) -> encoder states."""
    T = frames.shape[1]
    x = frames.astype(cfg.dtype) + jnp.asarray(
        sincos_positions(T, cfg.d_model), cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(T), x.shape[:2])

    def body(x, p):
        def blk(p, x):
            h = apply_norm(cfg, p["norm1"], x)
            x = x + attn.attention(cfg, p["attn"], h, positions, causal=False)
            h = apply_norm(cfg, p["norm2"], x)
            return x + apply_mlp(cfg, p["mlp"], h)
        if cfg.remat:
            x = jax.checkpoint(blk, policy=jax.checkpoint_policies.nothing_saveable)(p, x)
        else:
            x = blk(p, x)
        return x, None

    n_enc = cfg.n_enc_layers or cfg.n_layers
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=max(1, n_enc) if cfg.unroll_inner else 1)
    return apply_norm(cfg, params["enc_norm"], x)


def decode_train(cfg: ModelConfig, params: Tree, tokens: jax.Array,
                 memory: jax.Array) -> jax.Array:
    """Teacher-forced decoder: tokens [B, T_dec], memory [B, T_enc, d]."""
    T = tokens.shape[1]
    import math as _m
    x = (jnp.take(params["embed"], tokens, axis=0)
         * _m.sqrt(cfg.d_model)).astype(cfg.dtype)
    x = x + jnp.asarray(sincos_positions(T, cfg.d_model), cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(T), x.shape[:2])

    def body(x, p):
        def blk(p, x):
            h = apply_norm(cfg, p["norm1"], x)
            x = x + attn.attention(cfg, p["self_attn"], h, positions, causal=True)
            h = apply_norm(cfg, p["norm2"], x)
            mem_kv = attn.cross_kv(cfg, p["cross_attn"], memory)
            x = x + attn.cross_attention(cfg, p["cross_attn"], h, mem_kv)
            h = apply_norm(cfg, p["norm3"], x)
            return x + apply_mlp(cfg, p["mlp"], h)
        if cfg.remat:
            x = jax.checkpoint(blk, policy=jax.checkpoint_policies.nothing_saveable)(p, x)
        else:
            x = blk(p, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=max(1, cfg.n_layers) if cfg.unroll_inner else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    return (x @ params["embed"].T).astype(jnp.float32)


def forward(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array]):
    """Encode frames, teacher-forced decode; returns (logits, aux)."""
    memory = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], memory)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array], **_):
    """Masked cross-entropy over valid (label >= 0) positions."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    ce = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce, {"ce": ce, "aux": aux, "zloss": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Cached decode (serve_step): self-attn KV cache + precomputed cross KV
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, params: Tree, batch: int,
                      max_dec: int, memory: jax.Array) -> Tree:
    """Allocate self-attn KV caches and precompute per-layer cross K/V
    ([L, B, T_enc, KV, hd]) so decode steps never re-project the memory."""
    n_dec = cfg.n_layers
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    xk, xv = jax.vmap(lambda pc: attn.cross_kv(cfg, pc, memory))(
        params["dec_layers"]["cross_attn"])
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((n_dec, batch, max_dec, KV, hd), cfg.dtype),
        "v": jnp.zeros((n_dec, batch, max_dec, KV, hd), cfg.dtype),
        "xk": xk, "xv": xv,
    }


def decode_step(cfg: ModelConfig, params: Tree, state: Tree,
                tokens: jax.Array) -> Tuple[jax.Array, Tree]:
    """One decoder token against cached self KV + encoder memory."""
    import math as _m
    pos = state["pos"]
    x = (jnp.take(params["embed"], tokens, axis=0)
         * _m.sqrt(cfg.d_model)).astype(cfg.dtype)
    T_table = 1 << 16  # sincos table bound for decode positions
    # position embedding at `pos` (sin/cos is cheap to compute directly)
    d = cfg.d_model
    i = jnp.arange(d // 2)
    ang = pos.astype(jnp.float32) / (10_000 ** (2 * i / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    x = x + pe.astype(cfg.dtype)

    def body(carry, inp):
        x, ks, vs = carry  # full stacked self-KV caches as carry (in-place)
        p, xk, xv, i = inp
        ck = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
        h = apply_norm(cfg, p["norm1"], x)
        y, ck, cv = attn.decode_attention(cfg, p["self_attn"], h, ck, cv, pos)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        x = x + attn.cross_attention(cfg, p["cross_attn"], h, (xk, xv))
        h = apply_norm(cfg, p["norm3"], x)
        x = x + apply_mlp(cfg, p["mlp"], h)
        ks = jax.lax.dynamic_update_index_in_dim(ks, ck, i, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, cv, i, 0)
        return (x, ks, vs), None

    if cfg.n_layers == 0:  # 0-superblock cost-extrapolation variant
        x = apply_norm(cfg, params["final_norm"], x)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, dict(state, pos=pos + 1)

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, state["k"], state["v"]),
        (params["dec_layers"], state["xk"], state["xv"],
         jnp.arange(cfg.n_layers)),
        unroll=max(1, cfg.n_layers) if cfg.unroll_inner else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    new_state = {"pos": pos + 1, "k": ks, "v": vs,
                 "xk": state["xk"], "xv": state["xv"]}
    return logits, new_state

"""Model configuration dataclass shared by all architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One frozen hyperparameter record describing a model family
    member (dense / ssm / hybrid / moe / audio / vlm)."""
    name: str = "model"
    family: str = "dense"          # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 128
    vocab_size: int = 256

    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    qk_norm: bool = False
    pos: str = "rope"              # rope | mrope | sincos | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w dims (qwen2-vl)
    window: Optional[int] = None   # sliding-window attention size

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1             # every n-th layer is MoE (others dense)
    moe_shared: bool = False       # additional always-on shared expert
    capacity_factor: float = 1.25

    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid stacks: repeating pattern, "A"=attention, "M"=mamba
    layer_pattern: Optional[Tuple[str, ...]] = None

    # encoder-decoder (whisper backbone)
    encdec: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 8             # T_dec = seq_len // dec_ratio in shape cells

    # modality frontend stubs
    frontend: Optional[str] = None  # audio_frames | vision_patches
    vis_tokens: int = 1024          # stub patch-embedding count (vlm)

    tie_embeddings: bool = False

    # numerics / execution
    dtype: object = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False              # shard params along the data axis too
    attn_sp: str = "none"           # none | quorum | ring (long-seq strategy)
    seq_shard: bool = False         # Megatron-style SP: activations sharded
                                    # over the model axis between blocks
                                    # (enabled by the launcher, needs a mesh)
    dp_axes: Tuple[str, ...] = ("data",)  # mesh axes carrying the batch
    tp_axis: str = "model"
    attn_block_k: int = 1024        # kv-block size for blocked attention
    attn_block_threshold: int = 4096  # use blocked path when T >= this
    unroll_inner: bool = False      # unroll inner scans (cost-extrapolation
                                    # compiles need trip counts visible)
    moe_ec_constraint: Optional[str] = None  # None | "ep" | "cap" expert-
                                             # buffer constraints (see moe.py)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived -----------------------------------------------------------
    @property
    def out_scale(self) -> float:
        """GPT-2-style depth-scaled init for residual-branch output
        projections: without it the backward pass amplifies ~2x/layer and
        the embedding gradient at 12 layers measured 1.7e8 (see
        EXPERIMENTS.md Perf E1)."""
        import math
        return 1.0 / math.sqrt(max(1, 2 * self.n_layers))

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        """Mamba2 inner width (expand * d_model)."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        """SSM head count implied by inner width / head dim."""
        return max(1, self.d_inner // self.ssm_head_dim)

    def pattern(self) -> Tuple[str, ...]:
        """Per-layer kinds for one repeating superblock.

        The superblock must span the MoE periodicity so ``is_moe_layer``
        (indexed by pattern position) sees all phases — e.g. maverick's
        alternating dense/MoE becomes ("A", "A") with MoE at position 1.
        """
        if self.layer_pattern is not None:
            return self.layer_pattern
        if self.family == "ssm":
            return ("M",)
        reps = self.moe_every if self.moe_experts else 1
        return ("A",) * max(1, reps)

    @property
    def n_superblocks(self) -> int:
        """How many times the layer pattern repeats."""
        pat = self.pattern()
        assert self.n_layers % len(pat) == 0, (self.n_layers, pat)
        return self.n_layers // len(pat)

    def is_moe_layer(self, layer_in_pattern: int) -> bool:
        """True iff this pattern position carries the MoE MLP."""
        if self.moe_experts == 0:
            return False
        return layer_in_pattern % self.moe_every == (self.moe_every - 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and reports)."""
        from . import lm  # local import to avoid cycle
        return lm.count_params(self)

"""repro — quorum all-pairs reproduction (see DESIGN.md).

Importing any submodule installs the jax version-compat shims first
(:mod:`repro._compat`), so the package presents one API surface across the
jax versions we run on.
"""

from . import _compat

_compat.install()

"""Trace-file reporting: validate and render a ``Tracer`` export
(DESIGN.md section 14.4).

``python -m repro.obs.report trace.json`` loads a Chrome-trace JSON
written by :meth:`obs.trace.Tracer.export` (or any conforming file),
validates its structure, and renders two plain-text tables:

  * **spans** — per span name: count, total / mean / max duration in
    milliseconds (host wall-clock for runtime spans, Python trace time
    for jit-trace spans).
  * **counters** — per counter name: per-device values and the total,
    read from the ``repro.counters`` section when present (exact raw
    totals), else reconstructed from ``ph="C"`` samples.

Exit status is nonzero for a structurally invalid file, so CI's
trace-smoke job can gate on it.  The module is stdlib-only (no jax, no
numpy) — it must run anywhere a trace file lands.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Union

__all__ = [
    "load_trace",
    "validate_chrome_trace",
    "span_summary",
    "counter_summary",
    "render",
]


def load_trace(path: Union[str, Path]) -> Dict[str, Any]:
    """Load and validate a Chrome-trace JSON file; raises ValueError on
    a structurally invalid trace (DESIGN.md section 14.4)."""
    obj = json.loads(Path(path).read_text())
    errors = validate_chrome_trace(obj)
    if errors:
        raise ValueError(
            f"{path}: invalid Chrome trace:\n  " + "\n  ".join(errors))
    return obj


def validate_chrome_trace(obj: Any) -> List[str]:
    """Structural checks on a parsed Chrome-trace object; returns a list
    of problems (empty == valid).  Checks the envelope (``traceEvents``
    list), each event's required fields (``name``/``ph``/``ts``; ``dur
    >= 0`` for ``ph="X"``; ``args.value`` for ``ph="C"``), and — when
    the ``repro`` section is present — its version and counter shape
    (DESIGN.md section 14.4)."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents is missing or not a list"]
    for n, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {n}: not an object")
            continue
        for fld in ("name", "ph", "ts"):
            if fld not in ev:
                errors.append(f"event {n}: missing {fld!r}")
        ph = ev.get("ph")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {n} ({ev.get('name')!r}): ph=X "
                              f"needs dur >= 0, got {dur!r}")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or "value" not in args:
                errors.append(f"event {n} ({ev.get('name')!r}): ph=C "
                              f"needs args.value")
    repro = obj.get("repro")
    if repro is not None:
        if not isinstance(repro, dict):
            errors.append("repro section is not an object")
        else:
            if not isinstance(repro.get("version"), int):
                errors.append("repro.version missing or not an int")
            counters = repro.get("counters", {})
            if not isinstance(counters, dict):
                errors.append("repro.counters is not an object")
            else:
                for name, per_dev in counters.items():
                    if not isinstance(per_dev, dict):
                        errors.append(
                            f"repro.counters[{name!r}] is not an object")
    return errors


def span_summary(obj: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Aggregate ``ph="X"`` events per span name: ``{name: {count,
    total_ms, mean_ms, max_ms}}`` sorted by total descending
    (DESIGN.md section 14.4)."""
    acc: Dict[str, List[float]] = {}
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") == "X":
            acc.setdefault(ev["name"], []).append(float(ev.get("dur", 0.0)))
    out = {
        name: {
            "count": float(len(durs)),
            "total_ms": sum(durs) / 1e3,
            "mean_ms": (sum(durs) / len(durs)) / 1e3,
            "max_ms": max(durs) / 1e3,
        }
        for name, durs in acc.items()
    }
    return dict(sorted(out.items(), key=lambda kv: -kv[1]["total_ms"]))


def counter_summary(obj: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Per-counter ``{name: {device: value, ..., "total": sum}}``; reads
    the exact ``repro.counters`` section when present, else falls back
    to the last ``ph="C"`` sample per (name, pid) (DESIGN.md section
    14.4)."""
    counters: Dict[str, Dict[str, float]] = {}
    repro = obj.get("repro") or {}
    raw = repro.get("counters")
    if isinstance(raw, dict) and raw:
        for name, per_dev in raw.items():
            counters[name] = {str(d): float(v) for d, v in per_dev.items()}
    else:
        for ev in obj.get("traceEvents", []):
            if ev.get("ph") == "C":
                dev = str(ev.get("pid", 0))
                counters.setdefault(ev["name"], {})[dev] = float(
                    ev.get("args", {}).get("value", 0.0))
    for per_dev in counters.values():
        per_dev["total"] = sum(per_dev.values())
    return dict(sorted(counters.items()))


def _fmt_val(v: float) -> str:
    return f"{v:.0f}" if float(v).is_integer() else f"{v:.3f}"


def render(obj: Dict[str, Any]) -> str:
    """Render a validated trace object into the plain-text span +
    counter tables the CLI prints (DESIGN.md section 14.4)."""
    lines: List[str] = []
    repro = obj.get("repro") or {}
    meta = repro.get("meta") or {}
    n_ev = len(obj.get("traceEvents", []))
    lines.append(f"trace: {n_ev} events"
                 + (f", version {repro['version']}" if "version" in repro
                    else "")
                 + (f", meta={meta}" if meta else ""))

    spans = span_summary(obj)
    if spans:
        lines.append("")
        lines.append(f"{'span':32s} {'count':>7s} {'total_ms':>10s} "
                     f"{'mean_ms':>10s} {'max_ms':>10s}")
        for name, s in spans.items():
            lines.append(f"{name:32s} {int(s['count']):7d} "
                         f"{s['total_ms']:10.3f} {s['mean_ms']:10.3f} "
                         f"{s['max_ms']:10.3f}")
    else:
        lines.append("(no span events)")

    counters = counter_summary(obj)
    if counters:
        lines.append("")
        lines.append(f"{'counter':36s} {'per-device':28s} {'total':>14s}")
        for name, per_dev in counters.items():
            devs = {d: v for d, v in per_dev.items() if d != "total"}
            if set(devs) == {"-1"}:
                dev_str = "(program-wide)"
            else:
                dev_str = " ".join(
                    f"{d}:{_fmt_val(v)}" for d, v in sorted(
                        devs.items(), key=lambda kv: int(kv[0])))
            if len(dev_str) > 28:
                dev_str = dev_str[:25] + "..."
            lines.append(f"{name:36s} {dev_str:28s} "
                         f"{_fmt_val(per_dev['total']):>14s}")
    else:
        lines.append("(no counters)")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI: ``python -m repro.obs.report trace.json`` — validate the
    trace file and print the summary tables; returns nonzero on an
    invalid file (the CI trace-smoke gate; DESIGN.md section 14.4)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="validate + summarize a repro Chrome-trace JSON")
    ap.add_argument("trace", help="path to a Tracer-exported JSON file")
    args = ap.parse_args(argv)
    try:
        obj = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"INVALID: {e}")
        return 1
    print(render(obj))
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())

"""The Rocket feedback loop: measured per-device throughput becomes the
capacity weights of weighted pair ownership (DESIGN.md section 14.5).

PR 6 gave ``Placement.owner_of(weights=...)`` a capacity-weighted
partition (Rocket's heterogeneity model, arXiv:2009.04755) but no data
source for the weights.  This module closes the loop from the metrics
the fault-tolerant driver already records:

  1. a sweep runs and :class:`core.faults.RecoveryStats` accumulates
     per-device pairs computed and busy time (virtual busy time is
     deterministic — ``rows_x * rows_y * slow_factor`` per pair — so the
     derived weights are reproducible bit-for-bit);
  2. :func:`throughput_weights` turns (pairs, busy) into a normalized
     per-device throughput vector;
  3. the next sweep passes that vector as ``weights=`` and the slowed
     device owns proportionally fewer pairs — while the *result* stays
     bit-exact, because ownership only decides *where* a pure partial is
     computed, never its value or the canonical fold order.

:func:`feedback_selfcheck` (CLI: ``python -m repro.obs.feedback``)
asserts exactly that: a device slowed ``factor`` x gets a pair share at
most ``ceil(total * w / sum(w))`` — strictly below its unweighted share
— and the reweighted output is bit-identical to the unweighted run and
the brute-force oracle.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from ..core import faults as faults_mod
from ..core.placement import supported_placements

__all__ = [
    "throughput_weights",
    "weights_from_stats",
    "feedback_selfcheck",
]


def throughput_weights(pairs_by_device: Dict[int, float],
                       busy_by_device: Dict[int, float],
                       P: int) -> List[float]:
    """Per-device capacity weights from measured work: throughput_c =
    pairs_c / busy_c, normalized to mean 1 (DESIGN.md section 14.5).

    A device with no observations (it owned no pairs — e.g. it was dead)
    gets the observed mean, i.e. weight 1.0: no evidence means assume
    average capacity, not zero.  Raises ValueError on a non-positive
    busy time for a device that computed pairs.
    """
    tput: Dict[int, float] = {}
    for d, n in pairs_by_device.items():
        if n <= 0:
            continue
        busy = busy_by_device.get(d, 0.0)
        if busy <= 0.0:
            raise ValueError(
                f"device {d} computed {n} pairs with busy time {busy!r}")
        tput[int(d)] = float(n) / float(busy)
    if not tput:
        return [1.0] * P
    mean = sum(tput.values()) / len(tput)
    return [tput.get(d, mean) / mean for d in range(P)]


def weights_from_stats(stats, P: int) -> List[float]:
    """Capacity weights out of a sweep's
    :class:`core.faults.RecoveryStats` — the measured side of the
    feedback loop (DESIGN.md section 14.5).  Uses the deterministic
    virtual busy time, so the same fault history always yields the same
    weights."""
    return throughput_weights(stats.pairs_by_device, stats.busy_by_device,
                              P)


def feedback_selfcheck(P: int = 8, slow_factor: float = 4.0,
                       slow_device: int = 2, mode: str = "batched",
                       placements: Optional[Sequence[str]] = None,
                       verbose: bool = True) -> int:
    """The closed-loop check (DESIGN.md section 14.5; ISSUE 7 acceptance
    criterion): slow one device ``slow_factor`` x via the faults
    harness, derive throughput weights from the traced sweep, re-run
    with ``weights=`` — the slowed device must own at most its
    proportional share ``ceil(total * w / sum(w))`` of pairs (strictly
    fewer than before), and the output must stay bit-exact vs both the
    unweighted run and the brute-force oracle.  Returns the number of
    placements checked; CLI: ``python -m repro.obs.feedback``."""
    n_checked = 0
    for plc in supported_placements(P):
        if placements is not None and plc.name not in placements:
            continue
        if plc.full:
            continue  # no quorum schedule to drive the faults harness
        # equal-size blocks so virtual throughput is exactly 1/factor
        wl = faults_mod.DenseReduceWorkload(P, n_items=8 * P)
        plan = faults_mod.FaultPlan(events=(
            faults_mod.FaultEvent("slow", 0, slow_device,
                                  factor=slow_factor),))

        out1, stats1 = faults_mod.run_fault_tolerant_sweep(
            wl, plc, mode, plan)
        wl.check_oracle(out1)
        weights = weights_from_stats(stats1, P)
        fast = next(d for d in range(P) if d != slow_device)
        assert abs(weights[fast] - slow_factor * weights[slow_device]) \
            < 1e-9, ("virtual throughput ratio must be exactly "
                     f"{slow_factor}, got weights={weights}")

        out2, stats2 = faults_mod.run_fault_tolerant_sweep(
            wl, plc, mode, plan, weights=weights)
        assert wl.equal(out1, out2), (
            f"{plc.name}: reweighted output not bit-exact")

        total = len(wl.canonical_pairs())
        before = stats1.pairs_by_device.get(slow_device, 0)
        after = stats2.pairs_by_device.get(slow_device, 0)
        cap = math.ceil(total * weights[slow_device] / sum(weights))
        assert after <= cap, (
            f"{plc.name}: slowed device owns {after} pairs > "
            f"proportional cap {cap}")
        assert after < before, (
            f"{plc.name}: slowed device share did not shrink "
            f"({before} -> {after})")
        n_checked += 1
        if verbose:
            print(f"  feedback {plc.name:10s} P={P:<3d} {mode:7s}: "
                  f"slow dev {slow_device} x{slow_factor:g} -> "
                  f"{before} -> {after} pairs (cap {cap}, "
                  f"total {total}), bit-exact OK")
    if verbose:
        print(f"feedback selfcheck OK ({n_checked} placements at P={P}: "
              f"slowed device's share shrank proportionally, output "
              f"bit-exact)")
    return n_checked


def _main(argv=None) -> int:
    """CLI: ``python -m repro.obs.feedback [--P 8] [--factor 4]
    [--device 2] [--mode batched] [--placements ...]`` — the
    throughput-weighted ownership selfcheck (DESIGN.md section 14.5)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="closed-loop check: measured throughput -> capacity "
                    "weights -> proportionally smaller share for a "
                    "slowed device, bit-exact output")
    ap.add_argument("--P", type=int, default=8)
    ap.add_argument("--factor", type=float, default=4.0)
    ap.add_argument("--device", type=int, default=2)
    ap.add_argument("--mode", default="batched",
                    choices=["batched", "overlap", "scan"])
    ap.add_argument("--placements", nargs="*", default=None)
    args = ap.parse_args(argv)
    feedback_selfcheck(P=args.P, slow_factor=args.factor,
                       slow_device=args.device, mode=args.mode,
                       placements=args.placements)
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())

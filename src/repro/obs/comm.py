"""Analytical comm-volume predictor for the sweep data plane
(DESIGN.md section 14.3).

Every collective the runtime issues has a statically-known payload —
the schedule's shift structure fixes the hop count and block shapes fix
the bytes — so per-device communication is a pure function of
(placement, block bytes):

  * quorum gather:  one ppermute hop per **nonzero** shift, each moving
    one block — ``(k - 1) * block_bytes`` per device for a difference
    set containing 0.
  * quorum scatter: the inverse shifts move per-slot partials —
    ``(k - 1) * partial_bytes`` per device.
  * full placement: the engine routes through ``lax.all_gather`` —
    ``(P - 1) * block_bytes`` per device and **zero** ppermute hops.
  * serving tree merge: ``ceil(log2 P)`` doubling hops; ring gather:
    ``P - 1`` hops.

Resident bytes per device are ``replication * block_bytes`` — the
paper's O(N/sqrt(P)) replication claim, versus N for all-gather; the
cluster-wide ppermute ratio ``(k-1)/(P-1)`` is the same sqrt saving on
the wire.  The traced actuals (``obs.trace`` counters recorded at jit
trace time, exact because collective shapes are static) must match
these predictions bit-for-bit; :func:`verify_dense_comm` asserts it for
every registered placement and is wired into CI as ``python -m
repro.obs.comm`` (run under fake devices).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.placement import (Placement, resolve_placement,
                              supported_placements)
from . import trace as trace_mod

__all__ = [
    "SweepComm",
    "block_bytes_of",
    "quant_block_bytes",
    "predict_sweep_comm",
    "predict_tree_merge_comm",
    "predict_ring_gather_comm",
    "traced_sweep_comm",
    "verify_dense_comm",
    "verify_quant_comm",
]


def block_bytes_of(block: int, dim: int, dtype: str = "float32") -> int:
    """One [block, dim] quorum block's payload bytes under ``dtype``
    (DESIGN.md section 14.3) — the predictor's dtype-itemsize
    parametrization; ``int8``/``bfloat16`` stacks shrink every gather
    hop by the same 4x/2x their residency shrinks."""
    return block * dim * np.dtype(dtype).itemsize


def quant_block_bytes(block: int, dim: int, mode: str) -> int:
    """One quantized block's per-hop gather payload (DESIGN.md section
    17.1): the [block, dim] codes at the mode's itemsize plus the side
    arrays that ride the same shifts — scale + delta (two f32 scalars)
    and the l1 + sq f32 rows.  Mirrors core.quant's QuantBlocks pytree
    leaf-for-leaf, so the traced gather bytes of a quantized sweep
    equal ``nonzero_shifts * quant_block_bytes`` exactly."""
    from ..core.quant import quant_itemsize
    return block * dim * quant_itemsize(mode) + 8 + 8 * block


@dataclasses.dataclass(frozen=True)
class SweepComm:
    """Predicted per-device communication of one sweep under a placement
    (DESIGN.md section 14.3).  All byte fields are **per device**; the
    SPMD programs are symmetric, so the cluster total is ``P x`` each."""

    P: int
    placement: str
    block_bytes: int
    partial_bytes: int
    gather_hops: int
    scatter_hops: int
    gather_bytes: int
    scatter_bytes: int
    allgather_bytes: int
    resident_bytes: int

    @property
    def ppermute_bytes(self) -> int:
        """Total per-device ppermute bytes (gather + scatter)."""
        return self.gather_bytes + self.scatter_bytes

    def as_dict(self) -> Dict[str, int]:
        """The prediction as a plain dict (benchmark JSON output)."""
        return dataclasses.asdict(self)


def predict_sweep_comm(placement, block_bytes: int, *,
                       partial_bytes: Optional[int] = None,
                       P: Optional[int] = None) -> SweepComm:
    """Predict one sweep's per-device comm volume under ``placement``
    (a Placement or spec name; ``P`` required for a name) — the
    analytical side of the DESIGN.md section 14.3 cross-check.

    ``block_bytes`` is one block's payload; ``partial_bytes`` the
    per-slot scatter payload (defaults to ``block_bytes`` — exact for
    emitters whose partials have the block's shape).  A full placement
    predicts zero ppermute hops and the all-gather baseline instead.
    """
    if not isinstance(placement, Placement):
        if P is None:
            raise ValueError("P is required when placement is a spec name")
        placement = resolve_placement(placement, P)
    pb = int(block_bytes) if partial_bytes is None else int(partial_bytes)
    bb = int(block_bytes)
    resident = placement.replication * bb
    if placement.full:
        return SweepComm(
            P=placement.P, placement=placement.name, block_bytes=bb,
            partial_bytes=pb, gather_hops=0, scatter_hops=0,
            gather_bytes=0, scatter_bytes=0,
            allgather_bytes=(placement.P - 1) * bb,
            resident_bytes=resident)
    sched = placement.schedule()
    nz = sum(1 for a in sched.shifts if int(a) % placement.P != 0)
    return SweepComm(
        P=placement.P, placement=placement.name, block_bytes=bb,
        partial_bytes=pb, gather_hops=nz, scatter_hops=nz,
        gather_bytes=nz * bb, scatter_bytes=nz * pb, allgather_bytes=0,
        resident_bytes=resident)


def predict_tree_merge_comm(P: int, payload_bytes: int) -> Dict[str, int]:
    """Per-device comm of the serving recursive-doubling top-k merge:
    one ppermute hop per shift doubling (``ceil(log2 P)`` hops), each
    moving the running candidate payload (DESIGN.md sections 9, 14.3)."""
    hops = 0
    shift = 1
    while shift < P:
        hops += 1
        shift *= 2
    return {"hops": hops, "bytes": hops * int(payload_bytes)}


def predict_ring_gather_comm(P: int, payload_bytes: int) -> Dict[str, int]:
    """Per-device comm of the thresholded-query ppermute ring gather:
    ``P - 1`` single-step hops, each moving the full buffer payload
    (DESIGN.md sections 11.4, 14.3)."""
    return {"hops": max(0, P - 1),
            "bytes": max(0, P - 1) * int(payload_bytes)}


def traced_sweep_comm(tracer) -> Dict[str, int]:
    """The traced per-device comm actuals out of a tracer's counters —
    the empirical side of the DESIGN.md section 14.3 cross-check."""
    return {
        "gather_bytes": int(tracer.counter_total(
            "comm.ppermute.gather_bytes")),
        "scatter_bytes": int(tracer.counter_total(
            "comm.ppermute.scatter_bytes")),
        "gather_hops": int(tracer.counter_total(
            "comm.ppermute.gather_hops")),
        "scatter_hops": int(tracer.counter_total(
            "comm.ppermute.scatter_hops")),
        "allgather_bytes": int(tracer.counter_total("comm.allgather.bytes")),
    }


def verify_dense_comm(P: Optional[int] = None,
                      placements: Optional[Sequence[str]] = None,
                      *, block: int = 4, dim: int = 3,
                      mode: str = "batched", dtype: str = "float32",
                      verbose: bool = True) -> List[Dict[str, int]]:
    """Run one dense sweep per registered placement under a fresh tracer
    and assert the traced ppermute / all-gather bytes equal the
    analytical prediction **exactly** (DESIGN.md section 14.3; the CI
    trace-smoke cross-check, ``python -m repro.obs.comm``).

    Needs ``P`` jax devices (fake-device subprocesses in tests).  The
    toy pair function emits block-shaped partials, so
    ``partial_bytes == block_bytes`` and the default prediction is
    exact.  ``dtype`` parametrizes the block itemsize
    (:func:`block_bytes_of`) — ``bfloat16``/``int8`` stacks must trace
    to proportionally smaller hops.  Returns one traced-actuals dict
    per placement checked.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from ..core.allpairs import quorum_allpairs

    devs = jax.devices()
    Pn = P or len(devs)
    if len(devs) < Pn:
        raise RuntimeError(f"need {Pn} devices, have {len(devs)}")
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(Pn * block, dim)) * 10).astype(dtype)
    block_bytes = block_bytes_of(block, dim, dtype)

    def pair_fn(bi, bj):
        # out_j(bi, bj) == out_i(bj, bi): the engine's symmetry contract;
        # cast back to the stack dtype (jnp.sum promotes int8 -> int32)
        # so partial_bytes == block_bytes holds at every swept dtype
        return ((bi * jnp.sum(bj * bj)).astype(bi.dtype),
                (bj * jnp.sum(bi * bi)).astype(bj.dtype))

    out: List[Dict[str, int]] = []
    try:
        for plc in supported_placements(Pn):
            if placements is not None and plc.name not in placements:
                continue
            tracer = trace_mod.configure()

            def f(xb):
                return quorum_allpairs(pair_fn, xb, axis_name="q",
                                       mode=mode, placement=plc)

            run = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=PS("q"),
                                        out_specs=PS("q")))
            np.asarray(run(x))  # trace + run once: counters fire per trace
            pred = predict_sweep_comm(plc, block_bytes)
            got = traced_sweep_comm(tracer)
            for field in ("gather_bytes", "scatter_bytes", "gather_hops",
                          "scatter_hops", "allgather_bytes"):
                want = getattr(pred, field) if field != "allgather_bytes" \
                    else pred.allgather_bytes
                assert got[field] == want, (
                    f"{plc.name} P={Pn}: traced {field}={got[field]} != "
                    f"predicted {want}")
            out.append({"placement": plc.name, **got})
            if verbose:
                print(f"  comm {plc.name:10s} P={Pn:<3d} mode={mode}: "
                      f"gather={got['gather_bytes']}B x{got['gather_hops']} "
                      f"scatter={got['scatter_bytes']}B "
                      f"allgather={got['allgather_bytes']}B == predicted")
    finally:
        trace_mod.reset()
    if verbose:
        print(f"comm predictor OK: {len(out)} placement(s) at P={Pn} "
              f"dtype={dtype}, traced == predicted exactly")
    return out


def verify_quant_comm(P: Optional[int] = None,
                      placements: Optional[Sequence[str]] = None,
                      *, block: int = 4, dim: int = 3,
                      qmode: str = "int8",
                      verbose: bool = True) -> List[Dict[str, int]]:
    """Gather one quantized QuantBlocks stack per registered placement
    under a fresh tracer and assert the traced ppermute gather bytes
    equal ``nonzero_shifts * quant_block_bytes`` exactly (DESIGN.md
    sections 14.3, 17.1) — the quantized twin of
    :func:`verify_dense_comm`, pinning the side arrays' payload
    accounting to the predictor formula.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from ..core import sweep as sweep_mod
    from ..core.quant import QuantBlocks, quantize_corpus

    devs = jax.devices()
    Pn = P or len(devs)
    if len(devs) < Pn:
        raise RuntimeError(f"need {Pn} devices, have {len(devs)}")
    mesh = jax.make_mesh((Pn,), ("q",), devices=devs[:Pn])
    rng = np.random.default_rng(0)
    x = rng.normal(size=(Pn * block, dim)).astype(np.float32)
    qc = quantize_corpus(x, Pn, block, qmode)
    leaves = qc.device_arrays()
    payload = quant_block_bytes(block, dim, qmode)

    out: List[Dict[str, int]] = []
    try:
        for plc in supported_placements(Pn):
            if placements is not None and plc.name not in placements:
                continue
            sched = plc.schedule()
            tracer = trace_mod.configure()

            def f(q, s, d_, l1, sq):
                qb = QuantBlocks(q=q, scale=s, delta=d_, l1=l1, sq=sq)
                g = sweep_mod.quorum_gather(qb, sched, "q")
                return g.q

            spec = PS("q")
            run = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(spec,) * 5,
                                        out_specs=spec))
            np.asarray(run(*leaves))
            got = traced_sweep_comm(tracer)
            nz = sum(1 for a in sched.shifts if int(a) % plc.P != 0)
            want = nz * payload
            assert got["gather_bytes"] == want, (
                f"{plc.name} P={Pn} quant={qmode}: traced gather_bytes="
                f"{got['gather_bytes']} != predicted {want}")
            assert got["gather_hops"] == nz, (
                f"{plc.name} P={Pn} quant={qmode}: traced gather_hops="
                f"{got['gather_hops']} != {nz}")
            out.append({"placement": plc.name, "qmode": qmode, **got})
            if verbose:
                print(f"  quant comm {plc.name:10s} P={Pn:<3d} "
                      f"quant={qmode}: gather={got['gather_bytes']}B "
                      f"x{got['gather_hops']} == predicted")
    finally:
        trace_mod.reset()
    if verbose:
        print(f"quant comm predictor OK: {len(out)} placement(s) at "
              f"P={Pn} quant={qmode}, traced == predicted exactly")
    return out


def _main(argv=None) -> int:
    """CLI: ``python -m repro.obs.comm [--P N] [--placements ...]
    [--mode batched] [--dtype float32] [--quant int8]`` — the
    predictor-vs-traced equality check (DESIGN.md section 14.3); with
    ``--quant`` it also pins the quantized-stack gather payload
    (DESIGN.md section 17.1)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="assert traced ppermute bytes == analytical "
                    "prediction for every registered placement")
    ap.add_argument("--P", type=int, default=None)
    ap.add_argument("--placements", nargs="*", default=None)
    ap.add_argument("--mode", default="batched",
                    choices=["batched", "overlap", "scan"])
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--quant", default=None, choices=["int8", "bf16"])
    args = ap.parse_args(argv)
    verify_dense_comm(args.P, args.placements, mode=args.mode,
                      dtype=args.dtype)
    if args.quant is not None:
        verify_quant_comm(args.P, args.placements, qmode=args.quant)
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())

"""Structured tracing + metrics for the sweep runtime (DESIGN.md
section 14).

One :class:`Tracer` collects two kinds of records:

  * **spans** — named, nested wall-clock intervals with attributes
    (mode, placement, P, round id, ...).  Two families by *when* the
    code runs: trace-time spans (inside a jit trace — they measure the
    Python tracing of a program, and their counters are exact because
    collective shapes are static) and host-side runtime spans (the
    fault-tolerant driver's rounds, serving per-query latency).
  * **counters** — a monotonic value per (name, device) key; the
    comm-volume counters (``comm.ppermute.*``, ``comm.allgather.*``)
    record **bytes per device** (the SPMD programs are symmetric), the
    driver counters record cluster totals.  The taxonomy is DESIGN.md
    section 14.2.

Activation (read through the ``core.env`` registry at call time, cached
on the raw environment values):

  * ``REPRO_TRACE=0`` / unset — off: :func:`get_tracer` returns the
    falsy :data:`NOOP` singleton and instrumented call sites early-out
    (zero-cost: no span objects, no attribute dicts).
  * ``REPRO_TRACE=1`` — on; the Chrome-trace JSON is written to
    ``repro_trace.json`` in the working directory at process exit.
  * ``REPRO_TRACE=<path>`` — on; written to ``<path>`` at exit.
  * ``REPRO_METRICS=<n>=1`` — counters only: no span events, no file
    unless exported explicitly.

The exported file is Chrome-trace format (``{"traceEvents": [...]}``
with ``ph="X"`` complete events and ``ph="C"`` counter samples —
loadable in Perfetto / chrome://tracing) plus a ``repro`` section
carrying the raw counter totals for exact predictor comparison
(``obs.comm``).  This module stays jax-free so the report CLI and the
host drivers never pay a jax import for it; the optional
``jax.profiler`` annotation hook imports lazily.
"""

from __future__ import annotations

import atexit
import json
import os
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from ..core import env as env_mod

__all__ = [
    "Tracer",
    "NoopTracer",
    "NOOP",
    "get_tracer",
    "configure",
    "reset",
    "nbytes_of",
    "DEFAULT_TRACE_PATH",
    "TRACE_FORMAT_VERSION",
]

DEFAULT_TRACE_PATH = "repro_trace.json"
TRACE_FORMAT_VERSION = 1


def nbytes_of(x: Any) -> int:
    """Static byte size of an array-like (works on jax tracers — shape
    and dtype are static during a jit trace, which is what makes the
    traced comm counters exact; DESIGN.md section 14.2)."""
    return int(x.size) * int(np.dtype(x.dtype).itemsize)


class _NoopSpan:
    """The shared do-nothing context manager disabled span sites get."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """The disabled tracer: falsy, so instrumented sites guard with
    ``tr = get_tracer(); if tr: ...`` and pay nothing when tracing is
    off (DESIGN.md section 14.1)."""

    __slots__ = ()
    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **attrs) -> _NoopSpan:
        """No-op span: returns the shared singleton context manager."""
        return _NOOP_SPAN

    def record(self, name: str, dur_s: float = 0.0, **attrs) -> None:
        """No-op completed-span record."""

    def count(self, name: str, value: Union[int, float] = 1, *,
              device: int = -1) -> None:
        """No-op counter increment."""


NOOP = NoopTracer()


class _Span:
    """One live span interval (context manager); appended to the owning
    tracer's event list on exit.  ``attrs`` is stored by reference, so
    code inside the ``with`` block may add result attributes."""

    __slots__ = ("tracer", "name", "device", "attrs", "start", "depth",
                 "_ann")

    def __init__(self, tracer: "Tracer", name: str, device: int,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.device = device
        self.attrs = attrs
        self._ann = None

    def __enter__(self) -> "_Span":
        tr = self.tracer
        self.depth = len(tr._stack)
        if tr._stack:
            self.attrs.setdefault("parent", tr._stack[-1])
        tr._stack.append(self.name)
        if tr.profiler:  # optional jax.profiler annotation hook
            try:
                from jax.profiler import TraceAnnotation
                self._ann = TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:  # pragma: no cover - jax absent / old
                self._ann = None
        self.start = tr._now_us()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self.tracer
        end = tr._now_us()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr._stack.pop()
        attrs = dict(self.attrs)
        attrs["depth"] = self.depth
        tr.events.append({
            "name": self.name, "ph": "X", "ts": self.start,
            "dur": max(0.0, end - self.start),
            "pid": self.device if self.device >= 0 else 0,
            "tid": 0, "cat": "repro", "args": attrs,
        })
        return False


class Tracer:
    """The enabled tracer: span + counter collection and Chrome-trace
    export (DESIGN.md section 14.1).

    ``path`` is where :meth:`export` writes by default (the env-driven
    tracer flushes there at process exit).  ``metrics_only`` drops span
    events (the ``REPRO_METRICS`` mode).  ``profiler`` additionally
    wraps every span in a ``jax.profiler.TraceAnnotation`` so spans
    land in an XLA profile too (optional hook; lazily imported).
    """

    enabled = True

    def __init__(self, path: Optional[Union[str, Path]] = None,
                 metrics_only: bool = False, profiler: bool = False):
        self.path = Path(path) if path is not None else None
        self.metrics_only = metrics_only
        self.profiler = profiler
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[Tuple[str, int], float] = {}
        self.meta: Dict[str, Any] = {}
        self._stack: List[str] = []
        self._t0 = time.perf_counter()

    def __bool__(self) -> bool:
        return True

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # -- recording --------------------------------------------------------
    def span(self, name: str, *, device: int = -1, **attrs):
        """Open a nested span context manager (``with tr.span(...):``).

        ``device`` tags the span's pid lane (-1 = host / all devices);
        remaining keyword arguments become span attributes.  In
        ``metrics_only`` mode spans are skipped entirely."""
        if self.metrics_only:
            return _NOOP_SPAN
        return _Span(self, name, device, dict(attrs))

    def record(self, name: str, dur_s: float = 0.0, *, device: int = -1,
               **attrs) -> None:
        """Append an already-timed span of ``dur_s`` seconds ending now
        (for call sites that measured themselves)."""
        if self.metrics_only:
            return
        attrs = dict(attrs)
        attrs["depth"] = len(self._stack)
        end = self._now_us()
        self.events.append({
            "name": name, "ph": "X",
            "ts": max(0.0, end - dur_s * 1e6), "dur": dur_s * 1e6,
            "pid": device if device >= 0 else 0, "tid": 0,
            "cat": "repro", "args": attrs,
        })

    def count(self, name: str, value: Union[int, float] = 1, *,
              device: int = -1) -> None:
        """Add ``value`` to counter ``name`` for ``device`` (-1 = the
        per-device SPMD value / cluster scope, per the DESIGN.md 14.2
        taxonomy)."""
        key = (name, int(device))
        self.counters[key] = self.counters.get(key, 0) + value

    # -- reading ----------------------------------------------------------
    def counter_total(self, name: str) -> float:
        """Sum of ``name`` across all device keys."""
        return sum(v for (n, _d), v in self.counters.items() if n == name)

    def counters_by_device(self, name: str) -> Dict[int, float]:
        """``{device: value}`` for counter ``name``."""
        return {d: v for (n, d), v in self.counters.items() if n == name}

    def counter_names(self) -> List[str]:
        """Sorted distinct counter names."""
        return sorted({n for (n, _d) in self.counters})

    # -- export -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The collected data as a Chrome-trace dict: span events plus
        one ``ph="C"`` counter sample per (name, device), and the raw
        totals under the ``repro`` key for exact comparison."""
        now = self._now_us()
        events = list(self.events)
        for (name, dev), val in sorted(self.counters.items()):
            events.append({
                "name": name, "ph": "C", "ts": now,
                "pid": dev if dev >= 0 else 0, "cat": "repro",
                "args": {"value": val},
            })
        counters: Dict[str, Dict[str, float]] = {}
        for (name, dev), val in sorted(self.counters.items()):
            counters.setdefault(name, {})[str(dev)] = val
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "repro": {
                "version": TRACE_FORMAT_VERSION,
                "clock": "relative-us",
                "counters": counters,
                "meta": dict(self.meta),
            },
        }

    def export(self, path: Optional[Union[str, Path]] = None) -> Path:
        """Write the Chrome-trace JSON to ``path`` (default: the
        tracer's configured path) and return the written path."""
        out = Path(path) if path is not None else self.path
        if out is None:
            raise ValueError("no export path: pass one or construct the "
                             "Tracer with path=...")
        out.write_text(json.dumps(self.chrome_trace(), indent=1) + "\n")
        return out


# ---------------------------------------------------------------------------
# Activation: env knobs + programmatic override
# ---------------------------------------------------------------------------

_forced: Optional[Tracer] = None
_env_key: Optional[Tuple[str, str]] = None
_env_tracer: Union[Tracer, NoopTracer] = NOOP
_atexit_registered = False


def _flush_env_tracer() -> None:
    t = _env_tracer
    if isinstance(t, Tracer) and t.path is not None and (
            t.events or t.counters):
        t.export()


def _build_env_tracer() -> Union[Tracer, NoopTracer]:
    global _atexit_registered
    trace = env_mod.read_knob("REPRO_TRACE")
    metrics = env_mod.read_knob("REPRO_METRICS")
    if trace in (None, "0"):
        if not metrics:
            return NOOP
        return Tracer(metrics_only=True)
    path = DEFAULT_TRACE_PATH if trace == "1" else trace
    if not _atexit_registered:
        atexit.register(_flush_env_tracer)
        _atexit_registered = True
    return Tracer(path=path)


def get_tracer() -> Union[Tracer, NoopTracer]:
    """The active tracer (DESIGN.md section 14.1): a :func:`configure`d
    one if set, else the ``REPRO_TRACE`` / ``REPRO_METRICS`` selection
    (cached on the raw environment values, so the disabled fast path is
    two environment reads and a tuple compare).  Falsy when disabled —
    instrumented sites guard with ``if tr:``."""
    if _forced is not None:
        return _forced
    global _env_key, _env_tracer
    key = (os.environ.get("REPRO_TRACE") or "",
           os.environ.get("REPRO_METRICS") or "")
    if key != _env_key:
        _env_tracer = _build_env_tracer()
        _env_key = key
    return _env_tracer


def configure(path: Optional[Union[str, Path]] = None,
              metrics_only: bool = False,
              profiler: bool = False) -> Tracer:
    """Programmatically activate a fresh :class:`Tracer` (overriding the
    environment selection) and return it — the test / selfcheck entry
    point (DESIGN.md section 14.1).  Pair with :func:`reset`."""
    global _forced
    _forced = Tracer(path=path, metrics_only=metrics_only,
                     profiler=profiler)
    return _forced


def reset() -> None:
    """Drop any :func:`configure`d tracer and the environment cache, so
    the next :func:`get_tracer` re-reads ``REPRO_TRACE`` /
    ``REPRO_METRICS`` (DESIGN.md section 14.1)."""
    global _forced, _env_key, _env_tracer
    _forced = None
    _env_key = None
    _env_tracer = NOOP

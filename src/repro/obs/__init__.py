"""Observability for the pair-sweep runtime (DESIGN.md section 14).

Four modules, deliberately thin so instrumented hot paths stay cheap:

  * ``obs.trace``    — the :class:`Tracer`: structured spans + a counter
    registry, Chrome-trace (Perfetto-loadable) JSON export, and the
    ``REPRO_TRACE`` / ``REPRO_METRICS`` activation knobs (off = a falsy
    no-op singleton, so disabled call sites cost one cached lookup).
  * ``obs.comm``     — the analytical comm-volume predictor over the
    placement/schedule layer (bytes per device from residency + shifts,
    the paper's O(N/sqrt(P)) claim) and the predictor-vs-traced
    cross-check CLI (``python -m repro.obs.comm``).
  * ``obs.report``   — ``python -m repro.obs.report trace.json``:
    validate a trace file and render per-phase / per-device tables.
  * ``obs.feedback`` — per-device throughput estimates from sweep
    metrics, fed back as the capacity weights of
    ``core.placement.weighted_owner_table`` (the Rocket loop), with a
    slowed-device selfcheck (``python -m repro.obs.feedback``).

Only ``obs.trace`` is imported here: ``obs.feedback`` imports
``core.faults`` (which itself imports ``obs.trace``), so the package
root must stay cycle-free.
"""

from .trace import NoopTracer, Tracer, configure, get_tracer, nbytes_of, reset

__all__ = [
    "Tracer",
    "NoopTracer",
    "get_tracer",
    "configure",
    "reset",
    "nbytes_of",
]

from .pipeline import DataConfig, make_pipeline, synthetic_batches  # noqa: F401

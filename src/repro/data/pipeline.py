"""Token data pipeline: deterministic synthetic streams and binary token
files, with host-side double-buffered prefetch and per-shape batch shaping.

Design points for scale (DESIGN.md section 8):
  * deterministic seeding by (seed, step) — restart-safe: resuming from a
    checkpoint at step k regenerates exactly the batches k, k+1, ...
  * sharded placement: batches are created with the same NamedSharding as
    the train step expects, so no implicit host->device reshard happens
  * background prefetch thread keeps one batch ahead of the step loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Data-source settings shared by the synthetic and file loaders."""
    kind: str = "synthetic"       # synthetic | file
    path: Optional[str] = None    # .bin of uint16/uint32 tokens (file kind)
    seed: int = 0
    vocab_size: int = 256
    batch: int = 8
    seq_len: int = 128
    # modality stubs
    frontend: Optional[str] = None
    d_model: int = 0
    vis_tokens: int = 0
    dec_ratio: int = 8


def _synthetic_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """Markov-ish synthetic tokens: learnable structure so a ~100M model's
    loss visibly falls (examples/train_lm.py uses this)."""
    # the walk lives in a <=512-token alphabet regardless of vocab size:
    # with the full 32k alphabet each embedding row is visited ~once per 40
    # steps and a few-hundred-step example budget cannot move the loss
    # (measured plateau at ~uniform CE).
    rng = np.random.default_rng((cfg.seed, step))
    B, T = cfg.batch, cfg.seq_len
    alpha = min(cfg.vocab_size, 512)
    base = rng.integers(0, alpha, size=(B, 1))
    steps = rng.integers(-2, 3, size=(B, T)).cumsum(axis=1)
    toks = (base + np.abs(steps)) % alpha
    return toks.astype(np.int32)


def _file_tokens(cfg: DataConfig, step: int, arr: np.ndarray) -> np.ndarray:
    B, T = cfg.batch, cfg.seq_len
    n = arr.shape[0] - (T + 1)
    rng = np.random.default_rng((cfg.seed, step))
    starts = rng.integers(0, max(1, n), size=(B,))
    return np.stack([arr[s:s + T + 1] for s in starts]).astype(np.int32)


def make_batch(cfg: DataConfig, step: int, arr: Optional[np.ndarray] = None
               ) -> Dict[str, np.ndarray]:
    """One deterministic (tokens, labels) batch for ``step``."""
    if cfg.kind == "file":
        assert arr is not None
        chunk = _file_tokens(cfg, step, arr)     # [B, T+1]
        tokens, labels = chunk[:, :-1], chunk[:, 1:]
    else:
        tokens = _synthetic_tokens(cfg, step)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision_patches":
        rng = np.random.default_rng((cfg.seed, step, 7))
        batch["vision_embeds"] = rng.normal(
            size=(cfg.batch, cfg.vis_tokens, cfg.d_model)).astype(np.float32)
    elif cfg.frontend == "audio_frames":
        rng = np.random.default_rng((cfg.seed, step, 7))
        batch["frames"] = rng.normal(
            size=(cfg.batch, cfg.seq_len, cfg.d_model)).astype(np.float32)
        Td = max(1, cfg.seq_len // cfg.dec_ratio)
        batch["tokens"] = batch["tokens"][:, :Td]
        batch["labels"] = batch["labels"][:, :Td]
    return batch


def synthetic_batches(cfg: DataConfig, start_step: int = 0
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Endless batch iterator (file-backed when cfg.kind == "file")."""
    arr = None
    if cfg.kind == "file":
        raw = np.fromfile(cfg.path, dtype=np.uint16)
        arr = raw.astype(np.int32) % cfg.vocab_size
    step = start_step
    while True:
        yield make_batch(cfg, step, arr)
        step += 1


def make_pipeline(cfg: DataConfig, shardings=None, start_step: int = 0,
                  prefetch: int = 2) -> Iterator[Dict[str, jax.Array]]:
    """Device-placed, background-prefetched batch stream."""
    src = synthetic_batches(cfg, start_step)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def put(batch):
        if shardings is not None:
            return {k: jax.device_put(v, shardings.get(k)) for k, v in
                    batch.items()}
        return {k: jax.device_put(v) for k, v in batch.items()}

    def worker():
        for b in src:
            if stop.is_set():
                return
            q.put(put(b))

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()

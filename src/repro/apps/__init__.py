"""All-pairs application instances built on the quorum engine:

  pcit.py      — the paper's own evaluation app (gene co-expression, section 5)
  attention.py — quorum sequence-parallel block attention (beyond-paper)
  nbody.py     — direct-interaction n-body forces (paper's motivating family)
"""

"""Quorum sequence-parallel block attention (beyond-paper application).

Causal attention over sequence blocks IS the all-pairs problem (triangular):
every (q-block, kv-block) pair with kv <= q must meet in some device's memory.
Ring attention solves this with P-1 sequential ppermute steps; the quorum
schedule needs only k-1 ~ sqrt(P) gather shifts plus a k-shift partial-result
reduce — Theta(sqrt(P)) fewer collective steps and a 2-phase (not P-phase)
dependency structure (DESIGN.md section 2).

Partial softmax results combine with the exact flash-attention monoid
(m, l, o): associative and commutative, so quorum_scatter order is irrelevant.

Both the quorum and ring variants are validated against plain full attention.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.allpairs import quorum_gather
from ..core.scheduler import CausalSchedule, build_causal_schedule

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Block-pair flash attention (jnp path; kernels/flash_attention.py on TPU)
# ---------------------------------------------------------------------------

def flash_block(q, k, v, *, causal_diag: bool):
    """Partial attention of one (q-block, kv-block) pair.

    q: [B, Tq, H, hd]; k/v: [B, Tk, KV, hd].
    Returns (o [B, Tq, H, hd] fp32 — UNNORMALIZED (o = sum exp(s - m) v),
             m [B, Tq, H] row max, l [B, Tq, H] row sum-exp).
    causal_diag: apply the triangular mask (the d=0 self block).
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32) / math.sqrt(hd),
                   k.astype(jnp.float32))                  # [B,KV,G,Tq,Tk]
    if causal_diag:
        Tk = k.shape[1]
        msk = np.tril(np.ones((Tq, Tk), np.bool_))
        s = jnp.where(msk, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                # [B,KV,G,Tq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    # reshape to [B, Tq, H, ...]
    o = o.reshape(B, KV * G, Tq, hd).transpose(0, 2, 1, 3)
    m = m.reshape(B, KV * G, Tq).transpose(0, 2, 1)
    l = l.reshape(B, KV * G, Tq).transpose(0, 2, 1)
    return o, m, l


def merge_partials(a: Tuple, b: Tuple) -> Tuple:
    """Exact flash monoid on (o, m, l) with unnormalized o."""
    oa, ma, la = a
    ob, mb, lb = b
    m = jnp.maximum(ma, mb)
    ca = jnp.exp(ma - m)
    cb = jnp.exp(mb - m)
    return (oa * ca[..., None] + ob * cb[..., None], m, la * ca + lb * cb)


def empty_partial(shape_q, H, dtype=jnp.float32):
    """Identity element of the flash (o, m, l) merge monoid."""
    B, Tq, hd = shape_q
    return (jnp.zeros((B, Tq, H, hd), dtype),
            jnp.full((B, Tq, H), NEG_INF, dtype),
            jnp.zeros((B, Tq, H), dtype))


# ---------------------------------------------------------------------------
# Quorum attention (inside shard_map; sequence sharded over axis_name)
# ---------------------------------------------------------------------------

def quorum_attention_local(q, k, v, valid_row, *, schedule: CausalSchedule,
                           axis_name: str):
    """Per-device body.  q/k/v: local sequence block [B, T/P, H|KV, hd];
    valid_row: [n_pairs] this device's causal-validity mask
    (schedule.valid[i]).  Returns normalized context [B, T/P, H, hd].
    """
    B, Tq, H, hd = q.shape
    valid_row = valid_row.reshape(-1)
    kq = quorum_gather(q, schedule, axis_name)   # [k, B, T, H, hd]
    kk = quorum_gather(k, schedule, axis_name)
    kv = quorum_gather(v, schedule, axis_name)
    ksz = schedule.k

    lo_s = schedule.pair_slots[:, 0]   # kv side (static numpy)
    hi_s = schedule.pair_slots[:, 1]   # q side
    diffs = schedule.pair_diff

    acc = jax.tree.map(
        lambda a: lax.pcast(jnp.zeros((ksz,) + a.shape, a.dtype), axis_name,
                            to="varying"),
        empty_partial((B, Tq, hd), H))
    # m must start at NEG_INF, not 0
    acc = (acc[0], acc[1] + NEG_INF, acc[2])

    n_pairs = schedule.n_pairs
    for s in range(n_pairs):  # static loop: pair count is ~P, bodies fuse
        lo, hi, d = int(lo_s[s]), int(hi_s[s]), int(diffs[s])
        qb, kb, vb = kq[hi], kk[lo], kv[lo]
        o, m, l = flash_block(qb, kb, vb, causal_diag=(d == 0))
        w = valid_row[s]
        m = jnp.where(w > 0, m, NEG_INF)
        o = o * w
        l = l * w
        part = (acc[0][hi], acc[1][hi], acc[2][hi])
        o, m, l = merge_partials(part, (o, m, l))
        acc = (acc[0].at[hi].set(o), acc[1].at[hi].set(m), acc[2].at[hi].set(l))

    # route partials back to q-block owners with the flash monoid
    P = schedule.P
    shifts = [int(x) for x in schedule.shifts]

    def shift_back(t, a):
        if a == 0:
            return t
        perm = [(j, (j + a) % P) for j in range(P)]
        return jax.tree.map(lambda x: lax.ppermute(x, axis_name, perm), t)

    total = None
    for slot, a in enumerate(shifts):
        part = (acc[0][slot], acc[1][slot], acc[2][slot])
        arrived = shift_back(part, a)
        total = arrived if total is None else merge_partials(total, arrived)

    o, m, l = total
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Ring attention baseline (P-1 sequential steps)
# ---------------------------------------------------------------------------

def ring_attention_local(q, k, v, *, axis_name: str, axis_size: int):
    """Classic ring: rotate (k, v) P-1 times; accumulate causal partials."""
    B, Tq, H, hd = q.shape
    P = axis_size
    i = lax.axis_index(axis_name)
    perm = [(j, (j + 1) % P) for j in range(P)]

    def step(carry, t):
        (o, m, l), (kc, vc) = carry
        src = (i - t) % P                     # global block id of current kv
        is_diag = src == i
        causal_ok = src <= i
        ob, mb, lb = flash_block(q, kc, vc, causal_diag=False)
        # diagonal needs the triangular mask; recompute masked version and
        # select (uniform control flow across devices)
        od, md, ld = flash_block(q, kc, vc, causal_diag=True)
        ob = jnp.where(is_diag, od, ob)
        mb = jnp.where(is_diag, md, mb)
        lb = jnp.where(is_diag, ld, lb)
        w = causal_ok.astype(jnp.float32)
        mb = jnp.where(causal_ok, mb, NEG_INF)
        ob = ob * w
        lb = lb * w
        o, m, l = merge_partials((o, m, l), (ob, mb, lb))
        kc = lax.ppermute(kc, axis_name, perm)
        vc = lax.ppermute(vc, axis_name, perm)
        return ((o, m, l), (kc, vc)), None

    acc = empty_partial((B, Tq, hd), H)
    acc = jax.tree.map(lambda a: lax.pcast(a, axis_name, to="varying"), acc)
    ((o, m, l), _), _ = lax.scan(step, (acc, (k, v)), jnp.arange(P))
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def distributed_attention(q, k, v, mesh, *, axis_name: str = "q",
                          strategy: str = "quorum"):
    """q: [B, T, H, hd]; k/v: [B, T, KV, hd]; T sharded over axis_name.

    Block layout: global sequence order = block-major (device i holds tokens
    [i*T/P, (i+1)*T/P)), so cyclic block indices coincide with position order.
    """
    from jax.sharding import PartitionSpec as PS
    P = mesh.shape[axis_name]
    if strategy == "quorum":
        sched = build_causal_schedule(P)
        valid = sched.valid.astype(np.float32)

        def body(qb, kb, vb, vr):
            return quorum_attention_local(qb, kb, vb, vr, schedule=sched,
                                          axis_name=axis_name)

        return jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=(PS(None, axis_name), PS(None, axis_name),
                      PS(None, axis_name), PS(axis_name)),
            out_specs=PS(None, axis_name)))(q, k, v, valid)
    elif strategy == "ring":
        def body(qb, kb, vb):
            return ring_attention_local(qb, kb, vb, axis_name=axis_name,
                                        axis_size=P)
        return jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=(PS(None, axis_name),) * 3,
            out_specs=PS(None, axis_name)))(q, k, v)
    raise ValueError(strategy)


def reference_attention(q, k, v):
    """Plain causal full attention oracle."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, T, KV, H // KV, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32) / math.sqrt(hd),
                   k.astype(jnp.float32))
    msk = np.tril(np.ones((T, T), np.bool_))
    s = jnp.where(msk, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, T, hd).transpose(0, 2, 1, 3).astype(q.dtype)

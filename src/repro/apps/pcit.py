"""PCIT (partial correlation + information theory) — the paper's section 5 app.

Pipeline (all inside one shard_map over the quorum axis):

  phase 1  quorum-gather standardized expression blocks  (k ppermutes,
           k*N/P*G floats resident — the paper's O(N/sqrt(P)) array)
  phase 2  per owned block pair: correlation tile  r[Bx, By] = Xs_x @ Xs_y^T
           (Pallas pairwise_corr kernel on TPU)
  phase 3  tile->row assembly: local strip writes + quorum_scatter(sum) give
           each block owner its full correlation rows R_b [block, N];
           quorum_gather hands every device the rows of its k quorum blocks
           (k*N/P*N floats — the N^2/sqrt(P) phase-2 footprint, vs N^2
           single-node)
  phase 4  per owned pair: PCIT significance filter over all z
           (Pallas pcit_filter kernel), then the same strip/scatter route
           returns the boolean adjacency strip to each block owner.

Oracle: ``pcit_reference`` — direct O(N^3) numpy implementation of
Reverter & Chan (2008) as described in the paper's refs [5, 6].
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.allpairs import pair_mask_table, quorum_gather, quorum_scatter
from ..core.scheduler import PairSchedule, build_schedule

EPS = 1e-12


# ---------------------------------------------------------------------------
# Reference implementation (numpy, single node)
# ---------------------------------------------------------------------------

def standardize(X: np.ndarray) -> np.ndarray:
    """Rows -> zero mean, unit norm, so corr = Xs @ Xs.T exactly."""
    Xc = X - X.mean(axis=1, keepdims=True)
    nrm = np.linalg.norm(Xc, axis=1, keepdims=True)
    return Xc / np.maximum(nrm, EPS)

def correlation_reference(X: np.ndarray) -> np.ndarray:
    Xs = standardize(X)
    return Xs @ Xs.T


def pcit_reference(X: np.ndarray) -> np.ndarray:
    """Direct PCIT: keep[x, y] iff no z explains the (x, y) correlation.

    For each trio (x, y, z):
      r_xy.z = (r_xy - r_xz r_yz) / sqrt((1-r_xz^2)(1-r_yz^2))
      eps    = (r_xy.z/r_xy + r_xz.y/r_xz + r_yz.x/r_yz) / 3
      edge (x, y) is explained by z if |r_xy| <= |eps * r_xz| and
                                       |r_xy| <= |eps * r_yz|.
    """
    r = correlation_reference(X)
    N = r.shape[0]
    keep = np.ones((N, N), bool)

    def pc(a, b, c):  # r_ab.c
        den = np.sqrt(max((1 - r[a, c] ** 2) * (1 - r[b, c] ** 2), EPS))
        return (r[a, b] - r[a, c] * r[b, c]) / den

    for x in range(N):
        for y in range(N):
            if x == y:
                continue
            for z in range(N):
                if z == x or z == y:
                    continue
                rxy_z = pc(x, y, z)
                rxz_y = pc(x, z, y)
                ryz_x = pc(y, z, x)
                eps = (rxy_z / (r[x, y] + EPS) + rxz_y / (r[x, z] + EPS)
                       + ryz_x / (r[y, z] + EPS)) / 3.0
                if (abs(r[x, y]) <= abs(eps * r[x, z])
                        and abs(r[x, y]) <= abs(eps * r[y, z])):
                    keep[x, y] = False
                    break
    np.fill_diagonal(keep, True)
    return keep


# ---------------------------------------------------------------------------
# Vectorized tile primitives (jnp reference path; Pallas kernels in
# repro.kernels are drop-in replacements for TPU)
# ---------------------------------------------------------------------------

def corr_tile(xs_i: jax.Array, xs_j: jax.Array) -> jax.Array:
    """Correlation tile between standardized blocks [bm, G] x [bn, G]."""
    return xs_i @ xs_j.T


def pcit_tile(r_xy: jax.Array, rows_x: jax.Array, rows_y: jax.Array,
              gx: jax.Array, gy: jax.Array) -> jax.Array:
    """PCIT keep-mask for one tile.

    r_xy:  [bm, bn] direct correlations of the pair tile.
    rows_x:[bm, N] correlation rows of the x block; rows_y: [bn, N].
    gx/gy: [bm]/[bn] global gene ids (to exclude z == x / z == y).
    Returns keep [bm, bn] bool.
    """
    N = rows_x.shape[-1]
    rxz = rows_x[:, None, :]            # [bm, 1, N]
    ryz = rows_y[None, :, :]            # [1, bn, N]
    rxy = r_xy[:, :, None]              # [bm, bn, 1]

    den_z = jnp.sqrt(jnp.maximum((1 - rxz ** 2) * (1 - ryz ** 2), EPS))
    rxy_z = (rxy - rxz * ryz) / den_z
    den_y = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - ryz ** 2), EPS))
    rxz_y = (rxz - rxy * ryz) / den_y
    den_x = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - rxz ** 2), EPS))
    ryz_x = (ryz - rxy * rxz) / den_x

    eps = (rxy_z / (rxy + EPS) + rxz_y / (rxz + EPS) + ryz_x / (ryz + EPS)) / 3.0
    explained = ((jnp.abs(rxy) <= jnp.abs(eps * rxz))
                 & (jnp.abs(rxy) <= jnp.abs(eps * ryz)))
    z_ids = jnp.arange(N)[None, None, :]
    valid_z = (z_ids != gx[:, None, None]) & (z_ids != gy[None, :, None])
    explained &= valid_z
    keep = ~jnp.any(explained, axis=-1)
    # diagonal (x == y) pairs are trivially kept
    keep |= (gx[:, None] == gy[None, :])
    return keep


# ---------------------------------------------------------------------------
# Distributed quorum PCIT (runs inside shard_map over axis `axis_name`)
# ---------------------------------------------------------------------------

def quorum_pcit_local(xs_block: jax.Array, mask: jax.Array, *,
                      schedule: PairSchedule, axis_name: str,
                      use_kernels: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Per-device body.  xs_block: [block, G] standardized rows (this
    device's dataset block); mask: [n_pairs] dedup mask (pair_mask_table row).

    Returns (corr_rows [block, N], keep_rows [block, N]) for the local block.
    """
    if use_kernels:
        from ..kernels import ops as kops
        _corr = kops.pairwise_corr
        _pcit = kops.pcit_filter
    else:
        _corr, _pcit = corr_tile, pcit_tile

    P = schedule.P
    block = xs_block.shape[0]
    N = P * block
    mask = mask.reshape(-1)
    i = lax.axis_index(axis_name)

    xq = quorum_gather(xs_block, schedule, axis_name)      # [k, block, G]
    k = schedule.k
    shifts = jnp.asarray(schedule.shifts, jnp.int32)

    # ---- phase 2+3: correlation tiles -> row strips ----------------------
    strips = jnp.zeros((k, block, N), xs_block.dtype)
    strips = lax.pcast(strips, axis_name, to="varying")

    def corr_body(strips, inp):
        lo, hi, w = inp
        tile = _corr(jnp.take(xq, lo, axis=0), jnp.take(xq, hi, axis=0)) * w
        glo = (i + jnp.take(shifts, lo)) % P
        ghi = (i + jnp.take(shifts, hi)) % P
        # write tile at strip[lo][:, ghi*block] and its transpose at
        # strip[hi][:, glo*block]  (self pairs: same slot, same offset — the
        # second write would double the diagonal tile, so zero it)
        strips = lax.dynamic_update_slice(
            strips, tile[None],
            (lo, 0, ghi * block))
        tile_t = jnp.where(lo == hi, jnp.zeros_like(tile), tile.T)
        cur = lax.dynamic_slice(strips, (hi, 0, glo * block), (1, block, block))
        strips = lax.dynamic_update_slice(strips, cur + tile_t[None],
                                          (hi, 0, glo * block))
        return strips, None

    lo_s = jnp.asarray(schedule.pair_slots[:, 0])
    hi_s = jnp.asarray(schedule.pair_slots[:, 1])
    strips, _ = lax.scan(corr_body, strips, (lo_s, hi_s, mask))
    corr_rows = quorum_scatter(strips, schedule, axis_name)   # [block, N]

    # every device pulls the rows of its k quorum blocks
    rows_q = quorum_gather(corr_rows, schedule, axis_name)    # [k, block, N]

    # ---- phase 4: PCIT filter tiles -> keep strips -----------------------
    keep_strips = jnp.zeros((k, block, N), jnp.float32)
    keep_strips = lax.pcast(keep_strips, axis_name, to="varying")
    base_ids = jnp.arange(block)

    def pcit_body(ks, inp):
        lo, hi, w = inp
        glo = (i + jnp.take(shifts, lo)) % P
        ghi = (i + jnp.take(shifts, hi)) % P
        rows_x = jnp.take(rows_q, lo, axis=0)                 # [block, N]
        rows_y = jnp.take(rows_q, hi, axis=0)
        r_xy = lax.dynamic_slice(rows_x, (0, ghi * block), (block, block))
        gx = glo * block + base_ids
        gy = ghi * block + base_ids
        keep = _pcit(r_xy, rows_x, rows_y, gx, gy).astype(jnp.float32) * w
        ks = lax.dynamic_update_slice(ks, keep[None], (lo, 0, ghi * block))
        keep_t = jnp.where(lo == hi, jnp.zeros_like(keep), keep.T)
        cur = lax.dynamic_slice(ks, (hi, 0, glo * block), (1, block, block))
        ks = lax.dynamic_update_slice(ks, cur + keep_t[None], (hi, 0, glo * block))
        return ks, None

    keep_strips, _ = lax.scan(pcit_body, keep_strips, (lo_s, hi_s, mask))
    keep_rows = quorum_scatter(keep_strips, schedule, axis_name) > 0.5
    return corr_rows, keep_rows


def run_quorum_pcit(X: np.ndarray, mesh, axis_name: str = "q",
                    use_kernels: bool = False):
    """Driver: standardize on host, shard rows, run the quorum pipeline.

    X: [N, G] expression matrix; N must divide by the mesh axis size.
    Returns (corr [N, N], keep [N, N]) gathered to host.
    """
    from jax.sharding import PartitionSpec as PS
    P = mesh.shape[axis_name]
    N = X.shape[0]
    assert N % P == 0, (N, P)
    sched = build_schedule(P)
    masks = pair_mask_table(sched)
    Xs = standardize(np.asarray(X, np.float32))

    def body(xb, mb):
        return quorum_pcit_local(xb, mb, schedule=sched, axis_name=axis_name,
                                 use_kernels=use_kernels)

    fn = jax.jit(jax.shard_map(body, mesh=mesh,
                               in_specs=(PS(axis_name), PS(axis_name)),
                               out_specs=(PS(axis_name), PS(axis_name))))
    corr, keep = fn(Xs, masks)
    return np.asarray(corr), np.asarray(keep)

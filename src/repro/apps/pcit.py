"""PCIT (partial correlation + information theory) — the paper's section 5 app.

Pipeline (all inside one shard_map over the quorum axis):

  phase 1  quorum-gather standardized expression blocks  (k ppermutes,
           k*N/P*G floats resident — the paper's O(N/sqrt(P)) array)
  phase 2  per owned block pair: correlation tile  r[Bx, By] = Xs_x @ Xs_y^T
           (Pallas pairwise_corr kernel on TPU)
  phase 3  tile->row assembly: local strip writes + quorum_scatter(sum) give
           each block owner its full correlation rows R_b [block, N];
           quorum_gather hands every device the rows of its k quorum blocks
           (k*N/P*N floats — the N^2/sqrt(P) phase-2 footprint, vs N^2
           single-node)
  phase 4  per owned pair: PCIT significance filter over all z
           (Pallas pcit_filter kernel), then the same strip/scatter route
           returns the boolean adjacency strip to each block owner.

Oracle: ``pcit_reference`` — direct O(N^3) numpy implementation of
Reverter & Chan (2008) as described in the paper's refs [5, 6].
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.allpairs import (env_mode_override, mark_varying,
                             pair_mask_table, pair_ready_order,
                             quorum_gather, quorum_scatter)
from ..core.scheduler import PairSchedule, build_schedule

EPS = 1e-12


# ---------------------------------------------------------------------------
# Reference implementation (numpy, single node)
# ---------------------------------------------------------------------------

def standardize(X: np.ndarray) -> np.ndarray:
    """Rows -> zero mean, unit norm, so corr = Xs @ Xs.T exactly."""
    Xc = X - X.mean(axis=1, keepdims=True)
    nrm = np.linalg.norm(Xc, axis=1, keepdims=True)
    return Xc / np.maximum(nrm, EPS)

def correlation_reference(X: np.ndarray) -> np.ndarray:
    """Numpy correlation-matrix oracle over standardized rows."""
    Xs = standardize(X)
    return Xs @ Xs.T


def pcit_reference(X: np.ndarray) -> np.ndarray:
    """Direct PCIT: keep[x, y] iff no z explains the (x, y) correlation.

    For each trio (x, y, z):
      r_xy.z = (r_xy - r_xz r_yz) / sqrt((1-r_xz^2)(1-r_yz^2))
      eps    = (r_xy.z/r_xy + r_xz.y/r_xz + r_yz.x/r_yz) / 3
      edge (x, y) is explained by z if |r_xy| <= |eps * r_xz| and
                                       |r_xy| <= |eps * r_yz|.
    """
    r = correlation_reference(X)
    N = r.shape[0]
    keep = np.ones((N, N), bool)

    def pc(a, b, c):  # r_ab.c
        den = np.sqrt(max((1 - r[a, c] ** 2) * (1 - r[b, c] ** 2), EPS))
        return (r[a, b] - r[a, c] * r[b, c]) / den

    for x in range(N):
        for y in range(N):
            if x == y:
                continue
            for z in range(N):
                if z == x or z == y:
                    continue
                rxy_z = pc(x, y, z)
                rxz_y = pc(x, z, y)
                ryz_x = pc(y, z, x)
                eps = (rxy_z / (r[x, y] + EPS) + rxz_y / (r[x, z] + EPS)
                       + ryz_x / (r[y, z] + EPS)) / 3.0
                if (abs(r[x, y]) <= abs(eps * r[x, z])
                        and abs(r[x, y]) <= abs(eps * r[y, z])):
                    keep[x, y] = False
                    break
    np.fill_diagonal(keep, True)
    return keep


# ---------------------------------------------------------------------------
# Vectorized tile primitives (jnp reference path; Pallas kernels in
# repro.kernels are drop-in replacements for TPU)
# ---------------------------------------------------------------------------

def corr_tile(xs_i: jax.Array, xs_j: jax.Array) -> jax.Array:
    """Correlation tile between standardized blocks [bm, G] x [bn, G]."""
    return xs_i @ xs_j.T


def pcit_tile(r_xy: jax.Array, rows_x: jax.Array, rows_y: jax.Array,
              gx: jax.Array, gy: jax.Array) -> jax.Array:
    """PCIT keep-mask for one tile.

    r_xy:  [bm, bn] direct correlations of the pair tile.
    rows_x:[bm, N] correlation rows of the x block; rows_y: [bn, N].
    gx/gy: [bm]/[bn] global gene ids (to exclude z == x / z == y).
    Returns keep [bm, bn] bool.
    """
    N = rows_x.shape[-1]
    rxz = rows_x[:, None, :]            # [bm, 1, N]
    ryz = rows_y[None, :, :]            # [1, bn, N]
    rxy = r_xy[:, :, None]              # [bm, bn, 1]

    den_z = jnp.sqrt(jnp.maximum((1 - rxz ** 2) * (1 - ryz ** 2), EPS))
    rxy_z = (rxy - rxz * ryz) / den_z
    den_y = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - ryz ** 2), EPS))
    rxz_y = (rxz - rxy * ryz) / den_y
    den_x = jnp.sqrt(jnp.maximum((1 - rxy ** 2) * (1 - rxz ** 2), EPS))
    ryz_x = (ryz - rxy * rxz) / den_x

    eps = (rxy_z / (rxy + EPS) + rxz_y / (rxz + EPS) + ryz_x / (ryz + EPS)) / 3.0
    explained = ((jnp.abs(rxy) <= jnp.abs(eps * rxz))
                 & (jnp.abs(rxy) <= jnp.abs(eps * ryz)))
    z_ids = jnp.arange(N)[None, None, :]
    valid_z = (z_ids != gx[:, None, None]) & (z_ids != gy[None, :, None])
    explained &= valid_z
    keep = ~jnp.any(explained, axis=-1)
    # diagonal (x == y) pairs are trivially kept
    keep |= (gx[:, None] == gy[None, :])
    return keep


# ---------------------------------------------------------------------------
# Distributed quorum PCIT (runs inside shard_map over axis `axis_name`)
# ---------------------------------------------------------------------------

def _tile_strips(make_tile, source: jax.Array, *, schedule: PairSchedule,
                 axis_name: str, mask: jax.Array, mode: str, out_dtype):
    """Gather ``source`` [block, F] over the quorum and assemble the masked
    per-slot [block, N] tile strips (DESIGN.md 3.2 strip assembly), with the
    engine's execution modes:

      * ``scan``    — serial lax.scan with a stacked [k, block, N] carry and
        dynamic slot indexing (low-memory oracle),
      * ``batched`` — unrolled static loop over the pre-gathered stack; slot
        ids become static so every tile is an independent op for XLA,
      * ``overlap`` — tiles computed as their later block lands in the
        gather, hiding the ppermutes behind tile compute.

    ``make_tile(lo_blk, hi_blk, glo, ghi) -> [block, block]``.  Tile layout:
    the (lo, hi) pair's tile lands at strip[lo][:, ghi*block:...] and its
    transpose accumulates at strip[hi][:, glo*block:...] (self pairs write
    once — the transpose write would double the diagonal tile).
    Returns [k, block, N] (scan) or a per-slot list (unrolled modes); both
    are accepted by quorum_scatter.
    """
    P, k, n_pairs = schedule.P, schedule.k, schedule.n_pairs
    block = source.shape[0]
    N = P * block
    i = lax.axis_index(axis_name)
    lo_np = schedule.pair_slots[:, 0]
    hi_np = schedule.pair_slots[:, 1]

    if mode == "scan":
        xq = quorum_gather(source, schedule, axis_name)
        shifts = jnp.asarray(schedule.shifts, jnp.int32)
        strips = mark_varying(jnp.zeros((k, block, N), out_dtype), axis_name)

        def body(strips, inp):
            lo, hi, w = inp
            glo = (i + jnp.take(shifts, lo)) % P
            ghi = (i + jnp.take(shifts, hi)) % P
            tile = (make_tile(jnp.take(xq, lo, axis=0),
                              jnp.take(xq, hi, axis=0), glo, ghi)
                    * w).astype(out_dtype)
            strips = lax.dynamic_update_slice(strips, tile[None],
                                              (lo, 0, ghi * block))
            tile_t = jnp.where(lo == hi, jnp.zeros_like(tile), tile.T)
            cur = lax.dynamic_slice(strips, (hi, 0, glo * block),
                                    (1, block, block))
            strips = lax.dynamic_update_slice(strips, cur + tile_t[None],
                                              (hi, 0, glo * block))
            return strips, None

        strips, _ = lax.scan(body, strips,
                             (jnp.asarray(lo_np), jnp.asarray(hi_np), mask))
        return strips

    # unrolled modes: per-slot strip list, static slot ids
    strip: list = [None] * k

    def get(slot):
        if strip[slot] is None:
            strip[slot] = mark_varying(jnp.zeros((block, N), out_dtype), axis_name)
        return strip[slot]

    def compute(idx, blocks):
        lo, hi = int(lo_np[idx]), int(hi_np[idx])
        glo = (i + int(schedule.shifts[lo])) % P
        ghi = (i + int(schedule.shifts[hi])) % P
        tile = (make_tile(blocks[lo], blocks[hi], glo, ghi)
                * mask[idx]).astype(out_dtype)
        strip[lo] = lax.dynamic_update_slice(get(lo), tile, (0, ghi * block))
        if lo != hi:  # self pair: the transpose write would double the tile
            cur = lax.dynamic_slice(get(hi), (0, glo * block), (block, block))
            strip[hi] = lax.dynamic_update_slice(get(hi), cur + tile.T,
                                                 (0, glo * block))

    if mode == "overlap":
        ready = pair_ready_order(schedule)
        landed: list = []

        def on_land(slot, blk):
            landed.append(blk)
            for idx in ready[slot]:
                compute(idx, landed)

        quorum_gather(source, schedule, axis_name, overlap_fn=on_land)
    else:  # batched
        xq = quorum_gather(source, schedule, axis_name)
        blocks = [xq[s] for s in range(k)]
        for idx in range(n_pairs):
            compute(idx, blocks)
    return [get(s) for s in range(k)]


def quorum_pcit_local(xs_block: jax.Array, mask: jax.Array, *,
                      schedule: PairSchedule, axis_name: str,
                      use_kernels: bool = False,
                      mode: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Per-device body.  xs_block: [block, G] standardized rows (this
    device's dataset block); mask: [n_pairs] dedup mask (pair_mask_table row).
    ``mode``: engine execution mode for both tile phases (see _tile_strips);
    ``auto`` unrolls (batched) while the static pair count is small and falls
    back to the serial scan beyond that.

    Returns (corr_rows [block, N], keep_rows [block, N]) for the local block.
    """
    if use_kernels:
        from ..kernels import ops as kops
        _corr = kops.pairwise_corr
        _pcit = kops.pcit_filter
    else:
        _corr, _pcit = corr_tile, pcit_tile

    if mode == "auto":
        # env override first (same A/B hook as the engine), then: unroll
        # while the static pair count is small, serial scan beyond that
        mode = env_mode_override() or (
            "batched" if schedule.n_pairs <= 32 else "scan")
    if mode not in ("scan", "batched", "overlap"):
        raise ValueError(f"unknown mode {mode!r}")

    P = schedule.P
    block = xs_block.shape[0]
    mask = mask.reshape(-1)
    base_ids = jnp.arange(block)

    # ---- phase 2+3: correlation tiles -> row strips ----------------------
    strips = _tile_strips(lambda bx, by, glo, ghi: _corr(bx, by),
                          xs_block, schedule=schedule, axis_name=axis_name,
                          mask=mask, mode=mode, out_dtype=xs_block.dtype)
    corr_rows = quorum_scatter(strips, schedule, axis_name)   # [block, N]

    # ---- phase 4: PCIT filter tiles -> keep strips -----------------------
    # (the _tile_strips gather hands every device the corr rows of its k
    # quorum blocks — the N^2/sqrt(P) phase footprint vs N^2 single-node)
    def pcit_make(rows_x, rows_y, glo, ghi):
        r_xy = lax.dynamic_slice(rows_x, (0, ghi * block), (block, block))
        gx = glo * block + base_ids
        gy = ghi * block + base_ids
        return _pcit(r_xy, rows_x, rows_y, gx, gy).astype(jnp.float32)

    keep_strips = _tile_strips(pcit_make, corr_rows, schedule=schedule,
                               axis_name=axis_name, mask=mask, mode=mode,
                               out_dtype=jnp.float32)
    keep_rows = quorum_scatter(keep_strips, schedule, axis_name) > 0.5
    return corr_rows, keep_rows


def run_quorum_pcit(X: np.ndarray, mesh, axis_name: str = "q",
                    use_kernels: bool = False, mode: str = "auto"):
    """Driver: standardize on host, shard rows, run the quorum pipeline.

    X: [N, G] expression matrix; N must divide by the mesh axis size.
    ``mode``: engine execution mode for the tile phases (see _tile_strips).
    Returns (corr [N, N], keep [N, N]) gathered to host.
    """
    from jax.sharding import PartitionSpec as PS
    P = mesh.shape[axis_name]
    N = X.shape[0]
    assert N % P == 0, (N, P)
    sched = build_schedule(P)
    masks = pair_mask_table(sched)
    Xs = standardize(np.asarray(X, np.float32))

    def body(xb, mb):
        return quorum_pcit_local(xb, mb, schedule=sched, axis_name=axis_name,
                                 use_kernels=use_kernels, mode=mode)

    fn = jax.jit(jax.shard_map(body, mesh=mesh,
                               in_specs=(PS(axis_name), PS(axis_name)),
                               out_specs=(PS(axis_name), PS(axis_name))))
    corr, keep = fn(Xs, masks)
    return np.asarray(corr), np.asarray(keep)

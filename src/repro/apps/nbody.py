"""Direct-interaction n-body forces — the paper's motivating algorithm family
(atom-decomposition [7] vs force-decomposition vs quorums, paper section 1.2).

``quorum`` strategy uses the engine (one array of k*N/P bodies per device);
``atom`` is the all-gather atom-decomposition baseline (N bodies per device).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.allpairs import (allgather_allpairs, pair_mask_table,
                             quorum_allpairs)
from ..core.scheduler import build_schedule

SOFTENING = 1e-2


def pair_forces(bi: jax.Array, bj: jax.Array):
    """Gravitational interaction between body blocks [m, 4] (x, y, z, mass).

    Returns (force on bi bodies [m, 3], force on bj bodies [n, 3]).
    Newton's third law: computed once per pair — the paper's Fig. 1 saving.
    """
    pi, mi = bi[:, :3], bi[:, 3]
    pj, mj = bj[:, :3], bj[:, 3]
    d = pj[None, :, :] - pi[:, None, :]                 # [m, n, 3]
    r2 = jnp.sum(d * d, axis=-1) + SOFTENING
    inv_r3 = jax.lax.rsqrt(r2) / r2
    w = (mi[:, None] * mj[None, :] * inv_r3)[..., None]  # [m, n, 1]
    f_ij = w * d                                        # force ON i FROM j
    return jnp.sum(f_ij, axis=1), -jnp.sum(f_ij, axis=0)


def forces_reference(bodies: np.ndarray) -> np.ndarray:
    """Numpy O(N^2) force oracle (tests/benchmarks compare against it)."""
    p, m = bodies[:, :3], bodies[:, 3]
    d = p[None, :, :] - p[:, None, :]
    r2 = (d * d).sum(-1) + SOFTENING
    w = (m[:, None] * m[None, :]) / (np.sqrt(r2) * r2)
    return (w[..., None] * d).sum(axis=1)


@functools.lru_cache(maxsize=64)
def forces_fn(mesh, axis_name: str = "q", strategy: str = "quorum",
              mode: str = "auto", use_kernel: bool = False):
    """Build (and cache) the jitted distributed-forces callable.

    Cached per (mesh, axis_name, strategy, mode, use_kernel) so repeated
    calls — simulation steps, benchmark reps — reuse one traced/compiled
    executable instead of re-jitting a fresh closure every call.
    Returns ``f(bodies [N, 4]) -> forces [N, 3]``.
    """
    from jax.sharding import PartitionSpec as PS
    P = mesh.shape[axis_name]
    if strategy == "quorum":
        sched = build_schedule(P)
        masks = jnp.asarray(pair_mask_table(sched))
        batch_fn = None
        if use_kernel:
            if mode not in ("batched", "auto"):
                raise ValueError(
                    f"use_kernel needs the batched mode (got mode={mode!r}); "
                    "the fused kernel only replaces the batched inner step")
            from ..kernels import ops as kops
            batch_fn = functools.partial(kops.pairwise_batch_forces,
                                         softening=SOFTENING)

        def body(xb, mb):
            return quorum_allpairs(pair_forces, xb, axis_name=axis_name,
                                   schedule=sched, mask=mb, mode=mode,
                                   batch_fn=batch_fn)

        fn = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(PS(axis_name), PS(axis_name)),
            out_specs=PS(axis_name)))
        return lambda bodies: fn(bodies, masks)
    if strategy == "atom":
        if use_kernel:
            raise ValueError("use_kernel applies only to strategy='quorum'")

        def body(xb):
            return allgather_allpairs(pair_forces, xb, axis_name=axis_name,
                                      axis_size=P)
        return jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=PS(axis_name),
            out_specs=PS(axis_name)))
    raise ValueError(strategy)


def distributed_forces(bodies, mesh, *, axis_name: str = "q",
                       strategy: str = "quorum", mode: str = "auto",
                       use_kernel: bool = False):
    """bodies: [N, 4] sharded over axis_name.  Returns forces [N, 3].

    ``mode`` selects the engine execution mode (batched / overlap / scan /
    auto — see core.allpairs and DESIGN.md section 4).  ``use_kernel`` routes
    the batched mode through the fused Pallas pairwise_batch kernel.
    """
    return forces_fn(mesh, axis_name, strategy, mode, use_kernel)(bodies)


def leapfrog_step(bodies, vel, dt, forces):
    """Symplectic integrator step (example driver uses this)."""
    m = bodies[:, 3:4]
    vel = vel + dt * forces / m
    pos = bodies[:, :3] + dt * vel
    return jnp.concatenate([pos, bodies[:, 3:4]], axis=-1), vel

"""Request-batching driver tests (launch/query_serve.py): queue drain
with a padded tail batch returns exactly the per-microbatch query
results in request order, --stream-every interleaves block updates at
the documented cadence, and qps/warmup accounting stays sane.  Jax
meshes live in fake-device subprocesses (the dry-run isolation rule,
see tests/test_distributed.py).
"""

import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_serve_queries_drains_queue_and_pads_tail():
    """serve_queries == per-microbatch sc.query with the tail padded and
    the padding dropped: row order preserved, bit-exact per batch, qps
    finite once at least one steady-state batch is measured."""
    code = """
import math
import numpy as np, jax
from repro.launch.query_serve import serve_queries
from repro.serving import ServingCorpus

P, N, d, R, mb, topk = 4, 64, 8, 21, 8, 4
rng = np.random.default_rng(0)
corpus = rng.normal(size=(N, d)).astype(np.float32)
queries = rng.normal(size=(R, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh)

vals, idx, qps = serve_queries(sc, queries, microbatch=mb, topk=topk)
assert vals.shape == (R, topk) and idx.shape == (R, topk), (vals.shape,
                                                            idx.shape)
assert math.isfinite(qps) and qps > 0, qps

# the drain contract: each microbatch (tail zero-padded to mb) through
# sc.query, padded rows dropped -- must be bit-exact, same shapes
done = 0
for bi in range(-(-R // mb)):
    q = queries[done:done + mb]
    n = len(q)
    if n < mb:
        q = np.concatenate([q, np.zeros((mb - n, d), np.float32)])
    v, i = sc.query(q, topk=topk)
    assert np.array_equal(np.asarray(v)[:n], vals[done:done + n]), bi
    assert np.array_equal(np.asarray(i)[:n], idx[done:done + n]), bi
    done += n
assert done == R
print("SERVE-DRAIN-OK")
"""
    assert "SERVE-DRAIN-OK" in run_sub(code, 4)


def test_serve_queries_stream_interleave_and_counters():
    """--stream-every cadence: a block replacement lands every N-th
    non-initial microbatch; the obs counters record batches served,
    queries answered, and stream updates (ISSUE 7 satellite)."""
    code = """
import math
import numpy as np, jax
from repro.launch.query_serve import serve_queries
from repro.obs import trace as obs_trace
from repro.serving import ServingCorpus

P, N, d, R, mb = 4, 64, 8, 40, 8        # 5 batches -> updates at bi=2,4
rng = np.random.default_rng(1)
corpus = rng.normal(size=(N, d)).astype(np.float32)
queries = rng.normal(size=(R, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh)

seen = []
orig = sc.replace_block
def spy(b, vecs):
    seen.append(int(b))
    return orig(b, vecs)
sc.replace_block = spy

tr = obs_trace.configure(metrics_only=True)
try:
    vals, idx, qps = serve_queries(sc, queries, microbatch=mb, topk=4,
                                   stream_every=2, rng=rng)
    assert len(seen) == 2, seen
    assert vals.shape == (R, 4)
    assert math.isfinite(qps) and qps > 0, qps
    assert tr.counter_total("serve.batches") == 5
    assert tr.counter_total("serve.queries") == R
    assert tr.counter_total("serve.stream_updates") == 2
finally:
    obs_trace.reset()
print("SERVE-STREAM-OK")
"""
    assert "SERVE-STREAM-OK" in run_sub(code, 4)


def test_serve_queries_single_batch_warmup_clamp():
    """A single microbatch leaves nothing to warm up on: the clamp
    measures that one batch instead of reporting nan qps."""
    code = """
import math
import numpy as np, jax
from repro.launch.query_serve import serve_queries
from repro.serving import ServingCorpus

rng = np.random.default_rng(2)
corpus = rng.normal(size=(32, 8)).astype(np.float32)
queries = rng.normal(size=(5, 8)).astype(np.float32)
mesh = jax.make_mesh((2,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh)
vals, idx, qps = serve_queries(sc, queries, microbatch=8, topk=3)
assert vals.shape == (5, 3)
assert math.isfinite(qps) and qps > 0, qps
print("SERVE-WARMUP-OK")
"""
    assert "SERVE-WARMUP-OK" in run_sub(code, 2)


def test_query_serve_cli():
    """The module CLI end to end, stream updates on."""
    code = """
from repro.launch.query_serve import main
main(["--n", "256", "--d", "16", "--requests", "48", "--microbatch", "8",
      "--topk", "4", "--stream-every", "2"])
"""
    out = run_sub(code, 4)
    assert "queries/sec steady-state" in out
    assert "first request top-4" in out

"""Continuous-batching scheduler tests (serving/batching.py, DESIGN.md
section 15): heterogeneous packed batches bit-exact vs the per-request
query/query_threshold oracles, deadline-preemption semantics under an
injected clock, admission-control backpressure, the p50/p99 percentile
math on a deterministic synthetic trace, and the engine-side cache-key
quantization + block-update validation the scheduler leans on.  Jax
meshes live in fake-device subprocesses (the dry-run isolation rule,
see tests/test_distributed.py); the metrics/validation tests run
in-process against a duck-typed corpus stand-in.
"""

import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = Path(__file__).resolve().parents[1] / "src"
sys.path.insert(0, str(SRC))

from repro.core.env import ENV_KNOBS                      # noqa: E402
from repro.serving.batching import (AdmissionError, BatchScheduler,  # noqa: E402
                                    latency_summary, percentile)
from repro.serving.engine import quantize_pow2            # noqa: E402


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# --------------------------------------------------------------- host-side
# percentile / latency math on deterministic synthetic traces


def test_percentile_linear_interpolation():
    """The stdlib-checkable definition: fractional rank (n-1)*q/100 with
    linear interpolation — matches numpy's default method on a
    deterministic trace, exact at the knots."""
    trace = [0.4, 0.1, 0.3, 0.2]                       # unsorted on purpose
    assert percentile(trace, 0) == 0.1
    assert percentile(trace, 100) == 0.4
    assert percentile(trace, 50) == pytest.approx(0.25)
    assert percentile([7.0], 99) == 7.0
    rng = np.random.default_rng(3)
    xs = rng.exponential(size=37).tolist()
    for q in (0, 10, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)), rel=1e-12)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], 101)


def test_latency_summary_deterministic_trace():
    """p50/p99/qps over a synthetic 1..100 ms ramp: every field is
    hand-computable."""
    trace = [i / 1000.0 for i in range(1, 101)]        # 1ms .. 100ms
    s = latency_summary(trace, span_s=2.0)
    assert s["n"] == 100.0
    assert s["mean_s"] == pytest.approx(0.0505)
    assert s["p50_s"] == pytest.approx(0.0505)         # between 50 and 51
    assert s["p99_s"] == pytest.approx(0.09901)        # rank 98.01
    assert s["max_s"] == pytest.approx(0.1)
    assert s["qps"] == pytest.approx(50.0)
    empty = latency_summary([])
    assert empty == {"n": 0.0}
    no_span = latency_summary(trace)
    assert "qps" not in no_span


def test_quantize_pow2_buckets():
    """The program-cache bucket function (DESIGN.md section 15.2):
    round up to a power of two, with an optional floor."""
    assert [quantize_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9, 1000)] == \
        [1, 2, 4, 4, 8, 8, 16, 1024]
    assert quantize_pow2(3, floor=8) == 8
    assert quantize_pow2(0) == 1


def test_env_knobs_registered():
    """The scheduler's env knobs are in the central registry with int
    validation (tests/test_env.py separately pins the README table)."""
    for name in ("REPRO_SERVE_MAX_BATCH", "REPRO_SERVE_QUEUE_DEPTH"):
        knob = ENV_KNOBS[name]
        assert knob.kind == "int" and knob.minimum == 1
        assert knob.parse("4") == 4
        with pytest.raises(ValueError, match=">= 1"):
            knob.parse("0")


# --------------------------------------------------------------- host-side
# front-door behavior against a duck-typed corpus (no launch, no jax mesh)


class _FakeCorpus:
    """Just enough ServingCorpus surface for submit-side tests."""
    P, block, d = 4, 16, 8


def test_submit_validation_messages():
    sched = BatchScheduler(_FakeCorpus())
    q = np.zeros(8, np.float32)
    with pytest.raises(ValueError, match="kind"):
        sched.submit(q, kind="knn")
    with pytest.raises(ValueError, match="metric"):
        sched.submit(q, kind="topk", topk=3, metric="cosine")
    with pytest.raises(ValueError, match="8 features"):
        sched.submit(np.zeros(5, np.float32), kind="topk", topk=3)
    with pytest.raises(ValueError, match="topk >= 1"):
        sched.submit(q, kind="topk", topk=0)
    with pytest.raises(ValueError, match="needs a threshold"):
        sched.submit(q, kind="threshold")
    with pytest.raises(ValueError, match="capacity"):
        sched.submit(q, kind="threshold", threshold=1.0, capacity=0)


def test_admission_backpressure_counters():
    """Bounded queue: the (max_queue+1)-th waiting request raises
    AdmissionError naming the depth knob; counters record both sides
    (DESIGN.md section 15.1)."""
    sched = BatchScheduler(_FakeCorpus(), max_queue=2)
    q = np.zeros(8, np.float32)
    sched.submit(q, kind="topk", topk=1)
    sched.submit(q, kind="topk", topk=1)
    with pytest.raises(AdmissionError, match="REPRO_SERVE_QUEUE_DEPTH"):
        sched.submit(q, kind="topk", topk=1)
    assert sched.counters["admitted"] == 2
    assert sched.counters["rejected"] == 1
    assert sched.queue_depth == 2


def test_scheduler_env_knob_defaults(monkeypatch):
    """max_batch / max_queue default from the env registry; explicit
    arguments win over the knobs."""
    monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "7")
    monkeypatch.setenv("REPRO_SERVE_QUEUE_DEPTH", "9")
    sched = BatchScheduler(_FakeCorpus())
    assert (sched.max_batch, sched.max_queue) == (7, 9)
    sched = BatchScheduler(_FakeCorpus(), max_batch=3, max_queue=4)
    assert (sched.max_batch, sched.max_queue) == (3, 4)
    with pytest.raises(ValueError, match="narrower than"):
        BatchScheduler(_FakeCorpus(), max_batch=8, pad_queries_to=4)


# ------------------------------------------------------------- subprocess
# packed launches against a real fake-device mesh


def test_batching_selfcheck_small_mesh():
    """The module selfcheck end to end at P=5 (ragged tail): packed
    heterogeneous batches bit-exact vs solo oracles, escalation ladder,
    deadline expiry/partial, admission, async loop."""
    out = run_sub("from repro.serving.batching import main; main()", 5)
    assert "batching selfcheck OK: P=5" in out


def test_heterogeneous_pack_bit_exact_vs_oracles():
    """A single packed step with mixed k, mixed thresholds, both
    metrics returns bit-identical indices/scores to issuing each
    request alone (the ISSUE 8 acceptance criterion), on O(log)
    program keys."""
    code = """
import numpy as np, jax
from repro.serving import ServingCorpus
from repro.serving.batching import BatchScheduler

P, block, d = 4, 16, 12
rng = np.random.default_rng(7)
corpus = rng.normal(size=(P * block - 5, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh, block=block)

sched = BatchScheduler(sc, max_batch=32)
specs = ([dict(kind="topk", topk=k, metric=m)
          for m in ("dot", "l2") for k in (1, 2, 5, 7)] +
         [dict(kind="threshold", threshold=t, capacity=c, metric=m)
          for m in ("dot", "l2") for t, c in ((3.0, None), (-1e9, 4))])
reqs = [sched.submit(rng.normal(size=(d,)), **s) for s in specs]
sched.drain()
for req in reqs:
    res = req.result(0)
    assert res.ok, (req.rid, res.status)
    if req.kind == "topk":
        ov, oi = sc.query(req.query[None], topk=req.topk, metric=req.metric)
        assert np.array_equal(res.indices, np.asarray(oi)[0]), req.rid
        assert np.array_equal(res.scores, np.asarray(ov)[0]), req.rid
    else:
        ov, oi, oc = sc.query_threshold(req.query[None],
                                        threshold=req.threshold,
                                        metric=req.metric)
        n = int(np.asarray(oc)[0])
        assert res.count == n, (req.rid, res.count, n)
        assert np.array_equal(res.indices, np.asarray(oi)[0, :n]), req.rid
        assert np.array_equal(res.scores, np.asarray(ov)[0, :n]), req.rid
# mixed batch stayed on pow2-bucketed program keys
assert len(sched.program_keys) <= 10, sched.program_keys
assert sched.counters["launches"] < len(reqs), sched.counters
print("PACK-ORACLE-OK", len(sched.program_keys))
"""
    assert "PACK-ORACLE-OK" in run_sub(code, 4)


def test_deadline_preemption_semantics():
    """Manual clock: a request past deadline at assembly expires with
    sentinels and zero batch slots; an overflowing range query whose
    budget runs out mid-escalation returns partial (truncated prefix,
    true count); live batchmates are untouched."""
    code = """
import numpy as np, jax
from repro.kernels.ref import IDX_SENTINEL, NEG_INF
from repro.serving import ServingCorpus
from repro.serving.batching import BatchScheduler

P, block, d = 2, 16, 8
rng = np.random.default_rng(11)
corpus = rng.normal(size=(P * block, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh, block=block)

t = [0.0]
sched = BatchScheduler(sc, max_batch=8, clock=lambda: t[0])
live = sched.submit(rng.normal(size=(d,)), kind="topk", topk=3)
dead = sched.submit(rng.normal(size=(d,)), kind="topk", topk=3,
                    deadline_s=1.0)
t[0] = 5.0
sched.drain()
r_live, r_dead = live.result(0), dead.result(0)
assert r_dead.status == "expired" and not r_dead.ok
assert (r_dead.indices == IDX_SENTINEL).all()
assert (r_dead.scores == NEG_INF).all()
ov, oi = sc.query(live.query[None], topk=3)
assert np.array_equal(r_live.indices, np.asarray(oi)[0])
assert sched.counters["expired"] == 1 and sched.counters["done"] == 1

# partial: clock steps 0.5s per read -> deadline lands between the
# launch and its escalation decision
t2 = [0.0]
def clock2():
    t2[0] += 0.5
    return t2[0]
sched2 = BatchScheduler(sc, max_batch=8, clock=clock2)
part = sched2.submit(rng.normal(size=(d,)), kind="threshold",
                     threshold=-1e9, capacity=1, deadline_s=0.6)
sched2.step()
res = part.result(0)
assert res.status == "partial", res.status
assert res.count == sc.n_valid and len(res.indices) < res.count
_, oi, _ = sc.query_threshold(part.query[None], threshold=-1e9)
assert np.array_equal(res.indices, np.asarray(oi)[0, :len(res.indices)])
assert sched2.counters["partial"] == 1
print("DEADLINE-OK")
"""
    assert "DEADLINE-OK" in run_sub(code, 2)


def test_block_update_validation():
    """replace_block/append_block reject misshapen or oversized payloads
    at the handle layer, naming the block capacity (ISSUE 8
    satellite)."""
    code = """
import numpy as np, jax
from repro.serving import ServingCorpus

P, block, d = 2, 8, 4
rng = np.random.default_rng(0)
corpus = rng.normal(size=(P * block - 4, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh, block=block)

for bad, frag in [
        (np.zeros((block + 1, d), np.float32), "block capacity is 8"),
        (np.zeros((block, d + 1), np.float32), "[rows, 4]"),
        (np.zeros((block,), np.float32), "[rows, 4]")]:
    try:
        sc.replace_block(0, bad)
    except ValueError as e:
        assert frag in str(e), (frag, str(e))
    else:
        raise AssertionError(f"no ValueError for shape {bad.shape}")
    try:
        sc.append_block(bad)
    except ValueError as e:
        assert frag in str(e), (frag, str(e))
    else:
        raise AssertionError(f"append: no ValueError for {bad.shape}")

try:
    sc.replace_block(P, np.zeros((1, d), np.float32))
except ValueError as e:
    assert "out of range" in str(e)
else:
    raise AssertionError("no ValueError for bad block id")

# the happy path still works after the rejections
sc.replace_block(0, rng.normal(size=(block, d)).astype(np.float32))
v, i = sc.query(rng.normal(size=(1, d)).astype(np.float32), topk=2)
assert np.asarray(v).shape == (1, 2)
print("BLOCK-VALIDATE-OK")
"""
    assert "BLOCK-VALIDATE-OK" in run_sub(code, 2)


def test_threshold_capacity_quantized_program_keys():
    """Engine-side satellite: query_threshold quantizes requested and
    escalated capacities onto the pow2 ladder, so an escalating query
    reuses O(log N) compiled programs instead of flooding the LRU with
    raw-capacity keys."""
    code = """
import numpy as np, jax
from repro.serving import ServingCorpus
from repro.serving.engine import threshold_fn

P, block, d = 2, 32, 8
rng = np.random.default_rng(1)
corpus = rng.normal(size=(P * block, d)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(corpus, mesh, block=block)
q = rng.normal(size=(2, d)).astype(np.float32)

threshold_fn.cache_clear()
# raw capacities 5,6,7,8 all collapse onto the single pow2-8 program
for cap in (5, 6, 7, 8):
    v, i, c = sc.query_threshold(q, threshold=1e9, capacity=cap)
    assert np.asarray(v).shape[1] == 8, np.asarray(v).shape
assert threshold_fn.cache_info().misses == 1, threshold_fn.cache_info()

# escalation from capacity=1 doubles along the same ladder: 1, 2, 4,
# ... total -- every relaunch hits a pow2 (or total-clamped) shape
threshold_fn.cache_clear()
v, i, c = sc.query_threshold(q, threshold=-1e9, capacity=1)
total = P * block
assert int(np.asarray(c)[0]) == total
assert np.asarray(v).shape[1] == total
misses = threshold_fn.cache_info().misses
import math
assert misses <= math.ceil(math.log2(total)) + 1, (misses, total)
print("CAP-QUANTIZE-OK", misses)
"""
    assert "CAP-QUANTIZE-OK" in run_sub(code, 2)

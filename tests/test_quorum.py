"""Deterministic unit tests for the paper's core math (sections 3-4).

Hypothesis-based property sweeps live in tests/test_quorum_properties.py,
which degrades to a skip when hypothesis is not installed — this module
keeps the suite running (deterministic P sweeps) without it.
"""

import numpy as np
import pytest

from repro.core.quorum import (cyclic_quorums, difference_set,
                               is_difference_cover, ladder_difference_cover,
                               quorum_size_lower_bound, singer_difference_set,
                               verify_all_pairs_property)


@pytest.mark.parametrize("P", [1, 2, 3, 4, 5, 6, 7, 8, 11, 13, 16, 21, 31, 32])
def test_difference_set_is_cover(P):
    A = difference_set(P)
    assert is_difference_cover(A, P)
    assert all(0 <= a < P for a in A)
    assert len(set(A)) == len(A)


@pytest.mark.parametrize("P", [4, 7, 8, 13, 16, 21, 31])
def test_small_p_optimal(P):
    """Exact search matches the theoretical k(k-1)+1 >= P lower bound for
    the P values where an optimal set exists (paper cites Luk & Wong)."""
    A = difference_set(P)
    assert len(A) == quorum_size_lower_bound(P)


@pytest.mark.parametrize("q", [2, 3, 5, 7, 11])
def test_singer_sets(q):
    P = q * q + q + 1
    A = singer_difference_set(q)
    assert A is not None
    assert len(A) == q + 1 == quorum_size_lower_bound(P)
    assert is_difference_cover(A, P)


@pytest.mark.parametrize("P", [1, 2, 3, 9, 40, 97, 256, 400])
def test_ladder_cover(P):
    A = ladder_difference_cover(P)
    assert is_difference_cover(A, P)
    assert len(A) <= 2 * int(np.ceil(np.sqrt(P))) + 2


@pytest.mark.parametrize("P", [1, 2, 5, 6, 12, 31, 48, 160])
def test_all_pairs_property(P):
    """Paper Theorem 1: cyclic quorums from a relaxed difference set satisfy
    the all-pairs property (every unordered pair co-resident somewhere)."""
    Q = cyclic_quorums(P)
    assert verify_all_pairs_property(Q, P)


@pytest.mark.parametrize("P", [1, 3, 4, 8, 13, 36, 64, 150])
def test_quorum_properties(P):
    """Paper Eq. 10-13: equal size, equal responsibility, intersection."""
    Q = cyclic_quorums(P)
    k = len(Q[0])
    assert all(len(S) == k for S in Q)               # equal work (Eq. 12)
    counts = np.zeros(P, int)
    for S in Q:
        for b in S:
            counts[b] += 1
    assert (counts == k).all()                       # equal responsibility (Eq. 13)
    sets = [set(S) for S in Q]
    if P <= 64:  # O(P^2) check
        for i in range(P):
            for j in range(P):
                assert sets[i] & sets[j]             # intersection (Eq. 10)


@pytest.mark.parametrize("P", [1, 2, 7, 16, 63, 128, 300])
def test_memory_scaling(P):
    """The headline claim: one array of k*N/P = O(N/sqrt(P)) elements."""
    A = difference_set(P)
    # k within a constant factor of sqrt(P) (2.1x covers the ladder fallback
    # plus small-P constants)
    assert len(A) <= max(3, 2.1 * np.sqrt(P) + 2)

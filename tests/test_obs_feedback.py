"""Rocket feedback loop tests (obs/feedback.py, DESIGN.md section 14.5):
throughput-weight math, and the closed loop itself — a slowed device's
measured throughput shrinks its weighted pair share proportionally while
the sweep output stays bit-exact.  Host-only: the fault-tolerant driver
runs the sweep on numpy blocks, no jax devices needed.
"""

import pytest

from repro.core import faults as faults_mod
from repro.obs.feedback import (feedback_selfcheck, throughput_weights,
                                weights_from_stats)


def test_throughput_weights_ratio():
    """Weights are throughput normalized to mean 1: device 1 at half the
    throughput of device 0 gets half the weight."""
    w = throughput_weights({0: 10, 1: 10}, {0: 1.0, 1: 2.0}, P=2)
    assert abs(w[0] - 2 * w[1]) < 1e-12
    assert abs(sum(w) / len(w) - 1.0) < 1e-12


def test_throughput_weights_unobserved_device_gets_mean():
    """No evidence means assume average capacity (weight 1.0), not zero —
    a freshly-revived device must not be starved."""
    w = throughput_weights({0: 8, 1: 8}, {0: 1.0, 1: 1.0}, P=4)
    assert w == [1.0, 1.0, 1.0, 1.0]
    w = throughput_weights({0: 12, 1: 4}, {0: 1.0, 1: 1.0}, P=3)
    assert abs(w[2] - 1.0) < 1e-12           # unobserved -> the mean


def test_throughput_weights_no_observations():
    assert throughput_weights({}, {}, P=3) == [1.0, 1.0, 1.0]
    assert throughput_weights({0: 0}, {}, P=2) == [1.0, 1.0]


def test_throughput_weights_rejects_zero_busy():
    with pytest.raises(ValueError, match="busy time"):
        throughput_weights({0: 5}, {0: 0.0}, P=2)


def test_weights_from_stats():
    stats = faults_mod.RecoveryStats()
    stats.pairs_by_device = {0: 6, 1: 6}
    stats.busy_by_device = {0: 1.0, 1: 4.0}
    w = weights_from_stats(stats, P=2)
    assert abs(w[0] - 4 * w[1]) < 1e-12


@pytest.mark.parametrize("P", [5, 8])
def test_feedback_selfcheck_closes_the_loop(P):
    """ISSUE 7 acceptance: a 4x-slowed device gets a proportionally
    smaller pair share under the derived weights and the output stays
    bit-exact (asserted inside feedback_selfcheck per placement)."""
    n = feedback_selfcheck(P=P, verbose=False)
    assert n >= 1                            # at least cyclic was checked


def test_feedback_selfcheck_honors_placement_filter():
    n = feedback_selfcheck(P=8, placements=["cyclic"], slow_factor=2.0,
                           slow_device=0, mode="scan", verbose=False)
    assert n == 1

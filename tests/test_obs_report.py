"""Report CLI tests (obs/report.py, DESIGN.md section 14.4): validation
catches malformed traces, summaries aggregate spans/counters correctly,
and the CLI gates (exit 0 valid / 1 invalid) as the CI trace-smoke job
relies on.  Host-only — the report module is stdlib-only by design.
"""

import json

import pytest

from repro.obs import report as report_mod
from repro.obs import trace as trace_mod


def _sample_trace():
    tr = trace_mod.Tracer()
    with tr.span("sweep.gather", P=8):
        pass
    with tr.span("sweep.gather"):
        pass
    tr.record("faults.round", 0.002, round=0)
    tr.count("comm.ppermute.gather_bytes", 864)
    tr.count("serving.queries", 5, device=0)
    tr.count("serving.queries", 7, device=1)
    return tr.chrome_trace()


def test_validate_accepts_tracer_output():
    assert report_mod.validate_chrome_trace(_sample_trace()) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda o: o.pop("traceEvents"), "traceEvents"),
    (lambda o: o["traceEvents"][0].pop("name"), "missing 'name'"),
    (lambda o: o["traceEvents"][0].pop("dur"), "ph=X needs dur"),
    (lambda o: o["traceEvents"][0].update(dur=-1.0), "ph=X needs dur"),
    (lambda o: o["repro"].update(version="x"), "repro.version"),
    (lambda o: o["repro"].update(counters=[1]), "repro.counters"),
])
def test_validate_flags_malformed(mutate, needle):
    obj = _sample_trace()
    mutate(obj)
    errors = report_mod.validate_chrome_trace(obj)
    assert errors and any(needle in e for e in errors), errors


def test_validate_counter_sample_needs_value():
    obj = _sample_trace()
    c = next(e for e in obj["traceEvents"] if e["ph"] == "C")
    del c["args"]["value"]
    errors = report_mod.validate_chrome_trace(obj)
    assert any("ph=C needs args.value" in e for e in errors), errors


def test_validate_non_dict_top_level():
    assert report_mod.validate_chrome_trace([1, 2]) == [
        "top level is not an object"]


def test_span_summary_aggregates_per_name():
    s = report_mod.span_summary(_sample_trace())
    assert s["sweep.gather"]["count"] == 2
    assert s["faults.round"]["count"] == 1
    assert abs(s["faults.round"]["total_ms"] - 2.0) < 0.5
    for row in s.values():
        assert row["max_ms"] >= row["mean_ms"] >= 0
    # sorted by total descending
    totals = [row["total_ms"] for row in s.values()]
    assert totals == sorted(totals, reverse=True)


def test_counter_summary_prefers_repro_section():
    c = report_mod.counter_summary(_sample_trace())
    assert c["comm.ppermute.gather_bytes"] == {"-1": 864.0, "total": 864.0}
    assert c["serving.queries"] == {"0": 5.0, "1": 7.0, "total": 12.0}


def test_counter_summary_falls_back_to_samples():
    obj = _sample_trace()
    del obj["repro"]["counters"]
    c = report_mod.counter_summary(obj)
    assert c["serving.queries"]["total"] == 12.0


def test_render_tables():
    out = report_mod.render(_sample_trace())
    assert "sweep.gather" in out and "faults.round" in out
    assert "comm.ppermute.gather_bytes" in out
    assert "(program-wide)" in out            # device -1 counters
    assert "0:5 1:7" in out                   # per-device counters


def test_load_trace_raises_on_invalid(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    with pytest.raises(ValueError, match="invalid Chrome trace"):
        report_mod.load_trace(p)


def test_cli_valid_and_invalid(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_sample_trace()))
    assert report_mod.main([str(good)]) == 0
    out = capsys.readouterr().out
    assert "sweep.gather" in out and "trace:" in out

    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert report_mod.main([str(bad)]) == 1
    assert "INVALID" in capsys.readouterr().out

    missing = tmp_path / "nope.json"
    assert report_mod.main([str(missing)]) == 1

"""Direct coverage for the kernels/ops.py dispatch layers.

The engine ``batch_fn`` hooks (pairwise_batch_forces, query_topk,
pairwise_threshold) route through two fallback paths that the engine
sweeps only exercise indirectly:

  * **interpret-mode dispatch** — ``_interpret()`` selects interpret mode
    off-TPU and compiled mode on TPU; the flag must actually reach the
    Pallas launch.
  * **kernel-absent fallback** — when the Pallas machinery itself raises
    ImportError / NotImplementedError (a jax build without a usable
    lowering), ``_call_with_fallback`` degrades to the ref.py oracle with
    a RuntimeWarning instead of failing; other exception types (real
    kernel bugs) must propagate.

Shapes here are deliberately distinct from tests/test_kernels.py so every
call traces fresh — the jitted entry points would otherwise replay a
cached trace and bypass the monkeypatched kernels.
"""

import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import pairwise_threshold as pt_mod
from repro.kernels import query_score as qs_mod

RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _fresh_fallback_warnings():
    """The kernel-absent fallback warns once per hook per process
    (ops._warned_fallback); clear the keyset so every test here sees its
    own first warning regardless of execution order."""
    ops._warned_fallback.clear()
    yield
    ops._warned_fallback.clear()


def test_fallback_warns_once_per_hook(monkeypatch):
    """The RuntimeWarning fires on the first kernel-absent call of a
    hook and stays silent on repeats (a hot engine loop retraces the
    hook constantly — per-call warnings flood the log), while a
    *different* hook still gets its own first warning."""
    monkeypatch.setattr(
        ops, "pairwise_batch_pallas",
        lambda *a, **k: (_ for _ in ()).throw(ImportError("no pallas")))
    quorum, lo, hi, wi, wj = _forces_args(block=17)
    with pytest.warns(RuntimeWarning, match="falling back"):
        ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second call: no warning
        out = ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    want = ref.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # an unrelated hook is keyed separately: its first failure warns
    monkeypatch.setattr(
        qs_mod, "query_topk_pallas",
        lambda *a, **k: (_ for _ in ()).throw(ImportError("no pallas")))
    k, block, d, Q, topk = 3, 12, 6, 5, 4
    stack = jnp.asarray(RNG.normal(size=(k, block, d)), jnp.float32)
    queries = jnp.asarray(RNG.normal(size=(Q, d)), jnp.float32)
    mask = jnp.ones((k, block), jnp.float32)
    gidx = jnp.asarray(
        np.arange(k * block, dtype=np.int32).reshape(k, block))
    with pytest.warns(RuntimeWarning, match="query_topk"):
        ops.query_topk(stack, queries, mask, gidx, topk=topk)


def _forces_args(k=5, block=9, n_pairs=7):
    quorum = jnp.asarray(np.concatenate(
        [RNG.normal(size=(k, block, 3)),
         RNG.uniform(0.5, 2, (k, block, 1))], -1), jnp.float32)
    lo = RNG.integers(0, k, size=n_pairs).astype(np.int32)
    hi = RNG.integers(0, k, size=n_pairs).astype(np.int32)
    wi = np.ones(n_pairs, np.float32)
    wj = (lo != hi).astype(np.float32)
    return quorum, lo, hi, wi, wj


def test_interpret_dispatch_tracks_backend(monkeypatch):
    """_interpret() is the single source of the interpret/compiled
    decision: True off-TPU, False on TPU."""
    assert jax.default_backend() != "tpu"       # the CI/test environment
    assert ops._interpret() is True
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert ops._interpret() is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert ops._interpret() is True


def test_interpret_flag_reaches_pallas_launch(monkeypatch):
    """The hook wrappers pass _interpret()'s verdict into the Pallas
    call (recorded via a shim that then falls back, so the assertion
    works on any backend)."""
    seen = {}

    def shim(*args, **kwargs):
        seen["interpret"] = kwargs.get("interpret")
        raise NotImplementedError("recorded, now force the ref path")

    monkeypatch.setattr(ops, "pairwise_batch_pallas", shim)
    quorum, lo, hi, wi, wj = _forces_args(block=11)
    with pytest.warns(RuntimeWarning, match="falling back"):
        out = ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    assert seen["interpret"] is True            # CPU backend -> interpret
    want = ref.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_forces_kernel_absent_falls_back_to_ref(monkeypatch):
    monkeypatch.setattr(
        ops, "pairwise_batch_pallas",
        lambda *a, **k: (_ for _ in ()).throw(ImportError("no pallas")))
    quorum, lo, hi, wi, wj = _forces_args(block=13)
    with pytest.warns(RuntimeWarning, match="pairwise_batch_forces"):
        out = ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    want = ref.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_query_topk_kernel_absent_falls_back_to_ref(monkeypatch):
    def absent(*a, **k):
        raise NotImplementedError("no mosaic lowering")

    monkeypatch.setattr(qs_mod, "query_topk_pallas", absent)
    k, block, d, Q, topk = 3, 10, 6, 7, 5
    stack = jnp.asarray(RNG.normal(size=(k, block, d)), jnp.float32)
    queries = jnp.asarray(RNG.normal(size=(Q, d)), jnp.float32)
    mask = (RNG.uniform(size=(k, block)) > 0.4).astype(np.float32)
    gidx = np.arange(k * block, dtype=np.int32).reshape(k, block)
    with pytest.warns(RuntimeWarning, match="query_topk"):
        got_v, got_i = ops.query_topk(stack, queries, jnp.asarray(mask),
                                      jnp.asarray(gidx), topk=topk)
    # the ref path sees the same padded-Q operand the kernel would have
    want_v, want_i = ref.query_topk(stack, jnp.pad(queries, ((0, 1), (0, 0))),
                                    mask, gidx, topk=topk)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i[:Q]))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v[:Q]),
                               rtol=1e-5, atol=1e-5)


def test_pairwise_threshold_kernel_absent_falls_back_to_ref(monkeypatch):
    monkeypatch.setattr(
        pt_mod, "pairwise_threshold_pallas",
        lambda *a, **k: (_ for _ in ()).throw(ImportError("no pallas")))
    k, block, n_pairs, d = 3, 7, 4, 5
    quorum = jnp.asarray(RNG.normal(size=(k, block, d)), jnp.float32)
    lo = RNG.integers(0, k, n_pairs).astype(np.int32)
    hi = RNG.integers(0, k, n_pairs).astype(np.int32)
    meta = np.stack([np.ones(n_pairs), (lo == hi),
                     RNG.integers(0, 4, n_pairs),
                     RNG.integers(0, 4, n_pairs),
                     np.full(n_pairs, block),
                     np.full(n_pairs, block)], 1).astype(np.int32)
    with pytest.warns(RuntimeWarning, match="pairwise_threshold"):
        got = ops.pairwise_threshold(quorum, lo, hi, jnp.asarray(meta),
                                     threshold=0.4, capacity=100,
                                     block_rows=block)
    # the wrapper pads rows to 8 sublanes and capacity to 128 lanes
    qp = jnp.pad(quorum, ((0, 0), (0, 1), (0, 0)))
    want = ref.pairwise_threshold(qp, lo, hi, meta, threshold=0.4,
                                  capacity=128, block_rows=block)
    for g, w in zip(got[:3], want[:3]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w)[:100],
                                   rtol=1e-5, atol=1e-5)
    assert int(got[3]) == int(want[3])


def test_real_kernel_bugs_still_propagate(monkeypatch):
    """Only ImportError/NotImplementedError trigger the ref fallback;
    anything else (shape bugs, assertion failures) must surface."""
    def broken(*a, **k):
        raise ValueError("genuine kernel bug")

    monkeypatch.setattr(ops, "pairwise_batch_pallas", broken)
    quorum, lo, hi, wi, wj = _forces_args(block=15)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no fallback warning either
        with pytest.raises(ValueError, match="genuine kernel bug"):
            ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)


def test_pairwise_topk_kernel_absent_falls_back_to_ref(monkeypatch):
    from repro.kernels import pairwise_topk as ptk_mod
    monkeypatch.setattr(
        ptk_mod, "pairwise_topk_pallas",
        lambda *a, **k: (_ for _ in ()).throw(ImportError("no pallas")))
    k, block, n_pairs, d, topk = 3, 6, 4, 5, 3
    quorum = jnp.asarray(RNG.normal(size=(k, block, d)), jnp.float32)
    lo = RNG.integers(0, k, n_pairs).astype(np.int32)
    hi = RNG.integers(0, k, n_pairs).astype(np.int32)
    meta = np.stack([np.ones(n_pairs), (lo == hi),
                     np.arange(n_pairs),
                     n_pairs + np.arange(n_pairs),
                     np.full(n_pairs, block),
                     np.full(n_pairs, block)], 1).astype(np.int32)
    with pytest.warns(RuntimeWarning, match="pairwise_topk"):
        got_v, got_i = ops.pairwise_topk(quorum, lo, hi, jnp.asarray(meta),
                                         topk=topk, block_rows=block)
    # the wrapper pads rows to 8 sublanes; the ref path sees the padding
    qp = jnp.pad(quorum, ((0, 0), (0, 2), (0, 0)))
    want_v, want_i = ref.pairwise_topk(qp, lo, hi, meta, topk=topk,
                                       block_rows=block)
    np.testing.assert_array_equal(np.asarray(got_i),
                                  np.asarray(want_i)[:, :block])
    np.testing.assert_allclose(np.asarray(got_v),
                               np.asarray(want_v)[:, :block],
                               rtol=1e-5, atol=1e-5)

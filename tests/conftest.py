import os
import sys
from pathlib import Path

# tests run against src/ without installation
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Distributed tests spawn subprocesses with their
# own XLA_FLAGS (see tests/test_distributed.py).

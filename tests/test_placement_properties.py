"""Hypothesis property sweeps for the placement layer.

Skipped wholesale when hypothesis is not installed; the deterministic
per-(placement, P) conformance suite in
tests/test_placement_conformance.py always runs.

Two headline properties (ISSUE satellite):
  * a random P <= 64 -> the ``auto`` placement satisfies the conformance
    invariants (co-residency, balanced ownership partition, replication
    floor),
  * a random failed-device subset (small enough that no block can lose
    all its holders) -> ``reassign`` still partitions all of the failed
    devices' pairs onto live holders, under a randomly chosen supported
    placement.
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.placement import (auto_placement, get_placement,
                                  supported_placements)
from repro.core.quorum import quorum_size_lower_bound
from repro.core.scheduler import reassign


@given(st.integers(min_value=1, max_value=64))
@settings(max_examples=40, deadline=None)
def test_auto_placement_conformance_invariants(P):
    plc = auto_placement(P)
    sets = plc.residency_sets
    # co-residency of every unordered pair (incl. self-pairs)
    ok = np.zeros((P, P), dtype=bool)
    for S in sets:
        blocks = sorted(S)
        for x in blocks:
            for y in blocks:
                ok[x, y] = True
    assert ok.all()
    # balanced ownership partition
    loads = np.zeros(P, dtype=int)
    for x in range(P):
        for y in range(x, P):
            o = plc.owner_of(x, y)
            assert o == plc.owner_of(y, x)
            assert x in sets[o] and y in sets[o]
            loads[o] += 1
    total = P * (P + 1) // 2
    assert loads.sum() == total
    assert loads.max() <= math.ceil(total / P)
    assert loads.max() - loads.min() <= 1
    # replication floor, and auto really is minimal among supported
    assert plc.max_residency >= quorum_size_lower_bound(P)
    assert plc.replication == min(p.replication
                                  for p in supported_placements(P))


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_reassign_partitions_all_failed_pairs(data):
    P = data.draw(st.integers(min_value=2, max_value=32), label="P")
    names = [p.name for p in supported_placements(P)]
    plc = get_placement(data.draw(st.sampled_from(names), label="plc"), P)
    # keep |failed| < replication so no block can lose all its holders
    # (with replication holders per block, that needs >= replication
    # failures) and at least one device survives
    max_fail = min(P - 1, plc.replication - 1)
    if max_fail < 1:
        return
    failed = sorted(data.draw(
        st.sets(st.integers(min_value=0, max_value=P - 1),
                min_size=1, max_size=max_fail), label="failed"))
    sched = plc.schedule()
    plan = reassign(sched, failed, placement=plc)

    recovered = []
    for i, pairs in plan.extra_pairs.items():
        assert i not in failed
        for pair in pairs:
            assert set(pair) <= plc.residency_sets[i]
            recovered.append(pair)
    for i, entries in plan.fetch_pairs.items():
        assert i not in failed
        for (pair, missing, src) in entries:
            assert src not in failed
            assert missing in plc.residency_sets[src]
            recovered.append(pair)

    want = []
    for f in failed:
        want += [(min(x, y), max(x, y))
                 for (x, y) in sched.global_pairs_of(f)]
    # every failed pair recovered exactly once — a partition of lost work
    assert sorted(recovered) == sorted(want)
    assert plan.n_recovered == len(want)

"""Elastic rescale plans (launch/elastic.py): identity, grow, shrink."""

import pytest

from repro.core.quorum import cyclic_quorums
from repro.launch.elastic import rescale


@pytest.mark.parametrize("P", [1, 4, 8, 13])
def test_identity_rescale_is_noop(P):
    """Regression: an identity rescale must produce an EMPTY fetch plan —
    every device already holds its quorum and block ids keep their
    meaning."""
    plan = rescale(P, P)
    assert plan.total_fetch_blocks == 0
    assert plan.fetches == {}
    assert plan.schedule.P == P
    assert plan.new_quorums == cyclic_quorums(P)


@pytest.mark.parametrize("P_old,P_new", [(4, 8), (5, 12), (1, 6)])
def test_grow_fetches_full_new_quorums(P_old, P_new):
    """Across a resize block ids are re-chunked, so every device fetches
    its entire new quorum — no stale-id reuse."""
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)
    k = len(quorums[0])
    assert plan.total_fetch_blocks == P_new * k


@pytest.mark.parametrize("P_old,P_new", [(8, 4), (12, 5), (6, 1)])
def test_shrink_fetches_full_new_quorums(P_old, P_new):
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)

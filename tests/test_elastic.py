"""Elastic rescale plans (launch/elastic.py): identity, grow, shrink,
and same-P placement migration (cyclic -> plane / full)."""

import pytest

from repro.core.placement import get_placement
from repro.core.quorum import cyclic_quorums
from repro.launch.elastic import rescale


@pytest.mark.parametrize("P", [1, 4, 8, 13])
def test_identity_rescale_is_noop(P):
    """Regression: an identity rescale must produce an EMPTY fetch plan —
    every device already holds its quorum and block ids keep their
    meaning."""
    plan = rescale(P, P)
    assert plan.total_fetch_blocks == 0
    assert plan.fetches == {}
    assert plan.schedule.P == P
    assert plan.new_quorums == cyclic_quorums(P)


@pytest.mark.parametrize("P_old,P_new", [(4, 8), (5, 12), (1, 6)])
def test_grow_fetches_full_new_quorums(P_old, P_new):
    """Across a resize block ids are re-chunked, so every device fetches
    its entire new quorum — no stale-id reuse."""
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)
    k = len(quorums[0])
    assert plan.total_fetch_blocks == P_new * k


@pytest.mark.parametrize("P_old,P_new", [(8, 4), (12, 5), (6, 1)])
def test_shrink_fetches_full_new_quorums(P_old, P_new):
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)


@pytest.mark.parametrize("P,name", [(12, "affine"), (13, "projective"),
                                    (31, "projective"), (8, "full")])
def test_migration_fetches_residency_delta(P, name):
    """Same-P placement change: block ids keep their meaning, so each
    device fetches exactly its residency delta — a live cyclic -> plane
    (or -> full) migration moves only what's missing, never the corpus."""
    plc = get_placement(name, P)
    cyc = get_placement("cyclic", P)
    plan = rescale(P, P, placement_old="cyclic", placement_new=plc)
    assert plan.is_migration or plan.total_fetch_blocks == 0
    for i in range(P):
        new_res = set(plc.residency(i))
        old_res = cyc.residency(i)
        assert plan.new_quorums[i] == sorted(new_res)
        assert set(plan.fetches.get(i, [])) == new_res - old_res
    # schedule rides the new placement
    assert tuple(plan.schedule.shifts.tolist()) == tuple(sorted(plc.shifts))


def test_migration_to_full_fetches_complement():
    P = 6
    cyc = get_placement("cyclic", P)
    plan = rescale(P, P, placement_old="cyclic", placement_new="full")
    assert plan.is_migration
    assert plan.total_fetch_blocks == sum(
        P - len(cyc.residency(i)) for i in range(P))


def test_migration_roundtrip_is_reversible():
    """cyclic -> projective -> cyclic at P = 31 (where the Singer set
    differs from the search set): the reverse migration fetches exactly
    what the forward one dropped."""
    P = 31
    fwd = rescale(P, P, "cyclic", "projective")
    back = rescale(P, P, "projective", "cyclic")
    assert fwd.is_migration and back.is_migration
    assert fwd.total_fetch_blocks == back.total_fetch_blocks > 0


def test_env_placement_steers_rescale(monkeypatch):
    """REPRO_PLACEMENT selects the rescale target when no placement is
    passed (mirroring the engine's implicit selection)."""
    monkeypatch.setenv("REPRO_PLACEMENT", "full")
    plan = rescale(4, 8)
    assert plan.placement_new.name == "full"
    assert all(plan.fetches[i] == list(range(8)) for i in range(8))
    monkeypatch.delenv("REPRO_PLACEMENT")
    assert rescale(4, 8).placement_new.name == "cyclic"

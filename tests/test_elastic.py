"""Elastic rescale plans (launch/elastic.py): identity, grow, shrink
(divisible resizes reuse re-chunkable local shards), same-P placement
migration (cyclic -> plane / full), failover, and replication repair."""

import pytest

from repro.core.placement import get_placement
from repro.core.quorum import cyclic_quorums
from repro.launch.elastic import (failover, plan_replication_repair,
                                  rescale)


@pytest.mark.parametrize("P", [1, 4, 8, 13])
def test_identity_rescale_is_noop(P):
    """Regression: an identity rescale must produce an EMPTY fetch plan —
    every device already holds its quorum and block ids keep their
    meaning."""
    plan = rescale(P, P)
    assert plan.total_fetch_blocks == 0
    assert plan.fetches == {}
    assert plan.schedule.P == P
    assert plan.new_quorums == cyclic_quorums(P)


@pytest.mark.parametrize("P_old,P_new", [(5, 12), (3, 8), (7, 12)])
def test_grow_nondivisible_fetches_full_new_quorums(P_old, P_new):
    """Across a non-divisible resize chunk boundaries don't align, so
    every device fetches its entire new quorum — no stale-id reuse."""
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)
    k = len(quorums[0])
    assert plan.total_fetch_blocks == P_new * k


@pytest.mark.parametrize("P_old,P_new", [(12, 5), (8, 3)])
def test_shrink_nondivisible_fetches_full_new_quorums(P_old, P_new):
    plan = rescale(P_old, P_new)
    quorums = cyclic_quorums(P_new)
    assert set(plan.fetches) == set(range(P_new))
    for i, S in enumerate(quorums):
        assert plan.fetches[i] == list(S)


@pytest.mark.parametrize("P_old,P_new", [(4, 8), (1, 6), (2, 6), (4, 12)])
def test_grow_divisible_reuses_rechunked_shards(P_old, P_new):
    """When P_new % P_old == 0 old chunk boundaries nest: old block b
    splits into new blocks b*m .. b*m+m-1, so surviving devices re-chunk
    locally and fetch only the delta; fresh devices still fetch all."""
    m = P_new // P_old
    plan = rescale(P_old, P_new)
    old = get_placement("cyclic", P_old)
    full = sum(len(S) for S in cyclic_quorums(P_new))
    assert plan.total_fetch_blocks < full
    for i in range(P_new):
        new_res = set(plan.new_quorums[i])
        if i < P_old:
            derivable = {b * m + j for b in old.residency(i)
                         for j in range(m)}
        else:
            derivable = set()
        fetched = set(plan.fetches.get(i, []))
        assert fetched == new_res - derivable
        # old shards + fetches assemble the full new residency
        assert new_res <= derivable | fetched


@pytest.mark.parametrize("P_old,P_new", [(8, 4), (6, 1), (12, 4)])
def test_shrink_divisible_reuses_rechunked_shards(P_old, P_new):
    """When P_old % P_new == 0 new block b is derivable locally iff all
    of its constituent old blocks b*m .. b*m+m-1 were held."""
    m = P_old // P_new
    plan = rescale(P_old, P_new)
    old = get_placement("cyclic", P_old)
    for i in range(P_new):
        new_res = set(plan.new_quorums[i])
        held = old.residency(i)
        derivable = {b for b in range(P_new)
                     if all(b * m + j in held for j in range(m))}
        fetched = set(plan.fetches.get(i, []))
        assert fetched == new_res - derivable
        assert new_res <= derivable | fetched


def test_grow_from_one_device_reuses_everything_locally():
    """P=1 -> 6: the lone device held the whole corpus, so it re-chunks
    with zero fetches; the five new devices fetch their residency."""
    plan = rescale(1, 6)
    assert plan.fetches.get(0, []) == []
    for i in range(1, 6):
        assert plan.fetches[i] == plan.new_quorums[i]


@pytest.mark.parametrize("P,name", [(12, "affine"), (13, "projective"),
                                    (31, "projective"), (8, "full")])
def test_migration_fetches_residency_delta(P, name):
    """Same-P placement change: block ids keep their meaning, so each
    device fetches exactly its residency delta — a live cyclic -> plane
    (or -> full) migration moves only what's missing, never the corpus."""
    plc = get_placement(name, P)
    cyc = get_placement("cyclic", P)
    plan = rescale(P, P, placement_old="cyclic", placement_new=plc)
    assert plan.is_migration or plan.total_fetch_blocks == 0
    for i in range(P):
        new_res = set(plc.residency(i))
        old_res = cyc.residency(i)
        assert plan.new_quorums[i] == sorted(new_res)
        assert set(plan.fetches.get(i, [])) == new_res - old_res
    # schedule rides the new placement
    assert tuple(plan.schedule.shifts.tolist()) == tuple(sorted(plc.shifts))


def test_migration_to_full_fetches_complement():
    P = 6
    cyc = get_placement("cyclic", P)
    plan = rescale(P, P, placement_old="cyclic", placement_new="full")
    assert plan.is_migration
    assert plan.total_fetch_blocks == sum(
        P - len(cyc.residency(i)) for i in range(P))


def test_migration_roundtrip_is_reversible():
    """cyclic -> projective -> cyclic at P = 31 (where the Singer set
    differs from the search set): the reverse migration fetches exactly
    what the forward one dropped."""
    P = 31
    fwd = rescale(P, P, "cyclic", "projective")
    back = rescale(P, P, "projective", "cyclic")
    assert fwd.is_migration and back.is_migration
    assert fwd.total_fetch_blocks == back.total_fetch_blocks > 0


def test_env_placement_steers_rescale(monkeypatch):
    """REPRO_PLACEMENT selects the rescale target when no placement is
    passed (mirroring the engine's implicit selection)."""
    monkeypatch.setenv("REPRO_PLACEMENT", "full")
    plan = rescale(4, 8)
    assert plan.placement_new.name == "full"
    # full @ P=4 held everything, so surviving devices re-chunk locally
    # (4 | 8 is a divisible grow); the four fresh devices fetch all 8
    for i in range(4):
        assert plan.fetches.get(i, []) == []
    for i in range(4, 8):
        assert plan.fetches[i] == list(range(8))
    monkeypatch.delenv("REPRO_PLACEMENT")
    assert rescale(4, 8).placement_new.name == "cyclic"


# ---------------------------------------------------------------------------
# failover — first direct coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P,failed", [(8, [2]), (13, [0, 6]), (16, [15])])
def test_failover_wraps_reassign(P, failed):
    """failover() must hand back reassign()'s plan verbatim: every lost
    pair recovered exactly once, onto live devices only."""
    from repro.core.scheduler import build_schedule, reassign

    s = build_schedule(P)
    plan = failover(s, failed)
    want = reassign(s, failed)
    assert plan == want
    assert plan.n_recovered == len(failed) * s.n_pairs
    for i in list(plan.extra_pairs) + list(plan.fetch_pairs):
        assert i not in failed


def test_failover_honors_placement():
    """A plane placement's residency steers tier-1/tier-2 splitting."""
    plc = get_placement("projective", 13)
    s = plc.schedule()
    plan = failover(s, [3], placement=plc)
    assert plan.n_recovered == s.n_pairs
    for i, entries in plan.fetch_pairs.items():
        for (_pair, missing, src) in entries:
            assert missing in plc.residency_sets[src]


# ---------------------------------------------------------------------------
# replication repair
# ---------------------------------------------------------------------------

def _copy_counts(plc, dead, plan):
    """Per-block live copy count after applying the plan."""
    P = plc.P
    dead_set = set(dead)
    counts = [0] * P
    for i, S in enumerate(plc.residency_sets):
        if i in dead_set:
            continue
        for b in S:
            counts[b] += 1
    for (b, src, tgt) in plan.actions:
        assert src not in dead_set and tgt not in dead_set
        counts[b] += 1
    return counts


@pytest.mark.parametrize("name,P,dead", [
    ("cyclic", 8, [2]), ("cyclic", 13, [0, 6]),
    ("projective", 13, [1]), ("affine", 12, [3, 7]), ("full", 5, [0, 4])])
def test_replication_repair_restores_invariant(name, P, dead):
    plc = get_placement(name, P)
    plan = plan_replication_repair(plc, dead)
    orig = [0] * P
    for S in plc.residency_sets:
        for b in S:
            orig[b] += 1
    n_live = P - len(dead)
    counts = _copy_counts(plc, dead, plan)
    for b in range(P):
        want = min(orig[b], n_live)
        assert counts[b] == want, (name, P, dead, b)
    assert tuple(counts) == plan.copies_after
    # sources actually hold what they ship, and no action targets a holder
    for (b, src, tgt) in plan.actions:
        assert b in plc.residency_sets[src]
        assert b not in plc.residency_sets[tgt]


def test_replication_repair_is_deterministic():
    plc = get_placement("cyclic", 13)
    a = plan_replication_repair(plc, [2, 9])
    b = plan_replication_repair(plc, [9, 2])
    assert a == b
    assert a.n_copies == len(a.actions)
    assert a.blocks_repaired == tuple(sorted(set(a.blocks_repaired)))


def test_replication_repair_no_failures_is_noop():
    plc = get_placement("cyclic", 8)
    plan = plan_replication_repair(plc, [])
    assert plan.actions == ()
    assert plan.n_copies == 0


def test_replication_repair_block_lost_raises():
    """All holders of one block dead: repair must refuse (restore from
    checkpoint is the correct response)."""
    plc = get_placement("cyclic", 8)
    holders = [i for i in range(8) if 0 in plc.residency_sets[i]]
    with pytest.raises(RuntimeError, match="lost"):
        plan_replication_repair(plc, holders)


def test_replication_repair_all_dead_raises():
    plc = get_placement("cyclic", 4)
    with pytest.raises(ValueError, match="all devices dead"):
        plan_replication_repair(plc, [0, 1, 2, 3])


def test_replication_repair_uses_current_residency():
    """The residency override: a block already re-replicated onto a
    survivor needs fewer (or no) new copies."""
    plc = get_placement("cyclic", 8)
    dead = [i for i in range(8) if 0 in plc.residency_sets[i]][:-1]
    live_holder = [i for i in range(8) if 0 in plc.residency_sets[i]][-1]
    base = plan_replication_repair(plc, dead)
    assert any(b == 0 for (b, _s, _t) in base.actions)
    # hand every live device block 0 already: nothing left to repair for it
    current = [set(S) | {0} if i not in dead else set(S)
               for i, S in enumerate(plc.residency_sets)]
    plan = plan_replication_repair(plc, dead, residency=current)
    assert not any(b == 0 for (b, _s, _t) in plan.actions)
    assert live_holder not in dead

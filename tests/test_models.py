"""Model stack correctness: decode==forward, attention paths, MoE, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, whisper
from repro.models.attention import (banded_sdpa, blocked_sdpa,
                                    causal_window_bias, sdpa)
from repro.models.config import ModelConfig

F32 = jnp.float32


def tiny(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=64, dtype=F32)
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny(n_layers=4),
    "qknorm_swa": tiny(n_kv_heads=4, qk_norm=True, window=6),
    "moe": tiny(moe_experts=4, moe_top_k=2, capacity_factor=4.0),
    "ssm": tiny(family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                layer_pattern=("M",), ssm_state=16, ssm_head_dim=16,
                ssm_chunk=4),
    "hybrid": tiny(family="hybrid", n_layers=4, layer_pattern=("M", "A"),
                   ssm_state=16, ssm_head_dim=16, ssm_chunk=4),
    "mrope": tiny(pos="mrope", mrope_sections=(4, 2, 2)),
}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_forward(name):
    cfg = CONFIGS[name]
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    fwd, _ = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params, {"tokens": toks})
    state = lm.init_decode_state(cfg, B, max_len=T)
    step = jax.jit(lambda p, s, t: lm.decode_step(cfg, p, s, t))
    outs = []
    for t in range(T):
        lg, state = step(params, state, toks[:, t:t + 1])
        outs.append(lg)
    err = np.abs(np.asarray(fwd) - np.asarray(jnp.concatenate(outs, 1))).max()
    assert err < 2e-2, (name, err)


def test_whisper_decode_matches_forward():
    cfg = ModelConfig(family="audio", encdec=True, n_layers=2, n_enc_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=64, norm="layernorm", mlp="gelu", pos="sincos",
                      frontend="audio_frames", tie_embeddings=True, dtype=F32)
    params = whisper.init_params(cfg, jax.random.PRNGKey(3))
    B, Te, Td = 2, 12, 8
    frames = jax.random.normal(jax.random.PRNGKey(4), (B, Te, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, Td), 0, cfg.vocab_size)
    fwd, _ = jax.jit(lambda p, b: whisper.forward(cfg, p, b))(
        params, {"frames": frames, "tokens": toks})
    memory = jax.jit(lambda p, f: whisper.encode(cfg, p, f))(params, frames)
    state = whisper.init_decode_state(cfg, params, B, Td, memory)
    step = jax.jit(lambda p, s, t: whisper.decode_step(cfg, p, s, t))
    outs = []
    for t in range(Td):
        lg, state = step(params, state, toks[:, t:t + 1])
        outs.append(lg)
    err = np.abs(np.asarray(fwd) - np.asarray(jnp.concatenate(outs, 1))).max()
    assert err < 2e-2


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_blocked_attention_matches_plain(causal, window):
    rng = np.random.default_rng(0)
    B, T, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), F32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), F32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), F32)
    want = sdpa(q, k, v, causal_window_bias(T, T, causal=causal, window=window))
    out = blocked_sdpa(q, k, v, causal=causal, window=window, block_k=16,
                       unroll=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_banded_swa_matches_plain():
    rng = np.random.default_rng(0)
    B, T, H, KV, hd, W = 2, 64, 4, 2, 16, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), F32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), F32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), F32)
    want = sdpa(q, k, v, causal_window_bias(T, T, causal=True, window=W))
    out = banded_sdpa(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_routing_mass_conservation():
    """With generous capacity, MoE output is a convex combination of expert
    outputs (gates sum to 1; no token dropped)."""
    cfg = tiny(moe_experts=4, moe_top_k=2, capacity_factor=8.0)
    from repro.models import moe as moe_mod
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    p = params["layers"]["pos0"]["moe"]
    p0 = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe_mod.apply_moe(cfg, p0, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99  # Switch aux loss lower bound is 1 at balance


def test_loss_decreases_on_overfit():
    """Integration: 30 Adam steps on one tiny batch must cut the loss."""
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_mesh
    from repro.optim import AdamWConfig, adamw_init
    cfg = tiny(n_layers=2)
    mesh = make_mesh((1,), ("data",))
    cfg = steps_mod.prepare_config(cfg, mesh, seq_shard=False)
    step = jax.jit(steps_mod.build_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    first = None
    with mesh:
        for i in range(30):
            params, opt, metrics = step(params, opt, batch)
            if first is None:
                first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first * 0.7, (first, last)


def test_grad_accumulation_matches_full_batch():
    """accum=2 must produce (numerically) the same update as accum=1."""
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_mesh
    from repro.optim import AdamWConfig, adamw_init
    cfg = tiny(n_layers=2)
    mesh = make_mesh((1,), ("data",))
    cfg = steps_mod.prepare_config(cfg, mesh, seq_shard=False)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    with mesh:
        p1, _, m1 = jax.jit(steps_mod.build_train_step(cfg, ocfg, accum=1))(
            params, adamw_init(params), batch)
        p2, _, m2 = jax.jit(steps_mod.build_train_step(cfg, ocfg, accum=2))(
            params, adamw_init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)

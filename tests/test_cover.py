"""Cover-routing properties (serving/cover.py), deterministic P sweeps.

The headline property for every P <= 64: the plan's quorums union to all
P blocks, the dedup mask scores each block exactly once, and the cover is
small.  NOTE the size bound is ceil(P/k) + 3, not the naive ceil(P/k) + 1:
the tighter bound is not achievable in general — exhaustive search shows
no 5-translate cover exists for P = 22 (k = 6, ceil(P/k) + 1 = 5) — and
+3 is the exact worst case over P <= 64 (attained at P = 64), verified
against the branch-and-bound minimum build_cover itself uses.
"""

import itertools
import math

import numpy as np
import pytest

from repro.core.quorum import difference_set
from repro.serving.cover import (build_cover, closed_form_cover,
                                 greedy_cover, is_cover, step_cover)


@pytest.mark.parametrize("P", list(range(1, 65)))
def test_cover_plan_properties(P):
    plan = build_cover(P)
    k = plan.k
    assert plan.A == tuple(sorted(difference_set(P)))

    # 1. the cover's quorums union to all P blocks
    assert is_cover(P, plan.A, plan.devices)

    # 2. size: never worse than the always-available size-k closed form,
    #    and within +3 of the ceil(P/k) lower bound (exact worst case)
    assert plan.n_cover <= k
    assert plan.n_cover <= math.ceil(P / k) + 3

    # 3. dedup: summed over all devices and slots, each block is scored
    #    exactly once per query
    hits = np.zeros(P, int)
    for i in range(P):
        for s, a in enumerate(plan.A):
            if plan.slot_mask[i, s]:
                assert i in plan.devices  # only cover devices score
                hits[(a + i) % P] += 1
    assert (hits == 1).all()

    # 4. the assignment agrees with the mask
    for b in range(P):
        own = plan.block_owner[b]
        assert own in plan.devices
        s = plan.A.index((b - own) % P)
        assert plan.slot_mask[own, s] == 1.0


@pytest.mark.parametrize("P", [1, 2, 5, 13, 40, 64, 100, 150, 333])
def test_closed_form_cover_always_valid(P):
    """C = -A mod P covers for any difference set, any P (the cyclic
    closed form: A - A = Z_P), with zero search — the large-P fast path."""
    A = difference_set(P)
    C = closed_form_cover(P, A)
    assert len(C) <= len(A)
    assert is_cover(P, A, C)


@pytest.mark.parametrize("P", [4, 9, 25, 40, 81, 100, 121, 200])
def test_step_and_greedy_covers_valid(P):
    A = difference_set(P)
    g = greedy_cover(P, A)
    assert is_cover(P, A, g)
    s = step_cover(P, A)
    if s is not None:
        assert is_cover(P, A, s)


def test_bound_plus_one_infeasible_at_p22():
    """Pin the documented deviation: for P = 22 (k = 6) no 5-translate
    cover of the optimal difference set exists, so ceil(P/k) + 1 cannot
    be promised in general — exhaustively verified (wlog device 0 in the
    cover, by translational symmetry)."""
    P = 22
    A = difference_set(P)
    assert math.ceil(P / len(A)) + 1 == 5
    q0 = {a % P for a in A}
    for rest in itertools.combinations(range(1, P), 4):
        got = set(q0)
        for i in rest:
            got |= {(a + i) % P for a in A}
        assert len(got) < P
    assert build_cover(P).n_cover == 6  # and 6 is achieved


def test_cover_is_cached_and_pure():
    a = build_cover(12)
    b = build_cover(12)
    assert a is b
    assert a.devices == b.devices

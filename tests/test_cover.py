"""Cover-routing properties (serving/cover.py), deterministic P sweeps.

The headline property for every P <= 64: the plan's quorums union to all
P blocks, the dedup mask scores each block exactly once, and the cover is
small.  NOTE the size bound is ceil(P/k) + 3, not the naive ceil(P/k) + 1:
the tighter bound is not achievable in general — exhaustive search shows
no 5-translate cover exists for P = 22 (k = 6, ceil(P/k) + 1 = 5) — and
+3 is the exact worst case over P <= 64 (attained at P = 64), verified
against the branch-and-bound minimum build_cover itself uses.
"""

import itertools
import math

import numpy as np
import pytest

from repro.core.placement import get_placement, plane_placement
from repro.core.quorum import difference_set
from repro.serving.cover import (build_cover, closed_form_cover,
                                 exact_cover, exact_cover_sets, greedy_cover,
                                 is_cover, step_cover)


@pytest.mark.parametrize("P", list(range(1, 65)))
def test_cover_plan_properties(P):
    plan = build_cover(P)
    k = plan.k
    assert plan.A == tuple(sorted(difference_set(P)))

    # 1. the cover's quorums union to all P blocks
    assert is_cover(P, plan.A, plan.devices)

    # 2. size: never worse than the always-available size-k closed form,
    #    and within +3 of the ceil(P/k) lower bound (exact worst case)
    assert plan.n_cover <= k
    assert plan.n_cover <= math.ceil(P / k) + 3

    # 3. dedup: summed over all devices and slots, each block is scored
    #    exactly once per query
    hits = np.zeros(P, int)
    for i in range(P):
        for s, a in enumerate(plan.A):
            if plan.slot_mask[i, s]:
                assert i in plan.devices  # only cover devices score
                hits[(a + i) % P] += 1
    assert (hits == 1).all()

    # 4. the assignment agrees with the mask
    for b in range(P):
        own = plan.block_owner[b]
        assert own in plan.devices
        s = plan.A.index((b - own) % P)
        assert plan.slot_mask[own, s] == 1.0


@pytest.mark.parametrize("P", [1, 2, 5, 13, 40, 64, 100, 150, 333])
def test_closed_form_cover_always_valid(P):
    """C = -A mod P covers for any difference set, any P (the cyclic
    closed form: A - A = Z_P), with zero search — the large-P fast path."""
    A = difference_set(P)
    C = closed_form_cover(P, A)
    assert len(C) <= len(A)
    assert is_cover(P, A, C)


@pytest.mark.parametrize("P", [4, 9, 25, 40, 81, 100, 121, 200])
def test_step_and_greedy_covers_valid(P):
    A = difference_set(P)
    g = greedy_cover(P, A)
    assert is_cover(P, A, g)
    s = step_cover(P, A)
    if s is not None:
        assert is_cover(P, A, s)


def test_bound_plus_one_infeasible_at_p22():
    """Pin the documented deviation: for P = 22 (k = 6) no 5-translate
    cover of the optimal difference set exists, so ceil(P/k) + 1 cannot
    be promised in general — exhaustively verified (wlog device 0 in the
    cover, by translational symmetry)."""
    P = 22
    A = difference_set(P)
    assert math.ceil(P / len(A)) + 1 == 5
    q0 = {a % P for a in A}
    for rest in itertools.combinations(range(1, P), 4):
        got = set(q0)
        for i in rest:
            got |= {(a + i) % P for a in A}
        assert len(got) < P
    assert build_cover(P).n_cover == 6  # and 6 is achieved


def test_cover_is_cached_and_pure():
    a = build_cover(12)
    b = build_cover(12)
    assert a is b
    assert a.devices == b.devices
    # per-placement plans are cached separately and don't collide
    c = build_cover(12, "affine")
    assert c is build_cover(12, get_placement("affine", 12))
    assert c.placement == "affine" and a.placement == "cyclic"


# ---------------------------------------------------------------------------
# Placement sweep: plane covers (ISSUE satellite)
# ---------------------------------------------------------------------------

_PLANE_P = [6, 7, 12, 13, 21, 31, 57]


@pytest.mark.parametrize("P", _PLANE_P)
def test_plane_cover_properties_and_size_pin(P):
    """Plane placements route covers too, and at plane-friendly P the
    plane cover is never larger than the cyclic one (the plane's
    replication is the theoretical optimum, so its translates cover at
    least as efficiently)."""
    plc = plane_placement(P)
    assert plc is not None
    plan = build_cover(P, plc)
    # validity: union of the cover's residency is everything
    got: set = set()
    for i in plan.devices:
        got |= plc.residency(i)
    assert got == set(range(P))
    # dedup mask: each block scored exactly once
    hits = np.zeros(P, int)
    for i in range(P):
        for s, a in enumerate(plan.A):
            if plan.slot_mask[i, s]:
                hits[(a + i) % P] += 1
    assert (hits == 1).all()
    # the pin: plane cover <= cyclic cover at the same P
    assert plan.n_cover <= build_cover(P).n_cover


@pytest.mark.parametrize("P", [2, 5, 8, 31])
def test_full_placement_cover_is_single_device(P):
    plan = build_cover(P, "full")
    assert plan.n_cover == 1
    assert np.asarray(plan.slot_mask).sum() == P  # that device scores all


# ---------------------------------------------------------------------------
# exact_cover generalization (ISSUE small fix): arbitrary residency sets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [5, 12, 21, 22, 31])
def test_exact_cover_sets_matches_cyclic_wrapper(P):
    """Regression: the generalized branch-and-bound over explicit
    residency sets finds the same minimum as the historical cyclic
    search (which stays bit-identical via its pinned root and shift
    branch order)."""
    A = difference_set(P)
    sets = [frozenset((a + i) % P for a in A) for i in range(P)]
    ub = len(greedy_cover(P, A)) + 1
    old = exact_cover(P, A, ub)
    new = exact_cover_sets(sets, ub)          # no symmetry pin, any sets
    assert old is not None and new is not None
    assert len(old) == len(new)
    assert is_cover(P, A, old) and is_cover(P, A, new)


def test_exact_cover_sets_handles_non_cyclic_residency():
    """The point of the generalization: residency that is NOT a translate
    system (irregular sizes, no shift structure) is solved exactly."""
    sets = [{0, 1}, {1, 2, 3}, {3, 4}, {0, 4, 5}, {2, 5}]
    got = exact_cover_sets(sets, ub=len(sets) + 1)
    assert got is not None
    covered: set = set()
    for i in got:
        covered |= set(sets[i])
    assert covered == set(range(6))
    assert len(got) == 2                      # {1,2,3} + {0,4,5} is optimal
    # and an infeasible bound returns None rather than a worse cover
    assert exact_cover_sets(sets, ub=2) is None


def test_exact_cover_cyclic_results_unchanged():
    """Pin the exact minima the pre-generalization search produced for a
    spread of P (these feed build_cover, so any drift would change
    serving fan-out)."""
    for P, n in [(5, 2), (12, 4), (13, 4), (22, 6), (31, 6)]:
        assert build_cover(P).n_cover == n, P


# ---------------------------------------------------------------------------
# degraded covers: serving's half of failure handling (DESIGN.md section 13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [5, 8, 13, 22, 31])
def test_degraded_cover_avoids_dead_and_still_covers(P):
    from repro.serving.cover import build_degraded_cover

    base = build_cover(P)
    dead = [base.devices[0]]  # kill a device the healthy plan relies on
    plan = build_degraded_cover(P, dead=dead)
    assert not (set(plan.devices) & set(dead))
    assert is_cover(P, sorted(plan.A), list(plan.devices))
    # dedup invariant: every block scored exactly once by a live device
    assert sorted(int(b) for b in range(P)) == sorted(
        b for b in range(P) if plan.block_owner[b] >= 0)
    assert all(int(plan.block_owner[b]) in plan.devices for b in range(P))
    np.testing.assert_allclose(
        plan.slot_mask.sum(), P)  # one mask hit per block


@pytest.mark.parametrize("P", [5, 13])
def test_degraded_cover_empty_dead_is_build_cover(P):
    from repro.serving.cover import build_degraded_cover

    assert build_degraded_cover(P, dead=()) is build_cover(P)


def test_degraded_cover_raises_on_lost_block():
    from repro.serving.cover import build_degraded_cover

    P = 8
    plc = get_placement("cyclic", P)
    holders = [i for i in range(P) if 0 in plc.residency_sets[i]]
    with pytest.raises(RuntimeError, match="lost"):
        build_degraded_cover(P, dead=holders)

"""Equivalence tests for the engine's execution modes (DESIGN.md section 4).

Each subprocess pins its own fake-device count (dry-run isolation rule, see
tests/test_distributed.py).  repro.core.selfcheck compares every mode in
(batched, overlap, scan) against allgather_allpairs and the numpy oracle;
P values here complement test_distributed's 4/5/8 with the P = 2 edge
(k = P, single shift) and P = 6 (even, d = P/2 orbit with k = 3 so the
overlap schedule has a non-trivial ready order).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P", [2, 6])
def test_engine_modes_agree(P):
    out = run_sub(f"from repro.core.selfcheck import main; main({P})", P)
    assert "selfcheck OK" in out
    assert "batched,overlap,scan" in out


def test_nbody_modes_and_fused_kernel():
    """distributed_forces across every mode — including the batched mode
    routed through the fused Pallas pairwise_batch kernel — against the
    numpy O(N^2) reference."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.apps.nbody import distributed_forces, forces_reference
rng = np.random.default_rng(1)
N = 32
bodies = np.concatenate([rng.normal(size=(N,3)),
                         rng.uniform(0.5, 2, (N,1))], -1).astype(np.float32)
mesh = jax.make_mesh((4,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
ref = forces_reference(bodies)
for mode, uk in [("batched", True), ("batched", False), ("overlap", False),
                 ("scan", False), ("auto", False)]:
    out = np.asarray(distributed_forces(jnp.asarray(bodies), mesh,
                                        mode=mode, use_kernel=uk))
    err = np.abs(out - ref).max() / np.abs(ref).max()
    assert err < 1e-4, (mode, uk, err)
print("NBODY-MODES-OK")
"""
    assert "NBODY-MODES-OK" in run_sub(code, 4)


@pytest.mark.parametrize("mode", ["batched", "overlap"])
def test_pcit_modes_match_reference(mode):
    """The PCIT tile phases in the unrolled modes (scan is covered by
    test_distributed) against the O(N^3) numpy reference, odd P."""
    code = f"""
import numpy as np, jax
from repro.apps.pcit import run_quorum_pcit, pcit_reference, correlation_reference
rng = np.random.default_rng(0)
N, G = 30, 18
Z = rng.normal(size=(4, G)); W = rng.normal(size=(N, 4))
X = W @ Z + 0.5 * rng.normal(size=(N, G))
mesh = jax.make_mesh((5,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
corr, keep = run_quorum_pcit(X, mesh, mode="{mode}")
np.testing.assert_allclose(corr, correlation_reference(X), rtol=1e-4, atol=1e-5)
assert (keep == pcit_reference(X)).all()
print("PCIT-MODE-OK")
"""
    assert "PCIT-MODE-OK" in run_sub(code, 5)


def test_env_var_mode_override():
    """REPRO_ALLPAIRS_MODE forces auto-mode selection (the benchmark/CI
    A/B hook) without changing results."""
    code = """
import os
os.environ["REPRO_ALLPAIRS_MODE"] = "overlap"
from repro.core.selfcheck import main
main(4, modes=("auto",))
"""
    out = run_sub(code, 4)
    assert "selfcheck OK" in out


def test_default_mask_dedups_half_orbit():
    """mask=None must dedup the doubly-generated d = P/2 orbit on even P
    (the engine derives the device's pair_mask_table row via axis_index) —
    without it those pair contributions come out exactly 2x."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as PS
from repro.core.allpairs import quorum_allpairs
from repro.core.scheduler import build_schedule
from repro.core.selfcheck import pairwise_force, oracle
P, block = 6, 8
sched = build_schedule(P)
rng = np.random.default_rng(0)
x = rng.normal(size=(P * block, 3)).astype(np.float32)
mesh = jax.make_mesh((P,), ("q",))
for mode in ["scan", "batched", "overlap"]:
    def f(xb):
        return quorum_allpairs(pairwise_force, xb, axis_name="q",
                               schedule=sched, mode=mode)  # mask=None
    got = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=PS("q"),
                                out_specs=PS("q")))(x)
    np.testing.assert_allclose(np.asarray(got), oracle(x),
                               rtol=2e-4, atol=2e-5, err_msg=mode)
print("DEFAULT-MASK-OK")
"""
    assert "DEFAULT-MASK-OK" in run_sub(code, 6)


def test_select_mode_heuristic(monkeypatch):
    """The auto heuristic itself: env override wins (and typos raise, not
    silently fall through to the heuristic), a fused batch_fn forces
    batched, the byte budget pushes big problems to overlap/scan."""
    import jax
    import jax.numpy as jnp

    from repro.core.allpairs import _select_mode
    from repro.core.scheduler import build_schedule

    sched = build_schedule(8)  # k = 4
    x = jnp.zeros((16, 4), jnp.float32)
    probe = jax.ShapeDtypeStruct((16, 3), jnp.float32)

    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    assert _select_mode(sched, x, probe, None) == "batched"  # small: fits
    assert _select_mode(sched, x, probe, object()) == "batched"  # fused kernel

    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "scan")
    assert _select_mode(sched, x, probe, None) == "scan"
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "batch")  # typo
    with pytest.raises(ValueError, match="REPRO_ALLPAIRS_MODE"):
        _select_mode(sched, x, probe, None)
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE")

    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "1")
    assert _select_mode(sched, x, probe, None) == "overlap"  # k >= 3
    sched2 = build_schedule(2)  # k = 2: nothing to hide behind
    assert _select_mode(sched2, x, probe, None) == "scan"


def test_batch_bytes_limit_read_at_select_time(monkeypatch):
    """Regression: REPRO_BATCH_BYTES_LIMIT set *after* import must still be
    honored — the budget is read inside _select_mode, not at module load."""
    import jax
    import jax.numpy as jnp

    import repro.core.allpairs as ap
    from repro.core.scheduler import build_schedule

    sched = build_schedule(8)  # k = 4
    x = jnp.zeros((16, 4), jnp.float32)
    probe = jax.ShapeDtypeStruct((16, 3), jnp.float32)

    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.delenv("REPRO_BATCH_BYTES_LIMIT", raising=False)
    assert ap.auto_batch_bytes() == ap._DEFAULT_BATCH_BYTES
    assert ap._select_mode(sched, x, probe, None) == "batched"
    # the module is long imported; shrinking the budget now must take effect
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "1")
    assert ap.auto_batch_bytes() == 1
    assert ap._select_mode(sched, x, probe, None) == "overlap"


def test_use_kernel_requires_batched_mode():
    """The fused kernel only replaces the batched inner step; asking for it
    with another mode (or the atom strategy) must error, not silently run
    the jnp path."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.apps.nbody import distributed_forces
bodies = jnp.zeros((8, 4), jnp.float32)
mesh = jax.make_mesh((2,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
for kwargs in [dict(mode="overlap", use_kernel=True),
               dict(strategy="atom", use_kernel=True)]:
    try:
        distributed_forces(bodies, mesh, **kwargs)
    except ValueError as e:
        assert "use_kernel" in str(e), e
    else:
        raise AssertionError(f"no error for {kwargs}")

# the engine-level guard: batch_fn with a non-batched explicit mode
from repro.core.allpairs import quorum_allpairs
try:
    quorum_allpairs(lambda a, b: (a, b), bodies, axis_name="q",
                    axis_size=2, mode="scan", batch_fn=lambda *a: None)
except ValueError as e:
    assert "batch_fn" in str(e), e
else:
    raise AssertionError("no error for engine-level batch_fn conflict")
print("KERNEL-GUARD-OK")
"""
    assert "KERNEL-GUARD-OK" in run_sub(code, 2)

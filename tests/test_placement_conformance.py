"""Placement conformance suite — the executable interface contract.

Every placement registered in ``repro.core.placement`` must pass every
check here for every P <= 64 where it is defined (``supports(P)``), so
future placements are correct by construction (DESIGN.md section 10):

  1. **all-pairs co-residency** — every unordered block pair (including
     self-pairs) is co-resident on at least one device (paper Theorem 1
     generalized),
  2. **ownership partition** — ``owner_of`` assigns each of the
     C(P,2) + P unordered pairs to exactly one device that holds both
     blocks, symmetrically in its arguments,
  3. **balance** — per-device owned-pair loads within the paper's bound:
     max load <= ceil(total / P), max - min <= 1 (Eq. 12's "equal work",
     exact up to the indivisible even-P half orbit),
  4. **replication floor** — residency can't beat the
     ``quorum_size_lower_bound`` k(k-1)+1 >= P floor, and the placement's
     advertised ``replication`` matches the observed per-block copy count,
  5. **cover validity** — ``build_cover(P, placement)`` visits devices
     whose residency unions to all blocks, scoring each block exactly
     once (the serving dedup contract),
  6. **reassign/rescale closure** — failures reassign every lost pair to
     live holders exactly once, and rescale plans (resize or same-P
     migration) leave every device able to assemble its new residency.

Plus the plane-specific acceptance pins: projective/affine replication is
exactly q + 1 and never worse than cyclic at the same P.
"""

import math

import numpy as np
import pytest

from repro.core.placement import (AffinePlanePlacement,
                                  ProjectivePlanePlacement, auto_placement,
                                  get_placement, plane_placement,
                                  registered_placements, resolve_placement,
                                  supported_placements, weighted_owner_table)
from repro.core.quorum import quorum_size_lower_bound
from repro.core.scheduler import reassign
from repro.launch.elastic import rescale
from repro.serving.cover import build_cover

MAX_P = 64


def _cases():
    return [(name, P)
            for name, cls in sorted(registered_placements().items())
            for P in range(1, MAX_P + 1) if cls.supports(P)]


def _ids():
    return [f"{name}-P{P}" for name, P in _cases()]


def owned_loads(plc) -> np.ndarray:
    """[P] owned-pair count per device; asserts the partition on the way."""
    P = plc.P
    loads = np.zeros(P, dtype=int)
    for x in range(P):
        for y in range(x, P):
            o = plc.owner_of(x, y)
            assert o == plc.owner_of(y, x), (plc.name, P, x, y)
            assert 0 <= o < P
            res = plc.residency_sets[o]
            assert x in res and y in res, (plc.name, P, x, y, o)
            loads[o] += 1
    return loads


@pytest.mark.parametrize("name,P", _cases(), ids=_ids())
def test_all_pairs_co_residency(name, P):
    plc = get_placement(name, P)
    sets = plc.residency_sets
    assert len(sets) == P
    ok = np.zeros((P, P), dtype=bool)
    for S in sets:
        blocks = sorted(S)
        for x in blocks:
            for y in blocks:
                ok[x, y] = True
    assert ok.all(), (name, P)


@pytest.mark.parametrize("name,P", _cases(), ids=_ids())
def test_ownership_is_balanced_partition(name, P):
    plc = get_placement(name, P)
    loads = owned_loads(plc)          # asserts owner holds both + symmetry
    total = P * (P + 1) // 2          # C(P,2) + P unordered pairs
    assert loads.sum() == total       # a function is a partition; pin total
    assert loads.max() <= math.ceil(total / P)
    assert loads.max() - loads.min() <= 1, (name, P, loads)


@pytest.mark.parametrize("name,P", _cases(), ids=_ids())
def test_replication_floor_and_consistency(name, P):
    plc = get_placement(name, P)
    counts = np.zeros(P, dtype=int)
    for S in plc.residency_sets:
        for b in S:
            counts[b] += 1
    assert counts.min() >= 1
    assert plc.replication == counts.max()
    # the k(k-1)+1 >= P floor: co-residency is impossible below it
    assert plc.max_residency >= quorum_size_lower_bound(P)
    if plc.shifts is not None:
        assert plc.max_residency == len(plc.shifts) == plc.replication


@pytest.mark.parametrize("name,P", _cases(), ids=_ids())
def test_cover_validity(name, P):
    plc = get_placement(name, P)
    plan = build_cover(P, plc)
    assert plan.placement == name
    got: set = set()
    for i in plan.devices:
        got |= plc.residency_sets[i]
    assert got == set(range(P))
    assert plan.n_cover <= plc.replication
    # dedup: summed over devices and slots each block scores exactly once
    hits = np.zeros(P, dtype=int)
    for i in range(P):
        for s, a in enumerate(plan.A):
            if plan.slot_mask[i, s]:
                assert i in plan.devices
                hits[(a + i) % P] += 1
    assert (hits == 1).all(), (name, P)


# reassign is O(P^2 * k) per case; a diagonal slice of P values keeps the
# closure check meaningful at every placement without quadratic suite time
_REASSIGN_P = (1, 2, 5, 6, 7, 12, 13, 16, 31, 57, 64)


@pytest.mark.parametrize(
    "name,P", [(n, P) for (n, P) in _cases() if P in _REASSIGN_P],
    ids=[f"{n}-P{P}" for (n, P) in _cases() if P in _REASSIGN_P])
def test_reassign_closure(name, P):
    plc = get_placement(name, P)
    if P == 1:
        return  # no device can fail with a survivor left
    sched = plc.schedule()
    failed = [0] if P <= 4 else [0, P // 2]
    plan = reassign(sched, failed, placement=plc)
    recovered = []
    for i, pairs in plan.extra_pairs.items():
        assert i not in failed
        recovered += pairs
    for i, entries in plan.fetch_pairs.items():
        assert i not in failed
        for (pair, missing, src) in entries:
            assert src not in failed
            assert missing in plc.residency_sets[src]
            recovered.append(pair)
    want = []
    for f in failed:
        want += [(min(x, y), max(x, y)) for (x, y) in sched.global_pairs_of(f)]
    assert sorted(recovered) == sorted(want)


@pytest.mark.parametrize("name,P", _cases(), ids=_ids())
def test_rescale_closure(name, P):
    """Same-P migration from cyclic: fetches are exactly the residency
    delta, so old residency + fetches assembles the new placement."""
    plc = get_placement(name, P)
    cyc = get_placement("cyclic", P)
    plan = rescale(P, P, placement_old=cyc, placement_new=plc)
    assert plan.schedule.P == P
    for i in range(P):
        new_res = set(plan.new_quorums[i])
        assert new_res == set(plc.residency(i))
        fetched = set(plan.fetches.get(i, []))
        assert fetched == new_res - cyc.residency(i)
        assert new_res <= cyc.residency(i) | fetched
    if name == "cyclic":
        assert plan.total_fetch_blocks == 0 and not plan.is_migration


# ---------------------------------------------------------------------------
# Weighted ownership (DESIGN.md section 13): loads proportional to
# capacity weights within ceil rounding; uniform == unweighted bit-exact
# ---------------------------------------------------------------------------

_WEIGHT_PATTERNS = {
    "alt": lambda P: [1.0 if i % 2 == 0 else 2.0 for i in range(P)],
    "one_big": lambda P: [4.0 if i == 0 else 1.0 for i in range(P)],
    "ramp": lambda P: [1.0 + i / max(1, P - 1) for i in range(P)],
}

# the full P <= 64 sweep is the weighted_owner_table development check;
# a diagonal slice keeps suite time linear while covering every family
_WEIGHTED_P = (2, 5, 6, 7, 8, 12, 13, 16, 21, 31, 57, 64)


@pytest.mark.parametrize(
    "name,P", [(n, P) for (n, P) in _cases() if P in _WEIGHTED_P],
    ids=[f"{n}-P{P}" for (n, P) in _cases() if P in _WEIGHTED_P])
@pytest.mark.parametrize("pattern", sorted(_WEIGHT_PATTERNS))
def test_weighted_ownership_balance(name, P, pattern):
    """Weighted conformance: the owner of every pair holds at least one
    endpoint block (the other rides the tier-2 fetch path), the table is
    symmetric and total-preserving, and per-device load never exceeds
    ceil of its proportional target."""
    plc = get_placement(name, P)
    w = _WEIGHT_PATTERNS[pattern](P)
    table = weighted_owner_table(plc, w)
    sets = plc.residency_sets
    total = P * (P + 1) // 2
    loads = np.zeros(P, dtype=int)
    for x in range(P):
        for y in range(x, P):
            o = int(table[x, y])
            assert table[y, x] == o
            assert 0 <= o < P
            assert x in sets[o] or y in sets[o], (name, P, x, y, o)
            loads[o] += 1
    assert loads.sum() == total
    wsum = sum(w)
    for c in range(P):
        target = total * w[c] / wsum
        assert loads[c] <= math.ceil(target), (name, P, pattern, c,
                                               loads[c], target)


@pytest.mark.parametrize("name,P", [("cyclic", 8), ("projective", 13),
                                    ("affine", 12), ("full", 5)])
def test_weighted_uniform_bit_identical_to_unweighted(name, P):
    """Uniform weights must reproduce today's partition exactly — both
    through the table and through owner_of's weights kwarg."""
    plc = get_placement(name, P)
    table = weighted_owner_table(plc, [1.0] * P)
    for x in range(P):
        for y in range(P):
            assert table[x, y] == plc.owner_of(x, y)
            assert plc.owner_of(x, y, weights=[2.5] * P) \
                == plc.owner_of(x, y)


def test_weighted_owner_of_kwarg_routes_to_table():
    P = 8
    plc = get_placement("cyclic", P)
    w = [4.0 if i == 0 else 1.0 for i in range(P)]
    table = weighted_owner_table(plc, w)
    for x in range(P):
        for y in range(P):
            assert plc.owner_of(x, y, weights=w) == int(table[x, y])


def test_weighted_owner_table_validates_weights():
    plc = get_placement("cyclic", 8)
    with pytest.raises(ValueError, match="length"):
        weighted_owner_table(plc, [1.0] * 7)
    with pytest.raises(ValueError, match="positive"):
        weighted_owner_table(plc, [1.0] * 7 + [-1.0])


# ---------------------------------------------------------------------------
# Plane-specific acceptance pins
# ---------------------------------------------------------------------------

def test_projective_13_replication_exactly_4():
    """Acceptance: P = 13 = 3^2+3+1 — replication exactly q+1 = 4, never
    worse than the cyclic construction at the same P."""
    plc = get_placement("projective", 13)
    assert plc.order == 3
    assert plc.replication == 4
    assert plc.replication <= get_placement("cyclic", 13).replication


@pytest.mark.parametrize("P", [7, 13, 21, 31, 57])
def test_projective_replication_is_q_plus_1(P):
    plc = get_placement("projective", P)
    q = plc.order
    assert q * q + q + 1 == P
    assert plc.replication == q + 1 == quorum_size_lower_bound(P)
    assert plc.replication <= get_placement("cyclic", P).replication


@pytest.mark.parametrize("P", [6, 12])
def test_affine_replication_is_q_plus_1(P):
    plc = get_placement("affine", P)
    q = plc.order
    assert q * q + q == P
    assert plc.replication == q + 1 == quorum_size_lower_bound(P)
    assert plc.replication <= get_placement("cyclic", P).replication


def test_affine_not_defined_where_provably_impossible():
    """q = 4, 5: the exact search shows no (q+1)-element difference cover
    mod q^2+q exists (module docstring feasibility note), so the
    placement must report itself undefined rather than degrade."""
    for P in (20, 30):
        assert not AffinePlanePlacement.supports(P)
    assert not ProjectivePlanePlacement.supports(20)
    with pytest.raises(ValueError, match="not defined"):
        get_placement("affine", 20)


def test_projective_definition_domain():
    got = [P for P in range(1, MAX_P + 1)
           if ProjectivePlanePlacement.supports(P)]
    assert got == [7, 13, 21, 31, 57]
    aff = [P for P in range(1, MAX_P + 1) if AffinePlanePlacement.supports(P)]
    assert aff == [6, 12]


# ---------------------------------------------------------------------------
# Selection: auto / plane / env override
# ---------------------------------------------------------------------------

def test_auto_picks_smallest_replication_tie_cyclic():
    for P in range(1, MAX_P + 1):
        plc = auto_placement(P)
        best = min(p.replication for p in supported_placements(P))
        assert plc.replication == best
        # cyclic is optimal-or-tied everywhere planes are defined (exact
        # search / Singer), so the tie-break keeps auto bit-exact cyclic
        assert plc.name == "cyclic"


def test_plane_placement_prefers_projective_then_affine():
    assert plane_placement(13).name == "projective"
    assert plane_placement(12).name == "affine"
    assert plane_placement(8) is None
    assert resolve_placement("plane", 8).name == "cyclic"   # documented fallback
    assert resolve_placement("plane", 13).name == "projective"


def test_env_override(monkeypatch):
    from repro.core.placement import placement_from_env
    monkeypatch.delenv("REPRO_PLACEMENT", raising=False)
    assert placement_from_env(13).name == "cyclic"
    monkeypatch.setenv("REPRO_PLACEMENT", "plane")
    assert placement_from_env(13).name == "projective"
    assert placement_from_env(8).name == "cyclic"           # plane fallback
    monkeypatch.setenv("REPRO_PLACEMENT", "full")
    assert placement_from_env(8).name == "full"
    monkeypatch.setenv("REPRO_PLACEMENT", "projective")
    with pytest.raises(ValueError, match="not defined"):
        placement_from_env(8)                               # strict by name
    monkeypatch.setenv("REPRO_PLACEMENT", "hexagonal")      # typo
    with pytest.raises(ValueError, match="REPRO_PLACEMENT"):
        placement_from_env(8)


def test_downstream_registration_joins_selection():
    """register_placement's contract: a placement registered after import
    is swept by auto/supported without touching the built-in order (and
    wins selection where its replication is strictly smaller)."""
    import repro.core.placement as pm

    @pm.register_placement
    class EverythingOnDeviceZeroish(pm.ShiftPlacement):
        # strictly-better-than-cyclic replication is impossible (the
        # floor is tight), so prove selection mechanics with a tie-worse
        # placement: it must appear in supported, and never win auto
        name = "zz-test-only"

        @classmethod
        def supports(cls, P):
            return P == 9

        def _cover(self):
            return tuple(range(self.P))  # full-style cover, k = 9

    try:
        assert "zz-test-only" in [p.name for p in supported_placements(9)]
        assert auto_placement(9).name == "cyclic"
    finally:
        del pm._REGISTRY["zz-test-only"]
        pm.get_placement.cache_clear()
    assert "zz-test-only" not in [p.name for p in supported_placements(9)]


def test_placements_are_memoized_value_objects():
    a = get_placement("cyclic", 12)
    b = get_placement("cyclic", 12)
    assert a is b
    assert a == b and hash(a) == hash(b)
    assert a != get_placement("affine", 12)
    assert a.schedule().A == tuple(sorted(a.shifts))


def test_schedule_matches_placement_shifts():
    """build_schedule(P, placement) must derive from the placement's
    shifts — the engine layout contract (slot s holds (i + shifts[s]) % P)."""
    for name, P in [("cyclic", 8), ("projective", 31), ("affine", 12),
                    ("full", 5)]:
        plc = get_placement(name, P)
        sched = plc.schedule()
        assert sched.P == P
        assert tuple(sched.shifts.tolist()) == tuple(sorted(plc.shifts))
        assert sched.k == plc.replication

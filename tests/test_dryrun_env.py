"""Regression tests for launch/dryrun.py's XLA_FLAGS guard.

The seed unconditionally overwrote ``XLA_FLAGS`` at import time, so
*importing* dryrun as a library (e.g. for ``collective_bytes``) silently
reconfigured jax for every later consumer in the process and clobbered
any user-chosen device count.  The guard now applies the 512-device
default only when dryrun is the entrypoint AND the variable is unset.
"""

import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"


def test_guard_logic(monkeypatch):
    from repro.launch.dryrun import _apply_default_xla_flags

    # library import: never touches the environment
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert _apply_default_xla_flags(False) is False
    assert "XLA_FLAGS" not in os.environ

    # entrypoint with the variable unset: the 512-device default applies
    assert _apply_default_xla_flags(True) is True
    assert os.environ["XLA_FLAGS"].endswith("device_count=512")

    # entrypoint with a user-set value: never clobbered
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=3")
    assert _apply_default_xla_flags(True) is False
    assert os.environ["XLA_FLAGS"].endswith("device_count=3")


def test_library_import_preserves_user_flags():
    """Importing dryrun (the collective_bytes consumer path) leaves a
    user-set XLA_FLAGS untouched and jax on the user's device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = str(SRC)
    code = (
        "import os, jax\n"
        "import repro.launch.dryrun as d\n"
        "assert os.environ['XLA_FLAGS'].endswith('=2'), os.environ['XLA_FLAGS']\n"
        "assert jax.device_count() == 2, jax.device_count()\n"
        "print('DRYRUN-IMPORT-OK')\n")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DRYRUN-IMPORT-OK" in r.stdout


def test_library_import_sets_nothing_when_unset():
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = str(SRC)
    code = (
        "import os\n"
        "import repro.launch.dryrun as d\n"
        "assert 'XLA_FLAGS' not in os.environ, os.environ.get('XLA_FLAGS')\n"
        "print('DRYRUN-NOFLAGS-OK')\n")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DRYRUN-NOFLAGS-OK" in r.stdout
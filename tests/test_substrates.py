"""Substrate tests: checkpoint round-trip/restart, data pipeline, optimizer,
gradient compression, elastic plans."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, make_batch, make_pipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compress import (compress_bf16, compress_int8,
                                  decompress_int8)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}
    save_checkpoint(tmp_path, 7, tree)
    restored, step = load_checkpoint(tmp_path, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones((4,))}
    for s in [1, 2, 3, 4]:
        mgr.save_async(s, jax.tree.map(lambda a: a * s, tree))
    mgr.wait()
    restored, step = mgr.restore_latest(tree)
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)
    # retention: only `keep` newest survive
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_crash_resilience(tmp_path):
    """A partial (uncommitted) step dir is ignored on resume."""
    tree = {"w": jnp.ones((4,))}
    save_checkpoint(tmp_path, 1, tree)
    bad = tmp_path / "step_2"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"corrupt")  # no MANIFEST
    restored, step = load_checkpoint(tmp_path, tree)
    assert step == 1


def test_data_determinism_and_restart():
    cfg = DataConfig(seed=3, vocab_size=101, batch=4, seq_len=32)
    b5 = make_batch(cfg, 5)
    again = make_batch(cfg, 5)
    np.testing.assert_array_equal(b5["tokens"], again["tokens"])
    # pipeline resumed at step 5 produces the same batch
    it = make_pipeline(cfg, start_step=5)
    first = next(it)
    np.testing.assert_array_equal(np.asarray(first["tokens"]), b5["tokens"])


def test_cosine_schedule_monotone_segments():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0                   # warmup rises
    assert all(a >= b - 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # decay
    assert lrs[-1] >= 0.099                          # floor


def test_adamw_clips_and_steps():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=1, total_steps=10)
    new_p, opt, gnorm = adamw_update(cfg, grads, opt, params)
    assert float(gnorm) == pytest.approx(200.0)
    assert np.all(np.asarray(new_p["w"]) < 1.0)


def test_compress_roundtrip_bounds():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(1000,)), jnp.float32) * 1e-3}
    # bf16: relative error bounded by bf16 eps
    d = jax.tree.map(lambda x: x.astype(jnp.float32), compress_bf16(g))
    rel = np.abs(np.asarray(d["a"]) - np.asarray(g["a"])) / 1e-3
    assert rel.max() < 1e-2
    # int8 block codec
    enc = compress_int8(g)
    dec = decompress_int8(enc)
    err = np.abs(np.asarray(dec["a"]) - np.asarray(g["a"]))
    assert err.max() <= np.abs(np.asarray(g["a"])).max() / 127 + 1e-9


def test_elastic_plans():
    from repro.core.scheduler import build_schedule
    from repro.launch.elastic import failover, rescale
    plan = rescale(8, 12)
    assert plan.schedule.P == 12
    assert len(plan.new_quorums) == 12
    s = build_schedule(16)
    fo = failover(s, [5])
    assert fo.n_recovered == s.n_pairs
